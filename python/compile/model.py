"""L2 — the JAX compute graphs workers execute, built on the L1 kernel.

Two graphs cover Phase 2 of the CMPC protocol:

* ``worker_phase2(fa, fb)`` — the share product ``H(alpha_n) =
  F_A(alpha_n) @ F_B(alpha_n) mod p`` (eq. 17). This is the hot spot and
  the artifact the Rust runtime executes on its PJRT client.
* ``gn_eval(h, wvec, pows, rmats)`` — the batched evaluation of
  ``G_n(alpha_n')`` at all N peer points (eq. 19): a scalar-broadcast of H
  plus the mask-noise contraction. Exposed for AOT as an optional artifact;
  the Rust default keeps this memory-bound axpy native.

Everything is exact int64 residue arithmetic over GF(65537); see
``kernels/matmul_mod.py`` for the range analysis.
"""

import jax
import jax.numpy as jnp

from compile.kernels import P, matmul_mod

jax.config.update("jax_enable_x64", True)


def worker_phase2(fa, fb):
    """H = (F_A(alpha) @ F_B(alpha)) mod p, as a 1-tuple (AOT convention)."""
    return (matmul_mod(fa, fb),)


def gn_eval(h, wvec, pows, rmats):
    """G_n evaluated at all peer points.

    Args:
      h:     [bt, bt]    int64 — H(alpha_n), residues < p.
      wvec:  [N]         int64 — sum_{i,l} r_n^{(i,l)} alpha_{n'}^{i+t*l},
                          one per peer (precomputed scalars, < p).
      pows:  [N, z]      int64 — alpha_{n'}^{t^2+w} mask powers (< p).
      rmats: [z, bt, bt] int64 — the worker's uniform masks R_w (< p).

    Returns:
      ([N, bt, bt],) — G_n(alpha_{n'}) residues.
    """
    lin = wvec[:, None, None] * h[None, :, :]
    noise = jnp.tensordot(pows, rmats, axes=1)
    return ((lin + noise) % P,)


def phase2_flops(m, s, t):
    """Multiply–add count of the share product (for roofline accounting)."""
    return 2 * (m // t) * (m // s) * (m // t)
