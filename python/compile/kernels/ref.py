"""Pure-jnp correctness oracles for the L1 kernels.

These are the ground truth the Pallas kernels are tested against
(``python/tests/test_kernel.py``) — deliberately the most obvious possible
implementations.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

P = 65537


def matmul_mod_ref(x, y, p=P):
    """``(x @ y) mod p`` in one dense int64 contraction.

    Exact for this library's sizes: residues < 2^17, products < 2^34, and
    int64 accumulation overflows only beyond K ~ 2^29 rows — far above any
    CMPC block (K = m/s).
    """
    return (x.astype(jnp.int64) @ y.astype(jnp.int64)) % p


def gn_eval_ref(h, wvec, pows, rmats, p=P):
    """Reference for the G_n evaluation graph (eq. 19):

    ``out[n'] = (wvec[n'] * h + sum_w pows[n', w] * rmats[w]) mod p``.
    """
    h = h.astype(jnp.int64)
    lin = wvec.astype(jnp.int64)[:, None, None] * h[None, :, :]
    noise = jnp.tensordot(pows.astype(jnp.int64), rmats.astype(jnp.int64), axes=1)
    return (lin + noise) % p
