"""L1 — Pallas blocked modular matmul kernel.

The per-worker hot spot of CMPC Phase 2 is ``H(alpha_n) = F_A(alpha_n) @
F_B(alpha_n) mod p`` over GF(p), p = 65537. This kernel expresses it as a
TPU-shaped tiled matmul:

* grid ``(M/bm, N/bn, K/bk)`` with the K axis innermost, so each output tile
  stays resident while A/B tiles stream through VMEM (the ``BlockSpec``s
  below are the HBM<->VMEM schedule a CUDA kernel would express with
  threadblocks + shared memory);
* exact integer arithmetic: inputs are reduced residues (< p < 2^17), the
  dot accumulates in int64 (products < 2^34, a 256-wide K block keeps the
  running tile < 2^43), and ``mod p`` is applied once per K step — not per
  element — so the inner loop is pure multiply-add;
* bf16/MXU is unusable for exact field arithmetic, so tiles target the
  int path; on real TPU hardware the dot lowers to the 32x128 VPU lanes.

``interpret=True`` is mandatory in this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. The AOT pipeline
(``compile/aot.py``) therefore lowers the interpret-mode kernel to plain HLO,
which runs bit-exactly on any backend; correctness versus the pure-jnp
oracle (``ref.py``) is enforced by ``python/tests/test_kernel.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# GF(p), p = 2^16 + 1 — matches rust/src/ff/mod.rs.
P = 65537

# Default tile sizes: MXU/VPU-aligned on TPU, and small enough that one
# X tile + one Y tile + the int64 output tile stay well under 1 MiB of VMEM:
# 128*256*8 + 256*128*8 + 128*128*8 = 640 KiB.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 256


def _matmul_mod_kernel(x_ref, y_ref, o_ref, *, k_steps, p):
    """One (i, j, k) grid step: o[i,j] = (o[i,j] + x[i,k] @ y[k,j]) mod p."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref[...] + jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.int64
    )
    # One reduction per K block keeps the tile in range (< 2^43 + 2^17)
    # while avoiding a per-element mod in the MAC loop.
    o_ref[...] = acc % p
    del k_steps  # grid-shape bookkeeping only


def _pick_block(dim, preferred):
    """Largest divisor of ``dim`` that is <= preferred (tiles must divide)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("p",))
def matmul_mod(x, y, p=P):
    """``(x @ y) mod p`` for int64 residue matrices, via the Pallas kernel."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"shape mismatch {x.shape} @ {y.shape}"
    bm = _pick_block(m, BLOCK_M)
    bn = _pick_block(n, BLOCK_N)
    bk = _pick_block(k, BLOCK_K)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_mod_kernel, k_steps=grid[2], p=p)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.int64), y.astype(jnp.int64))


def vmem_bytes(bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Estimated VMEM residency per grid step (see DESIGN.md §Hardware)."""
    return bm * bk * 8 + bk * bn * 8 + bm * bn * 8
