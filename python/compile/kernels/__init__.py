"""L1 — Pallas kernels for the CMPC worker hot path."""

from .matmul_mod import BLOCK_K, BLOCK_M, BLOCK_N, P, matmul_mod, vmem_bytes
from .ref import gn_eval_ref, matmul_mod_ref

__all__ = [
    "matmul_mod",
    "matmul_mod_ref",
    "gn_eval_ref",
    "vmem_bytes",
    "P",
    "BLOCK_M",
    "BLOCK_N",
    "BLOCK_K",
]
