"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts for the Rust
runtime.

Run once at build time (``make artifacts``); Python never executes on the
request path. Interchange is HLO text — NOT ``lowered.compile().serialize()``
and NOT a serialized ``HloModuleProto``: jax >= 0.5 emits protos with 64-bit
instruction ids that the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser on the Rust side reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``artifacts/``):

* ``matmul_mod_{M}x{K}x{N}.hlo.txt`` — one per configured worker shape,
* ``manifest.txt`` — the shape->artifact index ``runtime::manifest`` reads.

Shapes default to the blocks used by the examples and integration tests;
pass ``--shapes M,K,N[;M,K,N...]`` to override.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from compile import model  # noqa: E402  (needs x64 flag set first)

# Shapes (M, K, N) of F_A(alpha) @ F_B(alpha) products the examples use:
#   (m/t, m/s) @ (m/s, m/t)
DEFAULT_SHAPES = [
    (32, 32, 32),  # quickstart: m=64,  s=t=2
    (64, 64, 64),  # tests:      m=128, s=t=2
    (128, 128, 128),  # e2e:     m=256, s=t=2
    (128, 64, 128),  # e2e:      m=256, s=4, t=2
    (256, 256, 256),  # e2e:     m=512, s=t=2
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_matmul(m, k, n) -> str:
    spec_a = jax.ShapeDtypeStruct((m, k), jnp.int64)
    spec_b = jax.ShapeDtypeStruct((k, n), jnp.int64)
    lowered = jax.jit(model.worker_phase2).lower(spec_a, spec_b)
    return to_hlo_text(lowered)


def parse_shapes(text):
    shapes = []
    for part in text.split(";"):
        m, k, n = (int(v) for v in part.split(","))
        shapes.append((m, k, n))
    return shapes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--shapes", default=None, help="M,K,N[;M,K,N...]")
    args = ap.parse_args(argv)

    shapes = parse_shapes(args.shapes) if args.shapes else DEFAULT_SHAPES
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = ["# model M K N path"]
    for m, k, n in shapes:
        rel = f"matmul_mod_{m}x{k}x{n}.hlo.txt"
        path = os.path.join(args.out_dir, rel)
        text = lower_matmul(m, k, n)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"matmul_mod {m} {k} {n} {rel}")
        print(f"wrote {path} ({len(text)} chars)")
    manifest = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {manifest} ({len(shapes)} artifacts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
