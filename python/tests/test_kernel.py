"""L1 correctness: the Pallas modular-matmul kernel versus the pure-jnp
oracle — the CORE numeric signal of the build-time stack.

Hypothesis sweeps shapes (including tile-misaligned primes that force the
block-size fallback), value ranges (full residue range, boundary values),
and dtypes. Everything is exact integer arithmetic, so comparisons are
strict equality, not allclose.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import P, matmul_mod, matmul_mod_ref, vmem_bytes
from compile.kernels.matmul_mod import _pick_block

jax.config.update("jax_enable_x64", True)

SETTINGS = dict(deadline=None, max_examples=25, derandomize=True)


def random_residues(rng, shape):
    return jnp.asarray(rng.integers(0, P, size=shape, dtype=np.int64))


@hypothesis.given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31),
)
@hypothesis.settings(**SETTINGS)
def test_kernel_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = random_residues(rng, (m, k))
    y = random_residues(rng, (k, n))
    got = matmul_mod(x, y)
    want = matmul_mod_ref(x, y)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@hypothesis.given(seed=st.integers(0, 2**31))
@hypothesis.settings(deadline=None, max_examples=5, derandomize=True)
def test_kernel_multi_k_block_path(seed):
    # K > BLOCK_K would need K >= 256; use a shape whose chosen block
    # divides it several times to exercise the K-loop accumulate+mod.
    rng = np.random.default_rng(seed)
    x = random_residues(rng, (8, 96))
    y = random_residues(rng, (96, 8))
    np.testing.assert_array_equal(
        np.asarray(matmul_mod(x, y)), np.asarray(matmul_mod_ref(x, y))
    )


def test_boundary_values_max_residue():
    # All entries p-1 = 65536: the worst-case accumulation magnitude.
    k = 64
    x = jnp.full((4, k), P - 1, dtype=jnp.int64)
    y = jnp.full((k, 4), P - 1, dtype=jnp.int64)
    got = np.asarray(matmul_mod(x, y))
    # (p-1)^2 = 1 mod p, summed k times = k mod p.
    np.testing.assert_array_equal(got, np.full((4, 4), k % P))


def test_identity_matrix():
    rng = np.random.default_rng(7)
    x = random_residues(rng, (16, 16))
    eye = jnp.eye(16, dtype=jnp.int64)
    np.testing.assert_array_equal(np.asarray(matmul_mod(x, eye)), np.asarray(x))


def test_int32_inputs_are_promoted():
    rng = np.random.default_rng(9)
    x32 = jnp.asarray(rng.integers(0, P, size=(8, 8), dtype=np.int32))
    y32 = jnp.asarray(rng.integers(0, P, size=(8, 8), dtype=np.int32))
    np.testing.assert_array_equal(
        np.asarray(matmul_mod(x32, y32)), np.asarray(matmul_mod_ref(x32, y32))
    )


def test_output_always_reduced():
    rng = np.random.default_rng(11)
    x = random_residues(rng, (32, 32))
    y = random_residues(rng, (32, 32))
    out = np.asarray(matmul_mod(x, y))
    assert out.min() >= 0 and out.max() < P


@pytest.mark.parametrize("dim,pref,expect", [(128, 128, 128), (96, 128, 96),
                                             (100, 64, 50), (7, 8, 7), (1, 256, 1)])
def test_pick_block_divides(dim, pref, expect):
    b = _pick_block(dim, pref)
    assert b == expect
    assert dim % b == 0 and b <= max(pref, 1)


def test_vmem_budget_within_design():
    # DESIGN.md §Hardware-Adaptation: <= 1 MiB per grid step at default tiles.
    assert vmem_bytes() <= 1 << 20


def test_shape_mismatch_raises():
    x = jnp.zeros((4, 5), dtype=jnp.int64)
    y = jnp.zeros((6, 4), dtype=jnp.int64)
    with pytest.raises(AssertionError):
        matmul_mod(x, y)
