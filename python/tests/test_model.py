"""L2 correctness: the worker graphs compose the kernel correctly, and the
AOT lowering emits loadable HLO text."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import P, gn_eval_ref, matmul_mod_ref

jax.config.update("jax_enable_x64", True)


def test_worker_phase2_is_tuple_of_product():
    rng = np.random.default_rng(0)
    fa = jnp.asarray(rng.integers(0, P, size=(12, 8), dtype=np.int64))
    fb = jnp.asarray(rng.integers(0, P, size=(8, 12), dtype=np.int64))
    out = model.worker_phase2(fa, fb)
    assert isinstance(out, tuple) and len(out) == 1
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(matmul_mod_ref(fa, fb)))


@hypothesis.given(
    n=st.integers(1, 6),
    z=st.integers(1, 4),
    bt=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
@hypothesis.settings(deadline=None, max_examples=20, derandomize=True)
def test_gn_eval_matches_ref(n, z, bt, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.integers(0, P, size=(bt, bt), dtype=np.int64))
    wvec = jnp.asarray(rng.integers(0, P, size=(n,), dtype=np.int64))
    pows = jnp.asarray(rng.integers(0, P, size=(n, z), dtype=np.int64))
    rmats = jnp.asarray(rng.integers(0, P, size=(z, bt, bt), dtype=np.int64))
    (got,) = model.gn_eval(h, wvec, pows, rmats)
    want = gn_eval_ref(h, wvec, pows, rmats)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).max() < P


def test_gn_eval_matches_protocol_semantics():
    # G_n(alpha') = w*H + sum_w alpha'^{t^2+w} R_w — evaluate the polynomial
    # directly at one point and compare.
    rng = np.random.default_rng(3)
    bt, z, t = 4, 2, 2
    h = jnp.asarray(rng.integers(0, P, size=(bt, bt), dtype=np.int64))
    rmats = jnp.asarray(rng.integers(0, P, size=(z, bt, bt), dtype=np.int64))
    alpha = 7
    r_il = rng.integers(0, P, size=(t * t,), dtype=np.int64)
    w = sum(int(r_il[il]) * pow(alpha, il, P) for il in range(t * t)) % P
    pows = jnp.asarray(
        [[pow(alpha, t * t + wi, P) for wi in range(z)]], dtype=jnp.int64
    )
    (got,) = model.gn_eval(h, jnp.asarray([w], dtype=jnp.int64), pows, rmats)
    manual = (
        w * np.asarray(h, dtype=object)
        + sum(
            pow(alpha, t * t + wi, P) * np.asarray(rmats[wi], dtype=object)
            for wi in range(z)
        )
    ) % P
    np.testing.assert_array_equal(np.asarray(got)[0], manual.astype(np.int64))


def test_phase2_flops_formula():
    assert model.phase2_flops(36000, 4, 9) == 2 * 4000 * 9000 * 4000


def test_aot_lowering_emits_hlo_text():
    text = aot.lower_matmul(8, 8, 8)
    assert "ENTRY" in text and "HloModule" in text
    # int64 residues in, 1-tuple out
    assert "s64[8,8]" in text


def test_aot_main_writes_manifest(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--shapes", "4,4,4;8,4,8"])
    assert rc == 0
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest[0].startswith("#")
    assert "matmul_mod 4 4 4 matmul_mod_4x4x4.hlo.txt" in manifest
    assert (tmp_path / "matmul_mod_8x4x8.hlo.txt").exists()


def test_parse_shapes():
    assert aot.parse_shapes("1,2,3;4,5,6") == [(1, 2, 3), (4, 5, 6)]
