//! Privacy-preserving quantized **multi-layer** inference at the edge —
//! the workload class the paper's introduction motivates, run as one
//! [`Pipeline`] job (v0.10): `scores = truncate(Xᵀ·W₀) ᵀ·W₁`.
//!
//! Scenario: a model vendor holds two quantized weight layers `W₀`, `W₁`
//! (trade secret); an edge device holds a batch of user feature vectors
//! `X` (private data). The whole two-layer forward pass runs under
//! AGE-CMPC **without decoding the hidden activation anywhere**: the
//! layer-1 product is opened only under a one-time mask (`Z = Y + R`),
//! truncation rescales the fixed point, and the workers re-share the
//! result for layer 2 — the master performs exactly one Phase-3 decode,
//! for the final scores.
//!
//! Quantized entries are small, so GF(p) arithmetic coincides with exact
//! integer arithmetic (no wraparound) and `truncate:4` is a right-shift
//! rescale, exact to the usual probabilistic-truncation ±1 ulp.
//!
//! The demo then replays the identical pipeline over **loopback TCP** —
//! every party its own thread on real sockets — and asserts the decoded
//! scores are byte-identical to the in-process run.
//!
//! Run: `cargo run --release --example edge_ml_inference`
//!
//! [`Pipeline`]: cmpc::mpc::pipeline::Pipeline

use cmpc::codes::SchemeParams;
use cmpc::ff::P;
use cmpc::matrix::FpMat;
use cmpc::mpc::pipeline::{pipeline_input, pipeline_weight, Pipeline};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::manifest::TopologyManifest;
use cmpc::transport::node::{digest_mat, job_secret_seed, run_local_cluster};
use cmpc::{Deployment, SchemeSpec};

const SPEC: &str = "matmul,truncate:4,matmul";

fn main() -> cmpc::Result<()> {
    let m = 32; // feature dim == hidden dim == classes == batch (square demo)
    let (s, t, z) = (2, 2, 2);
    let manifest_seed = 1009u64;
    // The same derivations the distributed cluster uses for its run 0, so
    // the two paths below are comparable digest-for-digest.
    let pipeline_seed = job_secret_seed(manifest_seed, 0);

    let pipe = Pipeline::parse_spec(SPEC)?;
    let x = pipeline_input(pipeline_seed, m);
    let weights: Vec<FpMat> = (0..pipe.rounds())
        .map(|r| pipeline_weight(pipeline_seed, m, r as u32))
        .collect();
    let wrefs: Vec<&FpMat> = weights.iter().collect();
    // Quantized inputs stay tiny (< 8), so neither layer can wrap GF(p):
    // layer 1 ≤ m·7² and layer 2 ≤ m·(m·7² >> 4)·7, both far below p.
    assert!((m as u64) * ((m as u64) * 49 >> 4) * 7 < P, "no field wraparound");

    // ---- in-process: one deployment, one pipeline job ----
    let deployment = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        SchemeParams::try_new(s, t, z)?,
        ProtocolConfig::default(),
    )?;
    println!(
        "{}: {} workers, tolerating {z} colluders, pipeline `{SPEC}`",
        deployment.scheme().name(),
        deployment.n_workers(),
    );
    let out = deployment.execute_pipeline_seeded(&pipe, &x, &wrefs, pipeline_seed)?;
    let health = deployment.health();
    println!(
        "in-process: {} rounds, {} Phase-3 decode(s), digest 0x{:016x}",
        out.rounds,
        health.phase3_decodes,
        digest_mat(&out.y)
    );
    assert!(out.verified, "must match the decode-re-encode reference");
    assert_eq!(
        health.phase3_decodes, 1,
        "the master decodes only the final scores"
    );

    // The hidden activation was never decoded, yet the secure scores track
    // a cleartext fixed-point forward pass to ±1 ulp of truncation — so
    // the predicted classes agree.
    let clear_hidden = x.transpose().matmul(&weights[0]);
    let clear_hidden = FpMat::from_fn(m, m, |r, c| clear_hidden.at(r, c) >> 4);
    let clear_scores = clear_hidden.transpose().matmul(&weights[1]);
    let agree = argmax_cols(&out.y)
        .iter()
        .zip(&argmax_cols(&clear_scores))
        .filter(|(a, b)| a == b)
        .count();
    println!("predictions matching cleartext fixed-point inference: {agree}/{m}");

    // ---- the same pipeline over loopback TCP (one thread per party) ----
    let mut manifest =
        TopologyManifest::template("age", s, t, z, m, manifest_seed, 1, "127.0.0.1", 0)?;
    manifest.pipeline_spec = Some(SPEC.to_string());
    let report = run_local_cluster(&manifest, None)?;
    let tcp = &report.master.jobs[0];
    println!(
        "loopback TCP: digest 0x{:016x}, {} bytes on the wire",
        tcp.digest,
        report.wire.total_bytes()
    );
    assert_eq!(tcp.y, out.y, "TCP run must be byte-identical to in-process");
    assert_eq!(tcp.digest, digest_mat(&out.y));
    println!("in-process and distributed pipelines agree byte-for-byte");
    Ok(())
}

/// Predicted class per column (sample) = row index of the max score.
fn argmax_cols(scores: &FpMat) -> Vec<usize> {
    (0..scores.cols)
        .map(|c| {
            (0..scores.rows)
                .max_by_key(|&r| scores.at(r, c))
                .unwrap()
        })
        .collect()
}
