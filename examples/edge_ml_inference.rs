//! Privacy-preserving quantized inference at the edge — the workload class
//! the paper's introduction motivates (matrix multiplication as the atomic
//! op of edge ML).
//!
//! Scenario: a model vendor holds quantized weights `W` (trade secret), an
//! edge device holds a batch of user feature vectors `X` (private data).
//! Classification scores `S = WᵀX` must be computed without revealing either
//! matrix to the edge workers or the aggregating master.
//!
//! Both matrices are quantized to small non-negative levels, so the GF(p)
//! product coincides with the exact integer product (no wraparound:
//! max entry q−1, inner dim m ⇒ scores ≤ m(q−1)² < p) — field arithmetic
//! *is* the quantized inference. The demo runs the multiplication under
//! AGE-CMPC, recovers the scores, and checks the predicted classes match
//! plaintext inference exactly.
//!
//! Run: `cargo run --release --example edge_ml_inference`

use cmpc::codes::{CmpcScheme, SchemeParams};
use cmpc::ff::P;
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

fn main() -> cmpc::Result<()> {
    let m = 96; // feature dimension == classes == batch (square demo)
    let q = 16u64; // quantization levels
    assert!(m as u64 * (q - 1) * (q - 1) < P, "no field wraparound");

    let mut rng = ChaChaRng::seed_from_u64(31337);
    // Vendor weights W (m×m: one column per class) and device batch X
    // (m×m: one column per sample), both quantized to [0, q).
    let w = FpMat::from_fn(m, m, |_, _| rng.gen_range(q));
    let x = FpMat::from_fn(m, m, |_, _| rng.gen_range(q));

    // Plaintext reference inference.
    let plain_scores = w.transpose().matmul(&x);
    let plain_classes = argmax_cols(&plain_scores);

    // Privacy-preserving inference: Y = WᵀX under AGE-CMPC. The vendor
    // provisions one deployment and reuses it for every inference batch.
    let (s, t, z) = (4, 2, 3);
    let params = SchemeParams::try_new(s, t, z)?;
    let deployment = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::default(),
    )?;
    println!(
        "{} inference: {} workers, tolerating {} colluders",
        deployment.scheme().name(),
        deployment.n_workers(),
        z
    );
    let out = deployment.execute(&w, &x)?;
    let mpc_classes = argmax_cols(&out.y);

    let agree = plain_classes
        .iter()
        .zip(&mpc_classes)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "predictions matching plaintext inference: {agree}/{} ({}%)",
        m,
        100 * agree / m
    );
    println!("scores bit-exact: {}", out.y == plain_scores);
    println!(
        "traffic: {} scalars worker↔worker across {} workers",
        out.traffic.worker_to_worker, out.n_workers
    );
    assert_eq!(out.y, plain_scores, "field product must equal integer product");
    assert_eq!(agree, m);
    Ok(())
}

/// Predicted class per column (sample) = row index of the max score.
fn argmax_cols(scores: &FpMat) -> Vec<usize> {
    (0..scores.cols)
        .map(|c| {
            (0..scores.rows)
                .max_by_key(|&r| scores.at(r, c))
                .unwrap()
        })
        .collect()
}
