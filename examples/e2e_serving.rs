//! END-TO-END SERVING DRIVER (E9): the session-based API streaming a batch
//! of privacy-preserving multiplication jobs through provisioned
//! deployments.
//!
//! Demonstrates the three properties the 0.2 API redesign guarantees:
//!
//! 1. **Provision once, execute many** — a [`Deployment`] solves the O(N³)
//!    generalized-Vandermonde setup exactly once and reuses it for every job
//!    of the same `(scheme, s, t, z)` signature (confirmed below by the
//!    deployment's job counter and the coordinator's cache-hit counter).
//! 2. **Fallible intake** — a malformed job in the batch is rejected with a
//!    typed [`cmpc::CmpcError`]; the process neither panics nor drops the
//!    rest of the batch.
//! 3. **Backend reuse** — the executor service (artifact cache included, when
//!    `artifacts/` exists) lives for the coordinator's lifetime, not per job.
//!
//! Run: `cargo run --release --example e2e_serving`

use std::path::PathBuf;
use std::time::Instant;

use cmpc::analysis::communication_overhead;
use cmpc::codes::{CmpcScheme, SchemeParams};
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::BackendChoice;
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

fn main() -> cmpc::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let backend = if artifacts.join("manifest.txt").exists() {
        println!("backend: artifact executor (AOT artifacts from {})", artifacts.display());
        BackendChoice::Pjrt {
            artifacts_dir: artifacts,
        }
    } else {
        println!("backend: native (run `make artifacts` for the AOT path)");
        BackendChoice::Native
    };
    let m = 128;
    let mut rng = ChaChaRng::seed_from_u64(4242);

    // ------------------------------------------------------------------
    // Part 1 — one Deployment, many jobs of the same signature.
    // ------------------------------------------------------------------
    let params = SchemeParams::try_new(2, 2, 2)?;
    let t0 = Instant::now();
    let deployment = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().backend(backend.clone()).build(),
    )?;
    let provision_time = t0.elapsed();
    println!(
        "\nprovisioned {} (N={} workers) in {provision_time:?} — Setup solved once",
        deployment.scheme().name(),
        deployment.n_workers()
    );

    let n_jobs = 3;
    let mut per_job = Vec::new();
    for j in 0..n_jobs {
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let t1 = Instant::now();
        let out = deployment.execute(&a, &b)?;
        per_job.push(t1.elapsed());
        assert!(out.verified);
        assert_eq!(out.y, a.transpose().matmul(&b), "job {j}");
        let zeta = communication_overhead(m, 2, out.n_workers as u64) as u64;
        assert_eq!(out.traffic.worker_to_worker, zeta, "ζ mismatch job {j}");
    }
    println!(
        "executed {} jobs through the cached setup (job counter = {}): {per_job:?}",
        n_jobs,
        deployment.jobs_executed()
    );
    assert_eq!(deployment.jobs_executed(), n_jobs);

    // ------------------------------------------------------------------
    // Part 2 — coordinator batch with a malformed job in the middle.
    // ------------------------------------------------------------------
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .policy(SchemePolicy::Adaptive)
            .backend(backend)
            .build(),
    );
    let mut inputs = Vec::new();
    let mut rejected = 0usize;
    for j in 0..4 {
        if j == 2 {
            // malformed: operand sizes disagree — rejected at intake with a
            // typed error, the batch keeps going.
            let bad_a = FpMat::random(&mut rng, m, m);
            let bad_b = FpMat::random(&mut rng, m / 2, m / 2);
            match coord.submit(bad_a, bad_b, 2, 2, 2) {
                Ok(_) => unreachable!("malformed job must be rejected"),
                Err(e) => {
                    rejected += 1;
                    println!("\njob {j} rejected gracefully: {e}");
                }
            }
            continue;
        }
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let handle = coord.submit(a.clone(), b.clone(), 2, 2, 2)?;
        inputs.push((handle, a, b));
    }
    assert_eq!(rejected, 1);

    let t2 = Instant::now();
    let reports = coord.drain();
    let wall = t2.elapsed();

    println!("\nper-job results (m={m}):");
    println!(
        "{:>4} {:>18} {:>4} {:>7} {:>12} {:>10}",
        "job", "scheme", "N", "cache", "phase2", "verified"
    );
    let mut cache_hits = 0usize;
    for r in &reports {
        let out = r.outcome.as_ref().expect("queued jobs all succeed");
        cache_hits += r.setup_cache_hit as usize;
        println!(
            "{:>4} {:>18} {:>4} {:>7} {:>12?} {:>10}",
            r.id,
            r.scheme,
            r.n_workers,
            if r.setup_cache_hit { "hit" } else { "miss" },
            out.timings.phase2_compute,
            out.verified
        );
    }
    for ((handle, a, b), r) in inputs.iter().zip(&reports) {
        assert_eq!(handle.id(), r.id);
        let out = r.outcome.as_ref().expect("verified above");
        assert_eq!(out.y, a.transpose().matmul(b), "job {}", r.id);
    }
    // 3 accepted jobs share one signature: first provisions, the rest hit.
    assert_eq!(reports.len(), 3);
    assert_eq!(cache_hits, 2, "setup cache must serve every repeat job");
    assert_eq!(coord.provisioned_deployments(), 1);

    println!("\nsummary:");
    println!("  accepted jobs     : {}", reports.len());
    println!("  rejected jobs     : {rejected} (typed error, batch unaffected)");
    println!("  deployments       : {} (cache hits: {cache_hits})", coord.provisioned_deployments());
    println!("  batch wall time   : {wall:?}");
    println!(
        "  throughput        : {:.2} jobs/s",
        reports.len() as f64 / wall.as_secs_f64()
    );
    println!("  all products verified bit-exact against plaintext AᵀB");
    Ok(())
}
