//! END-TO-END DRIVER (E9): the full three-layer system serving a stream of
//! privacy-preserving multiplication jobs.
//!
//! * **L3** — Rust coordinator: adaptive scheme selection, cached
//!   deployments, threaded worker fleet over the metered network fabric.
//! * **L2/L1** — each worker's `H(αₙ) = F_A(αₙ)·F_B(αₙ) mod p` runs the
//!   AOT-compiled JAX graph (Pallas modular-matmul kernel inside) on the
//!   PJRT CPU client — Python is *not* running; artifacts were lowered once
//!   by `make artifacts`.
//!
//! Reports per-job latency, aggregate throughput, phase breakdown, measured
//! vs closed-form communication (ζ), and verifies every product. Falls back
//! to the native backend (with a warning) if artifacts are missing so the
//! example always runs. Results are recorded in EXPERIMENTS.md §E9.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`

use std::path::PathBuf;
use std::time::Instant;

use cmpc::analysis::communication_overhead;
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::runtime::BackendChoice;
use cmpc::util::rng::ChaChaRng;

fn main() -> anyhow::Result<()> {
    let artifacts = PathBuf::from("artifacts");
    let backend = if artifacts.join("manifest.txt").exists() {
        println!("backend: PJRT (AOT artifacts from {})", artifacts.display());
        BackendChoice::Pjrt {
            artifacts_dir: artifacts,
        }
    } else {
        eprintln!("WARNING: artifacts/ missing — run `make artifacts`; using native backend");
        BackendChoice::Native
    };

    let mut coord = Coordinator::new(CoordinatorConfig {
        policy: SchemePolicy::Adaptive,
        backend,
        ..CoordinatorConfig::default()
    });

    // Workload: a burst of jobs at two shapes/privacy levels, mimicking a
    // small edge site multiplexing tenants.
    let m = 256;
    let n_jobs = 8;
    let mut rng = ChaChaRng::seed_from_u64(4242);
    let mut inputs = Vec::new();
    for j in 0..n_jobs {
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        // alternate privacy levels: z=2 and z=1 at s=t=2 → 128³ worker blocks
        let z = 1 + (j % 2);
        coord.submit(a.clone(), b.clone(), 2, 2, z);
        inputs.push((a, b));
    }

    let t0 = Instant::now();
    let reports = coord.run_all()?;
    let wall = t0.elapsed();

    println!("\nper-job results (m={m}):");
    println!(
        "{:>4} {:>18} {:>4} {:>7} {:>12} {:>12} {:>10}",
        "job", "scheme", "N", "cache", "phase1", "phase2+3", "verified"
    );
    for r in &reports {
        println!(
            "{:>4} {:>18} {:>4} {:>7} {:>12?} {:>12?} {:>10}",
            r.id,
            r.scheme,
            r.n_workers,
            if r.setup_cache_hit { "hit" } else { "miss" },
            r.timings.phase1_share,
            r.timings.phase2_compute,
            r.verified
        );
    }

    // Verify outputs against plaintext products and ζ against eq. (34).
    let mut total_scalars = 0u64;
    for (r, (a, b)) in reports.iter().zip(&inputs) {
        assert!(r.verified);
        assert_eq!(r.y, a.transpose().matmul(b), "job {}", r.id);
        let zeta = communication_overhead(m, 2, r.n_workers as u64) as u64;
        assert_eq!(r.traffic.worker_to_worker, zeta, "ζ mismatch job {}", r.id);
        total_scalars += r.traffic.worker_to_worker;
    }

    let mean_latency = wall / reports.len() as u32;
    println!("\nsummary:");
    println!("  jobs             : {}", reports.len());
    println!("  wall time        : {wall:?}");
    println!(
        "  throughput       : {:.2} jobs/s ({:.1} M field-ops/s effective)",
        reports.len() as f64 / wall.as_secs_f64(),
        reports.len() as f64 * (m as f64).powi(3) / 2.0 / wall.as_secs_f64() / 1e6
    );
    println!("  mean job latency : {mean_latency:?}");
    println!(
        "  worker↔worker    : {total_scalars} scalars, every job exactly ζ = N(N−1)m²/t²"
    );
    println!("  all products verified bit-exact against plaintext AᵀB");
    Ok(())
}
