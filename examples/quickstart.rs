//! Quickstart: the paper's Example 1 (§V-B) through the session-based API.
//!
//! Two edge devices hold private 64×64 matrices `A` and `B` over GF(65537).
//! With `s = t = 2` partitions and `z = 2` colluding workers, AGE-CMPC's
//! optimal gap is `λ* = 2`, requiring **17 workers** — versus 19 for
//! Entangled-CMPC. The master reconstructs `Y = AᵀB` from any `t²+z = 6`
//! worker responses without learning anything beyond `Y`.
//!
//! Run: `cargo run --release --example quickstart`

use cmpc::codes::{CmpcScheme, EntangledCmpc, SchemeParams};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

fn main() -> cmpc::Result<()> {
    let params = SchemeParams::try_new(2, 2, 2)?;
    let m = 64;

    // Phase 0 (Algorithm 3) happens at provisioning: the λ* scan picks the
    // gap minimizing the worker count, then the α assignment and the O(N³)
    // reconstruction solve are cached in the deployment.
    let deployment = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::default(),
    )?;
    let scheme = deployment.scheme();
    let entangled = EntangledCmpc::try_new(2, 2, 2)?;
    println!("scheme           : {}", scheme.name());
    println!("workers (AGE)    : {}", deployment.n_workers());
    println!("workers (Entangled baseline): {}", entangled.n_workers());
    println!("share polynomial supports:");
    println!(
        "  P(C_A) = {:?},  P(S_A) = {:?}",
        scheme.coded_support_a(),
        scheme.secret_powers_a()
    );
    println!(
        "  P(C_B) = {:?},  P(S_B) = {:?}",
        scheme.coded_support_b(),
        scheme.secret_powers_b()
    );
    println!(
        "  Y blocks live at powers {:?} of H(x)",
        scheme.important_powers()
    );

    // Private inputs.
    let mut rng = ChaChaRng::seed_from_u64(2024);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);

    // Full 3-phase protocol over the simulated edge fabric.
    let out = deployment.execute(&a, &b)?;

    println!("\nprotocol finished:");
    println!("  verified Y = AᵀB      : {}", out.verified);
    println!("  workers provisioned   : {}", out.n_workers);
    println!("  stragglers tolerated  : {}", out.stragglers_tolerated);
    println!(
        "  worker↔worker traffic : {} scalars (ζ = N(N−1)m²/t²)",
        out.traffic.worker_to_worker
    );
    println!("  wall time             : {:?}", out.timings.total());
    assert!(out.verified);
    Ok(())
}
