//! Adaptive provisioning, end to end — the deterministic demo behind the
//! CI `autoscale` lane.
//!
//! Two mis-provisioned deployments, two different pressures, one
//! controller:
//!
//! * **bandwidth profile** — a deployment pinned at λ = 0 (N = 18) pays
//!   ~11 % more Phase-2 traffic than the curve's optimum. The controller
//!   reads the measured worker↔worker scalars from live telemetry and
//!   swaps to λ* = 2 (N = 17), blue/green, zero dropped jobs.
//! * **straggler profile** — a deployment at λ = 2 (N = 17) loses two
//!   workers mid-exchange (seeded chaos kills; early decode keeps the
//!   jobs succeeding). The eroded margin blows the controller's miss
//!   budget, so it drafts standby capacity: back up the curve to λ = 0
//!   (N = 18), trading ζ for headroom.
//!
//! Every job in both profiles must succeed and decode the byte-identical
//! product — the swap is invisible to callers. The `autoscale:` lines
//! printed here are what CI greps.
//!
//! ```text
//! cargo run --release --example adaptive_provisioning
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpc::autoscale::{AutoscaleConfig, Autoscaler, Decision};
use cmpc::codes::SchemeParams;
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::ChaosPlan;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Deployment, Result, SchemeSpec};

const M: usize = 8;

fn inputs() -> (FpMat, FpMat, FpMat) {
    let mut rng = ChaChaRng::seed_from_u64(0xADA7);
    let a = FpMat::random(&mut rng, M, M);
    let b = FpMat::random(&mut rng, M, M);
    let y = a.transpose().matmul(&b);
    (a, b, y)
}

fn run_jobs(dep: &Deployment, a: &FpMat, b: &FpMat, y: &FpMat, base: u64, k: u64) -> Result<u64> {
    for i in 0..k {
        let out = dep.execute_seeded(a, b, base + i)?;
        if !out.verified || out.y != *y {
            return Err(CmpcError::NotDecodable(format!(
                "job {i}: output diverged across the swap"
            )));
        }
    }
    Ok(k)
}

fn wait_for_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    while dep.health().respawns < want {
        dep.runtime().reap();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "respawns stuck at {}",
            dep.health().respawns
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn expect_swap(scaler: &Autoscaler, profile: &str) -> Result<()> {
    match scaler.tick() {
        Decision::Reconfigure(rec) => {
            let history = scaler.deployment().swap_history();
            let swap = history.last().expect("applied swap is recorded");
            println!(
                "autoscale: reconfigured {} -> {} (profile={profile}, cause={:?}, \
                 workers {} -> {})",
                swap.from, swap.to, rec.cause, swap.from_workers, swap.to_workers
            );
            Ok(())
        }
        other => Err(CmpcError::InvalidParams(format!(
            "profile {profile}: controller did not reconfigure (got {other:?})"
        ))),
    }
}

/// λ = 0 start, healthy links: measured Phase-2 traffic walks it to λ*.
fn bandwidth_profile() -> Result<u64> {
    let (a, b, y) = inputs();
    let dep = Arc::new(Deployment::provision(
        SchemeSpec::Age { lambda: Some(0) },
        SchemeParams::new(2, 2, 2),
        ProtocolConfig::builder().threads(1).build(),
    )?);
    let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());
    let mut jobs = run_jobs(&dep, &a, &b, &y, 0x1000, 4)?;
    expect_swap(&scaler, "bandwidth")?;
    assert_eq!(dep.n_workers(), 17, "bandwidth profile converges to λ* = 2");
    jobs += run_jobs(&dep, &a, &b, &y, 0x2000, 4)?;
    Ok(jobs)
}

/// λ = 2 start, two seeded mid-exchange worker kills: the eroded margin
/// drafts standby capacity back up the curve.
fn straggler_profile() -> Result<u64> {
    let (a, b, y) = inputs();
    let n = 17;
    let plan = ChaosPlan::kill_k_workers_after_exchange(0xC0FFEE, n, 2);
    let dep = Arc::new(Deployment::provision(
        SchemeSpec::Age { lambda: Some(2) },
        SchemeParams::new(2, 2, 2),
        ProtocolConfig::builder()
            .threads(1)
            .early_decode(true)
            .recv_timeout(Duration::from_secs(10))
            .chaos(plan.into_shared())
            .build(),
    )?);
    let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());
    // Job 1 survives the two kills on the early-decode path; the dead
    // threads are evicted + respawned, which is exactly the margin
    // erosion the policy watches.
    let mut jobs = run_jobs(&dep, &a, &b, &y, 0x3000, 1)?;
    wait_for_respawns(&dep, 2);
    jobs += run_jobs(&dep, &a, &b, &y, 0x4000, 3)?;
    expect_swap(&scaler, "straggler")?;
    assert_eq!(dep.n_workers(), 18, "straggler profile drafts back to λ = 0");
    jobs += run_jobs(&dep, &a, &b, &y, 0x5000, 4)?;
    Ok(jobs)
}

fn main() -> Result<()> {
    let mut jobs = bandwidth_profile()?;
    jobs += straggler_profile()?;
    // Both asserts above passed, so every job verified: failed=0 by
    // construction (CI greps this line).
    println!("autoscale: jobs={jobs} failed=0");
    Ok(())
}
