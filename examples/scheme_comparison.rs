//! Scheme comparison across the collusion range — a live slice of Fig. 2.
//!
//! Prints the required worker count for all five schemes at `s = 4`,
//! `t = 15` as `z` sweeps upward, annotating the second-best scheme so the
//! paper's three regimes (SSMM → PolyDot → Entangled/GCSA-NA) are visible,
//! then demonstrates the coordinator's adaptive policy actually *running*
//! the winning constructible scheme.
//!
//! Run: `cargo run --release --example scheme_comparison`

use cmpc::analysis::figures::fig2_workers;
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::util::rng::ChaChaRng;

fn main() -> cmpc::Result<()> {
    println!("required workers, s=4 t=15 (Fig. 2 slice)\n");
    println!(
        "{:>4} {:>8} {:>6} {:>9} {:>11} {:>7} {:>9}   second-best",
        "z", "AGE", "λ*", "PolyDot", "Entangled", "SSMM", "GCSA-NA"
    );
    let rows = fig2_workers(4, 15, 300);
    for z in [1usize, 5, 20, 48, 49, 80, 120, 180, 181, 240, 300] {
        let r = &rows[z - 1];
        let cands = [
            ("PolyDot", r.polydot),
            ("Entangled", r.entangled),
            ("SSMM", r.ssmm),
            ("GCSA-NA", r.gcsa_na),
        ];
        let second = cands.iter().min_by_key(|&&(_, v)| v).unwrap();
        println!(
            "{:>4} {:>8} {:>6} {:>9} {:>11} {:>7} {:>9}   {} ({})",
            r.z, r.age, r.age_lambda, r.polydot, r.entangled, r.ssmm, r.gcsa_na, second.0, second.1
        );
    }

    // The adaptive coordinator puts this table to work: for each job it
    // provisions the constructible scheme with the fewest workers.
    println!("\nadaptive coordinator on three parameter points:");
    let mut rng = ChaChaRng::seed_from_u64(99);
    for (s, t, z, m) in [(2usize, 2usize, 2usize, 32usize), (3, 2, 4, 24), (2, 3, 1, 24)] {
        let mut coord = Coordinator::new(
            CoordinatorConfig::builder()
                .policy(SchemePolicy::Adaptive)
                .build(),
        );
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        coord.submit(a, b, s, t, z)?;
        let report = coord.drain().remove(0);
        let out = report.outcome?;
        println!(
            "  (s={s}, t={t}, z={z}) → {} with N={} workers, verified={}",
            report.scheme, report.n_workers, out.verified
        );
    }
    Ok(())
}
