//! Adaptive provisioning end to end: the blue/green swap under live
//! concurrent load (zero dropped jobs, byte-identical outputs), the
//! controller retuning a deliberately mis-provisioned deployment from
//! its own telemetry, and the Byzantine strike ledger surviving respawn
//! and escalating the adversary tolerance.
//!
//! Everything here is seeded — same binary, same decisions, same
//! outputs — which is what lets the CI `autoscale` lane assert on exact
//! audit trails.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpc::autoscale::{AutoscaleConfig, Autoscaler, Cause, Decision, HoldReason, PolicyConfig};
use cmpc::codes::SchemeParams;
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, PayloadClass};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

fn test_inputs(m: usize) -> (FpMat, FpMat, FpMat) {
    let mut rng = ChaChaRng::seed_from_u64(0xADA7);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let y = a.transpose().matmul(&b);
    (a, b, y)
}

/// Reap until the runtime reports `want` respawns (blame → eviction →
/// respawn is asynchronous).
fn wait_for_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    loop {
        dep.runtime().reap();
        if dep.health().respawns >= want {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "respawns stuck at {} (want {want})",
            dep.health().respawns
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Sweep retired generations until the deployment reports zero draining
/// (in-flight jobs finish asynchronously after a swap).
fn wait_for_drain(dep: &Deployment) {
    let t0 = Instant::now();
    while dep.drain_retired() > 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "retired generation never drained"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// The zero-downtime pin: a submitter thread hammers the deployment with
/// seeded jobs while the main thread swaps λ0 → λ2 mid-stream. Every job
/// must succeed, verify, and decode the byte-identical product — no
/// retries, no drops, no window where submissions land nowhere.
#[test]
fn blue_green_swap_drops_no_in_flight_jobs() {
    let (a, b, y_expect) = test_inputs(8);
    let dep = Arc::new(
        Deployment::provision(
            SchemeSpec::Age { lambda: Some(0) },
            SchemeParams::new(2, 2, 2),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap(),
    );
    assert_eq!(dep.n_workers(), 18);

    const JOBS: u64 = 12;
    let submitter = {
        let dep = dep.clone();
        let (a, b, y_expect) = (a.clone(), b.clone(), y_expect.clone());
        std::thread::spawn(move || {
            for k in 0..JOBS {
                let out = dep
                    .execute_seeded(&a, &b, 0x5EED + k)
                    .unwrap_or_else(|e| panic!("job {k} dropped across the swap: {e}"));
                assert!(out.verified, "job {k} failed verification");
                assert_eq!(out.y, y_expect, "job {k} decoded a different product");
            }
        })
    };

    // Swap while the stream is in flight. A tiny stagger makes it land
    // mid-stream in practice; correctness does not depend on where.
    std::thread::sleep(Duration::from_millis(5));
    let record = dep
        .reconfigure(SchemeSpec::Age { lambda: Some(2) }, 0)
        .unwrap();
    assert_eq!(record.generation, 1);
    assert_eq!(record.from, "AGE-CMPC(λ=0)");
    assert_eq!(record.to, "AGE-CMPC(λ=2)");
    assert_eq!(record.from_workers, 18);
    assert_eq!(record.to_workers, 17);

    submitter.join().expect("submitter thread panicked");

    // Deployment-level accounting is swap-transparent: every job counted,
    // none lost, and the retired blue is eventually torn down.
    assert_eq!(dep.telemetry().jobs_completed, JOBS);
    assert_eq!(dep.n_workers(), 17);
    assert_eq!(dep.generation(), 1);
    assert_eq!(dep.swap_history().len(), 1);
    wait_for_drain(&dep);

    // The green generation serves clean post-swap jobs.
    let out = dep.execute_seeded(&a, &b, 0xF00D).unwrap();
    assert!(out.verified);
    assert_eq!(out.y, y_expect);
}

/// Byte-identity across the swap: the same seeded jobs on a static λ0
/// deployment, a static λ2 deployment, and a deployment that swaps λ0→λ2
/// halfway through all decode the identical bytes. Serving generation is
/// an implementation detail of the answer.
#[test]
fn swapped_outputs_match_static_deployments_bit_for_bit() {
    let (a, b, y_expect) = test_inputs(8);
    let provision = |lambda: usize| {
        Deployment::provision(
            SchemeSpec::Age {
                lambda: Some(lambda),
            },
            SchemeParams::new(2, 2, 2),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap()
    };
    let seeds: Vec<u64> = (0..6).map(|k| 0xBEEF + k).collect();

    let run = |dep: &Deployment, seed: u64| {
        let out = dep.execute_seeded(&a, &b, seed).unwrap();
        assert!(out.verified);
        out.y
    };

    let static0 = provision(0);
    let static2 = provision(2);
    let swapping = provision(0);
    for (i, &seed) in seeds.iter().enumerate() {
        if i == 3 {
            swapping
                .reconfigure(SchemeSpec::Age { lambda: Some(2) }, 0)
                .unwrap();
        }
        let y0 = run(&static0, seed);
        let y2 = run(&static2, seed);
        let ys = run(&swapping, seed);
        assert_eq!(y0, y_expect, "static λ0, seed {seed:#x}");
        assert_eq!(y2, y_expect, "static λ2, seed {seed:#x}");
        assert_eq!(ys, y0, "swapped deployment diverged from static λ0");
        assert_eq!(ys, y2, "swapped deployment diverged from static λ2");
    }
    assert_eq!(swapping.telemetry().jobs_completed, seeds.len() as u64);
    assert_eq!(swapping.generation(), 1);
}

/// The controller walks a mis-provisioned deployment onto the λ curve's
/// optimum from nothing but its own telemetry: Entangled (N = 19) →
/// AGE λ* = 2 (N = 17), predicted ζ saving ≈ 20.5 %, recorded in the
/// audit log with the applied generation number.
#[test]
fn controller_retunes_entangled_onto_the_age_curve() {
    let (a, b, y_expect) = test_inputs(8);
    let dep = Arc::new(
        Deployment::provision(
            SchemeSpec::Entangled,
            SchemeParams::new(2, 2, 2),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap(),
    );
    assert_eq!(dep.n_workers(), 19);
    let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());

    // An empty window never reconfigures, whatever the position.
    assert_eq!(
        scaler.tick(),
        Decision::Hold {
            reason: HoldReason::InsufficientData
        }
    );

    for k in 0..4 {
        let out = dep.execute_seeded(&a, &b, 0xE2E + k).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, y_expect);
    }

    match scaler.tick() {
        Decision::Reconfigure(rec) => {
            assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
            assert_eq!(rec.cause, Cause::CommunicationCost);
            assert_eq!(rec.n_workers, 17);
            assert!((rec.predicted_gain_pct - 100.0 * 70.0 / 342.0).abs() < 1e-9);
        }
        other => panic!("expected the Entangled→AGE walk, got {other:?}"),
    }
    assert_eq!(dep.scheme().name(), "AGE-CMPC(λ=2)");
    assert_eq!(dep.n_workers(), 17);

    // Cooldown holds while the green generation warms, then the optimum
    // position holds on merit; the audit trail records the whole story.
    assert_eq!(
        scaler.tick(),
        Decision::Hold {
            reason: HoldReason::Cooldown
        }
    );
    assert_eq!(
        scaler.tick(),
        Decision::Hold {
            reason: HoldReason::Cooldown
        }
    );
    for k in 0..4 {
        let out = dep.execute_seeded(&a, &b, 0xCAFE + k).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, y_expect);
    }
    assert_eq!(
        scaler.tick(),
        Decision::Hold {
            reason: HoldReason::AlreadyOptimal
        }
    );

    let health = scaler.health();
    assert_eq!(health.ticks, 5);
    assert_eq!(health.reconfigurations, 1);
    assert_eq!(health.failed, 0);
    assert_eq!(health.decisions.len(), 5);
    assert_eq!(health.decisions[1].window_jobs, 4);
    match &health.decisions[1].outcome {
        cmpc::autoscale::Outcome::Applied { generation, from, to } => {
            assert_eq!(*generation, 1);
            assert_eq!(from, "Entangled-CMPC");
            assert_eq!(to, "AGE-CMPC(λ=2)");
        }
        other => panic!("audit log lost the applied swap: {other:?}"),
    }
    wait_for_drain(&dep);
}

/// The strike ledger: a located Byzantine worker's strike survives its
/// eviction + respawn, surfaces through `health()`, and — once past the
/// policy's threshold — makes the controller escalate the adversary
/// tolerance via blue/green swap instead of retrying the offender. The
/// fresh generation starts with a clean ledger.
#[test]
fn strikes_survive_respawn_and_escalate_adversary_tolerance() {
    let (a, b, y_expect) = test_inputs(8);
    let params = SchemeParams::new(2, 2, 2).with_adversary_tolerance(1);
    let n = 17; // λ = 2 at (2, 2, 2)
    let seed = 0xB1A4_AD;
    let plan = ChaosPlan::garble_k_workers(seed, n, 1);
    let mut victims = ChaosPlan::chosen_victims(seed, n, 1);
    victims.sort_unstable();

    // Shape honest I-shares slow so the garbled one lands inside the
    // raised quota deterministically (the Byzantine decoder must *see* it
    // to locate it).
    let mut shaper = LinkShaper::new();
    for w in (0..n).filter(|w| !victims.contains(w)) {
        shaper = shaper.rule(
            ShapeRule::new(LinkSpec::latency(Duration::from_millis(150)))
                .from_node(w)
                .class(PayloadClass::IShare),
        );
    }
    let dep = Arc::new(
        Deployment::provision(
            SchemeSpec::Age { lambda: Some(2) },
            params,
            ProtocolConfig::builder()
                .threads(1)
                .chaos(plan.into_shared())
                .shaper(shaper.into_shared())
                .build(),
        )
        .unwrap(),
    );

    // Job 1 carries the garble: located, excluded, byte-identical output.
    let out = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
    assert!(out.verified);
    assert_eq!(out.y, y_expect);
    assert_eq!(out.blamed_workers, victims);

    // The blamed worker is evicted and respawned — and its strike is
    // still on the ledger afterwards. Eviction wipes the thread, not the
    // record.
    wait_for_respawns(&dep, 1);
    let strikes: Vec<(usize, u64)> = victims.iter().map(|&w| (w, 1)).collect();
    assert_eq!(dep.health().worker_strikes, strikes);

    // Three clean jobs fill the policy window; the strike count is
    // untouched by healthy traffic.
    for k in 0..3 {
        let out = dep.execute_seeded(&a, &b, 0xC1EA + k).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, y_expect);
    }
    assert_eq!(dep.health().worker_strikes, strikes);

    // A strike-sensitive controller escalates: a 1 → 2, cheapest covering
    // λ stays 2 (quota 10 ≤ 17), and the swap replaces every worker.
    let scaler = Autoscaler::new(
        dep.clone(),
        AutoscaleConfig {
            policy: PolicyConfig {
                strike_threshold: 1,
                ..PolicyConfig::default()
            },
            ..AutoscaleConfig::default()
        },
    );
    match scaler.tick() {
        Decision::Reconfigure(rec) => {
            assert_eq!(rec.cause, Cause::StrikeEviction);
            assert_eq!(rec.adversary_tolerance, 2);
            assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
        }
        other => panic!("expected strike-driven escalation, got {other:?}"),
    }
    assert_eq!(dep.params().adversary_tolerance, 2);
    assert_eq!(dep.generation(), 1);
    assert_eq!(dep.swap_history()[0].adversary_tolerance, 2);
    // The green generation starts with a clean ledger and serves
    // byte-identical jobs at the raised tolerance.
    assert!(dep.health().worker_strikes.is_empty());
    let out = dep.execute_seeded(&a, &b, 0xAF7E2).unwrap();
    assert!(out.verified);
    assert_eq!(out.y, y_expect);
    wait_for_drain(&dep);
}

/// A swap the executor cannot build (λ off the curve) is rejected
/// atomically: the blue generation keeps serving and the controller
/// records the failure without touching the deployment.
#[test]
fn failed_swap_is_audited_and_blue_keeps_serving() {
    let (a, b, y_expect) = test_inputs(8);
    let dep = Arc::new(
        Deployment::provision(
            SchemeSpec::Age { lambda: Some(2) },
            SchemeParams::new(2, 2, 2),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap(),
    );
    assert!(dep.reconfigure(SchemeSpec::Age { lambda: Some(9) }, 0).is_err());
    assert_eq!(dep.generation(), 0, "failed swap must not advance the generation");
    assert!(dep.swap_history().is_empty());
    let out = dep.execute_seeded(&a, &b, 0x0B5E).unwrap();
    assert!(out.verified);
    assert_eq!(out.y, y_expect);
}
