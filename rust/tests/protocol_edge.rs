//! Edge-case and failure-injection tests for the protocol engine and the
//! scheme layer: feasibility invariants the paper assumes implicitly,
//! adversarial timing, degenerate partitions, and determinism guarantees.

use std::time::Duration;

use cmpc::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
use cmpc::coordinator::{Coordinator, CoordinatorConfig};
use cmpc::matrix::FpMat;
use cmpc::mpc::privacy;
use cmpc::mpc::protocol::{prepare_setup, run_protocol_with_setup, ProtocolConfig, ProtocolOutput};
use cmpc::poly::interp::evaluation_points;
use cmpc::util::rng::ChaChaRng;
use cmpc::util::testing::property;

/// One-shot protocol run (the pre-0.2 `run_protocol` shape): solve the
/// setup, then run through a config-derived environment. Tests that stream
/// multiple jobs use `Deployment` instead.
fn run_protocol(
    scheme: &dyn CmpcScheme,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
) -> cmpc::Result<ProtocolOutput> {
    let setup = prepare_setup(scheme)?;
    run_protocol_with_setup(scheme, &setup, a, b, config)
}

/// The master phase requires t²+z ≤ N; every construction must provision at
/// least that many workers or the scheme is undecodable by its own protocol.
#[test]
fn reconstruction_feasibility_across_sweep() {
    for s in 1..=5 {
        for t in 1..=5 {
            for z in 1..=12 {
                for scheme in [
                    Box::new(AgeCmpc::with_optimal_lambda(s, t, z)) as Box<dyn CmpcScheme>,
                    Box::new(PolyDotCmpc::new(s, t, z)),
                    Box::new(EntangledCmpc::new(s, t, z)),
                ] {
                    assert!(
                        t * t + z <= scheme.n_workers(),
                        "{} infeasible at s={s} t={t} z={z}: N={} < t²+z={}",
                        scheme.name(),
                        scheme.n_workers(),
                        t * t + z
                    );
                }
            }
        }
    }
}

/// The paper's attack model needs z < N/2; check the constructions satisfy
/// it (they always do: N ≥ 2z + coded terms).
#[test]
fn honest_majority_margin_holds() {
    property("N > 2z for all schemes", 200, |rng| {
        let s = rng.gen_index(5) + 1;
        let t = rng.gen_index(5) + 1;
        let z = rng.gen_index(15) + 1;
        for scheme in [
            Box::new(AgeCmpc::with_optimal_lambda(s, t, z)) as Box<dyn CmpcScheme>,
            Box::new(PolyDotCmpc::new(s, t, z)),
        ] {
            if scheme.n_workers() <= 2 * z {
                return Err(format!(
                    "{} violates z < N/2 at s={s} t={t} z={z}",
                    scheme.name()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn link_latency_does_not_affect_correctness() {
    let scheme = AgeCmpc::with_optimal_lambda(2, 2, 1);
    let mut rng = ChaChaRng::seed_from_u64(50);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let cfg = ProtocolConfig::builder()
        .link_delay(Some(Duration::from_micros(200)))
        .build();
    let out = run_protocol(&scheme, &a, &b, &cfg).unwrap();
    assert!(out.verified);
}

#[test]
fn every_worker_delayed_still_completes() {
    let scheme = PolyDotCmpc::new(2, 2, 2);
    let n = scheme.n_workers();
    let mut rng = ChaChaRng::seed_from_u64(51);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let cfg = ProtocolConfig::builder()
        .worker_delays(vec![Duration::from_millis(5); n])
        .build();
    assert!(run_protocol(&scheme, &a, &b, &cfg).unwrap().verified);
}

#[test]
fn adversarial_straggler_pattern_first_workers_slow() {
    // Delay exactly the workers whose αs the master would prefer; the dense
    // I(x) reconstruction must succeed from whichever t²+z arrive first.
    let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N=17, needs 6
    let mut delays = vec![Duration::ZERO; 17];
    for d in delays.iter_mut().take(11) {
        *d = Duration::from_millis(80);
    }
    let mut rng = ChaChaRng::seed_from_u64(52);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let cfg = ProtocolConfig::builder().worker_delays(delays).build();
    let out = run_protocol(&scheme, &a, &b, &cfg).unwrap();
    assert!(out.verified);
    // the slow pack can only appear after the fast pack
    assert!(out
        .y
        .data
        .iter()
        .zip(a.transpose().matmul(&b).data.iter())
        .all(|(x, y)| x == y));
}

#[test]
fn deterministic_output_across_secret_seeds() {
    // Y must be independent of the secret randomness (only shares differ).
    let scheme = AgeCmpc::with_optimal_lambda(3, 2, 2);
    let mut rng = ChaChaRng::seed_from_u64(53);
    let a = FpMat::random(&mut rng, 12, 12);
    let b = FpMat::random(&mut rng, 12, 12);
    let run = |seed: u64| {
        let cfg = ProtocolConfig::builder().seed(seed).build();
        run_protocol(&scheme, &a, &b, &cfg).unwrap().y
    };
    assert_eq!(run(1), run(999_999));
}

#[test]
fn identity_and_zero_matrices_roundtrip() {
    let scheme = AgeCmpc::with_optimal_lambda(2, 2, 1);
    let id = FpMat::identity(8);
    let z = FpMat::zeros(8, 8);
    let out = run_protocol(&scheme, &id, &id, &ProtocolConfig::default()).unwrap();
    assert_eq!(out.y, id);
    let out = run_protocol(&scheme, &z, &id, &ProtocolConfig::default()).unwrap();
    assert_eq!(out.y, z);
}

#[test]
fn extreme_partitions_t1_and_s1() {
    // t=1 (row-only split) and s=1 (column-only split) degenerate cases.
    let mut rng = ChaChaRng::seed_from_u64(54);
    let a = FpMat::random(&mut rng, 12, 12);
    let b = FpMat::random(&mut rng, 12, 12);
    for scheme in [
        Box::new(AgeCmpc::with_optimal_lambda(4, 1, 2)) as Box<dyn CmpcScheme>,
        Box::new(AgeCmpc::with_optimal_lambda(1, 4, 2)),
        Box::new(PolyDotCmpc::new(4, 1, 2)),
        Box::new(PolyDotCmpc::new(1, 4, 2)),
    ] {
        let out = run_protocol(scheme.as_ref(), &a, &b, &ProtocolConfig::default())
            .unwrap_or_else(|e| panic!("{}: {e}", scheme.name()));
        assert_eq!(out.y, a.transpose().matmul(&b), "{}", scheme.name());
    }
}

#[test]
fn rectangular_block_shapes_when_s_differs_from_t() {
    // s≠t produces rectangular F_A/F_B shares; verify several aspect ratios.
    let mut rng = ChaChaRng::seed_from_u64(55);
    for (s, t) in [(2usize, 4usize), (4, 2), (3, 6), (6, 3)] {
        let m = 12 * 2; // divisible by all of the above
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let scheme = AgeCmpc::with_optimal_lambda(s, t, 2);
        let out = run_protocol(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        assert_eq!(out.y, a.transpose().matmul(&b), "s={s} t={t}");
    }
}

#[test]
fn gn_mask_powers_also_pass_collusion_audit() {
    // Phase-2 privacy relies on the G-polynomial masks at powers t²..t²+z−1
    // (a contiguous band → classic Vandermonde, but audit anyway).
    let mut rng = ChaChaRng::seed_from_u64(56);
    for (t, z) in [(2usize, 2usize), (3, 4), (4, 3)] {
        let n = t * t + z + 5;
        let alphas = evaluation_points(n, 0);
        let g_mask_powers: Vec<u64> = (0..z as u64).map(|w| (t * t) as u64 + w).collect();
        assert_eq!(
            privacy::audit_collusion(&alphas, &g_mask_powers, z, 40, &mut rng),
            0,
            "t={t} z={z}"
        );
    }
}

#[test]
fn coordinator_mixed_matrix_sizes_batch_correctly() {
    let mut coord = Coordinator::new(CoordinatorConfig::default());
    let mut rng = ChaChaRng::seed_from_u64(57);
    let pairs: Vec<(FpMat, FpMat)> = [8usize, 16, 8, 24]
        .iter()
        .map(|&m| {
            (
                FpMat::random(&mut rng, m, m),
                FpMat::random(&mut rng, m, m),
            )
        })
        .collect();
    for (a, b) in &pairs {
        coord.submit(a.clone(), b.clone(), 2, 2, 2).unwrap();
    }
    let reports = coord.drain();
    // same scheme+params ⇒ deployments shared even across matrix sizes
    assert!(reports[2].setup_cache_hit);
    for (r, (a, b)) in reports.iter().zip(&pairs) {
        assert_eq!(r.outcome.as_ref().unwrap().y, a.transpose().matmul(b));
    }
}

#[test]
fn verify_mode_catches_tampering() {
    // Negative control for the verifier itself: a scheme whose important
    // powers are sabotaged must fail verification rather than silently
    // return a wrong product.
    struct Sabotaged(AgeCmpc);
    impl CmpcScheme for Sabotaged {
        fn name(&self) -> String {
            "sabotaged".into()
        }
        fn params(&self) -> cmpc::codes::SchemeParams {
            self.0.params()
        }
        fn coded_power_a(&self, i: usize, j: usize) -> u64 {
            self.0.coded_power_a(i, j)
        }
        fn coded_power_b(&self, k: usize, l: usize) -> u64 {
            self.0.coded_power_b(k, l)
        }
        fn secret_powers_a(&self) -> Vec<u64> {
            self.0.secret_powers_a()
        }
        fn secret_powers_b(&self) -> Vec<u64> {
            self.0.secret_powers_b()
        }
        fn important_power(&self, i: usize, l: usize) -> u64 {
            // off-by-one: reads garbage coefficients instead of Y blocks
            self.0.important_power(i, l) + 1
        }
    }
    let scheme = Sabotaged(AgeCmpc::with_optimal_lambda(2, 2, 2));
    let mut rng = ChaChaRng::seed_from_u64(58);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    // Either setup fails typed (power missing from the reconstruction
    // support) or verification trips — never a panic.
    match run_protocol(&scheme, &a, &b, &ProtocolConfig::default()) {
        Err(_) => {} // NotDecodable from setup or verification
        Ok(out) => assert!(!out.verified || out.y != a.transpose().matmul(&b)),
    }
}
