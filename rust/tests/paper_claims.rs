//! Paper-claim spot checks: concrete parameter points the paper asserts in
//! Lemmas 3–5, §VII, and Example 1, tested against the exact constructions.

use cmpc::analysis::{
    n_age_enum, n_entangled, n_polydot_enum, gamma_age_enum,
};
use cmpc::codes::{n_gcsa_na, n_ssmm};

/// Lemma 3, condition 5: `s=2, t=3, z=4` ⇒ PolyDot < Entangled.
#[test]
fn lemma3_condition5_point() {
    assert!(n_polydot_enum(2, 3, 4) < n_entangled(2, 3, 4));
}

/// Lemma 3, condition 6: `t=2, s=2, z∈{1,2}` ⇒ PolyDot < Entangled.
#[test]
fn lemma3_condition6_points() {
    for z in [1, 2] {
        assert!(
            n_polydot_enum(2, 2, z) < n_entangled(2, 2, z),
            "z={z}: {} vs {}",
            n_polydot_enum(2, 2, z),
            n_entangled(2, 2, z)
        );
    }
}

/// Lemma 3, condition 3: `(t−1)² < z < t(t−1), s = t−1` ⇒ PolyDot wins.
#[test]
fn lemma3_condition3_band() {
    for t in 3..=6usize {
        let s = t - 1;
        for z in (t - 1) * (t - 1) + 1..t * (t - 1) {
            assert!(
                n_polydot_enum(s, t, z) < n_entangled(s, t, z),
                "s={s} t={t} z={z}"
            );
        }
    }
}

/// Lemma 4: PolyDot < SSMM requires large z (condition 1/2); verify the
/// complementary small-z region has SSMM ≤ PolyDot.
#[test]
fn lemma4_ssmm_small_z_side() {
    for (s, t) in [(3usize, 3usize), (4, 3), (2, 4)] {
        for z in 1..=(t * s - 2 * t).max(1) {
            assert!(
                n_polydot_enum(s, t, z) >= n_ssmm(s, t, z),
                "s={s} t={t} z={z}"
            );
        }
    }
}

/// Lemma 5, condition 3: `z < ts − t` ⇒ PolyDot < GCSA-NA.
#[test]
fn lemma5_condition3_band() {
    for (s, t) in [(3usize, 3usize), (4, 2), (2, 5)] {
        for z in 1..t * s - t {
            assert!(
                n_polydot_enum(s, t, z) < n_gcsa_na(s, t, z),
                "s={s} t={t} z={z}"
            );
        }
    }
}

/// §VII, Fig. 2 narration: the second-best regime boundaries at s=4, t=15
/// fall at z = 48→49 (SSMM → PolyDot) and z = 180→181 (PolyDot →
/// Entangled/GCSA-NA).
#[test]
fn fig2_regime_boundaries_exact() {
    let second = |z: usize| {
        [
            ("polydot", n_polydot_enum(4, 15, z)),
            ("entangled", n_entangled(4, 15, z)),
            ("ssmm", n_ssmm(4, 15, z)),
            ("gcsa", n_gcsa_na(4, 15, z)),
        ]
        .into_iter()
        .min_by_key(|&(_, v)| v)
        .unwrap()
        .0
    };
    assert_eq!(second(48), "ssmm");
    assert_eq!(second(49), "polydot");
    assert_eq!(second(180), "polydot");
    assert_eq!(second(181), "entangled");
}

/// Example 1 (§V-B): N_AGE = 17 with λ* = 2; Γ curve 18/18/17.
#[test]
fn example1_full_story() {
    assert_eq!(n_age_enum(2, 2, 2), (17, 2));
    assert_eq!(gamma_age_enum(2, 2, 2, 0), 18);
    assert_eq!(gamma_age_enum(2, 2, 2, 1), 18);
    assert_eq!(gamma_age_enum(2, 2, 2, 2), 17);
    assert_eq!(n_entangled(2, 2, 2), 19);
}

/// Footnote 3 / Appendix H: λ > z never helps — the optimum over [0, z]
/// is already the global optimum over a wider scan. We verify the weaker,
/// testable form: Γ is non-increasing gains-wise, i.e. the [0,z] optimum is
/// ≤ Γ(z) and ≤ Γ(0) for a sweep.
#[test]
fn lambda_range_endpoints_never_beat_optimum() {
    for s in 1..=4usize {
        for t in 2..=4usize {
            for z in 1..=10usize {
                let (best, _) = n_age_enum(s, t, z);
                assert!(best <= gamma_age_enum(s, t, z, 0));
                assert!(best <= gamma_age_enum(s, t, z, z as u64));
            }
        }
    }
}
