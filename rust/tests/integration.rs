//! Cross-layer integration tests: the Rust protocol engine running over the
//! AOT-compiled L2/L1 artifacts via the executor service, the coordinator
//! serving path, and the measured-vs-closed-form overhead identities (E9/E10
//! in DESIGN.md).
//!
//! Tests that need `artifacts/` skip (with a note) when it is absent so
//! `cargo test` stays green before `make artifacts`; CI and the Makefile
//! always build artifacts first.

use std::path::PathBuf;
use std::sync::Arc;

use cmpc::analysis;
use cmpc::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::pjrt::PjrtService;
use cmpc::runtime::{BackendChoice, MatmulBackend, NativeBackend};
use cmpc::util::rng::ChaChaRng;
use cmpc::Deployment;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first ({})", dir.display());
        None
    }
}

#[test]
fn pjrt_matmul_matches_native_on_artifact_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = PjrtService::start(dir).unwrap();
    let mut pjrt = svc.handle();
    let mut native = NativeBackend;
    let mut rng = ChaChaRng::seed_from_u64(42);
    for (m, k, n) in [(32usize, 32usize, 32usize), (128, 64, 128), (128, 128, 128)] {
        let a = FpMat::random(&mut rng, m, k);
        let b = FpMat::random(&mut rng, k, n);
        let via_pjrt = pjrt.matmul_mod(&a, &b).unwrap();
        let via_native = native.matmul_mod(&a, &b).unwrap();
        assert_eq!(via_pjrt, via_native, "shape {m}x{k}x{n}");
    }
    let stats = svc.stats();
    assert_eq!(
        stats.pjrt_calls.load(std::sync::atomic::Ordering::Relaxed),
        3,
        "all three shapes must be served by compiled artifacts"
    );
    assert_eq!(
        stats
            .native_fallback_calls
            .load(std::sync::atomic::Ordering::Relaxed),
        0
    );
}

#[test]
fn pjrt_executable_cache_compiles_once_per_shape() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = PjrtService::start_with_lanes(dir, 1).unwrap();
    let mut pjrt = svc.handle();
    let mut rng = ChaChaRng::seed_from_u64(7);
    for _ in 0..5 {
        let a = FpMat::random(&mut rng, 32, 32);
        let b = FpMat::random(&mut rng, 32, 32);
        pjrt.matmul_mod(&a, &b).unwrap();
    }
    assert_eq!(
        svc.stats()
            .compilations
            .load(std::sync::atomic::Ordering::Relaxed),
        1,
        "steady-state executable cache must hit"
    );
}

#[test]
fn pjrt_unknown_shape_falls_back_to_native() {
    let Some(dir) = artifacts_dir() else { return };
    let svc = PjrtService::start(dir).unwrap();
    let mut pjrt = svc.handle();
    let mut rng = ChaChaRng::seed_from_u64(9);
    let a = FpMat::random(&mut rng, 5, 7);
    let b = FpMat::random(&mut rng, 7, 3);
    let out = pjrt.matmul_mod(&a, &b).unwrap();
    assert_eq!(out, a.matmul(&b));
    assert_eq!(
        svc.stats()
            .native_fallback_calls
            .load(std::sync::atomic::Ordering::Relaxed),
        1
    );
}

#[test]
fn full_protocol_over_pjrt_backend() {
    // E9: the three-layer composition — shares generated in Rust, worker
    // products executed through the artifact service, masks and
    // reconstruction in Rust — decodes AᵀB exactly.
    let Some(dir) = artifacts_dir() else { return };
    let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
    let m = 64; // blocks 32x32 → matmul_mod_32x32x32 artifact
    let mut rng = ChaChaRng::seed_from_u64(123);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let cfg = ProtocolConfig::builder()
        .backend(BackendChoice::Pjrt { artifacts_dir: dir })
        .build();
    let deployment = Deployment::for_scheme(Arc::new(scheme), cfg).unwrap();
    let out = deployment.execute(&a, &b).unwrap();
    assert!(out.verified);
    assert_eq!(out.y, a.transpose().matmul(&b));
    assert_eq!(out.n_workers, 17);
}

#[test]
fn coordinator_serves_mixed_jobs_over_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .policy(SchemePolicy::Adaptive)
            .backend(BackendChoice::Pjrt { artifacts_dir: dir })
            .build(),
    );
    let mut rng = ChaChaRng::seed_from_u64(5);
    let mut inputs = Vec::new();
    for _ in 0..2 {
        let a = FpMat::random(&mut rng, 64, 64);
        let b = FpMat::random(&mut rng, 64, 64);
        coord.submit(a.clone(), b.clone(), 2, 2, 2).unwrap();
        inputs.push((a, b));
    }
    // different privacy level → different deployment in the same batch
    let a = FpMat::random(&mut rng, 64, 64);
    let b = FpMat::random(&mut rng, 64, 64);
    coord.submit(a.clone(), b.clone(), 2, 2, 1).unwrap();
    inputs.push((a, b));
    let reports = coord.drain();
    assert_eq!(reports.len(), 3);
    for (r, (a, b)) in reports.iter().zip(&inputs) {
        let out = r.outcome.as_ref().unwrap();
        assert!(out.verified, "job {}", r.id);
        assert_eq!(out.y, a.transpose().matmul(b));
    }
    assert!(reports[1].setup_cache_hit);
    assert!(!reports[2].setup_cache_hit);
}

#[test]
fn all_constructible_schemes_decode_same_product() {
    let mut rng = ChaChaRng::seed_from_u64(31);
    let m = 12;
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let want = a.transpose().matmul(&b);
    let schemes: Vec<Arc<dyn CmpcScheme>> = vec![
        Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 3)),
        Arc::new(AgeCmpc::new(2, 2, 3, 0)),
        Arc::new(PolyDotCmpc::new(2, 2, 3)),
        Arc::new(EntangledCmpc::new(2, 2, 3)),
        Arc::new(AgeCmpc::with_optimal_lambda(3, 2, 2)),
        Arc::new(PolyDotCmpc::new(3, 2, 2)),
        Arc::new(AgeCmpc::with_optimal_lambda(2, 3, 2)),
        Arc::new(PolyDotCmpc::new(2, 3, 2)),
    ];
    for scheme in schemes {
        let name = scheme.name();
        let deployment = Deployment::for_scheme(scheme, ProtocolConfig::default())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let out = deployment
            .execute(&a, &b)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.y, want, "{name}");
    }
}

#[test]
fn measured_overheads_track_formulas_across_schemes() {
    // E10 across schemes and partitions: ξ, σ, ζ hold exactly for every
    // constructible scheme (Corollaries 10–12 are scheme-independent).
    let mut rng = ChaChaRng::seed_from_u64(17);
    for (s, t, z, m) in [(2usize, 2usize, 2usize, 8usize), (3, 2, 1, 12), (2, 3, 2, 12)] {
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let schemes: Vec<Arc<dyn CmpcScheme>> = vec![
            Arc::new(AgeCmpc::with_optimal_lambda(s, t, z)),
            Arc::new(PolyDotCmpc::new(s, t, z)),
            Arc::new(EntangledCmpc::new(s, t, z)),
        ];
        for scheme in schemes {
            let name = scheme.name();
            let deployment =
                Deployment::for_scheme(scheme, ProtocolConfig::default()).unwrap();
            let out = deployment.execute(&a, &b).unwrap();
            let n = out.n_workers as u64;
            let xi = analysis::computation_overhead(m, s, t, z, n) as u64;
            let sigma = analysis::storage_overhead(m, s, t, z, n) as u64;
            let zeta = analysis::communication_overhead(m, t, n) as u64;
            for c in &out.worker_counters {
                assert_eq!(c.mults(), xi, "{name} ξ");
                assert_eq!(c.stored(), sigma, "{name} σ");
            }
            assert_eq!(out.traffic.worker_to_worker, zeta, "{name} ζ");
        }
    }
}
