//! Fused-batch identity across all three schemes (PR 8).
//!
//! `Deployment::execute_fused_seeded` stacks k same-shape jobs into one
//! wide kernel pass per worker. Fusion is a scheduling change, not a
//! protocol change: for every scheme the batch must return, job for job,
//! byte-identical `Y` matrices, identical ξ/σ worker counters, and
//! identical metered traffic to k sequential `execute_seeded` calls with
//! the same seeds. The in-module unit tests pin this for AGE; this suite
//! pins it across AGE / PolyDot / Entangled through the public API, with
//! verification on (the full serving path including the reference
//! product).

use cmpc::codes::SchemeParams;
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

const SCHEMES: [SchemeSpec; 3] = [
    SchemeSpec::Age { lambda: None },
    SchemeSpec::PolyDot,
    SchemeSpec::Entangled,
];

fn batch_inputs(k: usize, m: usize, seed: u64) -> Vec<(FpMat, FpMat)> {
    let mut rng = ChaChaRng::seed_from_u64(seed);
    (0..k)
        .map(|_| (FpMat::random(&mut rng, m, m), FpMat::random(&mut rng, m, m)))
        .collect()
}

#[test]
fn fused_batch_matches_sequential_across_all_schemes() {
    let params = SchemeParams::new(2, 2, 2);
    let mats = batch_inputs(3, 8, 0xF0513D);
    let jobs: Vec<(&FpMat, &FpMat)> = mats.iter().map(|(a, b)| (a, b)).collect();
    let seeds = [31u64, 32, 33];
    for spec in SCHEMES {
        // Two fresh deployments of the same scheme: the fused batch on one,
        // the k sequential jobs on the other, identical per-job seeds.
        let provision = || {
            Deployment::provision(spec, params, ProtocolConfig::builder().build())
                .unwrap_or_else(|e| panic!("provision {spec:?}: {e}"))
        };
        let fused_dep = provision();
        let seq_dep = provision();
        let fused = fused_dep
            .execute_fused_seeded(&jobs, &seeds)
            .unwrap_or_else(|e| panic!("fused batch under {spec:?}: {e}"));
        assert_eq!(fused.len(), jobs.len());
        for (j, (out, &(a, b))) in fused.iter().zip(&jobs).enumerate() {
            let seq = seq_dep
                .execute_seeded(a, b, seeds[j])
                .unwrap_or_else(|e| panic!("sequential job {j} under {spec:?}: {e}"));
            assert_eq!(out.y, seq.y, "Y divergence, job {j} under {spec:?}");
            assert!(out.verified, "fused job {j} under {spec:?} not verified");
            assert!(seq.verified);
            assert_eq!(out.scheme_name, seq.scheme_name);
            assert_eq!(out.n_workers, seq.n_workers, "{spec:?}");
            assert_eq!(
                out.stragglers_tolerated, seq.stragglers_tolerated,
                "{spec:?}"
            );
            assert_eq!(out.traffic, seq.traffic, "traffic, job {j} under {spec:?}");
            assert_eq!(out.worker_counters.len(), seq.worker_counters.len());
            for (wn, (f, s)) in out
                .worker_counters
                .iter()
                .zip(&seq.worker_counters)
                .enumerate()
            {
                assert_eq!(
                    f.mults(),
                    s.mults(),
                    "ξ divergence, job {j} worker {wn} under {spec:?}"
                );
                assert_eq!(
                    f.stored(),
                    s.stored(),
                    "σ divergence, job {j} worker {wn} under {spec:?}"
                );
            }
        }
    }
}

#[test]
fn fused_batch_identity_holds_at_batch_sizes_one_and_larger() {
    // Batch size 1 routes through the sequential fallback; batch size 4
    // through the wide path — both must agree with plain execution.
    let params = SchemeParams::new(2, 2, 1);
    for k in [1usize, 4] {
        let mats = batch_inputs(k, 4, 0xBA7C + k as u64);
        let jobs: Vec<(&FpMat, &FpMat)> = mats.iter().map(|(a, b)| (a, b)).collect();
        let seeds: Vec<u64> = (0..k as u64).map(|i| 700 + i).collect();
        let fused_dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::builder().build(),
        )
        .unwrap();
        let seq_dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::builder().build(),
        )
        .unwrap();
        let fused = fused_dep.execute_fused_seeded(&jobs, &seeds).unwrap();
        for (j, (out, &(a, b))) in fused.iter().zip(&jobs).enumerate() {
            let seq = seq_dep.execute_seeded(a, b, seeds[j]).unwrap();
            assert_eq!(out.y, seq.y, "Y divergence at k={k}, job {j}");
            assert!(out.verified && seq.verified);
        }
    }
}
