//! Runtime-reuse stress: one persistent deployment serving many jobs —
//! sequentially and concurrently — must stay flat on threads, meter traffic
//! per job, isolate failures, and stay byte-deterministic under any
//! interleaving.
//!
//! Kept to a single `#[test]` so the OS thread-count measurement cannot be
//! perturbed by sibling tests provisioning their own runtimes in the same
//! process.

use cmpc::codes::SchemeParams;
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::pool::WorkerPool;
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Deployment, SchemeSpec};

/// Threads of this process per the kernel (Linux); `None` elsewhere.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

fn zeta(m: usize, t: usize, n: usize) -> u64 {
    cmpc::analysis::communication_overhead(m, t, n as u64) as u64
}

#[test]
fn persistent_runtime_serves_many_jobs() {
    let params = SchemeParams::new(2, 2, 2); // AGE λ*: N = 17
    // threads(1): every parallel section runs inline on the submitting
    // thread, so the only long-lived threads are the 17 persistent workers
    // — any OS-level growth across jobs would be a per-job spawn.
    let cfg = ProtocolConfig::builder().threads(1).build();
    let dep =
        Deployment::provision(SchemeSpec::Age { lambda: None }, params, cfg).unwrap();
    assert_eq!(dep.worker_threads(), 17);
    let n = dep.n_workers();
    let t = dep.params().t;

    let mut rng = ChaChaRng::seed_from_u64(0xACE);
    let a8 = FpMat::random(&mut rng, 8, 8);
    let b8 = FpMat::random(&mut rng, 8, 8);
    let a16 = FpMat::random(&mut rng, 16, 16);
    let b16 = FpMat::random(&mut rng, 16, 16);
    let y8 = a8.transpose().matmul(&b8);
    let y16 = a16.transpose().matmul(&b16);

    // --- phase 1: warm up, then 32 sequential jobs with mixed seeds ---
    assert!(dep.execute_seeded(&a8, &b8, 1).unwrap().verified);
    let baseline_threads = os_thread_count();
    for i in 0..32u64 {
        let out = dep.execute_seeded(&a8, &b8, 1000 + 7 * i).unwrap();
        assert!(out.verified, "job {i}");
        // Y is independent of the secret seed — byte-identical every time.
        assert_eq!(out.y, y8, "job {i} output differs");
        // per-job traffic accounting: exactly ζ worker↔worker scalars
        assert_eq!(out.traffic.worker_to_worker, zeta(8, t, n), "job {i}");
        assert_eq!(out.traffic.messages, (n + n * (n - 1) + n) as u64, "job {i}");
    }
    if let (Some(before), Some(after)) = (baseline_threads, os_thread_count()) {
        assert_eq!(
            after, before,
            "thread count grew across 32 warm jobs (per-job spawns?)"
        );
    }
    assert_eq!(dep.worker_threads(), 17);

    // --- phase 2: concurrent jobs on ONE runtime — mixed sizes, one
    // injected failure — with per-job meters and failure isolation ---
    let drive = WorkerPool::new(4);
    // (m, seed, valid): the 7×8 pair is the injected-failure job.
    let bad_a = FpMat::random(&mut rng, 7, 7);
    let specs: Vec<(usize, u64, bool)> = (0..16)
        .map(|i| {
            if i == 5 {
                (7, 0, false)
            } else if i % 3 == 0 {
                (16, 9000 + i as u64, true)
            } else {
                (8, 9000 + i as u64, true)
            }
        })
        .collect();
    let run_concurrent = || {
        drive.par_map(&specs, |_wid, _idx, &(m, seed, valid)| {
            if !valid {
                dep.execute_seeded(&bad_a, &b8, seed)
            } else if m == 16 {
                dep.execute_seeded(&a16, &b16, seed)
            } else {
                dep.execute_seeded(&a8, &b8, seed)
            }
        })
    };
    let concurrent = run_concurrent();
    let concurrent2 = run_concurrent();
    for (i, ((res, res2), &(m, _seed, valid))) in concurrent
        .iter()
        .zip(&concurrent2)
        .zip(&specs)
        .enumerate()
    {
        if !valid {
            // the malformed job fails typed and poisons nothing
            assert!(
                matches!(res, Err(CmpcError::ShapeMismatch(_))),
                "job {i} should be rejected"
            );
            continue;
        }
        let out = res.as_ref().unwrap_or_else(|e| panic!("job {i}: {e}"));
        let out2 = res2.as_ref().unwrap_or_else(|e| panic!("job {i} rerun: {e}"));
        assert!(out.verified, "job {i}");
        // deterministic outputs regardless of interleaving: two concurrent
        // sweeps agree byte-for-byte, and match the reference product
        assert_eq!(out.y, out2.y, "job {i} differs across interleavings");
        assert_eq!(out.y, if m == 16 { y16.clone() } else { y8.clone() }, "job {i}");
        // per-job traffic meters never bleed across the 8- and 16-sized
        // jobs interleaving on the same links
        assert_eq!(
            out.traffic.worker_to_worker,
            zeta(m, t, n),
            "job {i} (m={m}) traffic bled across jobs"
        );
        assert_eq!(out.traffic.worker_to_worker, out2.traffic.worker_to_worker);
        // per-job, per-worker overhead counters are exact under concurrency
        for (wc, wc2) in out.worker_counters.iter().zip(out2.worker_counters.iter()) {
            assert_eq!(wc.mults(), wc2.mults(), "job {i}");
            assert_eq!(wc.stored(), wc2.stored(), "job {i}");
        }
    }
    // 33 sequential + 2×15 concurrent (the bad job never reaches the runtime)
    assert_eq!(dep.runtime().jobs_started(), 33 + 30);
    assert_eq!(dep.worker_threads(), 17, "concurrent jobs spawned threads");

    // --- phase 3: concurrent drain through the coordinator pipelines into
    // one cached deployment, reports in submission order ---
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .policy(SchemePolicy::Fixed(SchemeSpec::Age { lambda: None }))
            .threads(4)
            .build(),
    );
    let mut handles = Vec::new();
    for i in 0..32 {
        let (a, b) = if i % 2 == 0 { (&a8, &b8) } else { (&a16, &b16) };
        handles.push(coord.submit(a.clone(), b.clone(), 2, 2, 2).unwrap());
    }
    let reports = coord.drain();
    assert_eq!(reports.len(), 32);
    assert_eq!(coord.provisioned_deployments(), 1);
    for (i, (h, r)) in handles.iter().zip(&reports).enumerate() {
        assert_eq!(h.id(), r.id, "report {i} out of submission order");
        let out = r.outcome.as_ref().unwrap_or_else(|e| panic!("job {i}: {e}"));
        let (m, want) = if i % 2 == 0 { (8, &y8) } else { (16, &y16) };
        assert_eq!(&out.y, want, "drain job {i}");
        assert_eq!(out.traffic.worker_to_worker, zeta(m, t, n), "drain job {i}");
    }
}
