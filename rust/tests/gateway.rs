//! Serving-gateway acceptance pins (v0.7/v0.8): byte-identity with
//! in-process execution, typed multi-tenant admission, observable batching,
//! a fixed-size poller thread pool under many concurrent connections,
//! token-authenticated shutdown, and the teardown flush (queued results
//! are delivered, not dropped, when the gateway stops).

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

use cmpc::coordinator::CoordinatorConfig;
use cmpc::gateway::client::{run_load, ClientReply, GatewayClient, LoadPlan};
use cmpc::gateway::{BatchKey, Gateway, GatewayConfig, LocalEngine, TenantQuota};
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::transport::node::{digest_mat, job_matrices};
use cmpc::transport::wire::RejectReason;
use cmpc::util::rng::ChaChaRng;
use cmpc::{Deployment, SchemeSpec};

/// Serialize the tests in this binary: the thread-count pin below reads
/// `/proc/self/status`, which is process-wide — a concurrently running
/// sibling test would make it flaky.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

/// Threads of this process per the kernel (Linux); `None` elsewhere.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

fn start_local(
    config: GatewayConfig,
) -> (Gateway, Arc<LocalEngine>, String) {
    let engine = Arc::new(LocalEngine::new(CoordinatorConfig::default()));
    let gateway =
        Gateway::start("127.0.0.1:0", config, engine.clone()).expect("gateway starts");
    let addr = gateway.local_addr().to_string();
    (gateway, engine, addr)
}

/// Acceptance (a): results served through the gateway are byte-identical
/// to direct in-process execution of the same inputs.
#[test]
fn gateway_results_match_in_process_execution() {
    let _serial = serial();
    let (gateway, _engine, addr) = start_local(GatewayConfig::default());
    let direct = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        cmpc::codes::SchemeParams::new(2, 2, 2),
        ProtocolConfig::default(),
    )
    .unwrap();
    let mut rng = ChaChaRng::seed_from_u64(31);
    let mut client = GatewayClient::connect(&addr, 0).unwrap();
    for corr in 0..3u64 {
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let reply = client
            .call(corr, 2, 2, 2, 0, a.clone(), b.clone())
            .expect("round trip");
        match reply {
            ClientReply::Accepted {
                corr: got, digest, y, ..
            } => {
                assert_eq!(got, corr);
                let expected = direct.execute(&a, &b).unwrap().y;
                assert_eq!(y, expected, "gateway Y differs from direct execute");
                assert_eq!(y, a.transpose().matmul(&b));
                assert_eq!(digest, digest_mat(&y));
            }
            ClientReply::Rejected { reason, detail, .. } => {
                panic!("job {corr} rejected: {reason} ({detail})")
            }
        }
    }
    let stats = gateway.shutdown();
    assert_eq!(stats.accepted, 3);
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.rejected_total(), 0);
}

/// Acceptance (b) + S3: over-quota submissions get typed rejections while
/// an in-quota tenant on the same gateway is unaffected.
#[test]
fn over_quota_tenant_is_rejected_without_hurting_neighbors() {
    let _serial = serial();
    let config = GatewayConfig {
        tenants: vec![
            TenantQuota {
                id: 0,
                burst: 100,
                rate_per_sec: 0.0,
                max_pending: 64,
            },
            // rate 0 + burst 2: exactly the first two submissions pass,
            // independent of timing.
            TenantQuota {
                id: 1,
                burst: 2,
                rate_per_sec: 0.0,
                max_pending: 64,
            },
        ],
        ..GatewayConfig::default()
    };
    let (gateway, _engine, addr) = start_local(config);
    let mut rng = ChaChaRng::seed_from_u64(32);
    let mut job = |client: &mut GatewayClient, corr: u64| {
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        client.call(corr, 2, 2, 2, 0, a, b).unwrap()
    };

    let mut limited = GatewayClient::connect(&addr, 1).unwrap();
    for corr in 0..4u64 {
        let reply = job(&mut limited, corr);
        match reply {
            ClientReply::Accepted { corr: got, .. } => {
                assert!(corr < 2, "job {got} should have been over quota");
            }
            ClientReply::Rejected { reason, corr: got, .. } => {
                assert!(got >= 2, "job {got} rejected while under quota");
                assert_eq!(reason, RejectReason::QuotaExceeded);
            }
        }
    }
    // The healthy tenant still flows — same gateway, after the storm.
    let mut healthy = GatewayClient::connect(&addr, 0).unwrap();
    for corr in 0..4u64 {
        assert!(
            matches!(job(&mut healthy, corr), ClientReply::Accepted { .. }),
            "healthy tenant was throttled by its neighbor"
        );
    }
    // Unknown tenants are a distinct typed refusal.
    let mut stranger = GatewayClient::connect(&addr, 99).unwrap();
    match job(&mut stranger, 0) {
        ClientReply::Rejected { reason, .. } => {
            assert_eq!(reason, RejectReason::UnknownTenant)
        }
        other => panic!("unknown tenant admitted: {other:?}"),
    }
    let stats = gateway.shutdown();
    assert_eq!(stats.accepted, 6);
    assert_eq!(stats.completed, 6);
    assert_eq!(
        stats.rejected[RejectReason::QuotaExceeded.as_u8() as usize],
        2
    );
    assert_eq!(
        stats.rejected[RejectReason::UnknownTenant.as_u8() as usize],
        1
    );
}

/// S3: malformed submissions are refused at the door — no deployment is
/// ever provisioned for them.
#[test]
fn malformed_submissions_never_touch_a_deployment() {
    let _serial = serial();
    let (gateway, engine, addr) = start_local(GatewayConfig::default());
    let mut client = GatewayClient::connect(&addr, 0).unwrap();
    // s=3 does not divide m=8: shape validation must fail at the door.
    let reply = client
        .call(7, 3, 2, 2, 0, FpMat::zeros(8, 8), FpMat::zeros(8, 8))
        .unwrap();
    match reply {
        ClientReply::Rejected { reason, corr, .. } => {
            assert_eq!(reason, RejectReason::Malformed);
            assert_eq!(corr, 7);
        }
        other => panic!("malformed job admitted: {other:?}"),
    }
    // The connection survives a malformed submission…
    let reply = client
        .call(8, 0, 0, 0, 0, FpMat::zeros(4, 4), FpMat::zeros(4, 4))
        .unwrap();
    assert!(matches!(
        reply,
        ClientReply::Rejected {
            reason: RejectReason::Malformed,
            ..
        }
    ));
    assert_eq!(engine.provisioned(), 0, "rejected jobs reached the engine");
    let stats = gateway.shutdown();
    assert_eq!(stats.accepted, 0);
    assert_eq!(stats.rejected[RejectReason::Malformed.as_u8() as usize], 2);
}

/// S3: oversized frames are refused from the header alone, and the shape
/// lock pins a gateway to its cluster's one signature.
#[test]
fn oversized_and_off_shape_submissions_are_typed_rejects() {
    let _serial = serial();
    let config = GatewayConfig {
        max_payload_bytes: 1024,
        ..GatewayConfig::default()
    };
    let (gateway, engine, addr) = start_local(config);
    let mut client = GatewayClient::connect(&addr, 0).unwrap();
    // m=64 ⇒ ~32 KiB payload, far over the 1 KiB cap.
    let reply = client
        .call(1, 2, 2, 2, 0, FpMat::zeros(64, 64), FpMat::zeros(64, 64))
        .unwrap();
    match reply {
        ClientReply::Rejected { reason, .. } => assert_eq!(reason, RejectReason::TooLarge),
        other => panic!("oversized job admitted: {other:?}"),
    }
    assert_eq!(engine.provisioned(), 0);
    let stats = gateway.shutdown();
    assert_eq!(stats.rejected[RejectReason::TooLarge.as_u8() as usize], 1);

    // Shape-locked gateway (the remote-cluster mode): only the pinned
    // signature passes the door.
    let config = GatewayConfig {
        shape_lock: Some(BatchKey {
            s: 2,
            t: 2,
            z: 2,
            adv: 0,
            m: 8,
        }),
        ..GatewayConfig::default()
    };
    let (gateway, engine, addr) = start_local(config);
    let mut client = GatewayClient::connect(&addr, 0).unwrap();
    let reply = client
        .call(2, 2, 2, 1, 0, FpMat::zeros(4, 4), FpMat::zeros(4, 4))
        .unwrap();
    match reply {
        ClientReply::Rejected { reason, .. } => assert_eq!(reason, RejectReason::Malformed),
        other => panic!("off-shape job admitted: {other:?}"),
    }
    assert!(matches!(
        client.call(3, 2, 2, 2, 0, FpMat::zeros(8, 8), FpMat::zeros(8, 8)).unwrap(),
        ClientReply::Accepted { .. }
    ));
    assert_eq!(engine.provisioned(), 1);
    gateway.shutdown();
}

/// Acceptance (c): compatible concurrent submissions are observably
/// batched onto one shared deployment.
#[test]
fn concurrent_compatible_jobs_batch_onto_one_deployment() {
    let _serial = serial();
    let config = GatewayConfig {
        max_batch: 4,
        // Window far beyond test scale: only a *full* batch flushes, so
        // the four jobs provably ran as one batch.
        max_wait: Duration::from_secs(30),
        ..GatewayConfig::default()
    };
    let (gateway, engine, addr) = start_local(config);
    std::thread::scope(|scope| {
        for k in 0..4u64 {
            let addr = addr.clone();
            scope.spawn(move || {
                let (a, b) = job_matrices(77, k, 8);
                let mut client = GatewayClient::connect(&addr, 0).unwrap();
                let reply = client.call(k, 2, 2, 2, 0, a, b).unwrap();
                assert!(matches!(reply, ClientReply::Accepted { .. }));
            });
        }
    });
    assert_eq!(engine.provisioned(), 1, "compatible jobs split deployments");
    let stats = gateway.shutdown();
    assert_eq!(stats.accepted, 4);
    assert_eq!(stats.batches, 1, "expected one shared batch");
    assert_eq!(stats.batched_jobs, 4);
    assert_eq!(stats.max_batch(), 4);
    assert_eq!(stats.peak_queue_depth, 4);
    assert_eq!(stats.queue_depth, 0);
}

/// The multi-tenant load driver end to end: concurrent tenants, digests
/// byte-identical to direct computation of the same deterministic inputs.
#[test]
fn load_driver_digests_match_direct_computation() {
    let _serial = serial();
    let (gateway, _engine, addr) = start_local(GatewayConfig::default());
    let plan = LoadPlan {
        addr,
        tenants: vec![0, 1],
        jobs_per_tenant: 3,
        m: 8,
        s: 2,
        t: 2,
        z: 2,
        adv: 0,
        seed: 123,
        qps: None,
    };
    let report = run_load(&plan).unwrap();
    assert_eq!(report.outcomes.len(), 6);
    assert_eq!(report.accepted(), 6);
    for o in &report.outcomes {
        let (a, b) = job_matrices(plan.seed, o.job, plan.m);
        match &o.reply {
            ClientReply::Accepted { digest, y, .. } => {
                assert_eq!(*y, a.transpose().matmul(&b));
                assert_eq!(*digest, digest_mat(y));
            }
            ClientReply::Rejected { reason, detail, .. } => {
                panic!("job {} rejected: {reason} ({detail})", o.job)
            }
        }
    }
    gateway.shutdown();
}

/// Acceptance (d): the gateway serves ≥ 64 concurrent connections with a
/// fixed-size poller pool — the process thread count does not scale with
/// connections.
#[test]
fn many_connections_do_not_spawn_threads() {
    let _serial = serial();
    let (gateway, _engine, addr) = start_local(GatewayConfig::default());
    // Warm up: provision the deployment (and its worker threads) once.
    let (a, b) = job_matrices(9, 0, 8);
    let mut warm = GatewayClient::connect(&addr, 0).unwrap();
    assert!(matches!(
        warm.call(0, 2, 2, 2, 0, a, b).unwrap(),
        ClientReply::Accepted { .. }
    ));
    let baseline = os_thread_count();
    std::thread::scope(|scope| {
        for k in 0..64u64 {
            let addr = addr.clone();
            scope.spawn(move || {
                let (a, b) = job_matrices(9, k + 1, 8);
                let mut client = GatewayClient::connect(&addr, 0).unwrap();
                let reply = client.call(k + 1, 2, 2, 2, 0, a, b).unwrap();
                assert!(matches!(reply, ClientReply::Accepted { .. }));
            });
        }
    });
    if let (Some(before), Some(after)) = (baseline, os_thread_count()) {
        assert_eq!(
            before, after,
            "thread count scaled with connection count"
        );
    }
    let stats = gateway.shutdown();
    assert!(
        stats.connections >= 65,
        "expected ≥65 connections, saw {}",
        stats.connections
    );
    assert_eq!(stats.accepted, 65);
    assert_eq!(stats.completed, 65);
}

/// v0.8: a client `Shutdown` frame must carry the gateway's admin token.
/// A mismatch is a typed `Unauthorized` reject, the offending connection
/// is dropped (a guesser pays a reconnect per attempt, so the token
/// cannot be brute-forced down one socket), the gateway keeps serving —
/// and only the matching token stops intake.
#[test]
fn shutdown_requires_the_admin_token() {
    let _serial = serial();
    const TOKEN: u64 = 0xD00_57EA_1ED;
    let config = GatewayConfig {
        shutdown_token: Some(TOKEN),
        ..GatewayConfig::default()
    };
    let (gateway, _engine, addr) = start_local(config);

    // Wrong token: typed refusal, nothing stops.
    let mut intruder = GatewayClient::connect(&addr, 0).unwrap();
    intruder.request_shutdown(TOKEN ^ 1).unwrap();
    match intruder.recv().unwrap() {
        ClientReply::Rejected { reason, .. } => {
            assert_eq!(reason, RejectReason::Unauthorized)
        }
        other => panic!("unauthorized shutdown was honored: {other:?}"),
    }
    assert!(!gateway.stopping(), "wrong token stopped the gateway");
    // …the intruder's connection is dropped after the refusal (the next
    // round-trip on it fails)…
    let (a, b) = job_matrices(55, 0, 8);
    assert!(
        intruder.call(1, 2, 2, 2, 0, a.clone(), b.clone()).is_err(),
        "connection survived a refused shutdown attempt"
    );
    // …while the gateway itself still serves fresh connections.
    let mut honest = GatewayClient::connect(&addr, 0).unwrap();
    match honest.call(1, 2, 2, 2, 0, a.clone(), b.clone()).unwrap() {
        ClientReply::Accepted { y, .. } => assert_eq!(y, a.transpose().matmul(&b)),
        other => panic!("job after refused shutdown: {other:?}"),
    }

    // The matching token stops intake (observable via `stopping`).
    GatewayClient::connect(&addr, 0)
        .unwrap()
        .shutdown_gateway(TOKEN)
        .unwrap();
    gateway.wait();
    let stats = gateway.shutdown();
    assert_eq!(stats.completed, 1);
    assert_eq!(
        stats.rejected[RejectReason::Unauthorized.as_u8() as usize],
        1
    );
}

/// v0.8 teardown flush: results still queued when shutdown starts are
/// delivered to their clients before the connections drop — a batching
/// window far beyond test scale guarantees the jobs are *only* flushed by
/// the shutdown drain itself.
#[test]
fn shutdown_flushes_queued_results_to_clients() {
    let _serial = serial();
    const TOKEN: u64 = 7;
    let jobs = 8u64;
    let config = GatewayConfig {
        max_batch: 64,
        max_wait: Duration::from_secs(3600),
        shutdown_token: Some(TOKEN),
        ..GatewayConfig::default()
    };
    let (gateway, _engine, addr) = start_local(config);
    std::thread::scope(|scope| {
        for k in 0..jobs {
            let addr = addr.clone();
            scope.spawn(move || {
                let (a, b) = job_matrices(44, k, 8);
                let mut client = GatewayClient::connect(&addr, 0).unwrap();
                match client.call(k, 2, 2, 2, 0, a.clone(), b.clone()).unwrap() {
                    ClientReply::Accepted { digest, y, .. } => {
                        assert_eq!(y, a.transpose().matmul(&b), "job {k}");
                        assert_eq!(digest, digest_mat(&y), "job {k}");
                    }
                    ClientReply::Rejected { reason, detail, .. } => {
                        panic!("queued job {k} lost in teardown: {reason} ({detail})")
                    }
                }
            });
        }
        // Wait until every job is admitted and parked in the batch queue
        // (the hour-long window cannot flush them), then pull the plug:
        // the clean-shutdown drain must execute and deliver all of them.
        let t0 = std::time::Instant::now();
        while gateway.stats().accepted < jobs {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "jobs not admitted: {:?}",
                gateway.stats()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        GatewayClient::connect(&addr, 0)
            .unwrap()
            .shutdown_gateway(TOKEN)
            .unwrap();
    });
    let stats = gateway.shutdown();
    assert_eq!(stats.accepted, jobs);
    assert_eq!(stats.completed, jobs, "queued results were dropped in teardown");
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.queue_depth, 0, "jobs left behind in the batch queues");
}
