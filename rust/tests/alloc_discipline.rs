//! Steady-state allocation discipline for the compute kernels **and the
//! fabric payload path**.
//!
//! A counting global allocator wraps `System`; after one warmup pass grows
//! every caller-owned buffer to its steady-state capacity, repeat
//! invocations of the in-place GF(p) kernels must perform **zero** heap
//! allocations — the contract `Deployment::execute` relies on for its
//! per-job compute loops. Since the persistent-runtime refactor the fabric
//! payloads are covered too: `FpMat` message buffers are loaned from the
//! shared `BufferPool` and returned on drop, so a warm loan→fill→return
//! cycle (including reshapes within capacity) is also pinned at zero
//! allocations. The only remaining per-message heap activity is the mpsc
//! channel's internal block storage, which amortizes and is runtime
//! plumbing, not payload.
//!
//! Kept to a single `#[test]` so no concurrent test can allocate inside
//! the measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cmpc::ff;
use cmpc::matrix::FpMat;
use cmpc::mpc::network::BufferPool;
use cmpc::mpc::source;
use cmpc::poly::MatPoly;
use cmpc::runtime::pool::{Scratch, ScratchPool};
use cmpc::util::rng::ChaChaRng;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_kernels_do_not_allocate() {
    let mut rng = ChaChaRng::seed_from_u64(0xA110C);
    let (m, k, n) = (24usize, 16usize, 20usize);
    let a = FpMat::random(&mut rng, m, k);
    let b = FpMat::random(&mut rng, k, n);
    let c = FpMat::random(&mut rng, m, k);

    // Share-polynomial evaluation fixture (the Phase-1 encode kernel).
    let scheme = cmpc::codes::AgeCmpc::new(2, 2, 2, 1);
    let sq = FpMat::random(&mut rng, 8, 8);
    let fa = source::build_f_a(&scheme, &sq, &mut rng);

    // Caller-owned buffers, grown once below.
    let mut out = FpMat::zeros(m, n);
    let mut acc: Vec<u64> = Vec::new();
    let mut tout = FpMat::zeros(k, m);
    let mut sum = FpMat::zeros(m, k);
    let mut scaled = FpMat::zeros(m, k);
    let mut eval_out = FpMat::zeros(1, 1);
    let mut scratch = Scratch::default();
    let mut ws_out = vec![0u32; k];
    let xs: Vec<u32> = (0..k).map(|_| rng.field_element() as u32).collect();
    let terms: Vec<(u64, &[u32])> = vec![(3, xs.as_slice()), (5, xs.as_slice())];
    let mut ws_acc: Vec<u64> = Vec::new();

    let run_all = |out: &mut FpMat,
                   acc: &mut Vec<u64>,
                   tout: &mut FpMat,
                   sum: &mut FpMat,
                   scaled: &mut FpMat,
                   eval_out: &mut FpMat,
                   scratch: &mut Scratch,
                   ws_out: &mut [u32],
                   ws_acc: &mut Vec<u64>| {
        a.matmul_into(&b, out, acc);
        a.transpose_into(tout);
        sum.add_assign(&c);
        sum.axpy_inplace(7, &c);
        a.scale_into(12345, scaled);
        fa.eval_into(9, eval_out, scratch);
        ff::weighted_sum_with_scratch(ws_out, &terms, ws_acc);
    };

    // Warmup: grows every buffer to steady-state capacity.
    run_all(
        &mut out, &mut acc, &mut tout, &mut sum, &mut scaled, &mut eval_out, &mut scratch,
        &mut ws_out, &mut ws_acc,
    );

    let before = allocs();
    for _ in 0..10 {
        run_all(
            &mut out, &mut acc, &mut tout, &mut sum, &mut scaled, &mut eval_out, &mut scratch,
            &mut ws_out, &mut ws_acc,
        );
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "steady-state kernel loop performed {delta} heap allocations"
    );

    // --- sharded ScratchPool checkout: warm `with()` borrows (home slot
    // and wrap-around probes alike) must stay allocation-free — the
    // cache-line-padded slots carry grown capacity between jobs, which is
    // the no-regression contract of the PR-8 sharding. ---
    let spool = ScratchPool::new(4);
    for wid in 0..4 {
        spool.with(wid, |s| fa.eval_into(9 + wid as u64, &mut eval_out, s));
    }
    let before = allocs();
    for round in 0..10u64 {
        for wid in 0..8 {
            // wids beyond the slot count exercise the wrapping index path.
            spool.with(wid, |s| {
                fa.eval_into(9 + ((round + wid as u64) % 4), &mut eval_out, s)
            });
        }
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "warm ScratchPool checkout cycle performed {delta} heap allocations"
    );

    // --- fabric payload buffers: loan → fill → return, zero allocations ---
    // Warmup: grow the pool's working set to the largest payload shape a
    // job uses (two share buffers + one G buffer in flight at once), and
    // grow the free-list Vec itself.
    // Three buffers at the largest in-flight shape: every later loan
    // reshapes within capacity no matter which recycled buffer it pops.
    let pool = BufferPool::new();
    {
        let _fa = BufferPool::loan(&pool, m, n);
        let _fb = BufferPool::loan(&pool, m, n);
        let _g = BufferPool::loan(&pool, m, n);
    }
    let before = allocs();
    for _ in 0..10 {
        // Same shapes as the warm set, plus a smaller reshape — both must
        // reuse recycled buffers without touching the heap.
        let mut fa = BufferPool::loan(&pool, m, k);
        let mut fb = BufferPool::loan(&pool, k, n);
        fa.fill_random(&mut rng);
        fb.fill_random(&mut rng);
        let mut g = BufferPool::loan(&pool, m / 2, n / 2);
        g.fill_random(&mut rng);
        drop(g);
        drop(fa);
        drop(fb);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "warm BufferPool loan/return cycle performed {delta} heap allocations"
    );

    // --- BufferPool high-water trim on the serving path: a huge-m job
    // followed by small-m jobs must release the peak buffers (RSS-creep
    // guard). Runs after the zero-alloc windows above — provisioning a
    // deployment allocates freely. ---
    use cmpc::codes::SchemeParams;
    use cmpc::mpc::protocol::ProtocolConfig;
    use cmpc::{Deployment, SchemeSpec};
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        SchemeParams::new(2, 2, 2),
        ProtocolConfig::builder().threads(1).build(),
    )
    .unwrap();
    let big_a = FpMat::random(&mut rng, 64, 64);
    let big_b = FpMat::random(&mut rng, 64, 64);
    assert!(dep.execute_seeded(&big_a, &big_b, 1).unwrap().verified);
    let after_big = dep.runtime().buffers().free_capacity_scalars();
    let small_a = FpMat::random(&mut rng, 8, 8);
    let small_b = FpMat::random(&mut rng, 8, 8);
    // The big job's own finish-trim sees its huge loans as recent demand
    // and keeps everything; once small jobs re-baseline demand, the
    // runtime's end-of-job trims release the m=64-sized buffers.
    for seed in 2..6 {
        assert!(dep.execute_seeded(&small_a, &small_b, seed).unwrap().verified);
    }
    let after_small = dep.runtime().buffers().free_capacity_scalars();
    assert!(
        after_small < after_big / 4,
        "trim kept {after_small} of {after_big} scalars after demand collapsed"
    );
}
