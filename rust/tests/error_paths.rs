//! Error-path coverage for the fallible serving API: every malformed input
//! class the acceptance criteria name must surface as a typed [`CmpcError`]
//! from the public surface — never a panic.

use std::sync::Arc;
use std::time::Duration;

use cmpc::codes::{AgeCmpc, CmpcScheme, PolyDotCmpc, SchemeParams};
use cmpc::coordinator::{Coordinator, CoordinatorConfig};
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, FaultAction, FaultRule};
use cmpc::mpc::master::run_master;
use cmpc::mpc::network::{Fabric, JobRouter};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::poly::interp::{choose_alphas, try_evaluation_points};
use cmpc::runtime::pool::{ScratchPool, WorkerPool};
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Deployment, SchemeSpec};

#[test]
fn zero_parameters_rejected_as_invalid_params() {
    for (s, t, z) in [(0usize, 2usize, 1usize), (2, 0, 1), (2, 2, 0), (0, 0, 0)] {
        let err = SchemeParams::try_new(s, t, z).unwrap_err();
        assert!(
            matches!(err, CmpcError::InvalidParams(_)),
            "(s={s}, t={t}, z={z}) → {err}"
        );
    }
    // the same guard protects every registry family
    for spec in SchemeSpec::CONSTRUCTIBLE {
        let err = spec
            .resolve(SchemeParams {
                s: 2,
                t: 2,
                z: 0,
                adversary_tolerance: 0,
            })
            .unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)), "{spec:?}");
    }
}

#[test]
fn scheme_constructors_reject_bad_input_without_panicking() {
    assert!(matches!(
        AgeCmpc::try_new(2, 2, 2, 3), // λ > z
        Err(CmpcError::InvalidParams(_))
    ));
    assert!(matches!(
        AgeCmpc::try_with_optimal_lambda(2, 2, 0),
        Err(CmpcError::InvalidParams(_))
    ));
    assert!(matches!(
        PolyDotCmpc::try_new(0, 1, 1),
        Err(CmpcError::InvalidParams(_))
    ));
}

#[test]
fn deployment_rejects_malformed_matrices() {
    let params = SchemeParams::try_new(2, 2, 1).unwrap();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::default(),
    )
    .unwrap();
    let mut rng = ChaChaRng::seed_from_u64(1);

    // non-square
    let rect = FpMat::random(&mut rng, 8, 6);
    let sq = FpMat::random(&mut rng, 8, 8);
    assert!(matches!(
        dep.execute(&rect, &sq),
        Err(CmpcError::ShapeMismatch(_))
    ));

    // mismatched sizes
    let small = FpMat::random(&mut rng, 4, 4);
    assert!(matches!(
        dep.execute(&sq, &small),
        Err(CmpcError::ShapeMismatch(_))
    ));

    // partition does not divide m (s=t=2, m=7)
    let odd = FpMat::random(&mut rng, 7, 7);
    let odd2 = FpMat::random(&mut rng, 7, 7);
    assert!(matches!(
        dep.execute(&odd, &odd2),
        Err(CmpcError::ShapeMismatch(_))
    ));

    // the deployment survives every rejection
    let b = FpMat::random(&mut rng, 8, 8);
    assert!(dep.execute(&sq, &b).unwrap().verified);
}

#[test]
fn worker_delay_vector_must_match_deployment_size() {
    let params = SchemeParams::try_new(2, 2, 2).unwrap();
    let cfg = ProtocolConfig::builder()
        .worker_delays(vec![Duration::ZERO; 3]) // deployment has N = 17
        .build();
    let dep =
        Deployment::provision(SchemeSpec::Age { lambda: None }, params, cfg).unwrap();
    let mut rng = ChaChaRng::seed_from_u64(2);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let err = dep.execute(&a, &b).unwrap_err();
    assert!(matches!(err, CmpcError::InvalidParams(_)), "{err}");
}

#[test]
fn alpha_space_exhaustion_is_typed() {
    // GF(65537) cannot supply 70000 distinct nonzero evaluation points.
    assert!(matches!(
        try_evaluation_points(70_000, 0),
        Err(CmpcError::InvalidParams(_))
    ));
    let support: Vec<u64> = (0..70_000u64).collect();
    let err = choose_alphas(70_000, &support).unwrap_err();
    assert!(matches!(err, CmpcError::InvalidParams(_)), "{err}");
    assert!(err.to_string().contains('α'), "{err}");

    // n ≠ |support| is caught before any solve
    let err = choose_alphas(3, &[0, 1]).unwrap_err();
    assert!(matches!(err, CmpcError::InvalidParams(_)));
}

#[test]
fn master_reports_insufficient_workers() {
    // 2 provisioned workers cannot meet the t²+z = 6 reconstruction quota.
    let (fabric, mut endpoints) = Fabric::new(2, None);
    let router = JobRouter::new(endpoints.remove(2)); // node id 2 = master
    let alphas = Arc::new(vec![1u64, 2]);
    let pool = WorkerPool::new(1);
    let scratch = ScratchPool::for_pool(&pool);
    let err = run_master(
        &router,
        &fabric,
        0,
        &alphas,
        2,
        2,
        2,
        0,
        Duration::from_millis(100),
        false,
        &[],
        &pool,
        &scratch,
    )
    .unwrap_err();
    assert_eq!(
        err,
        CmpcError::InsufficientWorkers {
            needed: 6,
            provisioned: 2
        }
    );
}

#[test]
fn dead_worker_surfaces_recv_timeout_not_deadlock() {
    // A worker thread that dies mid-job means its I-share never arrives;
    // the master must surface a typed Fabric error within the configured
    // receive window instead of blocking forever.
    let (fabric, mut endpoints) = Fabric::new(1, None);
    let router = JobRouter::new(endpoints.remove(1)); // node id 1 = master
    router.open(0);
    let alphas = Arc::new(vec![1u64]);
    let pool = WorkerPool::new(1);
    let scratch = ScratchPool::for_pool(&pool);
    let t0 = std::time::Instant::now();
    let err = run_master(
        &router,
        &fabric,
        0,
        &alphas,
        1,
        1,
        0,
        0,
        Duration::from_millis(20),
        false,
        &[],
        &pool,
        &scratch,
    )
    .unwrap_err();
    assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(5), "did not time out promptly");
}

#[test]
fn per_job_deadline_spares_healthy_concurrent_job() {
    // One peer is made mute *for one job only* (every envelope to worker 0
    // tagged job 0 is dropped by the chaos plan — the "dead peer from this
    // job's perspective" model). The victim job must fail with a typed
    // per-job deadline error; a healthy job running concurrently on the
    // same deployment — and therefore on the same starved workers — must
    // complete byte-identically to its solo run.
    let params = SchemeParams::try_new(2, 2, 2).unwrap();
    let seed_healthy = 0xFEED;

    // Solo reference for the healthy job on a fault-free deployment.
    let mut rng = ChaChaRng::seed_from_u64(4);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let solo = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::default(),
    )
    .unwrap();
    let solo_out = solo.execute_seeded(&a, &b, seed_healthy).unwrap();
    drop(solo);

    let plan = ChaosPlan::new()
        .rule(FaultRule::new(FaultAction::Drop).to_node(0).job(0))
        .into_shared();
    let cfg = ProtocolConfig::builder()
        .recv_timeout(Duration::from_millis(400))
        .chaos(plan)
        .build();
    let dep = Deployment::provision(SchemeSpec::Age { lambda: None }, params, cfg).unwrap();

    let (victim_res, healthy_out) = std::thread::scope(|s| {
        // The victim claims JobId 0 (first begin_job on this runtime);
        // the chaos rule targets exactly that job.
        let victim = s.spawn(|| dep.execute_seeded(&a, &b, 0xBAD));
        // Give the victim a comfortable head start on claiming job 0.
        std::thread::sleep(Duration::from_millis(100));
        let healthy = dep.execute_seeded(&a, &b, seed_healthy).unwrap();
        (victim.join().unwrap(), healthy)
    });

    // Victim: workers 1..N starve on worker 0's G-share for job 0 and fail
    // it on their per-job deadline; the driver surfaces a typed error.
    let err = victim_res.unwrap_err();
    assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    assert!(err.to_string().contains("deadline"), "{err}");

    // Healthy sibling: unaffected, byte-identical to its solo run.
    assert!(healthy_out.verified);
    assert_eq!(healthy_out.y, solo_out.y, "healthy job output diverged");
    assert_eq!(
        healthy_out.traffic.worker_to_worker,
        solo_out.traffic.worker_to_worker
    );
    for (wc, solo_wc) in healthy_out
        .worker_counters
        .iter()
        .zip(solo_out.worker_counters.iter())
    {
        assert_eq!(wc.mults(), solo_wc.mults());
        assert_eq!(wc.stored(), solo_wc.stored());
    }
    assert!(dep.health().deadline_misses >= 1);

    // The deployment keeps serving after the victim's failure (and no
    // worker was evicted — starving on one job is not thread death).
    let again = dep.execute_seeded(&a, &b, 7).unwrap();
    assert!(again.verified);
    assert_eq!(dep.health().evictions, 0);
}

#[test]
fn coordinator_reports_backend_failure_per_job() {
    // "/dev/null" as a directory component makes the artifact manifest
    // unreadable: deployment provisioning fails, the report carries the
    // typed error, and the drain still completes.
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .backend(cmpc::runtime::BackendChoice::Pjrt {
                artifacts_dir: std::path::PathBuf::from("/dev/null"),
            })
            .build(),
    );
    let mut rng = ChaChaRng::seed_from_u64(3);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    coord.submit(a, b, 2, 2, 1).unwrap();
    let reports = coord.drain();
    assert_eq!(reports.len(), 1);
    assert!(reports[0].outcome.is_err());
}

#[test]
fn custom_scheme_with_missing_important_power_is_not_decodable() {
    // A scheme whose important powers point outside its reconstruction
    // support must fail provisioning with NotDecodable, not panic.
    struct Sabotaged(AgeCmpc);
    impl CmpcScheme for Sabotaged {
        fn name(&self) -> String {
            "sabotaged".into()
        }
        fn params(&self) -> SchemeParams {
            self.0.params()
        }
        fn coded_power_a(&self, i: usize, j: usize) -> u64 {
            self.0.coded_power_a(i, j)
        }
        fn coded_power_b(&self, k: usize, l: usize) -> u64 {
            self.0.coded_power_b(k, l)
        }
        fn secret_powers_a(&self) -> Vec<u64> {
            self.0.secret_powers_a()
        }
        fn secret_powers_b(&self) -> Vec<u64> {
            self.0.secret_powers_b()
        }
        fn important_power(&self, i: usize, l: usize) -> u64 {
            self.0.important_power(i, l) + 1_000 // far outside P(H)
        }
    }
    let scheme = Sabotaged(AgeCmpc::with_optimal_lambda(2, 2, 2));
    let err =
        Deployment::for_scheme(Arc::new(scheme), ProtocolConfig::default()).unwrap_err();
    assert!(matches!(err, CmpcError::NotDecodable(_)), "{err}");
}
