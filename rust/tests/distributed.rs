//! Distributed-transport acceptance: a full multi-node topology — every
//! party its own thread, every link a real 127.0.0.1 TCP socket, every
//! envelope through the framed wire codec — must decode `Y` byte-identical
//! to the in-process fabric, for every constructible scheme, with the
//! measured on-wire bytes matching the analytical ζ within the framing
//! overhead budget (<5%). Plus one run under WAN link shaping, one
//! under a chaos kill with early decode, and one where a worker's
//! I-share is garbled on the wire and the Byzantine decoder must
//! locate and blame it.
//!
//! Kept to a single `#[test]` so the socket/thread churn of one scenario
//! cannot interfere with another's timings.

use std::time::Duration;

use cmpc::analysis;
use cmpc::codes::SchemeParams;
use cmpc::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::manifest::{ShapeLine, TopologyManifest};
use cmpc::transport::node::{self, run_local_cluster};
use cmpc::{Deployment, SchemeSpec};

#[test]
fn tcp_loopback_matches_the_in_process_fabric() {
    let (s, t, z) = (2usize, 2usize, 2usize);
    let m = 32usize; // (m/t)² = 256-scalar G blocks → ~3% framing overhead
    let seed = 0xD157u64;
    let jobs = 2usize;

    // ---- 1. Every scheme: multi-node loopback ≡ in-process, and wire
    // bytes ≡ ζ within the framing budget. ----
    for scheme in ["age", "polydot", "entangled"] {
        let mut manifest =
            TopologyManifest::template(scheme, s, t, z, m, seed, jobs, "127.0.0.1", 0).unwrap();
        manifest.recv_timeout = Duration::from_secs(20);

        // In-process reference with the same per-job seeds and data.
        let dep = Deployment::provision(
            manifest.spec().unwrap(),
            SchemeParams::new(s, t, z),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap();
        let mut refs = Vec::new();
        for k in 0..jobs {
            let (a, b) = node::job_matrices(seed, k as u64, m);
            let out = dep
                .execute_seeded(&a, &b, node::job_secret_seed(seed, k as u64))
                .unwrap();
            assert!(out.verified, "{scheme} reference job {k}");
            refs.push(out);
        }
        drop(dep);

        let report = run_local_cluster(&manifest, None).unwrap();
        assert_eq!(report.master.jobs.len(), jobs, "{scheme}");
        for (k, job) in report.master.jobs.iter().enumerate() {
            assert!(job.verified, "{scheme} job {k}");
            assert!(!job.early_decoded, "{scheme} job {k}: full drain expected");
            assert_eq!(
                job.y, refs[k].y,
                "{scheme} job {k}: distributed Y diverged from the in-process fabric"
            );
            assert_eq!(job.digest, node::digest_mat(&refs[k].y), "{scheme} job {k}");
            // The remote counter plumbing (totals riding JobDone) must
            // reproduce the in-process ξ/σ exactly, per worker.
            for (wid, (remote, local)) in job
                .worker_counters
                .iter()
                .zip(refs[k].worker_counters.iter())
                .enumerate()
            {
                assert_eq!(
                    remote.mults(),
                    local.mults(),
                    "{scheme} job {k}: ξ mismatch at worker {wid}"
                );
                assert_eq!(
                    remote.stored(),
                    local.stored(),
                    "{scheme} job {k}: σ mismatch at worker {wid}"
                );
            }
        }
        // Measured on-wire worker↔worker bytes vs the analytical ζ
        // (eq. 34, scalars × 4 bytes): transmitted, not just counted.
        let n = manifest.n_workers() as u64;
        let zeta_bytes = analysis::communication_overhead(m, t, n) as u64 * 4 * jobs as u64;
        let w2w = report.wire.bytes_worker_to_worker;
        assert!(
            w2w >= zeta_bytes,
            "{scheme}: wire carried fewer bytes than ζ ({w2w} < {zeta_bytes})"
        );
        let overhead_pct = (w2w - zeta_bytes) as f64 * 100.0 / zeta_bytes as f64;
        assert!(
            overhead_pct < 5.0,
            "{scheme}: framing overhead {overhead_pct:.2}% breaches the 5% budget"
        );
        assert_eq!(report.wire.decode_errors, 0, "{scheme}: corrupt frames on loopback");
        // Give the previous cluster's detached reader threads a beat to
        // observe EOF and release their sockets before the next bind wave.
        std::thread::sleep(Duration::from_millis(50));
    }

    // ---- 2. WAN shaping: all data links get in-flight latency + a token
    // bucket; the decode is byte-identical, just later. ----
    let m_small = 16usize;
    let (a, b) = node::job_matrices(seed, 0, m_small);
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        SchemeParams::new(s, t, z),
        ProtocolConfig::builder().threads(1).build(),
    )
    .unwrap();
    let want = dep
        .execute_seeded(&a, &b, node::job_secret_seed(seed, 0))
        .unwrap()
        .y;
    drop(dep);
    let mut manifest =
        TopologyManifest::template("age", s, t, z, m_small, seed, 1, "127.0.0.1", 0).unwrap();
    manifest.recv_timeout = Duration::from_secs(20);
    manifest.shapes.push(ShapeLine {
        from: None,
        to: None,
        latency_us: 5_000,        // 5 ms per hop
        rate_bps: 80_000_000,     // 10 MB/s
        burst_bytes: 8 * 1024,
        class: None,
    });
    let report = run_local_cluster(&manifest, None).unwrap();
    let job = &report.master.jobs[0];
    assert!(job.verified);
    assert_eq!(job.y, want, "WAN-shaped cluster diverged from the reference");
    assert!(
        job.elapsed >= Duration::from_millis(10),
        "WAN shaping had no measurable effect ({:?})",
        job.elapsed
    );

    // ---- 3. Chaos kill + early decode over real sockets: z workers die
    // after their exchange; the master still decodes the identical Y at
    // the quota and aborts the tail. ----
    let mut manifest =
        TopologyManifest::template("age", s, t, z, m_small, seed, 1, "127.0.0.1", 0).unwrap();
    manifest.early_decode = true;
    manifest.recv_timeout = Duration::from_secs(3);
    let n = manifest.n_workers();
    let plan = ChaosPlan::kill_k_workers_after_exchange(0xC1A0, n, z).into_shared();
    let report = run_local_cluster(&manifest, Some(plan)).unwrap();
    let job = &report.master.jobs[0];
    assert!(job.verified);
    assert!(job.early_decoded, "kill scenario should take the fast path");
    assert_eq!(job.y, want, "early-decoded distributed Y diverged");

    // ---- 4. Byzantine garble over real sockets: worker `victim`'s
    // I-share is corrupted in flight on the w2m edge; at
    // `adversary_tolerance 1` the master must locate the bad share,
    // decode the identical Y from the survivors, and blame the right
    // worker index in its job report. Honest workers' I-shares are
    // link-shaped +150 ms so the garbled share deterministically lands
    // inside the raised t²+z+2a quota window. ----
    let mut manifest =
        TopologyManifest::template("age", s, t, z, m_small, seed, 1, "127.0.0.1", 0).unwrap();
    manifest.adversary_tolerance = 1;
    manifest.recv_timeout = Duration::from_secs(20);
    let n = manifest.n_workers();
    let victim = 3usize;
    for w in (0..n).filter(|&w| w != victim) {
        manifest.shapes.push(ShapeLine {
            from: Some(w),
            to: None,
            latency_us: 150_000,
            rate_bps: 0, // unlimited — latency only
            burst_bytes: 0,
            class: Some(PayloadClass::IShare),
        });
    }
    let garble = ChaosPlan::new()
        .rule(
            FaultRule::new(FaultAction::Garble)
                .from_node(victim)
                .class(PayloadClass::IShare)
                .limit(1),
        )
        .into_shared();
    let report = run_local_cluster(&manifest, Some(garble)).unwrap();
    let job = &report.master.jobs[0];
    assert!(job.verified, "garbled cluster failed to decode");
    assert_eq!(
        job.y, want,
        "Byzantine-decoded distributed Y diverged from the in-process fabric"
    );
    assert_eq!(job.digest, node::digest_mat(&want));
    assert_eq!(
        job.blamed_workers,
        vec![victim],
        "master blamed the wrong worker for the garbled I-share"
    );
    // The in-process reference run of the same manifest (tolerance
    // included) must agree digest-for-digest with the garbled cluster.
    let refs = node::run_reference(&manifest).unwrap();
    assert_eq!(refs.len(), 1);
    assert_eq!(job.digest, refs[0].1, "reference digest diverged");
}
