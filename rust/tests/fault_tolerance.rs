//! Fault-tolerance acceptance suite: chaos-killed workers, the early-decode
//! fast path, worker eviction/respawn, straggler-tail cancellation, and
//! corruption detection — for every constructible scheme.
//!
//! Kept to a single `#[test]` so the OS thread-count measurements cannot be
//! perturbed by sibling tests provisioning runtimes in the same process.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpc::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc, SchemeParams};
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Deployment, SchemeSpec};

/// Threads of this process per the kernel (Linux); `None` elsewhere.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Drive the reaper until `want` respawns happened (worker threads exit
/// asynchronously after a chaos kill, so poll briefly).
fn wait_for_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    loop {
        dep.runtime().reap();
        if dep.health().respawns >= want {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "respawns stuck at {} (want {want}); evictions: {:?}",
            dep.health().respawns,
            dep.runtime().evictions()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn chaos_killed_workers_early_decode_and_respawn() {
    let params = SchemeParams::new(2, 2, 2); // t²+z = 6, z = 2
    let m = 8;
    let mut rng = ChaChaRng::seed_from_u64(0xFA17);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let y_expect = a.transpose().matmul(&b);

    // ---- 1. z workers killed mid-Phase-2, every scheme: the early-decode
    // path still yields the byte-identical product, the dead threads are
    // evicted and respawned, and the next job runs on a full complement. ----
    let schemes: Vec<Arc<dyn CmpcScheme>> = vec![
        Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2)),
        Arc::new(PolyDotCmpc::new(2, 2, 2)),
        Arc::new(EntangledCmpc::new(2, 2, 2)),
    ];
    for (idx, scheme) in schemes.into_iter().enumerate() {
        let n = scheme.n_workers();
        let z = scheme.params().z;
        let name = scheme.name();

        // Fault-free reference (default full-drain path).
        let reference = Deployment::for_scheme(
            scheme.clone(),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap();
        let y_ref = {
            let out = reference.execute_seeded(&a, &b, 0x5EED).unwrap();
            assert!(out.verified, "{name}: reference run");
            assert!(!out.early_decoded);
            assert_eq!(out.y, y_expect, "{name}: reference product");
            out.y
        };
        drop(reference);

        // Chaos run: deterministic seed-driven kills after the G-exchange.
        let plan = ChaosPlan::kill_k_workers_after_exchange(0xC0FFEE + idx as u64, n, z);
        let dep = Deployment::for_scheme(
            scheme,
            ProtocolConfig::builder()
                .threads(1)
                .early_decode(true)
                .recv_timeout(Duration::from_secs(10))
                .chaos(plan.into_shared())
                .build(),
        )
        .unwrap();
        let baseline_threads = os_thread_count();

        let out = dep.execute_seeded(&a, &b, 0x5EED).unwrap_or_else(|e| {
            panic!("{name}: job with {z} killed workers should early-decode: {e}")
        });
        assert!(out.verified, "{name}");
        assert!(out.early_decoded, "{name}: fast path not taken");
        assert_eq!(out.y, y_ref, "{name}: decode diverged from fault-free run");
        assert_eq!(out.stragglers_tolerated, n - 6, "{name}");

        // The kill victims died during their compute phase; evict + respawn.
        wait_for_respawns(&dep, z as u64);
        let health = dep.health();
        assert_eq!(health.evictions, z as u64, "{name}");
        assert_eq!(health.respawns, z as u64, "{name}");
        assert!(health.early_decodes >= 1, "{name}");
        assert_eq!(dep.runtime().evictions().len(), z, "{name}");
        assert_eq!(dep.worker_threads(), n, "{name}");
        if let (Some(before), Some(after)) = (baseline_threads, os_thread_count()) {
            assert_eq!(
                after, before,
                "{name}: thread count not flat after respawn"
            );
        }

        // The job following the faults runs on the respawned complement and
        // is byte-identical (kill rules are exhausted).
        let next = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
        assert!(next.verified, "{name}: post-respawn job");
        assert_eq!(next.y, y_ref, "{name}: post-respawn decode diverged");
        assert_eq!(dep.health().evictions, z as u64, "{name}: extra evictions");
        drop(dep);
    }

    // ---- 2. Straggler tail: early decode turns tail latency into a
    // measured win. Two workers sit behind slow *links*: every inbound
    // G-share into them is shaped +300 ms in flight (their own compute
    // and outbound shares are on time, so everyone else finishes fast).
    // The full-drain job must wait for the victims' late I-shares; the
    // early-decode job aborts them while they idle-wait — they ack
    // instantly, so the job returns early AND with exact counters. ----
    let delay = Duration::from_millis(300);
    let straggler_shaper = || {
        let mut shaper = LinkShaper::new();
        for victim in [2usize, 9] {
            shaper = shaper.rule(
                ShapeRule::new(LinkSpec::latency(delay))
                    .to_node(victim)
                    .class(PayloadClass::GShare),
            );
        }
        shaper.into_shared()
    };
    let dep_full = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .shaper(straggler_shaper())
            .build(),
    )
    .unwrap();
    let t0 = Instant::now();
    let out_full = dep_full.execute_seeded(&a, &b, 0x5EED).unwrap();
    let full_elapsed = t0.elapsed();
    assert!(out_full.verified && !out_full.early_decoded);
    assert!(
        full_elapsed >= delay,
        "full drain returned in {full_elapsed:?} despite a {delay:?} slow-link straggler"
    );
    drop(dep_full);
    let dep_early = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .early_decode(true)
            .shaper(straggler_shaper())
            .build(),
    )
    .unwrap();
    let t0 = Instant::now();
    let out_early = dep_early.execute_seeded(&a, &b, 0x5EED).unwrap();
    let early_elapsed = t0.elapsed();
    assert!(out_early.verified && out_early.early_decoded);
    assert_eq!(out_early.y, out_full.y);
    assert!(
        early_elapsed < full_elapsed,
        "early decode ({early_elapsed:?}) did not beat the full drain ({full_elapsed:?})"
    );
    // Exactness on the fast path (the JobAbort-ack contract): the victims
    // acked the abort after tombstoning the job, so even when their shaped
    // G-shares finally arrive, not one counter may move.
    let snap: Vec<(u64, u64)> = out_early
        .worker_counters
        .iter()
        .map(|c| (c.mults(), c.stored()))
        .collect();
    std::thread::sleep(delay + Duration::from_millis(100));
    let after: Vec<(u64, u64)> = out_early
        .worker_counters
        .iter()
        .map(|c| (c.mults(), c.stored()))
        .collect();
    assert_eq!(
        snap, after,
        "early-decoded counters ticked after the job returned"
    );
    drop(dep_early);

    // ---- 3. Garbled share: corruption in flight is detected, typed, and
    // non-poisonous (the rule is one-shot; the next job is clean). ----
    let garble_plan = ChaosPlan::new()
        .rule(
            FaultRule::new(FaultAction::Garble)
                .to_node(2)
                .class(PayloadClass::Shares)
                .limit(1),
        )
        .into_shared();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().threads(1).chaos(garble_plan).build(),
    )
    .unwrap();
    let err = dep.execute_seeded(&a, &b, 0x5EED).unwrap_err();
    assert!(matches!(err, CmpcError::NotDecodable(_)), "{err}");
    let clean = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
    assert!(clean.verified);
    assert_eq!(clean.y, y_expect);
    drop(dep);

    // ---- 4. Deadline-miss self-eviction: worker 5's *inbound* G-shares
    // for job 0 are dropped, so it alone starves mid-exchange, misses its
    // per-job deadline (limit 1), reports a typed JobError, and
    // self-evicts — strictly before the driver's abort can reach it, since
    // self-eviction happens in the same timeout round that sends the
    // JobError the driver reacts to. The reaper replaces it and the
    // deployment serves clean jobs again. ----
    let starve_plan = ChaosPlan::new()
        .rule(
            FaultRule::new(FaultAction::Drop)
                .to_node(5)
                .class(PayloadClass::GShare)
                .job(0),
        )
        .into_shared();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .recv_timeout(Duration::from_millis(200))
            .max_deadline_misses(1)
            .chaos(starve_plan)
            .build(),
    )
    .unwrap();
    let n = dep.n_workers();
    let baseline_threads = os_thread_count();
    let err = dep.execute_seeded(&a, &b, 0xDEAD).unwrap_err();
    assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    assert!(err.to_string().contains("deadline"), "{err}");
    wait_for_respawns(&dep, 1);
    let evictions = dep.runtime().evictions();
    assert_eq!(evictions.len(), 1);
    assert_eq!(evictions[0].worker, 5);
    assert!(
        evictions[0].reason.contains("self-evicted"),
        "{}",
        evictions[0].reason
    );
    assert_eq!(dep.health().deadline_misses, 1);
    assert!(dep.health().jobs_aborted >= 1);
    assert_eq!(dep.worker_threads(), n);
    if let (Some(before), Some(after)) = (baseline_threads, os_thread_count()) {
        assert_eq!(after, before, "thread count not flat after self-eviction respawn");
    }
    let clean = dep.execute_seeded(&a, &b, 0xF00D).unwrap();
    assert!(clean.verified);
    assert_eq!(clean.y, y_expect);
}
