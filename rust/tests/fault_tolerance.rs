//! Fault-tolerance acceptance suite: chaos-killed workers, the early-decode
//! fast path, worker eviction/respawn, straggler-tail cancellation,
//! corruption detection, and Byzantine error location (garbled shares are
//! *located*, excluded, blamed, and evicted) — for every constructible
//! scheme.
//!
//! Kept to a single `#[test]` so the OS thread-count measurements cannot be
//! perturbed by sibling tests provisioning runtimes in the same process.

use std::sync::Arc;
use std::time::{Duration, Instant};

use cmpc::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc, SchemeParams};
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Deployment, SchemeSpec};

/// Threads of this process per the kernel (Linux); `None` elsewhere.
fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find(|l| l.starts_with("Threads:"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
}

/// Drive the reaper until `want` respawns happened (worker threads exit
/// asynchronously after a chaos kill, so poll briefly).
fn wait_for_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    loop {
        dep.runtime().reap();
        if dep.health().respawns >= want {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "respawns stuck at {} (want {want}); evictions: {:?}",
            dep.health().respawns,
            dep.runtime().evictions()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn chaos_killed_workers_early_decode_and_respawn() {
    let params = SchemeParams::new(2, 2, 2); // t²+z = 6, z = 2
    let m = 8;
    let mut rng = ChaChaRng::seed_from_u64(0xFA17);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let y_expect = a.transpose().matmul(&b);

    // ---- 1. z workers killed mid-Phase-2, every scheme: the early-decode
    // path still yields the byte-identical product, the dead threads are
    // evicted and respawned, and the next job runs on a full complement. ----
    let schemes: Vec<Arc<dyn CmpcScheme>> = vec![
        Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2)),
        Arc::new(PolyDotCmpc::new(2, 2, 2)),
        Arc::new(EntangledCmpc::new(2, 2, 2)),
    ];
    for (idx, scheme) in schemes.into_iter().enumerate() {
        let n = scheme.n_workers();
        let z = scheme.params().z;
        let name = scheme.name();

        // Fault-free reference (default full-drain path).
        let reference = Deployment::for_scheme(
            scheme.clone(),
            ProtocolConfig::builder().threads(1).build(),
        )
        .unwrap();
        let y_ref = {
            let out = reference.execute_seeded(&a, &b, 0x5EED).unwrap();
            assert!(out.verified, "{name}: reference run");
            assert!(!out.early_decoded);
            assert_eq!(out.y, y_expect, "{name}: reference product");
            out.y
        };
        drop(reference);

        // Chaos run: deterministic seed-driven kills after the G-exchange.
        let plan = ChaosPlan::kill_k_workers_after_exchange(0xC0FFEE + idx as u64, n, z);
        let dep = Deployment::for_scheme(
            scheme,
            ProtocolConfig::builder()
                .threads(1)
                .early_decode(true)
                .recv_timeout(Duration::from_secs(10))
                .chaos(plan.into_shared())
                .build(),
        )
        .unwrap();
        let baseline_threads = os_thread_count();

        let out = dep.execute_seeded(&a, &b, 0x5EED).unwrap_or_else(|e| {
            panic!("{name}: job with {z} killed workers should early-decode: {e}")
        });
        assert!(out.verified, "{name}");
        assert!(out.early_decoded, "{name}: fast path not taken");
        assert_eq!(out.y, y_ref, "{name}: decode diverged from fault-free run");
        assert_eq!(out.stragglers_tolerated, n - 6, "{name}");

        // The kill victims died during their compute phase; evict + respawn.
        wait_for_respawns(&dep, z as u64);
        let health = dep.health();
        assert_eq!(health.evictions, z as u64, "{name}");
        assert_eq!(health.respawns, z as u64, "{name}");
        assert!(health.early_decodes >= 1, "{name}");
        assert_eq!(dep.runtime().evictions().len(), z, "{name}");
        assert_eq!(dep.worker_threads(), n, "{name}");
        if let (Some(before), Some(after)) = (baseline_threads, os_thread_count()) {
            assert_eq!(
                after, before,
                "{name}: thread count not flat after respawn"
            );
        }

        // The job following the faults runs on the respawned complement and
        // is byte-identical (kill rules are exhausted).
        let next = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
        assert!(next.verified, "{name}: post-respawn job");
        assert_eq!(next.y, y_ref, "{name}: post-respawn decode diverged");
        assert_eq!(dep.health().evictions, z as u64, "{name}: extra evictions");
        drop(dep);
    }

    // ---- 2. Straggler tail: early decode turns tail latency into a
    // measured win. Two workers sit behind slow *links*: every inbound
    // G-share into them is shaped +300 ms in flight (their own compute
    // and outbound shares are on time, so everyone else finishes fast).
    // The full-drain job must wait for the victims' late I-shares; the
    // early-decode job aborts them while they idle-wait — they ack
    // instantly, so the job returns early AND with exact counters. ----
    let delay = Duration::from_millis(300);
    let straggler_shaper = || {
        let mut shaper = LinkShaper::new();
        for victim in [2usize, 9] {
            shaper = shaper.rule(
                ShapeRule::new(LinkSpec::latency(delay))
                    .to_node(victim)
                    .class(PayloadClass::GShare),
            );
        }
        shaper.into_shared()
    };
    let dep_full = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .shaper(straggler_shaper())
            .build(),
    )
    .unwrap();
    let t0 = Instant::now();
    let out_full = dep_full.execute_seeded(&a, &b, 0x5EED).unwrap();
    let full_elapsed = t0.elapsed();
    assert!(out_full.verified && !out_full.early_decoded);
    assert!(
        full_elapsed >= delay,
        "full drain returned in {full_elapsed:?} despite a {delay:?} slow-link straggler"
    );
    drop(dep_full);
    let dep_early = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .early_decode(true)
            .shaper(straggler_shaper())
            .build(),
    )
    .unwrap();
    let t0 = Instant::now();
    let out_early = dep_early.execute_seeded(&a, &b, 0x5EED).unwrap();
    let early_elapsed = t0.elapsed();
    assert!(out_early.verified && out_early.early_decoded);
    assert_eq!(out_early.y, out_full.y);
    assert!(
        early_elapsed < full_elapsed,
        "early decode ({early_elapsed:?}) did not beat the full drain ({full_elapsed:?})"
    );
    // Exactness on the fast path (the JobAbort-ack contract): the victims
    // acked the abort after tombstoning the job, so even when their shaped
    // G-shares finally arrive, not one counter may move.
    let snap: Vec<(u64, u64)> = out_early
        .worker_counters
        .iter()
        .map(|c| (c.mults(), c.stored()))
        .collect();
    std::thread::sleep(delay + Duration::from_millis(100));
    let after: Vec<(u64, u64)> = out_early
        .worker_counters
        .iter()
        .map(|c| (c.mults(), c.stored()))
        .collect();
    assert_eq!(
        snap, after,
        "early-decoded counters ticked after the job returned"
    );
    drop(dep_early);

    // ---- 3. Garbled share: corruption in flight is detected, typed, and
    // non-poisonous (the rule is one-shot; the next job is clean). ----
    let garble_plan = ChaosPlan::new()
        .rule(
            FaultRule::new(FaultAction::Garble)
                .to_node(2)
                .class(PayloadClass::Shares)
                .limit(1),
        )
        .into_shared();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().threads(1).chaos(garble_plan).build(),
    )
    .unwrap();
    let err = dep.execute_seeded(&a, &b, 0x5EED).unwrap_err();
    assert!(matches!(err, CmpcError::NotDecodable(_)), "{err}");
    let clean = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
    assert!(clean.verified);
    assert_eq!(clean.y, y_expect);
    drop(dep);

    // ---- 4. Deadline-miss self-eviction: worker 5's *inbound* G-shares
    // for job 0 are dropped, so it alone starves mid-exchange, misses its
    // per-job deadline (limit 1), reports a typed JobError, and
    // self-evicts — strictly before the driver's abort can reach it, since
    // self-eviction happens in the same timeout round that sends the
    // JobError the driver reacts to. The reaper replaces it and the
    // deployment serves clean jobs again. ----
    let starve_plan = ChaosPlan::new()
        .rule(
            FaultRule::new(FaultAction::Drop)
                .to_node(5)
                .class(PayloadClass::GShare)
                .job(0),
        )
        .into_shared();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder()
            .threads(1)
            .recv_timeout(Duration::from_millis(200))
            .max_deadline_misses(1)
            .chaos(starve_plan)
            .build(),
    )
    .unwrap();
    let n = dep.n_workers();
    let baseline_threads = os_thread_count();
    let err = dep.execute_seeded(&a, &b, 0xDEAD).unwrap_err();
    assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    assert!(err.to_string().contains("deadline"), "{err}");
    wait_for_respawns(&dep, 1);
    let evictions = dep.runtime().evictions();
    assert_eq!(evictions.len(), 1);
    assert_eq!(evictions[0].worker, 5);
    assert!(
        evictions[0].reason.contains("self-evicted"),
        "{}",
        evictions[0].reason
    );
    assert_eq!(dep.health().deadline_misses, 1);
    assert!(dep.health().jobs_aborted >= 1);
    assert_eq!(dep.worker_threads(), n);
    if let (Some(before), Some(after)) = (baseline_threads, os_thread_count()) {
        assert_eq!(after, before, "thread count not flat after self-eviction respawn");
    }
    let clean = dep.execute_seeded(&a, &b, 0xF00D).unwrap();
    assert!(clean.verified);
    assert_eq!(clean.y, y_expect);
    drop(dep);

    // ---- 5. Byzantine location: with tolerance `a`, `a` chaos-garbled
    // I-shares per scheme are *located* by the error-correcting decoder,
    // excluded from reconstruction (the product stays byte-identical),
    // blamed in `health()`, and the blamed workers are evicted and
    // respawned like dead ones. Honest I-shares are link-shaped slow so
    // the garbled ones land inside the raised recovery quota
    // deterministically. ----
    fn slow_honest_ishares(n: usize, fast: &[usize]) -> Arc<LinkShaper> {
        let mut shaper = LinkShaper::new();
        for w in (0..n).filter(|w| !fast.contains(w)) {
            shaper = shaper.rule(
                ShapeRule::new(LinkSpec::latency(Duration::from_millis(150)))
                    .from_node(w)
                    .class(PayloadClass::IShare),
            );
        }
        shaper.into_shared()
    }
    for adv in [1usize, 2] {
        let schemes: Vec<Arc<dyn CmpcScheme>> = vec![
            Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2)),
            Arc::new(PolyDotCmpc::new(2, 2, 2)),
            Arc::new(EntangledCmpc::new(2, 2, 2)),
        ];
        for (idx, scheme) in schemes.into_iter().enumerate() {
            let n = scheme.n_workers();
            let name = scheme.name();
            let seed = 0xB1A4_E000 + (adv * 10 + idx) as u64;
            let plan = ChaosPlan::garble_k_workers(seed, n, adv);
            let mut victims = ChaosPlan::chosen_victims(seed, n, adv);
            victims.sort_unstable();

            // Exercise both tolerance channels: the scheme-params knob for
            // a = 1, the protocol-config knob for a = 2 (drive_job takes
            // the max of the two, so each alone must raise the quota).
            let (scheme, config) = if adv == 1 {
                let raised: Arc<dyn CmpcScheme> = match idx {
                    0 => Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2).with_adversary_tolerance(1)),
                    1 => Arc::new(PolyDotCmpc::new(2, 2, 2).with_adversary_tolerance(1)),
                    _ => Arc::new(EntangledCmpc::new(2, 2, 2).with_adversary_tolerance(1)),
                };
                (
                    raised,
                    ProtocolConfig::builder()
                        .threads(1)
                        .chaos(plan.into_shared())
                        .shaper(slow_honest_ishares(n, &victims))
                        .build(),
                )
            } else {
                (
                    scheme,
                    ProtocolConfig::builder()
                        .threads(1)
                        .adversary_tolerance(adv)
                        .chaos(plan.into_shared())
                        .shaper(slow_honest_ishares(n, &victims))
                        .build(),
                )
            };
            let dep = Deployment::for_scheme(scheme, config).unwrap();

            let out = dep.execute_seeded(&a, &b, 0x5EED).unwrap_or_else(|e| {
                panic!("{name} a={adv}: {adv} garbled shares should be located: {e}")
            });
            assert!(out.verified, "{name} a={adv}");
            assert_eq!(
                out.y, y_expect,
                "{name} a={adv}: decode diverged despite error location"
            );
            assert_eq!(
                out.blamed_workers, victims,
                "{name} a={adv}: wrong workers blamed"
            );

            // Blame surfaces in health and turns into eviction + respawn.
            wait_for_respawns(&dep, adv as u64);
            let health = dep.health();
            assert_eq!(health.byzantine_detected, adv as u64, "{name} a={adv}");
            assert_eq!(health.blamed_workers, victims, "{name} a={adv}");
            assert_eq!(health.evictions, adv as u64, "{name} a={adv}");
            assert_eq!(health.respawns, adv as u64, "{name} a={adv}");
            let evictions = dep.runtime().evictions();
            assert_eq!(evictions.len(), adv, "{name} a={adv}");
            let mut evicted: Vec<usize> = evictions.iter().map(|e| e.worker).collect();
            evicted.sort_unstable();
            assert_eq!(evicted, victims, "{name} a={adv}: evicted wrong workers");
            for ev in &evictions {
                assert!(
                    ev.reason.contains("blamed"),
                    "{name} a={adv}: eviction reason: {}",
                    ev.reason
                );
            }
            assert_eq!(dep.worker_threads(), n, "{name} a={adv}");

            // Garble rules are one-shot: the job after the respawn is clean,
            // byte-identical, and accrues no further blame.
            let next = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
            assert!(next.verified, "{name} a={adv}: post-blame job");
            assert_eq!(next.y, y_expect, "{name} a={adv}");
            assert!(next.blamed_workers.is_empty(), "{name} a={adv}");
            assert_eq!(dep.health().byzantine_detected, adv as u64, "{name} a={adv}");
            drop(dep);
        }
    }

    // ---- 6. Overload: `a + 1` garbled shares at tolerance `a` is a typed
    // refusal — never a panic, never a silently wrong product — and the
    // deployment is not poisoned. ----
    {
        let scheme: Arc<dyn CmpcScheme> = Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2));
        let n = scheme.n_workers();
        let seed = 0xB1A4_EBAD;
        let plan = ChaosPlan::garble_k_workers(seed, n, 2);
        let victims = ChaosPlan::chosen_victims(seed, n, 2);
        let dep = Deployment::for_scheme(
            scheme,
            ProtocolConfig::builder()
                .threads(1)
                .adversary_tolerance(1) // quota 8 locates at most 1 error
                .chaos(plan.into_shared())
                .shaper(slow_honest_ishares(n, &victims))
                .build(),
        )
        .unwrap();
        let err = dep.execute_seeded(&a, &b, 0x5EED).unwrap_err();
        assert!(
            matches!(err, CmpcError::NotDecodable(_)),
            "2 errors at tolerance 1 must be NotDecodable, got: {err}"
        );
        assert_eq!(dep.health().byzantine_detected, 0, "no blame on refusal");
        assert!(dep.health().blamed_workers.is_empty());
        let clean = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
        assert!(clean.verified);
        assert_eq!(clean.y, y_expect);
        drop(dep);
    }

    // ---- 7. Combined garble + kill with early decode at the raised
    // quota: one worker garbles its I-share, two more die mid-exchange,
    // and the fast path still returns the byte-identical product the
    // moment `t²+z+2a` usable shares are in — blaming the garbler and
    // evicting all three. ----
    {
        let scheme: Arc<dyn CmpcScheme> = Arc::new(AgeCmpc::with_optimal_lambda(2, 2, 2));
        let n = scheme.n_workers();
        let z = scheme.params().z;
        let kill_seed = 0xC0FFEE_BAD;
        let mut killed = ChaosPlan::chosen_victims(kill_seed, n, z);
        killed.sort_unstable();
        let garbler = (0..n).find(|w| !killed.contains(w)).unwrap();
        let plan = ChaosPlan::kill_k_workers_after_exchange(kill_seed, n, z).rule(
            FaultRule::new(FaultAction::Garble)
                .from_node(garbler)
                .class(PayloadClass::IShare)
                .limit(1),
        );
        let dep = Deployment::for_scheme(
            scheme,
            ProtocolConfig::builder()
                .threads(1)
                .adversary_tolerance(1)
                .early_decode(true)
                .recv_timeout(Duration::from_secs(10))
                .chaos(plan.into_shared())
                .shaper(slow_honest_ishares(n, &[garbler]))
                .build(),
        )
        .unwrap();
        let out = dep.execute_seeded(&a, &b, 0x5EED).unwrap_or_else(|e| {
            panic!("garble+kill at raised quota should early-decode: {e}")
        });
        assert!(out.verified);
        assert!(out.early_decoded, "fast path not taken under garble+kill");
        assert_eq!(out.y, y_expect, "garble+kill decode diverged");
        assert_eq!(out.blamed_workers, vec![garbler]);
        assert_eq!(out.stragglers_tolerated, n - 8); // quota t²+z+2a = 8

        // Three evictions: two dead, one blamed.
        wait_for_respawns(&dep, (z + 1) as u64);
        let health = dep.health();
        assert_eq!(health.byzantine_detected, 1);
        assert_eq!(health.blamed_workers, vec![garbler]);
        assert_eq!(health.evictions, (z + 1) as u64);
        let evictions = dep.runtime().evictions();
        let blamed_ev: Vec<&str> = evictions
            .iter()
            .filter(|e| e.reason.contains("blamed"))
            .map(|e| e.reason.as_str())
            .collect();
        assert_eq!(blamed_ev.len(), 1, "exactly one blamed eviction: {evictions:?}");
        let mut evicted: Vec<usize> = evictions.iter().map(|e| e.worker).collect();
        evicted.sort_unstable();
        let mut expect = killed.clone();
        expect.push(garbler);
        expect.sort_unstable();
        assert_eq!(evicted, expect, "evicted set must be killed + blamed");
        assert_eq!(dep.worker_threads(), n);

        // Full complement again: the next job is clean and byte-identical.
        let next = dep.execute_seeded(&a, &b, 0x5EED).unwrap();
        assert!(next.verified);
        assert_eq!(next.y, y_expect);
    }
}
