//! Pipeline acceptance pins (v0.10): a chained secure computation must be
//! byte-identical to the naive decode-re-encode reference for every
//! scheme, perform exactly **one** Phase-3 decode regardless of chain
//! length (the counter contract in `metrics`), replay deterministically,
//! survive chaos-killed workers mid-stage, and decode the same bytes over
//! a real TCP cluster as in-process.

use std::time::{Duration, Instant};

use cmpc::codes::SchemeParams;
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::ChaosPlan;
use cmpc::mpc::pipeline::{pipeline_input, pipeline_weight, reference_eval, Pipeline};
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::manifest::TopologyManifest;
use cmpc::transport::node::{job_secret_seed, run_local_cluster};
use cmpc::{Deployment, SchemeSpec};

const M: usize = 8;
const SEED: u64 = 0x1209;

/// `(s,t,z) = (2,2,2)`: every scheme constructible, stage quota t²+z = 6.
fn params() -> SchemeParams {
    SchemeParams::new(2, 2, 2)
}

fn provision(spec: SchemeSpec, config: ProtocolConfig) -> Deployment {
    Deployment::provision(spec, params(), config).unwrap()
}

/// The deterministic demo data the CI digest lanes and the example use.
fn demo_data(pipe: &Pipeline, seed: u64) -> (FpMat, Vec<FpMat>) {
    let x = pipeline_input(seed, M);
    let weights = (0..pipe.rounds())
        .map(|r| pipeline_weight(seed, M, r as u32))
        .collect();
    (x, weights)
}

/// Drive the reaper until `want` respawns happened (worker threads exit
/// asynchronously after a chaos kill, so poll briefly).
fn wait_for_respawns(dep: &Deployment, want: u64) {
    let t0 = Instant::now();
    loop {
        dep.runtime().reap();
        if dep.health().respawns >= want {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "respawns stuck at {} (want {want})",
            dep.health().respawns
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// 2-stage and 3-stage chains across all three constructible schemes:
/// verified against (and explicitly equal to) the decode-re-encode
/// reference, exactly one Phase-3 decode, one fabric job + one stage
/// counter tick per round, and deterministic under seed replay.
#[test]
fn pipelines_match_reference_across_schemes() {
    let specs = [
        "matmul,truncate:4,matmul",
        "matmul,truncate:3,matmul,scale:5,transpose,matmul",
    ];
    for scheme in [
        SchemeSpec::Age { lambda: None },
        SchemeSpec::PolyDot,
        SchemeSpec::Entangled,
    ] {
        for spec in specs {
            let pipe = Pipeline::parse_spec(spec).unwrap();
            let dep = provision(scheme, ProtocolConfig::builder().threads(1).build());
            let name = format!("{} `{spec}`", dep.scheme().name());
            let (x, weights) = demo_data(&pipe, SEED);
            let wrefs: Vec<&FpMat> = weights.iter().collect();

            let out = dep.execute_pipeline_seeded(&pipe, &x, &wrefs, SEED).unwrap();
            assert!(out.verified, "{name}");
            assert_eq!(out.rounds, pipe.rounds(), "{name}");
            assert_eq!(out.stage_traffic.len(), pipe.rounds(), "{name}");
            assert_eq!(out.stage_elapsed.len(), pipe.rounds(), "{name}");
            let expect = reference_eval(&pipe, params(), &x, &wrefs, SEED).unwrap();
            assert_eq!(out.y, expect, "{name}: diverged from reference");

            // The whole point: one decode for the whole chain, while the
            // fabric did one job's worth of work per round.
            let health = dep.health();
            assert_eq!(health.phase3_decodes, 1, "{name}");
            assert_eq!(health.pipeline_stages, pipe.rounds() as u64, "{name}");
            assert_eq!(
                dep.runtime().jobs_started(),
                pipe.rounds() as u64,
                "{name}"
            );

            // Same seed on the warm deployment → same bytes.
            let again = dep.execute_pipeline_seeded(&pipe, &x, &wrefs, SEED).unwrap();
            assert_eq!(again.y, out.y, "{name}: replay diverged");
            assert_eq!(dep.health().phase3_decodes, 2, "{name}");
        }
    }
}

/// Chaos kill mid-stage: z workers die mid-send of their final round-0
/// G-share. The masked open decodes at the stage quota anyway, the reaper
/// respawns the victims between rounds, and the pipeline output stays
/// byte-identical to the fault-free run — as does the next pipeline on
/// the healed deployment.
#[test]
fn pipeline_survives_chaos_kill_mid_stage() {
    let pipe = Pipeline::parse_spec("matmul,truncate:4,matmul").unwrap();
    let (x, weights) = demo_data(&pipe, SEED);
    let wrefs: Vec<&FpMat> = weights.iter().collect();

    let reference = provision(
        SchemeSpec::Age { lambda: None },
        ProtocolConfig::builder().threads(1).build(),
    );
    let n = reference.n_workers();
    let y_ref = reference
        .execute_pipeline_seeded(&pipe, &x, &wrefs, SEED)
        .unwrap()
        .y;
    drop(reference);

    let plan = ChaosPlan::kill_k_workers_after_exchange(0xDEAD_BEA7, n, 2);
    let dep = provision(
        SchemeSpec::Age { lambda: None },
        ProtocolConfig::builder()
            .threads(1)
            .early_decode(true) // final round must not full-drain dead peers
            .recv_timeout(Duration::from_secs(10))
            .chaos(plan.into_shared())
            .build(),
    );
    let out = dep
        .execute_pipeline_seeded(&pipe, &x, &wrefs, SEED)
        .expect("pipeline with 2 killed workers should decode at the stage quota");
    assert!(out.verified);
    assert_eq!(out.y, y_ref, "chaos run diverged from fault-free run");

    wait_for_respawns(&dep, 2);
    assert_eq!(dep.health().evictions, 2);
    assert_eq!(dep.worker_threads(), n);

    // Kill rules are exhausted; the healed complement replays identically.
    let next = dep.execute_pipeline_seeded(&pipe, &x, &wrefs, SEED).unwrap();
    assert!(next.verified);
    assert_eq!(next.y, y_ref, "post-respawn pipeline diverged");
}

/// A `pipeline <spec>` manifest line over a real loopback-TCP cluster —
/// every party its own thread, every envelope through the framed wire
/// codec, the split `Z′/R′` re-share between master and source A — must
/// decode byte-identical to the in-process driver for every run.
#[test]
fn pipeline_tcp_cluster_matches_in_process() {
    let spec = "matmul,truncate:4,matmul";
    let mut manifest =
        TopologyManifest::template("age", 2, 2, 2, M, 0xACE5, 2, "127.0.0.1", 0).unwrap();
    manifest.pipeline_spec = Some(spec.to_string());
    let report = run_local_cluster(&manifest, None).unwrap();
    assert_eq!(report.master.jobs.len(), 2);

    let pipe = Pipeline::parse_spec(spec).unwrap();
    let dep = provision(
        SchemeSpec::Age { lambda: None },
        ProtocolConfig::builder().threads(1).build(),
    );
    for (k, job) in report.master.jobs.iter().enumerate() {
        let seed = job_secret_seed(manifest.seed, k as u64);
        let (x, weights) = demo_data(&pipe, seed);
        let wrefs: Vec<&FpMat> = weights.iter().collect();
        let out = dep.execute_pipeline_seeded(&pipe, &x, &wrefs, seed).unwrap();
        assert!(job.verified, "TCP run {k}");
        assert_eq!(job.y, out.y, "TCP run {k} diverged from in-process");
    }
}
