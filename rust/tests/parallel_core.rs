//! The parallel compute core must be a pure optimization: identical
//! protocol outputs at any pool size, and the parallel kernels must agree
//! with naive references over random shapes.

use cmpc::codes::SchemeParams;
use cmpc::coordinator::{Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::ff::P;
use cmpc::matrix::FpMat;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::pool::{ScratchPool, WorkerPool};
use cmpc::util::rng::ChaChaRng;
use cmpc::util::testing::property;
use cmpc::{Deployment, SchemeSpec};

/// Schoolbook reference matmul with per-element modulo.
fn matmul_ref(a: &FpMat, b: &FpMat) -> FpMat {
    let mut out = FpMat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0u64;
            for k in 0..a.cols {
                acc = (acc + a.at(i, k) * b.at(k, j)) % P;
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[test]
fn parallel_matmul_matches_naive_over_random_shapes() {
    let pool = WorkerPool::new(4);
    let scratch = ScratchPool::for_pool(&pool);
    let mut out = FpMat::zeros(0, 0);
    let mut acc = Vec::new();
    property("matmul_into/par_matmul_into == naive", 150, |rng| {
        let m = rng.gen_index(24) + 1;
        let k = rng.gen_index(24) + 1;
        let n = rng.gen_index(24) + 1;
        let a = FpMat::random(rng, m, k);
        let b = FpMat::random(rng, k, n);
        let want = matmul_ref(&a, &b);
        a.matmul_into(&b, &mut out, &mut acc);
        if out != want {
            return Err(format!("matmul_into at {m}x{k}x{n}"));
        }
        a.par_matmul_into(&b, &mut out, &pool, &scratch);
        if out != want {
            return Err(format!("par_matmul_into at {m}x{k}x{n}"));
        }
        Ok(())
    });
}

/// Identical `ProtocolOutput` at pool sizes 1 vs N under the same seed:
/// the product, verification status, traffic meters, and per-worker
/// overhead counters must not depend on how the parallel sections are
/// scheduled.
#[test]
fn deployment_output_identical_across_pool_sizes() {
    let params = SchemeParams::new(2, 2, 2);
    let mut rng = ChaChaRng::seed_from_u64(404);
    let a = FpMat::random(&mut rng, 16, 16);
    let b = FpMat::random(&mut rng, 16, 16);
    let run = |threads: usize| {
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::builder().threads(threads).build(),
        )
        .unwrap();
        dep.execute_seeded(&a, &b, 1234).unwrap()
    };
    let base = run(1);
    assert!(base.verified);
    for threads in [2, 4, 8] {
        let out = run(threads);
        assert_eq!(out.y, base.y, "{threads} threads");
        assert_eq!(out.verified, base.verified, "{threads} threads");
        assert_eq!(out.n_workers, base.n_workers);
        assert_eq!(
            out.traffic.worker_to_worker, base.traffic.worker_to_worker,
            "{threads} threads"
        );
        assert_eq!(
            out.traffic.source_to_worker, base.traffic.source_to_worker,
            "{threads} threads"
        );
        for (wc, bc) in out.worker_counters.iter().zip(base.worker_counters.iter()) {
            assert_eq!(wc.mults(), bc.mults(), "{threads} threads");
            assert_eq!(wc.stored(), bc.stored(), "{threads} threads");
        }
    }
}

/// Byte-identical protocol outputs for fixed seeds whether jobs stream
/// through one persistent runtime sequentially or interleave concurrently
/// on its shared fabric links.
#[test]
fn runtime_output_identical_across_job_interleavings() {
    let params = SchemeParams::new(2, 2, 1);
    let mut rng = ChaChaRng::seed_from_u64(606);
    let a = FpMat::random(&mut rng, 8, 8);
    let b = FpMat::random(&mut rng, 8, 8);
    let seeds: Vec<u64> = (0..6).map(|i| 7000 + 13 * i).collect();
    let dep = Deployment::provision(
        SchemeSpec::Age { lambda: None },
        params,
        ProtocolConfig::builder().threads(1).build(),
    )
    .unwrap();
    // sequential reference on the warm runtime
    let sequential: Vec<_> = seeds
        .iter()
        .map(|&s| dep.execute_seeded(&a, &b, s).unwrap())
        .collect();
    // same seeds, same runtime, jobs interleaved by 3 driving threads
    let drive = WorkerPool::new(3);
    let concurrent = drive.par_map(&seeds, |_w, _i, &s| dep.execute_seeded(&a, &b, s).unwrap());
    for (i, (sq, cc)) in sequential.iter().zip(&concurrent).enumerate() {
        assert_eq!(sq.y, cc.y, "job {i} product differs under interleaving");
        assert_eq!(sq.verified, cc.verified);
        assert_eq!(
            sq.traffic.worker_to_worker, cc.traffic.worker_to_worker,
            "job {i} traffic differs under interleaving"
        );
        for (ws, wc) in sq.worker_counters.iter().zip(cc.worker_counters.iter()) {
            assert_eq!(ws.mults(), wc.mults(), "job {i}");
            assert_eq!(ws.stored(), wc.stored(), "job {i}");
        }
    }
}

/// `drain` must return reports in submission order with identical outputs
/// whether jobs run sequentially (threads=1) or concurrently.
#[test]
fn parallel_drain_is_deterministic_and_ordered() {
    let mut rng = ChaChaRng::seed_from_u64(505);
    // Mixed signatures → multiple deployments; mixed sizes within one
    // signature → shared deployment with distinct jobs.
    let jobs: Vec<(FpMat, FpMat, usize, usize, usize)> = vec![
        (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8), 2, 2, 2),
        (FpMat::random(&mut rng, 12, 12), FpMat::random(&mut rng, 12, 12), 2, 2, 1),
        (FpMat::random(&mut rng, 16, 16), FpMat::random(&mut rng, 16, 16), 2, 2, 2),
        (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8), 2, 2, 1),
    ];
    let run = |threads: usize| {
        let mut coord = Coordinator::new(
            CoordinatorConfig::builder()
                .policy(SchemePolicy::Adaptive)
                .threads(threads)
                .build(),
        );
        let mut handles = Vec::new();
        for (a, b, s, t, z) in &jobs {
            handles.push(coord.submit(a.clone(), b.clone(), *s, *t, *z).unwrap());
        }
        let reports = coord.drain();
        assert_eq!(coord.provisioned_deployments(), 2);
        for (h, r) in handles.iter().zip(&reports) {
            assert_eq!(h.id(), r.id, "submission order at {threads} threads");
        }
        reports
    };
    let seq = run(1);
    let par = run(4);
    assert_eq!(seq.len(), par.len());
    for (i, (rs, rp)) in seq.iter().zip(&par).enumerate() {
        let ys = &rs.outcome.as_ref().unwrap().y;
        let yp = &rp.outcome.as_ref().unwrap().y;
        assert_eq!(ys, yp, "job {i} product differs across pool sizes");
        let (a, b, ..) = &jobs[i];
        assert_eq!(ys, &a.transpose().matmul(b), "job {i} wrong product");
        assert_eq!(rs.scheme, rp.scheme, "job {i}");
    }
}
