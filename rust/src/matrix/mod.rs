//! Dense matrices over `GF(p)` with the `(s, t)` block partitioning of eq. (4)
//! and a cache-blocked modular matmul used as the native compute backend.
//!
//! Element storage is row-major `u32` (all values reduced `< p`). The matmul
//! hot path accumulates unreduced `u64` partial sums: with `p² < 2^34` a row
//! of up to `2^29` products fits without overflow, so reduction happens once
//! per output element (or once per K-panel in the blocked path).

use crate::ff::{self, P};
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::util::rng::ChaChaRng;

/// Row-major dense matrix over `GF(p)`.
#[derive(Clone, PartialEq, Eq)]
pub struct FpMat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage; every element is reduced `< p`.
    pub data: Vec<u32>,
}

impl std::fmt::Debug for FpMat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "FpMat({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 64 {
            writeln!(f)?;
            for r in 0..self.rows {
                writeln!(f, "  {:?}", &self.data[r * self.cols..(r + 1) * self.cols])?;
            }
        }
        Ok(())
    }
}

impl FpMat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> FpMat {
        FpMat {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> FpMat {
        let mut m = FpMat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1;
        }
        m
    }

    /// Matrix with uniformly random field entries.
    pub fn random(rng: &mut ChaChaRng, rows: usize, cols: usize) -> FpMat {
        let data = (0..rows * cols)
            .map(|_| rng.field_element() as u32)
            .collect();
        FpMat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    ///
    /// Inputs must already be reduced (`< p`): a debug assertion trips on
    /// out-of-range values so kernel bugs can't hide behind silent wrapping;
    /// release builds still reduce defensively.
    pub fn from_fn<F: FnMut(usize, usize) -> u64>(rows: usize, cols: usize, mut f: F) -> FpMat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let v = f(r, c);
                debug_assert!(
                    v < P,
                    "FpMat::from_fn expects reduced elements (got {v} at ({r},{c}))"
                );
                data.push((v % P) as u32);
            }
        }
        FpMat { rows, cols, data }
    }

    /// The element at `(r, c)`, already reduced `< p`.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> u64 {
        self.data[r * self.cols + c] as u64
    }

    /// Store a **reduced** element. Debug builds assert `v < p`; release
    /// builds still reduce defensively (same policy as [`FpMat::from_fn`]).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: u64) {
        debug_assert!(v < P, "FpMat::set expects a reduced element (got {v})");
        self.data[r * self.cols + c] = (v % P) as u32;
    }

    /// Number of scalar entries.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Whether the matrix has zero entries (either dimension is 0).
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Serialized size in bytes (u32 per scalar) — used by the network fabric
    /// for communication accounting.
    pub fn nbytes(&self) -> u64 {
        (self.len() * 4) as u64
    }

    /// Reshape `out` to `rows × cols`, reusing its buffer. Steady-state
    /// calls with an already-correctly-sized `out` never allocate.
    #[inline]
    fn shape_into(out: &mut FpMat, rows: usize, cols: usize) {
        out.rows = rows;
        out.cols = cols;
        out.data.resize(rows * cols, 0);
    }

    /// Reshape in place, reusing the buffer (contents of any retained
    /// prefix are unspecified — callers overwrite before use). Never
    /// allocates once the buffer has grown to its steady-state capacity;
    /// the fabric [`BufferPool`] relies on this for recycled payloads.
    ///
    /// [`BufferPool`]: crate::mpc::network::BufferPool
    #[inline]
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        FpMat::shape_into(self, rows, cols);
    }

    /// Overwrite every entry with a fresh uniform field element, in the
    /// same element order as [`FpMat::random`] (so a reused mask buffer
    /// draws the byte-identical stream a freshly allocated one would).
    pub fn fill_random(&mut self, rng: &mut ChaChaRng) {
        for v in self.data.iter_mut() {
            *v = rng.field_element() as u32;
        }
    }

    /// Transpose.
    pub fn transpose(&self) -> FpMat {
        let mut out = FpMat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// [`FpMat::transpose`] into a caller-owned buffer (allocation-free at
    /// steady state).
    pub fn transpose_into(&self, out: &mut FpMat) {
        FpMat::shape_into(out, self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &FpMat) -> FpMat {
        let mut out = self.clone();
        out.add_assign(other);
        out
    }

    /// `self += other` elementwise, in place.
    pub fn add_assign(&mut self, other: &FpMat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (o, &x) in self.data.iter_mut().zip(other.data.iter()) {
            *o = ff::add(*o as u64, x as u64) as u32;
        }
    }

    /// `self += c · other` in place (axpy).
    pub fn axpy_inplace(&mut self, c: u64, other: &FpMat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        ff::axpy(&mut self.data, c % P, &other.data);
    }

    /// Scalar multiple.
    pub fn scale(&self, c: u64) -> FpMat {
        let mut out = FpMat::zeros(self.rows, self.cols);
        self.scale_into(c, &mut out);
        out
    }

    /// `out = c · self` into a caller-owned buffer.
    pub fn scale_into(&self, c: u64, out: &mut FpMat) {
        FpMat::shape_into(out, self.rows, self.cols);
        ff::scale_into(&mut out.data, c % P, &self.data);
    }

    /// Modular matrix product, cache-blocked with delayed reduction.
    ///
    /// Layout: `ikj` loop order with a `u64` accumulator row so the inner loop
    /// is a pure multiply–add over contiguous memory. Safe because
    /// `p² · cols_inner < 2^34 · 2^29 < 2^63` for any realistic size; a guard
    /// asserts the bound.
    pub fn matmul(&self, other: &FpMat) -> FpMat {
        let mut out = FpMat::zeros(self.rows, other.cols);
        let mut acc = Vec::new();
        self.matmul_into(other, &mut out, &mut acc);
        out
    }

    #[inline]
    fn assert_matmul_shapes(&self, other: &FpMat) {
        assert_eq!(
            self.cols, other.rows,
            "matmul shape mismatch: {}x{} · {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        assert!(
            (self.cols as u64) < (1u64 << 29),
            "inner dimension too large for delayed reduction"
        );
    }

    /// Compute one output row band `[row0, row0+rows)` of `self · other`
    /// into `band` (row-major, `other.cols` wide) using `acc` as the
    /// unreduced accumulator row. Shared by the sequential and parallel
    /// matmul drivers.
    fn matmul_rows_into(&self, other: &FpMat, row0: usize, band: &mut [u32], acc: &mut [u64]) {
        let (k, n) = (self.cols, other.cols);
        for (r, orow) in band.chunks_mut(n).enumerate() {
            let i = row0 + r;
            for a in acc.iter_mut() {
                *a = 0;
            }
            let arow = &self.data[i * k..(i + 1) * k];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0 {
                    continue;
                }
                let a64 = aik as u64;
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (a, &bkj) in acc.iter_mut().zip(brow.iter()) {
                    *a += a64 * bkj as u64;
                }
            }
            // Montgomery fold: the row accumulated k products of reduced
            // elements, so the REDC fast path is valid up to k = 65536
            // inner terms; the dispatcher falls back to `reduce` above.
            ff::mont::fold(orow, acc, k);
        }
    }

    /// [`FpMat::matmul`] into caller-owned output and scratch buffers: `out`
    /// is reshaped in place and `acc` grows to `other.cols` once — repeat
    /// calls at the same shape allocate nothing (the `alloc_discipline`
    /// suite pins this).
    pub fn matmul_into(&self, other: &FpMat, out: &mut FpMat, acc: &mut Vec<u64>) {
        self.assert_matmul_shapes(other);
        let (m, n) = (self.rows, other.cols);
        FpMat::shape_into(out, m, n);
        if m == 0 || n == 0 {
            return;
        }
        acc.clear();
        acc.resize(n, 0);
        self.matmul_rows_into(other, 0, &mut out.data, acc);
    }

    /// Parallel [`FpMat::matmul_into`]: output rows are split into one
    /// contiguous band per pool worker; each band is computed with that
    /// worker's [`Scratch`] accumulator, so the kernel stays allocation-free
    /// at steady state while scaling across cores.
    ///
    /// [`Scratch`]: crate::runtime::pool::Scratch
    pub fn par_matmul_into(
        &self,
        other: &FpMat,
        out: &mut FpMat,
        pool: &WorkerPool,
        scratch: &ScratchPool,
    ) {
        self.assert_matmul_shapes(other);
        let (m, n) = (self.rows, other.cols);
        FpMat::shape_into(out, m, n);
        if m == 0 || n == 0 {
            return;
        }
        let workers = pool.threads().min(m).max(1);
        let band_rows = m.div_ceil(workers);
        pool.par_chunks_mut(&mut out.data, band_rows * n, |wid, band_idx, band| {
            scratch.with(wid, |s| {
                s.acc.clear();
                s.acc.resize(n, 0);
                self.matmul_rows_into(other, band_idx * band_rows, band, &mut s.acc);
            });
        });
    }

    /// Partition into `row_parts × col_parts` equal blocks (eq. 4).
    ///
    /// # Panics
    /// Panics unless `row_parts | rows` and `col_parts | cols` (the paper's
    /// `s|m`, `t|m` condition).
    pub fn blocks(&self, row_parts: usize, col_parts: usize) -> Vec<Vec<FpMat>> {
        assert!(
            self.rows % row_parts == 0 && self.cols % col_parts == 0,
            "partition {}x{} does not divide {}x{}",
            row_parts,
            col_parts,
            self.rows,
            self.cols
        );
        let br = self.rows / row_parts;
        let bc = self.cols / col_parts;
        let mut out = Vec::with_capacity(row_parts);
        for pr in 0..row_parts {
            let mut rowv = Vec::with_capacity(col_parts);
            for pc in 0..col_parts {
                let mut blk = FpMat::zeros(br, bc);
                for r in 0..br {
                    let src = (pr * br + r) * self.cols + pc * bc;
                    let dst = r * bc;
                    blk.data[dst..dst + bc].copy_from_slice(&self.data[src..src + bc]);
                }
                rowv.push(blk);
            }
            out.push(rowv);
        }
        out
    }

    /// Inverse of [`blocks`]: assemble a matrix from a block grid.
    pub fn from_blocks(blocks: &[Vec<FpMat>]) -> FpMat {
        let row_parts = blocks.len();
        let col_parts = blocks[0].len();
        let br = blocks[0][0].rows;
        let bc = blocks[0][0].cols;
        let mut out = FpMat::zeros(row_parts * br, col_parts * bc);
        for (pr, rowv) in blocks.iter().enumerate() {
            assert_eq!(rowv.len(), col_parts);
            for (pc, blk) in rowv.iter().enumerate() {
                assert_eq!((blk.rows, blk.cols), (br, bc));
                for r in 0..br {
                    let dst = (pr * br + r) * out.cols + pc * bc;
                    let src = r * bc;
                    out.data[dst..dst + bc].copy_from_slice(&blk.data[src..src + bc]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;

    fn small_random(rng: &mut ChaChaRng, max: usize) -> FpMat {
        let r = rng.gen_index(max) + 1;
        let c = rng.gen_index(max) + 1;
        FpMat::random(rng, r, c)
    }

    /// Schoolbook reference matmul with per-element modulo.
    fn matmul_ref(a: &FpMat, b: &FpMat) -> FpMat {
        let mut out = FpMat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0u64;
                for k in 0..a.cols {
                    acc = (acc + a.at(i, k) * b.at(k, j)) % P;
                }
                out.set(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_schoolbook() {
        property("matmul == schoolbook", 200, |rng| {
            let m = rng.gen_index(12) + 1;
            let k = rng.gen_index(12) + 1;
            let n = rng.gen_index(12) + 1;
            let a = FpMat::random(rng, m, k);
            let b = FpMat::random(rng, k, n);
            if a.matmul(&b) != matmul_ref(&a, &b) {
                return Err(format!("mismatch at {m}x{k}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_into_and_parallel_match_schoolbook() {
        // The in-place and pool-parallel kernels against the naive
        // triple-loop reference over random shapes, reusing one scratch
        // set across iterations the way the serving path does.
        let pools = [WorkerPool::new(1), WorkerPool::new(4)];
        let scratches = [ScratchPool::for_pool(&pools[0]), ScratchPool::for_pool(&pools[1])];
        let mut out = FpMat::zeros(0, 0);
        let mut acc = Vec::new();
        property("matmul_into/par == schoolbook", 120, |rng| {
            let m = rng.gen_index(17) + 1;
            let k = rng.gen_index(17) + 1;
            let n = rng.gen_index(17) + 1;
            let a = FpMat::random(rng, m, k);
            let b = FpMat::random(rng, k, n);
            let want = matmul_ref(&a, &b);
            a.matmul_into(&b, &mut out, &mut acc);
            if out != want {
                return Err(format!("matmul_into mismatch at {m}x{k}x{n}"));
            }
            for (pool, scratch) in pools.iter().zip(scratches.iter()) {
                a.par_matmul_into(&b, &mut out, pool, scratch);
                if out != want {
                    return Err(format!(
                        "par_matmul_into mismatch at {m}x{k}x{n}, {} threads",
                        pool.threads()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn transpose_into_reuses_buffer() {
        let mut rng = ChaChaRng::seed_from_u64(12);
        let mut out = FpMat::zeros(0, 0);
        for _ in 0..10 {
            let r = rng.gen_index(9) + 1;
            let c = rng.gen_index(9) + 1;
            let a = FpMat::random(&mut rng, r, c);
            a.transpose_into(&mut out);
            assert_eq!(out, a.transpose());
            assert_eq!((out.rows, out.cols), (c, r));
        }
    }

    #[test]
    fn add_assign_matches_add() {
        property("add_assign == add", 100, |rng| {
            let a = small_random(rng, 8);
            let b = FpMat::random(rng, a.rows, a.cols);
            let mut inplace = a.clone();
            inplace.add_assign(&b);
            if inplace != a.add(&b) {
                return Err("add_assign".into());
            }
            Ok(())
        });
    }

    #[test]
    fn scale_into_matches_scale() {
        let mut rng = ChaChaRng::seed_from_u64(13);
        let a = FpMat::random(&mut rng, 6, 7);
        let mut out = FpMat::zeros(0, 0);
        a.scale_into(12345, &mut out);
        assert_eq!(out, a.scale(12345));
    }

    #[test]
    #[should_panic(expected = "reduced element")]
    #[cfg(debug_assertions)]
    fn set_rejects_unreduced_in_debug() {
        FpMat::zeros(1, 1).set(0, 0, P + 1);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 9, 9);
        assert_eq!(a.matmul(&FpMat::identity(9)), a);
        assert_eq!(FpMat::identity(9).matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        property("transpose twice is id", 100, |rng| {
            let a = small_random(rng, 10);
            if a.transpose().transpose() != a {
                return Err("transpose".into());
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_transpose_identity() {
        // (AB)^T = B^T A^T
        property("(AB)^T == B^T A^T", 100, |rng| {
            let m = rng.gen_index(8) + 1;
            let k = rng.gen_index(8) + 1;
            let n = rng.gen_index(8) + 1;
            let a = FpMat::random(rng, m, k);
            let b = FpMat::random(rng, k, n);
            if a.matmul(&b).transpose() != b.transpose().matmul(&a.transpose()) {
                return Err("identity".into());
            }
            Ok(())
        });
    }

    #[test]
    fn blocks_roundtrip() {
        property("blocks/from_blocks roundtrip", 100, |rng| {
            let s = rng.gen_index(4) + 1;
            let t = rng.gen_index(4) + 1;
            let rows = s * (rng.gen_index(4) + 1);
            let cols = t * (rng.gen_index(4) + 1);
            let a = FpMat::random(rng, rows, cols);
            if FpMat::from_blocks(&a.blocks(s, t)) != a {
                return Err("roundtrip".into());
            }
            Ok(())
        });
    }

    #[test]
    fn block_matmul_identity() {
        // Block (i,l) of A^T·B equals sum_j (A^T)_{i,j} · B_{j,l} — the
        // identity the CMPC decoding relies on (eq. 18).
        let mut rng = ChaChaRng::seed_from_u64(8);
        let (s, t, mm) = (3, 2, 12);
        let a = FpMat::random(&mut rng, mm, mm);
        let b = FpMat::random(&mut rng, mm, mm);
        let at = a.transpose();
        let at_blocks = at.blocks(t, s); // t row-parts, s col-parts
        let b_blocks = b.blocks(s, t);
        let y = at.matmul(&b);
        let y_blocks = y.blocks(t, t);
        for i in 0..t {
            for l in 0..t {
                let mut acc = FpMat::zeros(mm / t, mm / t);
                for j in 0..s {
                    acc = acc.add(&at_blocks[i][j].matmul(&b_blocks[j][l]));
                }
                assert_eq!(acc, y_blocks[i][l], "block ({i},{l})");
            }
        }
    }

    #[test]
    fn axpy_scale_add_consistent() {
        property("axpy == add(scale)", 100, |rng| {
            let a = small_random(rng, 8);
            let b = FpMat::random(rng, a.rows, a.cols);
            let c = rng.field_element();
            let mut via_axpy = a.clone();
            via_axpy.axpy_inplace(c, &b);
            if via_axpy != a.add(&b.scale(c)) {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "partition")]
    fn blocks_requires_divisibility() {
        FpMat::zeros(10, 10).blocks(3, 2);
    }
}
