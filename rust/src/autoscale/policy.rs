//! The autoscaler's **policy engine**: a pure, deterministic function
//! from a telemetry window to a provisioning recommendation.
//!
//! [`decide`] consumes a [`TelemetrySnapshot`] (what the deployment
//! *measured*: completed jobs, Phase-2 traffic, deadline misses,
//! evictions, Byzantine strike ledger) plus the analytical
//! [`CostModel`] (what the paper *predicts*: the λ ↦ N curve and the
//! ξ/σ/ζ overheads of Corollaries 10–12) and returns a [`Decision`]. No
//! clocks, no locks, no I/O — the decision-table tests drive it with
//! literal snapshots and assert exact outputs.
//!
//! Rule order (first match wins):
//!
//! 1. **Insufficient data** — fewer than `min_window_jobs` completed jobs
//!    in the window: hold, whatever the other signals say.
//! 2. **Strike-driven eviction** — some worker slot accumulated
//!    `strike_threshold` Byzantine strikes: stop retrying it. Raise the
//!    adversary tolerance `a` by one (quota `t²+z+2a`) and pick the
//!    cheapest λ whose `N(λ)` covers the new quota; the blue/green swap
//!    this recommends replaces *every* worker, striker included.
//! 3. **Standby draft** — the window's deadline-miss + eviction rate
//!    exceeds `miss_budget_pct`: margins are eroding, so draft more
//!    workers — the cheapest λ with `N ≥ N_current + standby_draft`
//!    (or the largest reachable `N` when no λ gets that far). A deployment
//!    already at the top of the curve holds rather than shrinking while
//!    it is struggling.
//! 4. **Communication cost** — the window shows real Phase-2 exchange
//!    (`w2w_scalars > 0`) and the measured configuration sits above the
//!    curve's optimum: moving to `λ*` shrinks ζ by
//!    `1 − N*(N*−1)/(N(N−1))` — an *m-independent* ratio, so the policy
//!    needs no knowledge of the workload's matrix sizes. Reconfigure only
//!    when that predicted gain clears `hysteresis_pct`, so a borderline
//!    link cannot thrash reprovisioning. This rule also walks non-AGE
//!    schemes (Entangled, PolyDot) onto the AGE curve.
//! 5. Otherwise: hold, already optimal.

use crate::analysis::CostModel;
use crate::codes::SchemeSpec;

/// One observation window of a live deployment, as the controller hands it
/// to [`decide`]. Counter fields are **window deltas** (since the last
/// reconfiguration); `strikes` is the cumulative per-slot ledger of the
/// serving generation.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    /// Row-wise partitions (fixed for the deployment's lifetime).
    pub s: usize,
    /// Column-wise partitions (fixed for the deployment's lifetime).
    pub t: usize,
    /// Colluding workers tolerated (fixed for the deployment's lifetime).
    pub z: usize,
    /// Byzantine adversary tolerance `a` the deployment currently runs at.
    pub adversary_tolerance: usize,
    /// The active scheme's AGE gap λ (`None`: a non-AGE family serves).
    pub lambda: Option<u64>,
    /// Workers the active generation provisions.
    pub n_workers: u64,
    /// Jobs completed in the window.
    pub jobs: u64,
    /// Per-job deadline expiries reported by workers in the window.
    pub deadline_misses: u64,
    /// Worker threads evicted (died and respawned) in the window.
    pub evictions: u64,
    /// Jobs that took the early-decode fast path in the window.
    pub early_decodes: u64,
    /// Garbled I-shares located by the Byzantine decoder in the window.
    pub byzantine_detected: u64,
    /// The strike ledger: `(worker_id, cumulative_strikes)`, slots with at
    /// least one strike only (see `RuntimeHealthReport::worker_strikes`).
    pub strikes: Vec<(usize, u64)>,
    /// Phase-2 worker↔worker scalars exchanged in the window — the
    /// *measured* ζ of eq. 34.
    pub w2w_scalars: u64,
    /// Mean end-to-end job latency over the window, nanoseconds.
    pub mean_job_latency_ns: u64,
}

/// Tunable thresholds of the policy. [`PolicyConfig::default`] matches the
/// decision-table suite and the `autoscale` CI lane.
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Minimum completed jobs before the window is trusted at all.
    pub min_window_jobs: u64,
    /// Minimum predicted ζ gain (percent) before a communication-cost
    /// reconfiguration fires — the anti-flapping band. The Example-1 curve
    /// calibrates it: the λ0→λ2 move (18→17 workers) predicts ≈11.1 %,
    /// so the 10 % default lets it through and 15 % suppresses it.
    pub hysteresis_pct: f64,
    /// Cumulative strikes at one worker slot before the policy prefers
    /// eviction-by-reprovisioning over another retry.
    pub strike_threshold: u64,
    /// Ceiling on the adversary tolerance `a` the policy may recommend
    /// (each step costs `2` extra quota shares).
    pub max_adversary_tolerance: usize,
    /// Deadline-miss + eviction rate (percent of window jobs) above which
    /// the standby draft fires.
    pub miss_budget_pct: f64,
    /// Workers a standby draft tries to add on top of the current `N`.
    pub standby_draft: u64,
}

impl Default for PolicyConfig {
    fn default() -> PolicyConfig {
        PolicyConfig {
            min_window_jobs: 4,
            hysteresis_pct: 10.0,
            strike_threshold: 3,
            max_adversary_tolerance: 2,
            miss_budget_pct: 25.0,
            standby_draft: 1,
        }
    }
}

/// Why a [`Decision::Hold`] held.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum HoldReason {
    /// Fewer than `min_window_jobs` completed jobs in the window.
    InsufficientData,
    /// A cheaper configuration exists but its predicted gain is inside the
    /// hysteresis band.
    WithinHysteresis,
    /// No rule found a better configuration than the current one.
    AlreadyOptimal,
    /// A reconfiguration landed recently; the controller is letting the
    /// new generation accumulate a fresh window. (Issued by the
    /// controller, never by [`decide`] itself.)
    Cooldown,
}

/// Which rule produced a [`Recommendation`].
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Cause {
    /// Rule 2: a repeat Byzantine offender crossed the strike threshold.
    StrikeEviction,
    /// Rule 3: straggler margins eroded past the miss budget.
    StandbyDraft,
    /// Rule 4: the measured configuration sits above the λ curve's
    /// optimum by more than the hysteresis band.
    CommunicationCost,
}

/// A concrete `(scheme, λ, N, a)` the policy wants the executor to swap
/// to.
#[derive(Clone, Debug, PartialEq)]
pub struct Recommendation {
    /// The scheme family + knobs to resolve (always pins λ explicitly so
    /// the swap is reproducible).
    pub spec: SchemeSpec,
    /// Byzantine adversary tolerance to provision at.
    pub adversary_tolerance: usize,
    /// Workers the recommended configuration provisions (informational —
    /// derived from the cost model, pinned so audit logs are self-contained).
    pub n_workers: u64,
    /// The rule that fired.
    pub cause: Cause,
    /// Predicted ζ saving of the move, percent (0 for margin-motivated
    /// moves, which *spend* communication to buy robustness).
    pub predicted_gain_pct: f64,
}

/// The policy's verdict for one window.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep the current configuration.
    Hold {
        /// Why the policy held.
        reason: HoldReason,
    },
    /// Swap to the recommended configuration.
    Reconfigure(Recommendation),
}

/// The pure decision function — see the module docs for the rule order.
/// `model` must be the [`CostModel`] of the snapshot's `(s, t, z)`.
pub fn decide(snap: &TelemetrySnapshot, cfg: &PolicyConfig, model: &CostModel) -> Decision {
    if snap.jobs < cfg.min_window_jobs {
        return Decision::Hold {
            reason: HoldReason::InsufficientData,
        };
    }

    // Rule 2 — strike-driven eviction: stop retrying a repeat offender;
    // buy error-correction margin instead. The swap replaces every worker
    // thread, so the striker is evicted as a side effect of provisioning.
    let repeat_offender = snap
        .strikes
        .iter()
        .any(|&(_, strikes)| strikes >= cfg.strike_threshold);
    if repeat_offender && snap.adversary_tolerance < cfg.max_adversary_tolerance {
        let a = snap.adversary_tolerance + 1;
        // The raised quota t²+z+2a must fit under some N(λ); widen λ as
        // needed. If no gap reaches it, fall through — more margin is
        // simply not purchasable at this (s, t, z).
        if let Some((lambda, n)) = model.smallest_with_margin(model.quota(a)) {
            return Decision::Reconfigure(Recommendation {
                spec: SchemeSpec::Age {
                    lambda: Some(lambda as usize),
                },
                adversary_tolerance: a,
                n_workers: n,
                cause: Cause::StrikeEviction,
                predicted_gain_pct: 0.0,
            });
        }
    }

    // Rule 3 — standby draft: eroding straggler margins buy workers.
    let misses = snap.deadline_misses + snap.evictions;
    let miss_pct = misses as f64 * 100.0 / snap.jobs as f64;
    if miss_pct > cfg.miss_budget_pct {
        let target = snap.n_workers + cfg.standby_draft;
        let draft = model
            .smallest_with_margin(target)
            .or_else(|| model.smallest_with_margin(model.max_workers()));
        match draft {
            Some((lambda, n)) if n > snap.n_workers => {
                return Decision::Reconfigure(Recommendation {
                    spec: SchemeSpec::Age {
                        lambda: Some(lambda as usize),
                    },
                    adversary_tolerance: snap.adversary_tolerance,
                    n_workers: n,
                    cause: Cause::StandbyDraft,
                    predicted_gain_pct: 0.0,
                });
            }
            // Already at the top of the curve: hold — never *shrink* a
            // deployment that is missing deadlines.
            _ => {
                return Decision::Hold {
                    reason: HoldReason::AlreadyOptimal,
                }
            }
        }
    }

    // Rule 4 — communication cost: only with measured Phase-2 evidence.
    if snap.w2w_scalars > 0 {
        let (lambda_star, n_star) = model.optimal_lambda();
        if n_star < snap.n_workers {
            let gain = CostModel::gain_pct(snap.n_workers, n_star);
            if gain >= cfg.hysteresis_pct {
                return Decision::Reconfigure(Recommendation {
                    spec: SchemeSpec::Age {
                        lambda: Some(lambda_star as usize),
                    },
                    adversary_tolerance: snap.adversary_tolerance,
                    n_workers: n_star,
                    cause: Cause::CommunicationCost,
                    predicted_gain_pct: gain,
                });
            }
            return Decision::Hold {
                reason: HoldReason::WithinHysteresis,
            };
        }
    }

    Decision::Hold {
        reason: HoldReason::AlreadyOptimal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A healthy Example-1 window at the given λ position on the curve.
    fn snap(lambda: u64, n_workers: u64) -> TelemetrySnapshot {
        TelemetrySnapshot {
            s: 2,
            t: 2,
            z: 2,
            adversary_tolerance: 0,
            lambda: Some(lambda),
            n_workers,
            jobs: 8,
            deadline_misses: 0,
            evictions: 0,
            early_decodes: 0,
            byzantine_detected: 0,
            strikes: Vec::new(),
            w2w_scalars: 100_000,
            mean_job_latency_ns: 1_000_000,
        }
    }

    fn model() -> CostModel {
        CostModel::new(2, 2, 2)
    }

    #[test]
    fn short_window_is_insufficient_data() {
        let mut s = snap(0, 18);
        s.jobs = 3; // below the default min_window_jobs = 4
        s.deadline_misses = 3; // even with screaming signals…
        s.strikes = vec![(5, 99)];
        assert_eq!(
            decide(&s, &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::InsufficientData
            }
        );
    }

    #[test]
    fn lambda_switch_point_clears_default_hysteresis() {
        // λ=0 (N=18) → λ*=2 (N=17): predicted ζ gain 34/306 ≈ 11.1 %,
        // above the 10 % default band.
        let d = decide(&snap(0, 18), &PolicyConfig::default(), &model());
        match d {
            Decision::Reconfigure(rec) => {
                assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
                assert_eq!(rec.n_workers, 17);
                assert_eq!(rec.cause, Cause::CommunicationCost);
                assert!((rec.predicted_gain_pct - 100.0 * 34.0 / 306.0).abs() < 1e-9);
            }
            other => panic!("expected λ switch, got {other:?}"),
        }
    }

    #[test]
    fn hysteresis_band_suppresses_the_same_switch() {
        // The identical snapshot holds when the band is widened to 15 %.
        let cfg = PolicyConfig {
            hysteresis_pct: 15.0,
            ..PolicyConfig::default()
        };
        assert_eq!(
            decide(&snap(0, 18), &cfg, &model()),
            Decision::Hold {
                reason: HoldReason::WithinHysteresis
            }
        );
    }

    #[test]
    fn no_phase2_evidence_means_no_communication_move() {
        // Same suboptimal position, but the window saw no worker↔worker
        // exchange — nothing to save, so the policy holds.
        let mut s = snap(0, 18);
        s.w2w_scalars = 0;
        assert_eq!(
            decide(&s, &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );
    }

    #[test]
    fn optimum_position_holds() {
        assert_eq!(
            decide(&snap(2, 17), &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );
    }

    #[test]
    fn entangled_walks_onto_the_age_curve() {
        // Entangled (N=19, no λ) → AGE λ*=2 (N=17): gain ≈ 20.5 %.
        let mut s = snap(0, 19);
        s.lambda = None;
        let d = decide(&s, &PolicyConfig::default(), &model());
        match d {
            Decision::Reconfigure(rec) => {
                assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
                assert_eq!(rec.cause, Cause::CommunicationCost);
                assert!((rec.predicted_gain_pct - 100.0 * 70.0 / 342.0).abs() < 1e-9);
            }
            other => panic!("expected scheme switch, got {other:?}"),
        }
    }

    #[test]
    fn eroded_margins_draft_a_standby_worker() {
        // 3 misses over 8 jobs = 37.5 % > the 25 % budget: draft from 17
        // up the curve — the cheapest N ≥ 18 is λ=0 (ties toward small λ).
        let mut s = snap(2, 17);
        s.deadline_misses = 2;
        s.evictions = 1;
        let d = decide(&s, &PolicyConfig::default(), &model());
        match d {
            Decision::Reconfigure(rec) => {
                assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(0) });
                assert_eq!(rec.n_workers, 18);
                assert_eq!(rec.cause, Cause::StandbyDraft);
            }
            other => panic!("expected standby draft, got {other:?}"),
        }
    }

    #[test]
    fn draft_at_the_top_of_the_curve_holds() {
        // Already at the max N=18: the policy must not shrink a struggling
        // deployment, so it holds rather than dropping back to 17.
        let mut s = snap(0, 18);
        s.deadline_misses = 4;
        assert_eq!(
            decide(&s, &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );
    }

    #[test]
    fn strike_threshold_prefers_eviction_over_retry() {
        // A slot with 3 cumulative strikes: raise a to 1 (quota 8) on the
        // cheapest λ that covers it — λ=2, N=17 — even though the window
        // is otherwise healthy.
        let mut s = snap(2, 17);
        s.strikes = vec![(4, 3)];
        s.byzantine_detected = 1;
        let d = decide(&s, &PolicyConfig::default(), &model());
        match d {
            Decision::Reconfigure(rec) => {
                assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
                assert_eq!(rec.adversary_tolerance, 1);
                assert_eq!(rec.cause, Cause::StrikeEviction);
            }
            other => panic!("expected strike eviction, got {other:?}"),
        }
    }

    #[test]
    fn strikes_below_threshold_do_not_fire() {
        let mut s = snap(2, 17);
        s.strikes = vec![(4, 2), (9, 1)];
        assert_eq!(
            decide(&s, &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );
    }

    #[test]
    fn adversary_tolerance_ceiling_is_respected() {
        // Already at max_adversary_tolerance: strikes cannot raise a
        // further, so the rule falls through to the healthy-window hold.
        let mut s = snap(2, 17);
        s.adversary_tolerance = 2;
        s.strikes = vec![(4, 10)];
        s.w2w_scalars = 0;
        assert_eq!(
            decide(&s, &PolicyConfig::default(), &model()),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );
    }

    #[test]
    fn decision_table_is_deterministic() {
        // Same snapshot in, same decision out — the purity contract the
        // controller and the seeded CI lane rely on.
        let s = snap(0, 18);
        let cfg = PolicyConfig::default();
        let m = model();
        let first = decide(&s, &cfg, &m);
        for _ in 0..10 {
            assert_eq!(decide(&s, &cfg, &m), first);
        }
    }
}
