//! Adaptive provisioning: the closed-loop autoscaler that retunes
//! `(scheme, λ, N, a)` from live telemetry.
//!
//! The whole point of AGE codes is *adapting* the gap λ to the worker
//! budget and cost tradeoff — but a λ chosen at provision time is a bet
//! about conditions the deployment only discovers while serving. This
//! module closes the loop with three separable pieces:
//!
//! * the **policy engine** ([`policy::decide`]) — a pure function from a
//!   [`TelemetrySnapshot`] + the analytical [`CostModel`] to a
//!   [`Decision`]; unit-tested as a decision table, no runtime needed;
//! * the **reconfiguration executor** — [`Deployment::reconfigure`], the
//!   blue/green swap that provisions the recommended generation beside
//!   the live one and cuts submissions over with zero dropped jobs;
//! * the **controller loop** ([`Autoscaler`]) — samples a deployment's
//!   health on an interval (or on explicit [`Autoscaler::tick`] calls for
//!   deterministic tests), feeds the policy, applies its recommendations,
//!   and records every decision in a typed audit log surfaced through
//!   [`Autoscaler::health`].
//!
//! # Window semantics
//!
//! The controller's telemetry window spans **since the last
//! reconfiguration** (or since attach): deployment-lifetime totals are
//! delta'd against a baseline that resets only when a swap lands. That
//! makes decisions reproducible for a given job stream — the same jobs
//! observed over one tick or ten produce the same cumulative window —
//! and it matches the generation-scoped health counters, which reset at
//! each swap anyway. After a swap the controller holds for
//! `cooldown_ticks` ticks ([`HoldReason::Cooldown`]) so the green
//! generation accumulates a fresh window before being judged.
//!
//! ```no_run
//! use std::sync::Arc;
//! use cmpc::autoscale::{AutoscaleConfig, Autoscaler};
//! use cmpc::codes::SchemeParams;
//! use cmpc::mpc::protocol::ProtocolConfig;
//! use cmpc::{Deployment, SchemeSpec};
//!
//! # fn main() -> cmpc::Result<()> {
//! let dep = Arc::new(Deployment::provision(
//!     SchemeSpec::Age { lambda: Some(0) }, // deliberately suboptimal
//!     SchemeParams::try_new(2, 2, 2)?,
//!     ProtocolConfig::default(),
//! )?);
//! let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());
//! // … run jobs …
//! scaler.tick(); // manual control loop step; spawn() runs it on a thread
//! println!("{:?}", scaler.health().decisions.last());
//! # Ok(())
//! # }
//! ```

pub mod policy;

pub use policy::{
    decide, Cause, Decision, HoldReason, PolicyConfig, Recommendation, TelemetrySnapshot,
};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::analysis::CostModel;
use crate::metrics::RuntimeHealthReport;
use crate::mpc::deployment::{Deployment, DeploymentTelemetry};

/// Retained [`DecisionRecord`]s (the counters stay exact; only per-event
/// detail rotates).
const AUDIT_LOG_CAP: usize = 256;

/// Controller configuration: the sampling cadence plus the policy's
/// thresholds.
#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    /// Sampling interval of the spawned controller thread (ignored by
    /// manual [`Autoscaler::tick`] driving).
    pub interval: Duration,
    /// Ticks to hold ([`HoldReason::Cooldown`]) after a swap lands, so the
    /// green generation accumulates a fresh window before being judged.
    pub cooldown_ticks: u64,
    /// The policy engine's thresholds.
    pub policy: PolicyConfig,
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            interval: Duration::from_millis(250),
            cooldown_ticks: 2,
            policy: PolicyConfig::default(),
        }
    }
}

/// What the controller did with one decision.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// The recommendation was applied: a blue/green swap produced this
    /// generation.
    Applied {
        /// Generation number the swap produced.
        generation: u64,
        /// Scheme name of the retired blue generation.
        from: String,
        /// Scheme name of the new green generation.
        to: String,
    },
    /// The swap was attempted and failed; the blue generation kept
    /// serving (the error is preserved verbatim).
    Failed(String),
    /// A hold — nothing to apply.
    NotApplied,
}

/// One audited controller step: the tick number, the window it judged,
/// the policy's decision, and what happened to it.
#[derive(Clone, Debug, PartialEq)]
pub struct DecisionRecord {
    /// 1-based tick number.
    pub tick: u64,
    /// Completed jobs in the judged window.
    pub window_jobs: u64,
    /// The policy's verdict.
    pub decision: Decision,
    /// What the controller did with it.
    pub outcome: Outcome,
}

/// Point-in-time controller health: counters plus the audit trail and the
/// deployment's own runtime report — the `health()` surface the issue's
/// audit-log contract names.
#[derive(Clone, Debug)]
pub struct AutoscaleHealth {
    /// Controller steps taken (manual or timed).
    pub ticks: u64,
    /// Blue/green swaps applied.
    pub reconfigurations: u64,
    /// Hold decisions (including cooldown holds).
    pub holds: u64,
    /// Swap attempts that failed (blue kept serving).
    pub failed: u64,
    /// Retired blue generations still draining in-flight jobs.
    pub retired_draining: u64,
    /// The audit trail, oldest first (last 256 decisions; the counters
    /// above stay exact).
    pub decisions: Vec<DecisionRecord>,
    /// The active generation's runtime health report.
    pub runtime: RuntimeHealthReport,
}

/// The telemetry baseline a window is delta'd against; reset whenever a
/// swap lands (generation health counters reset there anyway).
#[derive(Default)]
struct Baseline {
    telemetry: DeploymentTelemetry,
    deadline_misses: u64,
    evictions: u64,
    early_decodes: u64,
    byzantine_detected: u64,
}

struct ControllerState {
    baseline: Baseline,
    cooldown_remaining: u64,
}

struct Inner {
    dep: Arc<Deployment>,
    config: AutoscaleConfig,
    /// The λ curve of the deployment's (s, t, z), enumerated once.
    model: CostModel,
    state: Mutex<ControllerState>,
    ticks: AtomicU64,
    reconfigurations: AtomicU64,
    holds: AtomicU64,
    failed: AtomicU64,
    decisions: Mutex<Vec<DecisionRecord>>,
}

impl Inner {
    fn record(&self, record: DecisionRecord) {
        let mut log = self.decisions.lock().unwrap();
        if log.len() == AUDIT_LOG_CAP {
            log.remove(0);
        }
        log.push(record);
    }

    fn tick(&self) -> Decision {
        let tick = self.ticks.fetch_add(1, Ordering::Relaxed) + 1;
        let mut state = self.state.lock().unwrap();

        if state.cooldown_remaining > 0 {
            state.cooldown_remaining -= 1;
            drop(state);
            let decision = Decision::Hold {
                reason: HoldReason::Cooldown,
            };
            self.holds.fetch_add(1, Ordering::Relaxed);
            self.record(DecisionRecord {
                tick,
                window_jobs: 0,
                decision: decision.clone(),
                outcome: Outcome::NotApplied,
            });
            self.dep.drain_retired();
            return decision;
        }

        // Assemble the window: deployment-lifetime telemetry delta'd
        // against the post-swap baseline, generation-scoped health
        // counters likewise (saturating: an external swap between ticks
        // can only shrink the window, never panic it).
        let tel = self.dep.telemetry();
        let health = self.dep.health();
        let params = self.dep.params();
        let b = &state.baseline;
        let jobs = tel.jobs_completed.saturating_sub(b.telemetry.jobs_completed);
        let latency_ns = tel
            .latency_ns_total
            .saturating_sub(b.telemetry.latency_ns_total);
        let snapshot = TelemetrySnapshot {
            s: params.s,
            t: params.t,
            z: params.z,
            adversary_tolerance: params.adversary_tolerance,
            lambda: self.dep.gap_lambda(),
            n_workers: self.dep.n_workers() as u64,
            jobs,
            deadline_misses: health.deadline_misses.saturating_sub(b.deadline_misses),
            evictions: health.evictions.saturating_sub(b.evictions),
            early_decodes: health.early_decodes.saturating_sub(b.early_decodes),
            byzantine_detected: health
                .byzantine_detected
                .saturating_sub(b.byzantine_detected),
            strikes: health.worker_strikes.clone(),
            w2w_scalars: tel.w2w_scalars.saturating_sub(b.telemetry.w2w_scalars),
            mean_job_latency_ns: if jobs > 0 { latency_ns / jobs } else { 0 },
        };

        let decision = policy::decide(&snapshot, &self.config.policy, &self.model);
        let outcome = match &decision {
            Decision::Hold { .. } => {
                self.holds.fetch_add(1, Ordering::Relaxed);
                Outcome::NotApplied
            }
            Decision::Reconfigure(rec) => {
                match self.dep.reconfigure(rec.spec, rec.adversary_tolerance) {
                    Ok(swap) => {
                        self.reconfigurations.fetch_add(1, Ordering::Relaxed);
                        // Fresh generation → fresh window + cooldown.
                        state.baseline = Baseline {
                            telemetry: self.dep.telemetry(),
                            ..Baseline::default()
                        };
                        state.cooldown_remaining = self.config.cooldown_ticks;
                        Outcome::Applied {
                            generation: swap.generation,
                            from: swap.from,
                            to: swap.to,
                        }
                    }
                    Err(e) => {
                        self.failed.fetch_add(1, Ordering::Relaxed);
                        Outcome::Failed(e.to_string())
                    }
                }
            }
        };
        drop(state);

        self.record(DecisionRecord {
            tick,
            window_jobs: jobs,
            decision: decision.clone(),
            outcome,
        });
        self.dep.drain_retired();
        decision
    }
}

/// The controller: owns the policy thresholds and the audit log, drives
/// [`policy::decide`] over a live [`Deployment`], and applies its
/// recommendations via blue/green swap. Construct with
/// [`Autoscaler::new`] for manual (deterministic) ticking or
/// [`Autoscaler::spawn`] for a sampling thread; dropping the autoscaler
/// stops the thread. The deployment keeps serving either way — the
/// autoscaler is an *observer with a lever*, never on the job path.
pub struct Autoscaler {
    inner: Arc<Inner>,
    stop: Arc<AtomicBool>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl Autoscaler {
    /// Attach a controller to `dep` without a sampling thread: the caller
    /// drives it with explicit [`Autoscaler::tick`] calls. This is the
    /// deterministic mode the decision-table integration tests and the CI
    /// lane use.
    pub fn new(dep: Arc<Deployment>, config: AutoscaleConfig) -> Autoscaler {
        let params = dep.params();
        let model = CostModel::new(params.s, params.t, params.z);
        let baseline = Baseline {
            telemetry: dep.telemetry(),
            ..Baseline::default()
        };
        Autoscaler {
            inner: Arc::new(Inner {
                dep,
                config,
                model,
                state: Mutex::new(ControllerState {
                    baseline,
                    cooldown_remaining: 0,
                }),
                ticks: AtomicU64::new(0),
                reconfigurations: AtomicU64::new(0),
                holds: AtomicU64::new(0),
                failed: AtomicU64::new(0),
                decisions: Mutex::new(Vec::new()),
            }),
            stop: Arc::new(AtomicBool::new(false)),
            thread: Mutex::new(None),
        }
    }

    /// [`Autoscaler::new`] plus a sampling thread that ticks every
    /// `config.interval` until the autoscaler is dropped.
    pub fn spawn(dep: Arc<Deployment>, config: AutoscaleConfig) -> Autoscaler {
        let interval = config.interval;
        let scaler = Autoscaler::new(dep, config);
        let inner = scaler.inner.clone();
        let stop = scaler.stop.clone();
        let handle = std::thread::Builder::new()
            .name("cmpc-autoscaler".to_string())
            .spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    std::thread::park_timeout(interval);
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    inner.tick();
                }
            })
            .expect("spawning the autoscaler thread");
        *scaler.thread.lock().unwrap() = Some(handle);
        scaler
    }

    /// One controller step: assemble the window, run the policy, apply a
    /// recommendation (if any), audit the outcome, sweep retired
    /// generations. Returns the decision so tests can assert on it.
    pub fn tick(&self) -> Decision {
        self.inner.tick()
    }

    /// The deployment this controller steers.
    pub fn deployment(&self) -> &Arc<Deployment> {
        &self.inner.dep
    }

    /// The controller's configuration.
    pub fn config(&self) -> &AutoscaleConfig {
        &self.inner.config
    }

    /// Counters + audit trail + the active generation's runtime report.
    pub fn health(&self) -> AutoscaleHealth {
        AutoscaleHealth {
            ticks: self.inner.ticks.load(Ordering::Relaxed),
            reconfigurations: self.inner.reconfigurations.load(Ordering::Relaxed),
            holds: self.inner.holds.load(Ordering::Relaxed),
            failed: self.inner.failed.load(Ordering::Relaxed),
            retired_draining: self.inner.dep.retired_generations() as u64,
            decisions: self.inner.decisions.lock().unwrap().clone(),
            runtime: self.inner.dep.health(),
        }
    }
}

impl Drop for Autoscaler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.thread.lock().unwrap().take() {
            handle.thread().unpark();
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{SchemeParams, SchemeSpec};
    use crate::matrix::FpMat;
    use crate::mpc::protocol::ProtocolConfig;
    use crate::util::rng::ChaChaRng;

    fn provision(lambda: Option<usize>) -> Arc<Deployment> {
        Arc::new(
            Deployment::provision(
                SchemeSpec::Age { lambda },
                SchemeParams::new(2, 2, 2),
                ProtocolConfig::default(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn controller_converges_to_lambda_star_and_cools_down() {
        let dep = provision(Some(0)); // N = 18, suboptimal
        let scaler = Autoscaler::new(dep.clone(), AutoscaleConfig::default());

        // Tick 1: empty window → insufficient data.
        assert_eq!(
            scaler.tick(),
            Decision::Hold {
                reason: HoldReason::InsufficientData
            }
        );

        let mut rng = ChaChaRng::seed_from_u64(31);
        for _ in 0..4 {
            let a = FpMat::random(&mut rng, 8, 8);
            let b = FpMat::random(&mut rng, 8, 8);
            assert!(dep.execute(&a, &b).unwrap().verified);
        }

        // Tick 2: the window shows 4 jobs of real Phase-2 traffic at a
        // suboptimal λ → reconfigure to λ* = 2.
        match scaler.tick() {
            Decision::Reconfigure(rec) => {
                assert_eq!(rec.spec, SchemeSpec::Age { lambda: Some(2) });
                assert_eq!(rec.cause, Cause::CommunicationCost);
            }
            other => panic!("expected reconfigure, got {other:?}"),
        }
        assert_eq!(dep.n_workers(), 17);
        assert_eq!(dep.generation(), 1);

        // Ticks 3–4: cooldown holds.
        for _ in 0..2 {
            assert_eq!(
                scaler.tick(),
                Decision::Hold {
                    reason: HoldReason::Cooldown
                }
            );
        }

        // Post-cooldown the fresh window is empty again; run jobs on the
        // green generation and confirm the controller now holds at λ*.
        for _ in 0..4 {
            let a = FpMat::random(&mut rng, 8, 8);
            let b = FpMat::random(&mut rng, 8, 8);
            assert!(dep.execute(&a, &b).unwrap().verified);
        }
        assert_eq!(
            scaler.tick(),
            Decision::Hold {
                reason: HoldReason::AlreadyOptimal
            }
        );

        let health = scaler.health();
        assert_eq!(health.ticks, 5);
        assert_eq!(health.reconfigurations, 1);
        assert_eq!(health.holds, 4);
        assert_eq!(health.failed, 0);
        assert_eq!(health.retired_draining, 0, "blue was drained");
        assert_eq!(health.decisions.len(), 5);
        match &health.decisions[1].outcome {
            Outcome::Applied { generation, from, to } => {
                assert_eq!(*generation, 1);
                assert_eq!(from, "AGE-CMPC(λ=0)");
                assert_eq!(to, "AGE-CMPC(λ=2)");
            }
            other => panic!("expected applied outcome, got {other:?}"),
        }
    }

    #[test]
    fn spawned_controller_stops_on_drop() {
        let dep = provision(None);
        let scaler = Autoscaler::spawn(
            dep,
            AutoscaleConfig {
                interval: Duration::from_millis(5),
                ..AutoscaleConfig::default()
            },
        );
        // Give the thread a chance to take at least one timed tick.
        std::thread::sleep(Duration::from_millis(40));
        assert!(scaler.health().ticks >= 1);
        drop(scaler); // must join promptly, not hang
    }
}
