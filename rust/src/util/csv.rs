//! Tiny CSV writer used by the figure-regeneration harness to dump the data
//! series behind each paper figure (`results/fig*.csv`).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent directories) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            cols: header.len(),
        })
    }

    /// Write one data row; panics if the column count mismatches the header.
    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(
            fields.len(),
            self.cols,
            "csv row has {} fields, header has {}",
            fields.len(),
            self.cols
        );
        writeln!(self.out, "{}", fields.join(","))
    }

    /// Flush buffered rows to the underlying file.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

/// Format helper: shorthand to stringify heterogeneous row items.
#[macro_export]
macro_rules! csv_row {
    ($w:expr, $($x:expr),+ $(,)?) => {
        $w.row(&[$(format!("{}", $x)),+]).expect("csv write")
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("cmpc_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            csv_row!(w, 1, 2.5);
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    #[should_panic(expected = "csv row has")]
    fn row_width_checked() {
        let dir = std::env::temp_dir().join("cmpc_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into()]).unwrap();
    }
}
