//! Deterministic ChaCha12 random number generator.
//!
//! The CMPC constructions require secret coefficients "chosen independently
//! and uniformly at random" from `GF(p)`; ChaCha12 is a conservative stream
//! cipher core giving cryptographic-quality bytes while remaining fully
//! deterministic under a seed (essential for reproducible experiments and for
//! the privacy test harness, which replays protocol runs under different
//! secret streams).

/// ChaCha12 stream RNG.
///
/// Produces the ChaCha keystream for an all-zero nonce with a 64-bit block
/// counter; the 256-bit key is derived from the seed by splat-and-mix.
pub struct ChaChaRng {
    key: [u32; 8],
    counter: u64,
    buf: [u32; 16],
    /// Next unread word in `buf`; 16 means exhausted.
    idx: usize,
}

const ROUNDS: usize = 12;

#[inline(always)]
fn quarter_round(state: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(16);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(12);
    state[a] = state[a].wrapping_add(state[b]);
    state[d] = (state[d] ^ state[a]).rotate_left(8);
    state[c] = state[c].wrapping_add(state[d]);
    state[b] = (state[b] ^ state[c]).rotate_left(7);
}

impl ChaChaRng {
    /// Build a generator from a 256-bit key.
    pub fn from_key(key: [u32; 8]) -> ChaChaRng {
        ChaChaRng {
            key,
            counter: 0,
            buf: [0; 16],
            idx: 16,
        }
    }

    /// Derive a generator from a 64-bit seed (splitmix64-expanded to 256 bits).
    pub fn seed_from_u64(seed: u64) -> ChaChaRng {
        let mut s = seed;
        let mut key = [0u32; 8];
        for i in 0..4 {
            // splitmix64 step
            s = s.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            key[2 * i] = z as u32;
            key[2 * i + 1] = (z >> 32) as u32;
        }
        ChaChaRng::from_key(key)
    }

    /// Fork an independent child stream (used to give each protocol node its
    /// own secret stream from one job seed).
    pub fn fork(&mut self) -> ChaChaRng {
        let mut key = [0u32; 8];
        for k in key.iter_mut() {
            *k = self.next_u32();
        }
        ChaChaRng::from_key(key)
    }

    fn refill(&mut self) {
        let mut state: [u32; 16] = [
            0x61707865,
            0x3320646e,
            0x79622d32,
            0x6b206574,
            self.key[0],
            self.key[1],
            self.key[2],
            self.key[3],
            self.key[4],
            self.key[5],
            self.key[6],
            self.key[7],
            self.counter as u32,
            (self.counter >> 32) as u32,
            0,
            0,
        ];
        let initial = state;
        for _ in 0..ROUNDS / 2 {
            // column rounds
            quarter_round(&mut state, 0, 4, 8, 12);
            quarter_round(&mut state, 1, 5, 9, 13);
            quarter_round(&mut state, 2, 6, 10, 14);
            quarter_round(&mut state, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut state, 0, 5, 10, 15);
            quarter_round(&mut state, 1, 6, 11, 12);
            quarter_round(&mut state, 2, 7, 8, 13);
            quarter_round(&mut state, 3, 4, 9, 14);
        }
        for i in 0..16 {
            state[i] = state[i].wrapping_add(initial[i]);
        }
        self.buf = state;
        self.counter = self.counter.wrapping_add(1);
        self.idx = 0;
    }

    /// Next 32 bits of the keystream.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.idx >= 16 {
            self.refill();
        }
        let v = self.buf[self.idx];
        self.idx += 1;
        v
    }

    /// Next 64 bits of the keystream (two `next_u32` draws, low half first).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) | ((self.next_u32() as u64) << 32)
    }

    /// Uniform value in `[0, bound)` by rejection sampling (no modulo bias).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        if bound.is_power_of_two() {
            return self.next_u64() & (bound - 1);
        }
        let zone = u64::MAX - (u64::MAX % bound);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % bound;
            }
        }
    }

    /// Uniform field element of `GF(p)`.
    #[inline]
    pub fn field_element(&mut self) -> u64 {
        self.gen_range(crate::ff::P)
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range(bound as u64) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = ChaChaRng::seed_from_u64(42);
        let mut b = ChaChaRng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = ChaChaRng::seed_from_u64(1);
        let mut b = ChaChaRng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = ChaChaRng::seed_from_u64(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.gen_range(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn field_element_uniformish() {
        // coarse chi-square over 16 buckets
        let mut rng = ChaChaRng::seed_from_u64(5);
        let n = 64_000usize;
        let mut buckets = [0usize; 16];
        for _ in 0..n {
            let v = rng.field_element();
            buckets[(v * 16 / crate::ff::P) as usize] += 1;
        }
        let expected = n as f64 / 16.0;
        let chi2: f64 = buckets
            .iter()
            .map(|&c| {
                let d = c as f64 - expected;
                d * d / expected
            })
            .sum();
        // 15 dof, p=0.001 critical value ~ 37.7
        assert!(chi2 < 37.7, "chi2 = {chi2}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = ChaChaRng::seed_from_u64(11);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = ChaChaRng::seed_from_u64(3);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
