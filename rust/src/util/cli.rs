//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset the `cmpc` binary and examples need:
//! `prog subcommand --key value --flag positional`.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, bare `--flag`
/// switches and positional arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// First non-dashed token, e.g. `node` in `cmpc node --role master`.
    pub subcommand: Option<String>,
    /// `--key value` (and `--key=value`) pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches, in order of appearance.
    pub flags: Vec<String>,
    /// Non-dashed tokens after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv\[0\]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    /// Parse a raw argv vector. The first non-dashed token becomes the
    /// subcommand; `--key value` pairs become options unless the value
    /// starts with `--`, in which case `--key` is a bare flag.
    pub fn parse(argv: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Whether the bare switch `--name` was present.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Typed option with default; exits with a usage error on parse failure.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!("error: --{name} expects a {}", std::any::type_name::<T>());
                std::process::exit(2);
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(sv(&["run", "--m", "256", "--verbose", "--s=2", "extra"]));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("m"), Some("256"));
        assert_eq!(a.get("s"), Some("2"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(sv(&["x", "--n", "7"]));
        assert_eq!(a.get_parse("n", 0usize), 7);
        assert_eq!(a.get_parse("missing", 3usize), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(sv(&["x", "--check"]));
        assert!(a.flag("check"));
    }
}
