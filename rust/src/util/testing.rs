//! Minimal randomized property-testing driver (proptest is unavailable
//! offline).
//!
//! [`property`] runs a closure against many seeded RNG streams and reports
//! the failing seed so a failure reproduces deterministically:
//!
//! ```text
//! property 'field axioms' failed at case 381 (seed 0x1f3a...): mul assoc
//! ```

use super::rng::ChaChaRng;

/// Run `cases` randomized checks. The closure receives a fresh deterministic
/// RNG per case and returns `Err(description)` to fail.
///
/// Set `CMPC_PROPTEST_SEED` to re-run a single failing case.
pub fn property<F>(name: &str, cases: usize, mut f: F)
where
    F: FnMut(&mut ChaChaRng) -> Result<(), String>,
{
    if let Ok(s) = std::env::var("CMPC_PROPTEST_SEED") {
        let seed = u64::from_str_radix(s.trim_start_matches("0x"), 16)
            .or_else(|_| s.parse::<u64>())
            .expect("CMPC_PROPTEST_SEED must be an integer");
        let mut rng = ChaChaRng::seed_from_u64(seed);
        if let Err(e) = f(&mut rng) {
            panic!("property '{name}' failed under CMPC_PROPTEST_SEED={seed:#x}: {e}");
        }
        return;
    }
    // Base seed mixes the property name so distinct properties explore
    // distinct streams even with identical case indices.
    let base: u64 = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = ChaChaRng::seed_from_u64(seed);
        if let Err(e) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {e}\n\
                 reproduce with CMPC_PROPTEST_SEED={seed:#x}"
            );
        }
    }
}

/// Convenience: draw a value uniformly from a slice.
pub fn pick<'a, T>(rng: &mut ChaChaRng, xs: &'a [T]) -> &'a T {
    &xs[rng.gen_index(xs.len())]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes_trivially() {
        property("trivial", 100, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn property_reports_failure() {
        property("always fails", 10, |_| Err("boom".into()));
    }

    #[test]
    fn pick_draws_from_slice() {
        let mut rng = ChaChaRng::seed_from_u64(1);
        let xs = [1, 2, 3];
        for _ in 0..50 {
            assert!(xs.contains(pick(&mut rng, &xs)));
        }
    }
}
