//! Self-contained utility substrates.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (rand, clap, serde, proptest, criterion) are
//! unavailable. This module provides the small, well-tested subset the rest
//! of the library needs:
//!
//! * [`rng`] — a ChaCha12-based deterministic CSPRNG (secret coefficients,
//!   test-case generation).
//! * [`cli`] — a minimal `--flag value` argv parser for the `cmpc` binary and
//!   the examples.
//! * [`testing`] — a seeded randomized property-test driver.
//! * [`csv`] — tiny CSV/TSV writers for the figure regeneration harness.

pub mod cli;
pub mod csv;
pub mod rng;
pub mod testing;
