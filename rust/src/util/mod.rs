//! Self-contained utility substrates.
//!
//! The build environment vendors no registry crates at all (the crate is
//! dependency-free by design), so the usual ecosystem crates (rand, clap,
//! serde, proptest, criterion, anyhow) are unavailable. This module provides
//! the small, well-tested subset the rest of the library needs:
//!
//! * [`rng`] — a ChaCha12-based deterministic CSPRNG (secret coefficients,
//!   test-case generation).
//! * [`cli`] — a minimal `--flag value` argv parser for the `cmpc` binary and
//!   the examples.
//! * [`testing`] — a seeded randomized property-test driver.
//! * [`csv`] — tiny CSV/TSV writers for the figure regeneration harness.

pub mod cli;
pub mod csv;
pub mod rng;
pub mod testing;
