//! The unified scheme registry: serializable [`SchemeSpec`]s that resolve to
//! shared [`CmpcScheme`] instances.
//!
//! A serving layer should not hand-construct concrete scheme types per
//! request. A [`SchemeSpec`] names a construction *family* (plus any family
//! knobs, like AGE's gap `λ`), and [`SchemeSpec::resolve`] instantiates it
//! for a validated [`SchemeParams`] triple, returning `Arc<dyn CmpcScheme>`
//! so the instance can be shared by a deployment, its workers, and the
//! coordinator's cache.
//!
//! [`SchemeSpec::resolve_adaptive`] is Phase 0 of Algorithm 3 generalized
//! across the registry: resolve every constructible family and keep the one
//! with the fewest provisioned workers. The same routine backs
//! `SchemePolicy::Adaptive` in the coordinator.

use std::sync::Arc;

use super::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc, SchemeParams};
use crate::analysis::SchemeKind;
use crate::error::{CmpcError, Result};

/// A constructible scheme family, resolvable against any valid `(s, t, z)`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SchemeSpec {
    /// AGE-CMPC. `lambda: None` runs the exact `λ*` scan of Phase 0;
    /// `Some(λ)` pins the gap (must satisfy `λ ≤ z`).
    Age {
        /// Exponent base override; `None` picks the cost-optimal λ.
        lambda: Option<usize>,
    },
    /// PolyDot-CMPC (Algorithm 1 secret terms over PolyDot coded terms).
    PolyDot,
    /// Entangled-CMPC baseline (degree-based provisioning of [15]).
    Entangled,
}

impl SchemeSpec {
    /// Every family the registry can construct, with default knobs.
    pub const CONSTRUCTIBLE: [SchemeSpec; 3] = [
        SchemeSpec::Age { lambda: None },
        SchemeSpec::PolyDot,
        SchemeSpec::Entangled,
    ];

    /// Human-readable family label (without instance knobs).
    pub fn label(&self) -> &'static str {
        match self {
            SchemeSpec::Age { .. } => "AGE-CMPC",
            SchemeSpec::PolyDot => "PolyDot-CMPC",
            SchemeSpec::Entangled => "Entangled-CMPC",
        }
    }

    /// Instantiate this family for `params` (Byzantine adversary tolerance
    /// rides along onto the resolved instance).
    pub fn resolve(&self, params: SchemeParams) -> Result<Arc<dyn CmpcScheme>> {
        let SchemeParams {
            s,
            t,
            z,
            adversary_tolerance: a,
        } = params;
        let scheme: Arc<dyn CmpcScheme> = match *self {
            SchemeSpec::Age { lambda: None } => {
                Arc::new(AgeCmpc::try_with_optimal_lambda(s, t, z)?.with_adversary_tolerance(a))
            }
            SchemeSpec::Age { lambda: Some(l) } => {
                Arc::new(AgeCmpc::try_new(s, t, z, l as u64)?.with_adversary_tolerance(a))
            }
            SchemeSpec::PolyDot => {
                Arc::new(PolyDotCmpc::try_new(s, t, z)?.with_adversary_tolerance(a))
            }
            SchemeSpec::Entangled => {
                Arc::new(EntangledCmpc::try_new(s, t, z)?.with_adversary_tolerance(a))
            }
        };
        Ok(scheme)
    }

    /// Phase 0 across the whole registry: the constructible scheme with the
    /// fewest provisioned workers for `params` (ties broken in
    /// [`SchemeSpec::CONSTRUCTIBLE`] order, i.e. toward AGE).
    pub fn resolve_adaptive(params: SchemeParams) -> Result<Arc<dyn CmpcScheme>> {
        let mut best: Option<Arc<dyn CmpcScheme>> = None;
        for spec in SchemeSpec::CONSTRUCTIBLE {
            let cand = spec.resolve(params)?;
            let better = match &best {
                Some(b) => cand.n_workers() < b.n_workers(),
                None => true,
            };
            if better {
                best = Some(cand);
            }
        }
        best.ok_or_else(|| CmpcError::InvalidParams("empty scheme registry".to_string()))
    }

    /// Map an analysis-level [`SchemeKind`] onto the registry. The
    /// formula-only baselines (SSMM, GCSA-NA) cannot be run, only analyzed —
    /// they yield [`CmpcError::InvalidParams`].
    pub fn from_kind(kind: SchemeKind) -> Result<SchemeSpec> {
        match kind {
            SchemeKind::Age => Ok(SchemeSpec::Age { lambda: None }),
            SchemeKind::PolyDot => Ok(SchemeSpec::PolyDot),
            SchemeKind::Entangled => Ok(SchemeSpec::Entangled),
            SchemeKind::Ssmm | SchemeKind::GcsaNa => Err(CmpcError::InvalidParams(format!(
                "{} is a formula-level baseline, not constructible",
                kind.label()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_matches_direct_construction() {
        let p = SchemeParams::new(2, 2, 2);
        let age = SchemeSpec::Age { lambda: None }.resolve(p).unwrap();
        assert_eq!(age.n_workers(), 17);
        let pinned = SchemeSpec::Age { lambda: Some(0) }.resolve(p).unwrap();
        assert_eq!(pinned.n_workers(), 18);
        let pd = SchemeSpec::PolyDot.resolve(p).unwrap();
        assert_eq!(pd.name(), "PolyDot-CMPC");
        let ent = SchemeSpec::Entangled.resolve(p).unwrap();
        assert_eq!(ent.n_workers(), 19);
    }

    #[test]
    fn adaptive_picks_minimum_workers() {
        // Example 1 territory: AGE(17) < PolyDot(18) < Entangled(19).
        let best = SchemeSpec::resolve_adaptive(SchemeParams::new(2, 2, 2)).unwrap();
        assert_eq!(best.n_workers(), 17);
        assert!(best.name().starts_with("AGE"));
    }

    #[test]
    fn invalid_lambda_is_typed_error() {
        let p = SchemeParams::new(2, 2, 2);
        let err = SchemeSpec::Age { lambda: Some(3) }.resolve(p).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }

    #[test]
    fn formula_baselines_not_constructible() {
        for kind in [SchemeKind::Ssmm, SchemeKind::GcsaNa] {
            let err = SchemeSpec::from_kind(kind).unwrap_err();
            assert!(err.to_string().contains("formula-level baseline"));
        }
        assert_eq!(
            SchemeSpec::from_kind(SchemeKind::Age).unwrap(),
            SchemeSpec::Age { lambda: None }
        );
    }
}
