//! CMPC code constructions.
//!
//! A scheme is fully described by *which powers of `x`* carry which payloads
//! in the two share-generating polynomials
//!
//! ```text
//! F_A(x) = C_A(x) + S_A(x)        F_B(x) = C_B(x) + S_B(x)
//! ```
//!
//! * `C_A` carries the `t×s` blocks of `Aᵀ` (coded term),
//! * `C_B` carries the `s×t` blocks of `B`,
//! * `S_A`, `S_B` carry `z` uniformly random matrices each (secret terms),
//! * the *important powers* of `H(x) = F_A(x)·F_B(x)` are the exponents whose
//!   coefficients equal the output blocks `Y_{i,l} = Σ_j (Aᵀ)_{i,j} B_{j,l}`.
//!
//! Everything else — worker counts (eq. 23), decodability, the protocol's
//! share generation — derives from these maps, so the [`CmpcScheme`] trait
//! exposes exactly them. Implementations:
//!
//! * [`PolyDotCmpc`] — §IV, PolyDot coded terms + garbage-aware secrets
//!   (Algorithm 1 / Theorem 1).
//! * [`AgeCmpc`] — §V, Adaptive Gap Entangled codes (Algorithm 2 /
//!   Theorems 6–8) with the `λ*` optimization.
//! * [`EntangledCmpc`] — the [15] baseline; construction identical to AGE at
//!   `λ = 0` but provisioned with the *degree-based* worker count of [15]
//!   (dense reconstruction — [15] does not exploit garbage-term gaps, which
//!   is precisely the inefficiency this paper attacks).
//! * [`baselines`] — formula-level models of SSMM [16] and GCSA-NA [17].

pub mod age;
pub mod baselines;
pub mod entangled;
pub mod polydot;
pub mod spec;

pub use age::AgeCmpc;
pub use baselines::{n_gcsa_na, n_ssmm};
pub use entangled::EntangledCmpc;
pub use polydot::PolyDotCmpc;
pub use spec::SchemeSpec;

use crate::error::{CmpcError, Result};
use crate::poly::powers::{self, PowerSet};

/// Common `(s, t, z)` parameters: `s` row-wise partitions, `t` column-wise
/// partitions (so each worker handles a `1/(st)` fraction of each input) and
/// `z` colluding workers.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct SchemeParams {
    /// Row-wise partitions of each input.
    pub s: usize,
    /// Column-wise partitions of each input.
    pub t: usize,
    /// Colluding workers tolerated (secret terms per share polynomial).
    pub z: usize,
    /// Byzantine adversary tolerance `a`: how many *garbled* (not merely
    /// dead) worker shares the master can locate and exclude during
    /// reconstruction. Raises the recovery quota from `t²+z` to `t²+z+2a`
    /// — the Reed–Solomon unique-decoding bound: `2a` extra evaluations
    /// buy location + correction of up to `a` errors. `0` (the default)
    /// keeps the erasure-only decode byte-identical to previous releases.
    pub adversary_tolerance: usize,
}

impl SchemeParams {
    /// Validated construction — the serving path's entry point. Rejects
    /// degenerate partitions (`s = 0`, `t = 0`) and `z = 0` (the paper
    /// assumes at least one colluding worker; `z = 0` would need no secret
    /// terms at all and a different construction). Adversary tolerance
    /// starts at `0`; raise it with
    /// [`SchemeParams::with_adversary_tolerance`].
    pub fn try_new(s: usize, t: usize, z: usize) -> Result<SchemeParams> {
        if s < 1 || t < 1 {
            return Err(CmpcError::InvalidParams(format!(
                "need s >= 1 and t >= 1 partitions (got s={s}, t={t})"
            )));
        }
        if z < 1 {
            return Err(CmpcError::InvalidParams(
                "need z >= 1 colluding workers".to_string(),
            ));
        }
        Ok(SchemeParams {
            s,
            t,
            z,
            adversary_tolerance: 0,
        })
    }

    /// Infallible construction for statically-known-good parameters
    /// (analysis sweeps, tests).
    ///
    /// # Panics
    /// Panics when [`SchemeParams::try_new`] would return an error.
    pub fn new(s: usize, t: usize, z: usize) -> SchemeParams {
        match SchemeParams::try_new(s, t, z) {
            Ok(p) => p,
            Err(e) => panic!("{e}"),
        }
    }

    /// The same parameters with Byzantine adversary tolerance `a`.
    pub fn with_adversary_tolerance(mut self, a: usize) -> SchemeParams {
        self.adversary_tolerance = a;
        self
    }

    /// Shares the master must collect before reconstruction can start:
    /// `t²+z` (the erasure quota) plus `2·a` error-correction margin.
    pub fn recovery_quota(&self) -> usize {
        self.t * self.t + self.z + 2 * self.adversary_tolerance
    }

    /// Per-stage recovery quota of a pipeline round: every round's workers
    /// exchange a dense degree-`< t²+z` I-polynomial, so each intermediate
    /// masked open interpolates `t²+z` stage-tagged shares — checked **per
    /// round** by `validate_pipeline`, not assumed from round 0. Pipelines
    /// require `adversary_tolerance = 0` (the masked open is an erasure
    /// decode), so no `2a` margin appears here.
    pub fn stage_quota(&self) -> usize {
        self.t * self.t + self.z
    }
}

/// A fully constructible CMPC scheme (share polynomials can be built and the
/// protocol run end-to-end).
pub trait CmpcScheme: Send + Sync {
    /// Human-readable name, e.g. `"AGE-CMPC(λ=2)"`.
    fn name(&self) -> String;

    /// The `(s, t, z, a)` parameters this instance was built with.
    fn params(&self) -> SchemeParams;

    /// Power of `x` carrying block `(Aᵀ)_{i,j}` (`i < t`, `j < s`) in `C_A`.
    fn coded_power_a(&self, i: usize, j: usize) -> u64;

    /// Power of `x` carrying block `B_{k,l}` (`k < s`, `l < t`) in `C_B`.
    fn coded_power_b(&self, k: usize, l: usize) -> u64;

    /// Exponents of the `z` secret terms of `F_A`, sorted.
    fn secret_powers_a(&self) -> PowerSet;

    /// Exponents of the `z` secret terms of `F_B`, sorted.
    fn secret_powers_b(&self) -> PowerSet;

    /// Power of `H(x)` whose coefficient is the output block `Y_{i,l}`.
    fn important_power(&self, i: usize, l: usize) -> u64;

    /// Number of workers the scheme provisions.
    ///
    /// Default: the exact support size `|P(H)|` of eq. (23) — the paper's
    /// garbage-aware count. `EntangledCmpc` overrides this with the
    /// degree-based count of [15].
    fn n_workers(&self) -> usize {
        self.support_h().len()
    }

    /// Exponents the master's reconstruction treats as unknowns.
    ///
    /// Default: the exact support `P(H)`. Schemes that reconstruct densely
    /// (Entangled) override with `0..=deg(H)`.
    fn reconstruction_support(&self) -> PowerSet {
        self.support_h()
    }

    /// The AGE gap parameter `λ` this instance was built at, if the scheme
    /// family has one. `None` for families without a gap knob (PolyDot,
    /// Entangled) — the autoscaler uses this to read a live deployment's
    /// position on the λ curve without downcasting.
    fn gap_lambda(&self) -> Option<u64> {
        None
    }

    // ---- derived helpers (do not override) ----

    /// Sorted support of `C_A`.
    fn coded_support_a(&self) -> PowerSet {
        let p = self.params();
        let mut v: Vec<u64> = (0..p.t)
            .flat_map(|i| (0..p.s).map(move |j| (i, j)))
            .map(|(i, j)| self.coded_power_a(i, j))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Sorted support of `C_B`.
    fn coded_support_b(&self) -> PowerSet {
        let p = self.params();
        let mut v: Vec<u64> = (0..p.s)
            .flat_map(|k| (0..p.t).map(move |l| (k, l)))
            .map(|(k, l)| self.coded_power_b(k, l))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// `P(F_A) = P(C_A) ∪ P(S_A)`.
    fn support_a(&self) -> PowerSet {
        powers::union(&self.coded_support_a(), &self.secret_powers_a())
    }

    /// `P(F_B) = P(C_B) ∪ P(S_B)`.
    fn support_b(&self) -> PowerSet {
        powers::union(&self.coded_support_b(), &self.secret_powers_b())
    }

    /// Exact support of `H(x)` — the sumset of eq. (23).
    fn support_h(&self) -> PowerSet {
        powers::sumset(&self.support_a(), &self.support_b())
    }

    /// All `t²` important powers, sorted.
    fn important_powers(&self) -> PowerSet {
        let p = self.params();
        let mut v: Vec<u64> = (0..p.t)
            .flat_map(|i| (0..p.t).map(move |l| (i, l)))
            .map(|(i, l)| self.important_power(i, l))
            .collect();
        v.sort_unstable();
        v
    }
}

/// Structural decodability + privacy-collision audit for a scheme instance.
///
/// Checks (cf. Theorem 6 and conditions (9)/(27)):
/// 1. the `t²` important powers are distinct;
/// 2. the coefficient of each important power in `C_A·C_B` is exactly
///    `Σ_j (Aᵀ)_{i,j} B_{j,l}` — i.e. coded cross terms land on an important
///    power iff their block indices match (`j = k`) and map to that power's
///    `(i, l)`;
/// 3. no garbage cross term (`C_A·S_B`, `S_A·C_B`, `S_A·S_B`) collides with
///    any important power;
/// 4. there are exactly `z` secret powers per side, disjoint from the coded
///    supports.
pub fn verify_construction(scheme: &dyn CmpcScheme) -> Result<(), String> {
    let p = scheme.params();
    let (s, t, z) = (p.s, p.t, p.z);
    let imp = scheme.important_powers();
    // (1) distinct
    for w in imp.windows(2) {
        if w[0] == w[1] {
            return Err(format!("important power {} repeats", w[0]));
        }
    }
    // (2) coded×coded alignment
    let mut imp_of = std::collections::BTreeMap::new();
    for i in 0..t {
        for l in 0..t {
            imp_of.insert(scheme.important_power(i, l), (i, l));
        }
    }
    for i in 0..t {
        for j in 0..s {
            for k in 0..s {
                for l in 0..t {
                    let e = scheme.coded_power_a(i, j) + scheme.coded_power_b(k, l);
                    if let Some(&(ii, ll)) = imp_of.get(&e) {
                        if !(ii == i && ll == l && j == k) {
                            return Err(format!(
                                "coded term A({i},{j})·B({k},{l}) at power {e} pollutes \
                                 important block ({ii},{ll})"
                            ));
                        }
                    } else if j == k && imp_of.contains_key(&e) {
                        unreachable!()
                    }
                }
            }
        }
    }
    // every Y block must actually receive all s products
    for i in 0..t {
        for l in 0..t {
            let e = scheme.important_power(i, l);
            for j in 0..s {
                if scheme.coded_power_a(i, j) + scheme.coded_power_b(j, l) != e {
                    return Err(format!(
                        "product A({i},{j})·B({j},{l}) misses important power {e}"
                    ));
                }
            }
        }
    }
    // (3) garbage avoidance
    let sa = scheme.secret_powers_a();
    let sb = scheme.secret_powers_b();
    let ca = scheme.coded_support_a();
    let cb = scheme.coded_support_b();
    let hit = |xs: &PowerSet, ys: &PowerSet, label: &str| -> Result<(), String> {
        for &x in xs {
            for &y in ys {
                if imp.binary_search(&(x + y)).is_ok() {
                    return Err(format!(
                        "{label} cross term {x}+{y} collides with important power {}",
                        x + y
                    ));
                }
            }
        }
        Ok(())
    };
    hit(&ca, &sb, "C_A·S_B")?;
    hit(&sa, &cb, "S_A·C_B")?;
    hit(&sa, &sb, "S_A·S_B")?;
    // (4) secret term counts & disjointness
    if sa.len() != z || sb.len() != z {
        return Err(format!(
            "expected {z} secret powers, got |S_A|={}, |S_B|={}",
            sa.len(),
            sb.len()
        ));
    }
    for &e in &sa {
        if ca.binary_search(&e).is_ok() {
            return Err(format!("secret power {e} overlaps C_A"));
        }
    }
    for &e in &sb {
        if cb.binary_search(&e).is_ok() {
            return Err(format!("secret power {e} overlaps C_B"));
        }
    }
    Ok(())
}

/// Greedy secret-power selection shared by Algorithm 1 and Algorithm 2:
/// the `z` smallest non-negative exponents `e` such that `e + c` misses every
/// important power for all `c` in each of the `against` supports.
pub(crate) fn greedy_secret_powers(z: usize, imp: &PowerSet, against: &[&PowerSet]) -> PowerSet {
    let mut forbidden: PowerSet = Vec::new();
    for cs in against {
        forbidden = powers::union(&forbidden, &powers::nonneg_differences(imp, cs));
    }
    powers::smallest_excluding(z, &forbidden)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_example1_age() {
        // s=t=2, λ=2 (paper Example 1): S_A must be {4,5}.
        let imp = vec![1, 3, 7, 9];
        let cb = vec![0, 1, 6, 7];
        let got = greedy_secret_powers(2, &imp, &[&cb]);
        assert_eq!(got, vec![4, 5]);
    }
}
