//! Adaptive Gap Entangled polynomial codes (§V) and AGE-CMPC.
//!
//! AGE codes instantiate the generalized construction (24) with
//! `(α, β, θ) = (1, s, ts + λ)`:
//!
//! ```text
//! C_A(x) = Σ_{i<t} Σ_{j<s} (Aᵀ)_{i,j} · x^{j + s·i}
//! C_B(x) = Σ_{k<s} Σ_{l<t} B_{k,l}   · x^{(s−1−k) + (ts+λ)·l}
//! ```
//!
//! The gap parameter `λ ∈ [0, z]` *widens* the spacing of `C_B`'s exponent
//! blocks. A pure coded-computation design would minimize `deg(C_A·C_B)`
//! (λ = 0, entangled codes); the paper's key insight is that in the MPC
//! setting a *larger* degree can align the garbage cross terms
//! (`C_A·S_B`, `S_A·C_B`, `S_A·S_B`) into the gaps, shrinking the total
//! support of `H(x)` — and it is `|P(H)|`, not the degree, that dictates the
//! number of workers (eq. 23). `λ` is chosen per `(s,t,z)` by exact
//! minimization ([`AgeCmpc::with_optimal_lambda`], Phase 0 of Algorithm 3).
//!
//! Secret terms follow Algorithm 2: `S_B` sits in the `z` powers right above
//! the largest important power (satisfying C4/C6 for free), and `S_A` takes
//! the `z` smallest powers whose products with `C_B` avoid the important
//! powers (C5).

use super::{greedy_secret_powers, CmpcScheme, SchemeParams};
use crate::error::{CmpcError, Result};
use crate::poly::powers::PowerSet;

/// An AGE-CMPC instance at a fixed gap parameter `λ`.
#[derive(Clone, Debug)]
pub struct AgeCmpc {
    params: SchemeParams,
    /// Gap parameter `λ ∈ [0, z]`; `θ = ts + λ`.
    pub lambda: u64,
    secret_a: PowerSet,
    secret_b: PowerSet,
}

impl AgeCmpc {
    /// Fallible construction with an explicit `λ` — the serving path's entry
    /// point. Rejects invalid `(s, t, z)` and `λ > z` (larger gaps never
    /// help — Appendix H) with [`CmpcError::InvalidParams`].
    pub fn try_new(s: usize, t: usize, z: usize, lambda: u64) -> Result<AgeCmpc> {
        let params = SchemeParams::try_new(s, t, z)?;
        if lambda > z as u64 {
            return Err(CmpcError::InvalidParams(format!(
                "AGE gap λ={lambda} must lie in [0, z={z}]"
            )));
        }
        Ok(AgeCmpc::construct(params, lambda))
    }

    /// Construct with an explicit `λ`.
    ///
    /// # Panics
    /// Panics when [`AgeCmpc::try_new`] would return an error.
    pub fn new(s: usize, t: usize, z: usize, lambda: u64) -> AgeCmpc {
        match AgeCmpc::try_new(s, t, z, lambda) {
            Ok(scheme) => scheme,
            Err(e) => panic!("{e}"),
        }
    }

    /// Algorithm-2 construction over pre-validated parameters.
    fn construct(params: SchemeParams, lambda: u64) -> AgeCmpc {
        let (t, z) = (params.t, params.z);
        let mut scheme = AgeCmpc {
            params,
            lambda,
            secret_a: Vec::new(),
            secret_b: Vec::new(),
        };
        // Algorithm 2 step 1: S_B = z consecutive powers from (max important)+1.
        let max_imp = scheme.important_power(t - 1, t - 1);
        scheme.secret_b = (1..=z as u64).map(|r| max_imp + r).collect();
        // Algorithm 2 step 2: S_A greedy-minimal against C5
        // (imp ∉ P(S_A)+P(C_B)). C4/C6 hold automatically because every S_B
        // power already exceeds every important power.
        let imp = scheme.important_powers();
        let cb = scheme.coded_support_b();
        scheme.secret_a = greedy_secret_powers(z, &imp, &[&cb]);
        debug_assert!(super::verify_construction(&scheme).is_ok());
        scheme
    }

    /// Fallible Phase-0 construction: validate `(s, t, z)` once, then run
    /// the `λ*` scan of [`AgeCmpc::with_optimal_lambda`].
    pub fn try_with_optimal_lambda(s: usize, t: usize, z: usize) -> Result<AgeCmpc> {
        let params = SchemeParams::try_new(s, t, z)?;
        Ok(AgeCmpc::optimal_over_validated(params))
    }

    /// Phase 0 of Algorithm 3: scan `λ ∈ [0, z]` and keep the instance with
    /// the fewest workers (ties broken toward smaller λ, i.e. lower degree).
    ///
    /// §Perf P3: the scan is embarrassingly parallel (each λ is an
    /// independent construction + sumset); large `z` fans out across
    /// threads, which cuts the Fig. 2 paper-range regeneration ~4×.
    ///
    /// # Panics
    /// Panics on invalid `(s, t, z)`; use
    /// [`AgeCmpc::try_with_optimal_lambda`] on untrusted input.
    pub fn with_optimal_lambda(s: usize, t: usize, z: usize) -> AgeCmpc {
        match AgeCmpc::try_with_optimal_lambda(s, t, z) {
            Ok(scheme) => scheme,
            Err(e) => panic!("{e}"),
        }
    }

    fn optimal_over_validated(params: SchemeParams) -> AgeCmpc {
        let z = params.z;
        let scan = |range: std::ops::RangeInclusive<u64>| -> Option<(usize, AgeCmpc)> {
            let mut best: Option<(usize, AgeCmpc)> = None;
            for lambda in range {
                let cand = AgeCmpc::construct(params, lambda);
                let n = cand.n_workers();
                match &best {
                    Some((bn, _)) if *bn <= n => {}
                    _ => best = Some((n, cand)),
                }
            }
            best
        };
        let zu = z as u64;
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(8) as u64;
        if zu < 32 || threads < 2 {
            return scan(0..=zu).unwrap().1;
        }
        let chunk = (zu + 1).div_ceil(threads);
        let mut partials: Vec<(usize, AgeCmpc)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|i| {
                    let lo = i * chunk;
                    let hi = ((i + 1) * chunk - 1).min(zu);
                    scope.spawn(move || if lo <= hi { scan(lo..=hi) } else { None })
                })
                .collect();
            handles
                .into_iter()
                .filter_map(|h| h.join().expect("λ-scan thread panicked"))
                .collect()
        });
        // smallest N, ties toward smaller λ (partials arrive in λ order)
        let mut best = partials.remove(0);
        for cand in partials {
            if cand.0 < best.0 {
                best = cand;
            }
        }
        best.1
    }

    /// The same instance with Byzantine adversary tolerance `a` (see
    /// [`SchemeParams::with_adversary_tolerance`]). Construction is
    /// unaffected — only the master's recovery quota rises to `t²+z+2a`.
    pub fn with_adversary_tolerance(mut self, a: usize) -> AgeCmpc {
        self.params.adversary_tolerance = a;
        self
    }

    /// `θ = ts + λ`.
    #[inline]
    pub fn theta(&self) -> u64 {
        (self.params.t * self.params.s) as u64 + self.lambda
    }
}

impl CmpcScheme for AgeCmpc {
    fn name(&self) -> String {
        format!("AGE-CMPC(λ={})", self.lambda)
    }

    fn params(&self) -> SchemeParams {
        self.params
    }

    fn coded_power_a(&self, i: usize, j: usize) -> u64 {
        debug_assert!(i < self.params.t && j < self.params.s);
        (j + self.params.s * i) as u64
    }

    fn coded_power_b(&self, k: usize, l: usize) -> u64 {
        debug_assert!(k < self.params.s && l < self.params.t);
        (self.params.s - 1 - k) as u64 + self.theta() * l as u64
    }

    fn secret_powers_a(&self) -> PowerSet {
        self.secret_a.clone()
    }

    fn secret_powers_b(&self) -> PowerSet {
        self.secret_b.clone()
    }

    fn important_power(&self, i: usize, l: usize) -> u64 {
        debug_assert!(i < self.params.t && l < self.params.t);
        (self.params.s - 1) as u64 + (self.params.s * i) as u64 + self.theta() * l as u64
    }

    fn gap_lambda(&self) -> Option<u64> {
        Some(self.lambda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::verify_construction;
    use crate::util::testing::property;

    #[test]
    fn example1_matches_paper() {
        // Paper Example 1: s=t=z=2 → λ* = 2, N = 17.
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        assert_eq!(scheme.lambda, 2);
        assert_eq!(scheme.n_workers(), 17);
        // Explicit polynomial layout from the example:
        // C_A = A00 + A01 x + A10 x² + A11 x³
        assert_eq!(scheme.coded_power_a(0, 0), 0);
        assert_eq!(scheme.coded_power_a(0, 1), 1);
        assert_eq!(scheme.coded_power_a(1, 0), 2);
        assert_eq!(scheme.coded_power_a(1, 1), 3);
        // C_B = B00 x + B10 + B01 x⁷ + B11 x⁶
        assert_eq!(scheme.coded_power_b(0, 0), 1);
        assert_eq!(scheme.coded_power_b(1, 0), 0);
        assert_eq!(scheme.coded_power_b(0, 1), 7);
        assert_eq!(scheme.coded_power_b(1, 1), 6);
        // S_A = {4,5}, S_B = {10,11}
        assert_eq!(scheme.secret_powers_a(), vec![4, 5]);
        assert_eq!(scheme.secret_powers_b(), vec![10, 11]);
        // important powers (Y blocks) at x^1, x^3, x^7, x^9... wait:
        // imp(i,l) = 1 + 2i + 6l → {1,3,7,9}
        assert_eq!(scheme.important_powers(), vec![1, 3, 7, 9]);
        // Support of H is {0..16} — 17 contiguous powers.
        assert_eq!(scheme.support_h(), (0..=16).collect::<Vec<u64>>());
    }

    #[test]
    fn all_lambdas_verify_structurally() {
        property("AGE verifies for random (s,t,z,λ)", 300, |rng| {
            let s = rng.gen_index(5) + 1;
            let t = rng.gen_index(5) + 1;
            let z = rng.gen_index(8) + 1;
            let lambda = rng.gen_range(z as u64 + 1);
            let scheme = AgeCmpc::new(s, t, z, lambda);
            verify_construction(&scheme).map_err(|e| format!("s={s} t={t} z={z} λ={lambda}: {e}"))
        });
    }

    #[test]
    fn optimal_lambda_never_worse_than_endpoints() {
        property("λ* beats λ=0 and λ=z", 150, |rng| {
            let s = rng.gen_index(4) + 1;
            let t = rng.gen_index(4) + 1;
            let z = rng.gen_index(6) + 1;
            let best = AgeCmpc::with_optimal_lambda(s, t, z);
            let n0 = AgeCmpc::new(s, t, z, 0).n_workers();
            let nz = AgeCmpc::new(s, t, z, z as u64).n_workers();
            if best.n_workers() > n0 || best.n_workers() > nz {
                return Err(format!(
                    "s={s} t={t} z={z}: N*={} vs N(0)={n0} N(z)={nz}",
                    best.n_workers()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn secret_b_sits_above_max_important() {
        property("S_B > max important", 100, |rng| {
            let s = rng.gen_index(4) + 1;
            let t = rng.gen_index(4) + 1;
            let z = rng.gen_index(5) + 1;
            let lambda = rng.gen_range(z as u64 + 1);
            let sch = AgeCmpc::new(s, t, z, lambda);
            let max_imp = *sch.important_powers().last().unwrap();
            if sch.secret_powers_b().iter().any(|&e| e <= max_imp) {
                return Err("S_B power below max important".into());
            }
            Ok(())
        });
    }

    #[test]
    fn lambda_zero_is_entangled_codes() {
        // At λ=0 the coded layout is the entangled polynomial code:
        // contiguous C_A = {0..ts-1}, C_B spaced by ts.
        let sch = AgeCmpc::new(3, 2, 2, 0);
        assert_eq!(sch.coded_support_a(), (0..6).collect::<Vec<u64>>());
        assert_eq!(sch.coded_support_b(), vec![0, 1, 2, 6, 7, 8]);
    }

    #[test]
    fn t_equals_one_reduces_to_polynomial_codes() {
        // Thm 8: N = 2s + 2z − 1 for t = 1.
        for s in 1..6 {
            for z in 1..5 {
                let sch = AgeCmpc::with_optimal_lambda(s, 1, z);
                assert_eq!(
                    sch.n_workers(),
                    2 * s + 2 * z - 1,
                    "s={s} z={z}"
                );
            }
        }
    }
}
