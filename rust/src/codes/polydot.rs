//! PolyDot-CMPC (§IV): PolyDot coded terms + garbage-aware secret terms.
//!
//! Coded terms follow PolyDot codes [26] (eq. 7–8), i.e. the generalized
//! construction (24) with `(α, β, θ) = (t, 1, t(2s−1))`:
//!
//! ```text
//! C_A(x) = Σ_{i<t} Σ_{j<s} (Aᵀ)_{i,j} · x^{i + t·j}
//! C_B(x) = Σ_{k<s} Σ_{l<t} B_{k,l}   · x^{t(s−1−k) + θ'·l},   θ' = t(2s−1)
//! ```
//!
//! so block `Y_{i,l}` appears at power `i + t(s−1) + θ'·l`. The paper's
//! contribution is the *secret-term* design (Algorithm 1): pick the `z`
//! smallest powers for `S_A` avoiding C1 (`imp ∉ P(S_A)+P(C_B)`), then the
//! `z` smallest for `S_B` avoiding C2 and C3 — i.e. reuse the garbage
//! exponents of `C_A·C_B` instead of inflating the degree. The appendix
//! lemmas (15–17, 26–31) derive the same sets case by case; here they fall
//! out of one greedy pass, and the property tests in [`crate::analysis`]
//! confirm the closed forms.

use super::{greedy_secret_powers, CmpcScheme, SchemeParams};
use crate::error::Result;
use crate::poly::powers::PowerSet;

/// A PolyDot-CMPC instance.
#[derive(Clone, Debug)]
pub struct PolyDotCmpc {
    params: SchemeParams,
    secret_a: PowerSet,
    secret_b: PowerSet,
}

impl PolyDotCmpc {
    /// Fallible construction of Theorem 1 for `(s, t, z)` — the serving
    /// path's entry point.
    pub fn try_new(s: usize, t: usize, z: usize) -> Result<PolyDotCmpc> {
        Ok(PolyDotCmpc::construct(SchemeParams::try_new(s, t, z)?))
    }

    /// Build the construction of Theorem 1 for `(s, t, z)`.
    ///
    /// The paper excludes `s = t = 1` (that degenerate case is plain BGW —
    /// no coding); we allow it for completeness, where the construction
    /// reduces to Shamir sharing of the whole matrices.
    ///
    /// # Panics
    /// Panics on invalid `(s, t, z)`; use [`PolyDotCmpc::try_new`] on
    /// untrusted input.
    pub fn new(s: usize, t: usize, z: usize) -> PolyDotCmpc {
        match PolyDotCmpc::try_new(s, t, z) {
            Ok(scheme) => scheme,
            Err(e) => panic!("{e}"),
        }
    }

    fn construct(params: SchemeParams) -> PolyDotCmpc {
        let z = params.z;
        let mut scheme = PolyDotCmpc {
            params,
            secret_a: Vec::new(),
            secret_b: Vec::new(),
        };
        let imp = scheme.important_powers();
        // Algorithm 1, step 1: S_A minimal under C1 (against C_B).
        let cb = scheme.coded_support_b();
        scheme.secret_a = greedy_secret_powers(z, &imp, &[&cb]);
        // Algorithm 1, step 2: S_B minimal under C2 (against the fixed S_A)
        // and C3 (against C_A).
        let ca = scheme.coded_support_a();
        let sa = scheme.secret_a.clone();
        scheme.secret_b = greedy_secret_powers(z, &imp, &[&ca, &sa]);
        debug_assert!(super::verify_construction(&scheme).is_ok());
        scheme
    }

    /// The same instance with Byzantine adversary tolerance `a` (see
    /// [`SchemeParams::with_adversary_tolerance`]).
    pub fn with_adversary_tolerance(mut self, a: usize) -> PolyDotCmpc {
        self.params.adversary_tolerance = a;
        self
    }

    /// `θ' = t(2s − 1)`.
    #[inline]
    pub fn theta_prime(&self) -> u64 {
        (self.params.t * (2 * self.params.s - 1)) as u64
    }
}

impl CmpcScheme for PolyDotCmpc {
    fn name(&self) -> String {
        "PolyDot-CMPC".to_string()
    }

    fn params(&self) -> SchemeParams {
        self.params
    }

    fn coded_power_a(&self, i: usize, j: usize) -> u64 {
        debug_assert!(i < self.params.t && j < self.params.s);
        (i + self.params.t * j) as u64
    }

    fn coded_power_b(&self, k: usize, l: usize) -> u64 {
        debug_assert!(k < self.params.s && l < self.params.t);
        (self.params.t * (self.params.s - 1 - k)) as u64 + self.theta_prime() * l as u64
    }

    fn secret_powers_a(&self) -> PowerSet {
        self.secret_a.clone()
    }

    fn secret_powers_b(&self) -> PowerSet {
        self.secret_b.clone()
    }

    fn important_power(&self, i: usize, l: usize) -> u64 {
        debug_assert!(i < self.params.t && l < self.params.t);
        (i + self.params.t * (self.params.s - 1)) as u64 + self.theta_prime() * l as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::verify_construction;
    use crate::util::testing::property;

    #[test]
    fn coded_supports_match_eq_7_8() {
        let sch = PolyDotCmpc::new(3, 2, 2); // s=3, t=2, θ' = 2·5 = 10
        assert_eq!(sch.theta_prime(), 10);
        // P(C_A) = {i + tj} = {0..ts-1} (eq. 7)
        assert_eq!(sch.coded_support_a(), (0..6).collect::<Vec<u64>>());
        // P(C_B) = {t(s-1-k) + θ'l} = {0,2,4} ∪ {10,12,14} (eq. 8)
        assert_eq!(sch.coded_support_b(), vec![0, 2, 4, 10, 12, 14]);
        // important powers i + t(s-1) + θ'l = {4,5,14,15}
        assert_eq!(sch.important_powers(), vec![4, 5, 14, 15]);
    }

    #[test]
    fn construction_verifies_across_parameters() {
        property("PolyDot verifies for random (s,t,z)", 300, |rng| {
            let s = rng.gen_index(5) + 1;
            let t = rng.gen_index(5) + 1;
            let z = rng.gen_index(10) + 1;
            let scheme = PolyDotCmpc::new(s, t, z);
            verify_construction(&scheme).map_err(|e| format!("s={s} t={t} z={z}: {e}"))
        });
    }

    #[test]
    fn secret_a_matches_lemma_16_small_z() {
        // Lemma 16: for z ≤ θ'−ts and s,t ≠ 1, P(S_A) = {ts, …, ts+z−1}.
        let sch = PolyDotCmpc::new(3, 2, 2); // θ'−ts = 10−6 = 4 ≥ z=2
        assert_eq!(sch.secret_powers_a(), vec![6, 7]);
    }

    #[test]
    fn secret_a_matches_lemma_15_large_z() {
        // Lemma 15 (z > θ'−ts): S_A fills the gaps {ts+θ'l … (l+1)θ'−1}.
        // s=2, t=2: θ'=6, θ'−ts=2, z=3 → first gap {4,5} then {10,...}.
        let sch = PolyDotCmpc::new(2, 2, 3);
        assert_eq!(sch.secret_powers_a(), vec![4, 5, 10]);
    }

    #[test]
    fn s_equals_one_matches_lemma_17() {
        // Lemma 17: s=1 → P(S_A) = {t², …, t²+z−1}.
        let sch = PolyDotCmpc::new(1, 4, 3);
        assert_eq!(sch.secret_powers_a(), vec![16, 17, 18]);
        // Lemma 30: P(S_B) = {t², …} too.
        assert_eq!(sch.secret_powers_b(), vec![16, 17, 18]);
    }

    #[test]
    fn t_equals_one_matches_lemma_17_and_31() {
        // t=1: P(S_A) = P(S_B) = {s, …, s+z−1}; N = 2s+2z−1 (Lemma 32).
        let sch = PolyDotCmpc::new(5, 1, 2);
        assert_eq!(sch.secret_powers_a(), vec![5, 6]);
        assert_eq!(sch.secret_powers_b(), vec![5, 6]);
        assert_eq!(sch.n_workers(), 2 * 5 + 2 * 2 - 1);
    }

    #[test]
    fn secret_b_matches_lemma_26_large_z() {
        // Lemma 26 (z > θ'−ts): P(S_B) = {ts+(t−1)θ' + r}.
        let sch = PolyDotCmpc::new(2, 2, 3); // θ'=6, θ'−ts=2 < 3=z
        assert_eq!(sch.secret_powers_b(), vec![10, 11, 12]);
    }

    #[test]
    fn secret_b_matches_lemma_29_small_z() {
        // Lemma 29 (z ≤ (θ'−ts−t+1)/2): P(S_B) = {ts, …, ts+z−1}.
        // s=4, t=2: θ'=14, τ=θ'−ts−t=4, (τ+1)/2=2.5 → z=2 qualifies.
        let sch = PolyDotCmpc::new(4, 2, 2);
        assert_eq!(sch.secret_powers_b(), vec![8, 9]);
    }
}
