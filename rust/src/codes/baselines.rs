//! Formula-level baselines: SSMM [16] and GCSA-NA [17].
//!
//! Both papers target secure *batch* matrix multiplication with modified MPC
//! phases (noise alignment); this paper compares against them at batch size 1
//! using their published worker counts:
//!
//! * SSMM (Zhu–Yan–Tang, Theorem 1 of [16]): `N = (t+1)(ts+z) − 1`
//! * GCSA-NA (Chen et al., Table 1 of [17], one multiplication):
//!   `N = 2st² + 2z − 1`
//!
//! Their end-to-end protocols are not reconstructible from this paper alone,
//! so — exactly like the paper's own evaluation — they participate in the
//! figures through these formulas plus the shared overhead model of
//! Corollaries 10–12 (computation/storage/communication depend on the scheme
//! only through `N`). See DESIGN.md §Substitutions.

/// SSMM [16] worker count, `N = (t+1)(ts+z) − 1`.
pub fn n_ssmm(s: usize, t: usize, z: usize) -> u64 {
    let (s, t, z) = (s as u64, t as u64, z as u64);
    (t + 1) * (t * s + z) - 1
}

/// GCSA-NA [17] worker count at batch size 1, `N = 2st² + 2z − 1`.
pub fn n_gcsa_na(s: usize, t: usize, z: usize) -> u64 {
    let (s, t, z) = (s as u64, t as u64, z as u64);
    2 * s * t * t + 2 * z - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_anchor_points() {
        // s=4, t=15 (Fig. 2 parameters).
        // SSMM: (16)(60+z)−1
        assert_eq!(n_ssmm(4, 15, 1), 16 * 61 - 1);
        assert_eq!(n_ssmm(4, 15, 300), 16 * 360 - 1);
        // GCSA-NA: 2·4·225 + 2z − 1 = 1800 + 2z − 1
        assert_eq!(n_gcsa_na(4, 15, 1), 1801);
        assert_eq!(n_gcsa_na(4, 15, 300), 2399);
    }

    #[test]
    fn gcsa_equals_entangled_large_z_form() {
        // The paper notes GCSA-NA and Entangled-CMPC coincide for large z
        // (both 2st²+2z−1).
        for (s, t, z) in [(4, 15, 200), (6, 6, 100), (2, 18, 80)] {
            assert_eq!(
                n_gcsa_na(s, t, z),
                crate::analysis::n_entangled(s, t, z),
                "s={s} t={t} z={z}"
            );
        }
    }
}
