//! Entangled-CMPC baseline (Nodehi et al. [15]).
//!
//! Entangled-CMPC combines entangled polynomial codes with BGW-style secret
//! terms but — crucially — does *not* exploit garbage-term gaps: its master
//! reconstructs `H(x)` densely from `deg(H)+1` evaluations. The construction
//! is exactly AGE-CMPC at `λ = 0` (Appendix F, Lemmas 47–48); only the worker
//! provisioning differs:
//!
//! * [`CmpcScheme::n_workers`] returns `deg(F_A) + deg(F_B) + 1`, which
//!   reproduces eq. (194) = Theorem 1 of [15];
//! * [`CmpcScheme::reconstruction_support`] is the full interval
//!   `{0, …, deg(H)}` (a plain Vandermonde solve — always invertible).
//!
//! This pairing is the paper's motivating observation: for some `(s,t,z)`
//! the *worse* coded-computation code (PolyDot) beats the *better* one
//! (entangled) once secret terms enter the picture, because what matters is
//! `|P(H)|`, not `deg(H)`.

use super::{age::AgeCmpc, CmpcScheme, SchemeParams};
use crate::error::Result;
use crate::poly::powers::{max_power, PowerSet};

/// The Entangled-CMPC baseline scheme.
#[derive(Clone, Debug)]
pub struct EntangledCmpc {
    inner: AgeCmpc,
}

impl EntangledCmpc {
    /// Fallible construction — the serving path's entry point.
    pub fn try_new(s: usize, t: usize, z: usize) -> Result<EntangledCmpc> {
        Ok(EntangledCmpc {
            inner: AgeCmpc::try_new(s, t, z, 0)?,
        })
    }

    /// # Panics
    /// Panics on invalid `(s, t, z)`; use [`EntangledCmpc::try_new`] on
    /// untrusted input.
    pub fn new(s: usize, t: usize, z: usize) -> EntangledCmpc {
        match EntangledCmpc::try_new(s, t, z) {
            Ok(scheme) => scheme,
            Err(e) => panic!("{e}"),
        }
    }

    /// The same instance with Byzantine adversary tolerance `a` (see
    /// [`SchemeParams::with_adversary_tolerance`]).
    pub fn with_adversary_tolerance(mut self, a: usize) -> EntangledCmpc {
        self.inner = self.inner.with_adversary_tolerance(a);
        self
    }

    /// `deg(H) = deg(F_A) + deg(F_B)`.
    pub fn degree_h(&self) -> u64 {
        max_power(&self.inner.support_a()).unwrap() + max_power(&self.inner.support_b()).unwrap()
    }
}

impl CmpcScheme for EntangledCmpc {
    fn name(&self) -> String {
        "Entangled-CMPC".to_string()
    }

    fn params(&self) -> SchemeParams {
        self.inner.params()
    }

    fn coded_power_a(&self, i: usize, j: usize) -> u64 {
        self.inner.coded_power_a(i, j)
    }

    fn coded_power_b(&self, k: usize, l: usize) -> u64 {
        self.inner.coded_power_b(k, l)
    }

    fn secret_powers_a(&self) -> PowerSet {
        self.inner.secret_powers_a()
    }

    fn secret_powers_b(&self) -> PowerSet {
        self.inner.secret_powers_b()
    }

    fn important_power(&self, i: usize, l: usize) -> u64 {
        self.inner.important_power(i, l)
    }

    /// Degree-based provisioning of [15] — `deg(H) + 1` workers, no gap
    /// exploitation.
    fn n_workers(&self) -> usize {
        self.degree_h() as usize + 1
    }

    /// Dense reconstruction over `{0, …, deg(H)}`.
    fn reconstruction_support(&self) -> PowerSet {
        (0..=self.degree_h()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::n_entangled;
    use crate::codes::verify_construction;
    use crate::util::testing::property;

    #[test]
    fn example1_needs_19_workers() {
        // Paper Example 1 cites N_Entangled-CMPC = 19 at s=t=z=2.
        assert_eq!(EntangledCmpc::new(2, 2, 2).n_workers(), 19);
    }

    #[test]
    fn degree_count_matches_eq_194_large_z() {
        // Our runnable Entangled instance realizes [15]'s large-z branch
        // (z > ts−s) exactly: N = deg(H)+1 = 2st²+2z−1. The small-z branch of
        // eq. (194) relies on a specialized placement internal to [15]; the
        // analysis-level `n_entangled` reproduces the full formula, and the
        // runnable scheme upper-bounds it (see DESIGN.md §Substitutions).
        property("Entangled N == eq.(194) for z > ts−s", 200, |rng| {
            let s = rng.gen_index(6) + 1;
            let t = rng.gen_index(6) + 1;
            let z = rng.gen_index(12) + 1;
            let sch = EntangledCmpc::new(s, t, z);
            let got = sch.n_workers() as u64;
            if got != (2 * s * t * t + 2 * z - 1) as u64 {
                return Err(format!("s={s} t={t} z={z}: deg count {got}"));
            }
            if z > t * s - s && got != n_entangled(s, t, z) {
                return Err(format!(
                    "s={s} t={t} z={z}: {got} != {}",
                    n_entangled(s, t, z)
                ));
            }
            // never better than the formula (it is [15]'s own optimization)
            if got < n_entangled(s, t, z) {
                return Err(format!("s={s} t={t} z={z}: beats eq.(194)?"));
            }
            Ok(())
        });
    }

    #[test]
    fn construction_verifies() {
        property("Entangled verifies", 150, |rng| {
            let s = rng.gen_index(5) + 1;
            let t = rng.gen_index(5) + 1;
            let z = rng.gen_index(8) + 1;
            verify_construction(&EntangledCmpc::new(s, t, z))
                .map_err(|e| format!("s={s} t={t} z={z}: {e}"))
        });
    }

    #[test]
    fn reconstruction_support_is_dense_superset() {
        let sch = EntangledCmpc::new(3, 2, 4);
        let dense = sch.reconstruction_support();
        assert_eq!(dense.len(), sch.n_workers());
        for e in sch.support_h() {
            assert!(dense.binary_search(&e).is_ok());
        }
    }
}
