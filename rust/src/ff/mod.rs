//! Finite-field arithmetic over `GF(p)` with `p = 65537` (the Fermat prime
//! `2^16 + 1`).
//!
//! All CMPC shares, polynomials and matrices live in this field. The prime is
//! chosen so that
//!
//! * products of two field elements fit comfortably in `u64`/`i64`
//!   (`p² < 2^34`), letting both the Rust hot path and the XLA/Pallas i64
//!   kernels accumulate long dot products before reducing;
//! * there are ≥ 65536 distinct evaluation points `αₙ`, far more than the
//!   largest worker count in the paper's sweeps (Fig. 2 tops out below 3000);
//! * reduction is cheap: `2^16 ≡ −1 (mod p)`, so `x mod p` folds in two steps
//!   without division ([`reduce`]).
//!
//! The module exposes both a plain-`u64` functional API (used by the tight
//! loops in [`crate::matrix`]) and the [`Fp`] newtype used everywhere else.
//!
//! The hot-path reduction lives in [`mont`]: for this prime `R = 2³² ≡ 1
//! (mod p)`, so Montgomery REDC returns exactly `T mod p` in about a
//! third of the operations of the folding [`reduce`] — with conversion at
//! the loop edges a literal no-op. [`reduce`] stays as the full-range
//! fallback (REDC is valid only below `p·2³²`) and as the independent
//! reference the byte-identity tests pin against.

pub mod mont;

/// The field modulus `p = 2^16 + 1 = 65537` (a Fermat prime).
pub const P: u64 = 65537;

/// Add two reduced elements.
#[inline(always)]
pub fn add(a: u64, b: u64) -> u64 {
    let s = a + b;
    if s >= P {
        s - P
    } else {
        s
    }
}

/// Subtract two reduced elements.
#[inline(always)]
pub fn sub(a: u64, b: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + P - b
    }
}

/// Multiply two reduced elements.
///
/// Routed through Montgomery REDC ([`mont::redc`]): the product of two
/// reduced elements is `≤ (p−1)² = 2³²`, far inside REDC's `p·2³²`
/// validity bound, and with `R ≡ 1 (mod p)` the result is exactly
/// `a·b mod p` — byte-identical to the old `reduce(a*b)`, ~3× cheaper.
#[inline(always)]
pub fn mul(a: u64, b: u64) -> u64 {
    debug_assert!(a < P && b < P, "mul expects reduced inputs");
    mont::redc(a * b)
}

/// Reduce an arbitrary `u64` modulo `p`, exploiting `2^16 ≡ −1 (mod p)`.
///
/// Splitting `x = hi·2^16 + lo` gives `x ≡ lo − hi (mod p)`; four folding
/// rounds bring any 64-bit value into `(0, 2p)` and one conditional
/// subtraction finishes the job — fully division-free, which is ~3× faster
/// than the hardware `%` on the matmul hot path.
#[inline(always)]
pub fn reduce(x: u64) -> u64 {
    // Round 1: x < 2^64 -> y < 2^48 + 2^16 (signed fold).
    let lo = x & 0xffff;
    let hi = x >> 16;
    // lo - hi may be negative; add a multiple of P to keep unsigned.
    // hi < 2^48, and (2^48/P + 1) * P < 2^49.
    let y = lo + (P << 32) - hi; // y < 2^49 + 2^16 < 2^50
    let lo2 = y & 0xffff;
    let hi2 = y >> 16;
    let z = lo2 + (P << 18) - hi2; // z < 2^35
    let lo3 = z & 0xffff;
    let hi3 = z >> 16;
    let w = lo3 + (P << 3) - hi3; // w < 2^20
    // Round 4: w < 2^20 ⇒ hi4 ≤ 9 and lo4 ≤ 2^16 − 1, so one more fold
    // lands in (0, 2p − 1] and a single conditional subtraction finishes —
    // no hardware division anywhere.
    let lo4 = w & 0xffff;
    let hi4 = w >> 16;
    let mut r = lo4 + P - hi4; // 0 < r ≤ 2p − 2
    if r >= P {
        r -= P;
    }
    r
}

/// Modular exponentiation by squaring.
#[inline]
pub fn pow(mut base: u64, mut exp: u64) -> u64 {
    base %= P;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul(acc, base);
        }
        base = mul(base, base);
        exp >>= 1;
    }
    acc
}

/// Multiplicative inverse via Fermat's little theorem (`a^(p-2)`).
///
/// # Panics
/// Panics on `a ≡ 0`, which has no inverse.
#[inline]
pub fn inv(a: u64) -> u64 {
    assert!(a % P != 0, "zero has no multiplicative inverse in GF(p)");
    pow(a, P - 2)
}

/// Negate a reduced element.
#[inline(always)]
pub fn neg(a: u64) -> u64 {
    if a == 0 {
        0
    } else {
        P - a
    }
}

/// A reduced element of `GF(p)`. Thin wrapper used by the non-hot-path API.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Default, PartialOrd, Ord)]
pub struct Fp(pub u32);

impl Fp {
    /// The additive identity.
    pub const ZERO: Fp = Fp(0);
    /// The multiplicative identity.
    pub const ONE: Fp = Fp(1);

    /// Reduce an arbitrary u64 into the field.
    #[inline]
    pub fn new(v: u64) -> Fp {
        Fp((v % P) as u32)
    }

    /// The reduced representative as a `u64`.
    #[inline]
    pub fn val(self) -> u64 {
        self.0 as u64
    }

    /// `self^e` by square-and-multiply.
    #[inline]
    pub fn pow(self, e: u64) -> Fp {
        Fp(pow(self.val(), e) as u32)
    }

    /// Multiplicative inverse (panics on zero, like the scalar [`inv`]).
    #[inline]
    pub fn inv(self) -> Fp {
        Fp(inv(self.val()) as u32)
    }
}

impl std::fmt::Debug for Fp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for Fp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::ops::Add for Fp {
    type Output = Fp;
    #[inline]
    fn add(self, rhs: Fp) -> Fp {
        Fp(add(self.val(), rhs.val()) as u32)
    }
}

impl std::ops::Sub for Fp {
    type Output = Fp;
    #[inline]
    fn sub(self, rhs: Fp) -> Fp {
        Fp(sub(self.val(), rhs.val()) as u32)
    }
}

impl std::ops::Mul for Fp {
    type Output = Fp;
    #[inline]
    fn mul(self, rhs: Fp) -> Fp {
        Fp(mul(self.val(), rhs.val()) as u32)
    }
}

impl std::ops::Neg for Fp {
    type Output = Fp;
    #[inline]
    fn neg(self) -> Fp {
        Fp(neg(self.val()) as u32)
    }
}

impl std::ops::AddAssign for Fp {
    #[inline]
    fn add_assign(&mut self, rhs: Fp) {
        *self = *self + rhs;
    }
}

impl std::ops::SubAssign for Fp {
    #[inline]
    fn sub_assign(&mut self, rhs: Fp) {
        *self = *self - rhs;
    }
}

impl std::ops::MulAssign for Fp {
    #[inline]
    fn mul_assign(&mut self, rhs: Fp) {
        *self = *self * rhs;
    }
}

impl From<u64> for Fp {
    fn from(v: u64) -> Fp {
        Fp::new(v)
    }
}

/// `out[i] = (out[i] + c * x[i]) mod p` — the axpy kernel used when workers
/// sum weighted share matrices (`Gₙ` accumulation, eq. 20).
#[inline]
pub fn axpy(out: &mut [u32], c: u64, x: &[u32]) {
    debug_assert_eq!(out.len(), x.len());
    let c = c % P; // reduce once, loop-invariant: keeps every product in REDC range
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = add(*o as u64, mul(c, v as u64)) as u32;
    }
}

/// `out[i] = (c * x[i]) mod p` — scalar-matrix product kernel.
#[inline]
pub fn scale_into(out: &mut [u32], c: u64, x: &[u32]) {
    debug_assert_eq!(out.len(), x.len());
    let c = c % P; // reduce once, loop-invariant: keeps every product in REDC range
    for (o, &v) in out.iter_mut().zip(x.iter()) {
        *o = mul(c, v as u64) as u32;
    }
}

/// `out[i] = Σ_k c_k·x_k[i] mod p` with **delayed reduction** (§Perf P4):
/// partial sums accumulate unreduced in `u64` (safe for up to 2^29 terms at
/// p² < 2^34) and reduce once per element — ~k× fewer reductions than a
/// chain of [`axpy`] calls. This is the hot kernel behind share-polynomial
/// evaluation (Phase 1) and `Gₙ` evaluation (Phase 2).
pub fn weighted_sum_into(out: &mut [u32], terms: &[(u64, &[u32])]) {
    let mut acc = Vec::new();
    weighted_sum_with_scratch(out, terms, &mut acc);
}

/// [`weighted_sum_into`] with a caller-owned accumulator: `acc` grows to
/// `out.len()` once and is reused on every subsequent call, so steady-state
/// invocations allocate nothing (the `alloc_discipline` suite pins this).
/// This is the form the job hot path uses — per-worker [`Scratch`] buffers
/// live in a [`ScratchPool`] shared across jobs.
///
/// [`Scratch`]: crate::runtime::pool::Scratch
/// [`ScratchPool`]: crate::runtime::pool::ScratchPool
pub fn weighted_sum_with_scratch(out: &mut [u32], terms: &[(u64, &[u32])], acc: &mut Vec<u64>) {
    assert!(terms.len() < (1 << 29), "too many terms for delayed reduction");
    let n = out.len();
    acc.clear();
    acc.resize(n, 0);
    for &(c, xs) in terms {
        debug_assert_eq!(xs.len(), n);
        let c = c % P;
        if c == 0 {
            continue;
        }
        for (a, &x) in acc.iter_mut().zip(xs.iter()) {
            *a += c * x as u64;
        }
    }
    // Montgomery fold: each accumulator slot summed ≤ terms.len() products
    // of reduced elements, so the REDC fast path applies whenever the term
    // count fits `mont::MAX_FOLD_TERMS` (it always does on the protocol
    // paths — t²+z terms); the dispatcher falls back to `reduce` above it.
    mont::fold(out, acc, terms.len());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;
    use crate::util::testing::property;

    #[test]
    fn reduce_matches_modulo() {
        property("reduce == %", 20_000, |rng| {
            let x = rng.next_u64();
            if reduce(x) != x % P {
                return Err(format!("reduce({x}) = {} != {}", reduce(x), x % P));
            }
            Ok(())
        });
    }

    #[test]
    fn reduce_boundary_values_exact() {
        // The division-free tail must agree with `%` at every boundary the
        // folding rounds pivot on.
        for x in [
            0u64,
            1,
            P - 1,
            P,
            P + 1,
            2 * P - 1,
            2 * P,
            (1 << 16) - 1,
            1 << 16,
            (1 << 17) - 1,
            (1 << 20) - 1,
            1 << 20,
            (1 << 32) - 1,
            1 << 32,
            (1 << 48) - 1,
            1 << 48,
            u64::MAX - 1,
            u64::MAX,
        ] {
            assert_eq!(reduce(x), x % P, "reduce({x})");
        }
        // Dense sweep around multiples of p across the whole u64 range.
        for k in [1u64, 2, 1 << 10, 1 << 20, 1 << 30, (u64::MAX / P) - 1, u64::MAX / P] {
            let base = k * P;
            for d in 0..3u64 {
                let x = base.wrapping_add(d);
                assert_eq!(reduce(x), x % P, "reduce({x}) near {k}·p");
            }
            let x = base.wrapping_sub(1);
            assert_eq!(reduce(x), x % P, "reduce({x}) below {k}·p");
        }
    }

    #[test]
    fn weighted_sum_scratch_reuse_matches() {
        let mut rng = ChaChaRng::seed_from_u64(17);
        let mut acc = Vec::new();
        for _ in 0..20 {
            let n = rng.gen_index(30) + 1;
            let k = rng.gen_index(6) + 1;
            let xs: Vec<Vec<u32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.field_element() as u32).collect())
                .collect();
            let cs: Vec<u64> = (0..k).map(|_| rng.field_element()).collect();
            let terms: Vec<(u64, &[u32])> =
                cs.iter().zip(&xs).map(|(&c, x)| (c, x.as_slice())).collect();
            let mut via_fresh = vec![0u32; n];
            weighted_sum_into(&mut via_fresh, &terms);
            let mut via_scratch = vec![0u32; n];
            weighted_sum_with_scratch(&mut via_scratch, &terms, &mut acc);
            assert_eq!(via_scratch, via_fresh);
        }
    }

    #[test]
    fn field_axioms_hold() {
        property("field axioms", 5_000, |rng| {
            let a = rng.gen_range(P);
            let b = rng.gen_range(P);
            let c = rng.gen_range(P);
            // commutativity / associativity / distributivity
            if add(a, b) != add(b, a) || mul(a, b) != mul(b, a) {
                return Err("commutativity".into());
            }
            if add(add(a, b), c) != add(a, add(b, c)) {
                return Err("add assoc".into());
            }
            if mul(mul(a, b), c) != mul(a, mul(b, c)) {
                return Err("mul assoc".into());
            }
            if mul(a, add(b, c)) != add(mul(a, b), mul(a, c)) {
                return Err("distributivity".into());
            }
            // inverses
            if add(a, neg(a)) != 0 {
                return Err("additive inverse".into());
            }
            if a != 0 && mul(a, inv(a)) != 1 {
                return Err("multiplicative inverse".into());
            }
            Ok(())
        });
    }

    #[test]
    fn pow_matches_repeated_mul() {
        let mut rng = ChaChaRng::seed_from_u64(3);
        for _ in 0..200 {
            let a = rng.gen_range(P);
            let e = rng.gen_range(50);
            let mut acc = 1u64;
            for _ in 0..e {
                acc = mul(acc, a);
            }
            assert_eq!(pow(a, e), acc);
        }
    }

    #[test]
    fn sub_is_add_of_neg() {
        property("sub == add(neg)", 5_000, |rng| {
            let a = rng.gen_range(P);
            let b = rng.gen_range(P);
            if sub(a, b) != add(a, neg(b)) {
                return Err(format!("sub({a},{b})"));
            }
            Ok(())
        });
    }

    #[test]
    fn fp_ops_match_raw() {
        let a = Fp::new(12345);
        let b = Fp::new(54321);
        assert_eq!((a + b).val(), add(12345, 54321));
        assert_eq!((a - b).val(), sub(12345, 54321));
        assert_eq!((a * b).val(), mul(12345, 54321));
        assert_eq!((-a).val(), neg(12345));
        assert_eq!(a.pow(5).val(), pow(12345, 5));
        assert_eq!((a.inv() * a).val(), 1);
    }

    #[test]
    fn weighted_sum_matches_axpy_chain() {
        property("weighted_sum == axpy chain", 300, |rng| {
            let n = rng.gen_index(40) + 1;
            let k = rng.gen_index(8);
            let xs: Vec<Vec<u32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.field_element() as u32).collect())
                .collect();
            let cs: Vec<u64> = (0..k).map(|_| rng.field_element()).collect();
            let mut via_axpy = vec![0u32; n];
            for (c, x) in cs.iter().zip(&xs) {
                axpy(&mut via_axpy, *c, x);
            }
            let mut via_ws = vec![0u32; n];
            let terms: Vec<(u64, &[u32])> =
                cs.iter().zip(&xs).map(|(&c, x)| (c, x.as_slice())).collect();
            weighted_sum_into(&mut via_ws, &terms);
            if via_ws != via_axpy {
                return Err(format!("n={n} k={k}"));
            }
            Ok(())
        });
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1u32, 2, 3, 65536];
        let mut out = vec![10u32, 20, 30, 40];
        axpy(&mut out, 2, &x);
        assert_eq!(
            out,
            vec![12, 24, 36, (40 + 2 * 65536) as u32 % P as u32]
        );
        let mut out2 = vec![0u32; 4];
        scale_into(&mut out2, 3, &x);
        assert_eq!(out2, vec![3, 6, 9, (3 * 65536 % P) as u32]);
    }
}
