//! Montgomery-form reduction for `GF(p)`, `p = 65537 = 2¹⁶ + 1`.
//!
//! # The degenerate-identity Montgomery domain
//!
//! Montgomery arithmetic represents `a` as `a·R mod p` for `R = 2³²` and
//! replaces every `mod p` with a **REDC** step — two multiplies, an add
//! and a shift — that computes `T·R⁻¹ mod p` from any `T < p·R`. For our
//! Fermat prime the domain is *degenerate in the best possible way*:
//!
//! ```text
//! R = 2³² = (2¹⁶)² ≡ (−1)² = 1  (mod 2¹⁶ + 1)
//! ```
//!
//! `R ≡ 1 (mod p)`, so the Montgomery representation of `a` **is** `a`:
//! [`to_mont`]/[`from_mont`] are the identity, the "convert at the
//! edges" invariant costs zero instructions, and `REDC(T) = T·R⁻¹ =
//! T mod p` exactly. REDC therefore doubles as a drop-in replacement
//! for the generic folding [`reduce`](crate::ff::reduce) on the hot
//! path — ~5 data ops against ~14 — while every output stays
//! **byte-identical** (both compute the same mathematical value
//! `T mod p`; this is pinned by tests here and across the kernels).
//!
//! This was settled analytically rather than by microbenchmark: Barrett
//! reduction for a 17-bit modulus needs a 64×64→high-half multiply plus
//! a correction subtract-and-compare, strictly more work than the
//! single 32×32 low-half multiply REDC needs once `R ≡ 1` removes both
//! conversions. There is no configuration in which Barrett wins here.
//!
//! # Validity bound — why [`MAX_FOLD_TERMS`] exists
//!
//! REDC is exact only for `T < p·R ≈ 2⁴⁸`. A delayed-reduction
//! accumulator sums terms `c·x ≤ (p−1)² = 2³²`, so `n` terms stay below
//! the bound iff `n ≤ 65536` (`65536·2³² = 2⁴⁸ < p·2³²`). Every kernel
//! fold in this crate routes through [`fold`], which enforces the bound
//! by falling back to the full-range [`reduce`](crate::ff::reduce) when
//! a caller exceeds it — the two paths agree bit-for-bit, the fallback
//! is merely slower.
//!
//! # Vectorization
//!
//! The per-element fold is branchless (the canonical subtraction is a
//! `min` idiom, not a compare-and-branch), and [`fold_chunked`]
//! restructures it into fixed-width [`LANES`]-element chunks with no
//! cross-lane dependency — the shape LLVM's SLP/loop vectorizer turns
//! into packed integer code on any target with 64-bit SIMD. The `simd`
//! cargo feature swaps in [`fold_simd`], the same computation over
//! wider [`SIMD_LANES`] blocks with the lane ops written out
//! explicitly; it is where a nightly `std::simd` implementation slots
//! once portable SIMD stabilizes (the crate's MSRV is stable 1.73, so
//! the gated path is stable code shaped for the vectorizer rather than
//! `core::simd` intrinsics).

use crate::ff::P;

/// `−p⁻¹ mod 2³²`. Since `(2¹⁶+1)(2¹⁶−1) = 2³²−1 ≡ −1 (mod 2³²)`,
/// `p⁻¹ = −(2¹⁶−1)` and `NPRIME = 2¹⁶−1 = 65535`.
pub const NPRIME: u32 = 65535;

/// Largest delayed-reduction term count for which [`redc`] of the
/// accumulator is valid: `n` terms of at most `(p−1)² = 2³²` keep the
/// sum `≤ n·2³²`, which stays below the REDC bound `p·2³²` iff
/// `n ≤ 65536`.
pub const MAX_FOLD_TERMS: usize = 65536;

/// Chunk width of [`fold_chunked`] — sized for one AVX2 register of
/// u64 lanes times unroll, small enough that remainders stay cheap.
pub const LANES: usize = 8;

/// Chunk width of the `simd`-feature path ([`fold_simd`]).
#[cfg(feature = "simd")]
pub const SIMD_LANES: usize = 16;

/// Montgomery REDC for `p = 65537`, exact for every `T < p·2³²`:
/// returns `T·R⁻¹ mod p`, which equals **`T mod p`** because
/// `R = 2³² ≡ 1 (mod p)`.
///
/// `m = T·(−p⁻¹) mod 2³²` makes `T + m·p ≡ 0 (mod 2³²)`, so the shift
/// drops no information; the quotient is `< 2p` and one branchless
/// conditional subtraction canonicalizes it.
#[inline(always)]
pub fn redc(t: u64) -> u64 {
    debug_assert!(t < P << 32, "REDC input {t:#x} exceeds p·2³²");
    let m = (t as u32).wrapping_mul(NPRIME);
    let q = (t + (m as u64) * P) >> 32;
    // q < 2p. If q < p the wrapping subtraction underflows to a huge
    // value and `min` keeps q; otherwise it keeps q − p. No branch.
    q.min(q.wrapping_sub(P))
}

/// Convert into the Montgomery domain. For `R ≡ 1 (mod p)` this is the
/// identity on canonical residues — kept as a named function so every
/// kernel edge documents *where* the domain boundary sits, at zero cost.
#[inline(always)]
pub fn to_mont(a: u64) -> u64 {
    debug_assert!(a < P);
    a
}

/// Convert out of the Montgomery domain — the identity, see [`to_mont`].
#[inline(always)]
pub fn from_mont(a: u64) -> u64 {
    debug_assert!(a < P);
    a
}

/// Scalar reference fold: one REDC per element. The chunked and `simd`
/// paths must match this bit-for-bit (pinned in tests).
#[inline]
pub fn fold_scalar(out: &mut [u32], acc: &[u64]) {
    debug_assert_eq!(out.len(), acc.len());
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o = redc(a) as u32;
    }
}

/// Fold `acc` into `out` in fixed-width [`LANES`]-element chunks of
/// independent branchless REDCs — the autovectorizable hot-path shape.
#[inline]
pub fn fold_chunked(out: &mut [u32], acc: &[u64]) {
    debug_assert_eq!(out.len(), acc.len());
    let mut o_it = out.chunks_exact_mut(LANES);
    let mut a_it = acc.chunks_exact(LANES);
    for (oc, ac) in (&mut o_it).zip(&mut a_it) {
        // Fixed-width, no cross-lane dependency: each iteration is
        // LANES independent mul/add/shift/min pipelines.
        for i in 0..LANES {
            let t = ac[i];
            let m = (t as u32).wrapping_mul(NPRIME);
            let q = (t + (m as u64) * P) >> 32;
            oc[i] = q.min(q.wrapping_sub(P)) as u32;
        }
    }
    fold_scalar(o_it.into_remainder(), a_it.remainder());
}

/// `simd`-feature fold: the same REDC over wider [`SIMD_LANES`] blocks,
/// each lane written out as an independent pipeline (stable-Rust shape
/// for the vectorizer; the nightly `std::simd` port drops in here).
#[cfg(feature = "simd")]
#[inline]
pub fn fold_simd(out: &mut [u32], acc: &[u64]) {
    debug_assert_eq!(out.len(), acc.len());
    let mut o_it = out.chunks_exact_mut(SIMD_LANES);
    let mut a_it = acc.chunks_exact(SIMD_LANES);
    for (oc, ac) in (&mut o_it).zip(&mut a_it) {
        let mut q = [0u64; SIMD_LANES];
        for i in 0..SIMD_LANES {
            let t = ac[i];
            let m = (t as u32).wrapping_mul(NPRIME);
            q[i] = (t + (m as u64) * P) >> 32;
        }
        for i in 0..SIMD_LANES {
            oc[i] = q[i].min(q[i].wrapping_sub(P)) as u32;
        }
    }
    fold_scalar(o_it.into_remainder(), a_it.remainder());
}

/// Fold a delayed-reduction accumulator into canonical residues:
/// `out[i] = acc[i] mod p`, one reduction per element, no allocation.
///
/// `terms` is the number of `c·x` products summed into each
/// accumulator slot; at most [`MAX_FOLD_TERMS`] the REDC fast path is
/// valid and dispatch picks the chunked (or `simd`-feature) kernel.
/// Beyond the bound — or for accumulators built from arbitrary u64s —
/// the full-range [`reduce`](crate::ff::reduce) fallback runs instead.
/// Both paths produce identical bytes.
#[inline]
pub fn fold(out: &mut [u32], acc: &[u64], terms: usize) {
    if terms <= MAX_FOLD_TERMS {
        #[cfg(feature = "simd")]
        fold_simd(out, acc);
        #[cfg(not(feature = "simd"))]
        fold_chunked(out, acc);
    } else {
        for (o, &a) in out.iter_mut().zip(acc.iter()) {
            *o = crate::ff::reduce(a) as u32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ff;
    use crate::util::rng::ChaChaRng;

    /// REDC must equal `T mod p` across the boundary lattice of its
    /// validity range: 0, 1, p−1, p, p±ε, k·p±ε, powers of two, and
    /// the extreme accumulator values near the 2⁴⁸ bound.
    #[test]
    fn redc_boundary_values_exact() {
        let eps = [0u64, 1, 2, 3, 7, 65535];
        let anchors = [
            0u64,
            1,
            P - 1,
            P,
            P + 1,
            2 * P,
            (1 << 16) - 1,
            1 << 16,
            (1 << 32) - 1,
            1 << 32,
            (P - 1) * (P - 1),              // largest single product
            65536 * ((1u64 << 32) - 1),     // near the fold bound
            (P << 32) - 1,                  // largest valid REDC input
        ];
        for &a in &anchors {
            for &e in &eps {
                for t in [a.saturating_sub(e), a.saturating_add(e)] {
                    if t < P << 32 {
                        assert_eq!(redc(t), t % P, "redc({t:#x})");
                    }
                }
            }
        }
    }

    #[test]
    fn redc_matches_reduce_on_random_inputs() {
        let mut rng = ChaChaRng::seed_from_u64(0xBEEF);
        for _ in 0..20_000 {
            let t = rng.next_u64() % (P << 32);
            assert_eq!(redc(t), ff::reduce(t), "redc({t:#x})");
        }
    }

    /// `R ≡ 1 (mod p)`: the Montgomery domain is the identity, so
    /// round-trips are trivially exact on every residue boundary.
    #[test]
    fn mont_round_trip_is_identity_on_all_boundaries() {
        for a in [0, 1, 2, P / 2, P - 2, P - 1] {
            assert_eq!(to_mont(a), a);
            assert_eq!(from_mont(to_mont(a)), a);
        }
        // And exhaustively: the field is small enough to sweep whole.
        for a in 0..P {
            assert_eq!(from_mont(to_mont(a)), a);
        }
    }

    /// Montgomery product of domain values: redc(aR·bR) = abR, which
    /// with R ≡ 1 collapses to plain modular multiplication.
    #[test]
    fn mont_multiplication_matches_field_mul() {
        let mut rng = ChaChaRng::seed_from_u64(7);
        for _ in 0..10_000 {
            let a = rng.field_element();
            let b = rng.field_element();
            let got = from_mont(redc(to_mont(a) * to_mont(b)));
            assert_eq!(got, (a * b) % P);
        }
    }

    fn random_acc(len: usize, terms: usize, seed: u64) -> Vec<u64> {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        (0..len)
            .map(|_| {
                (0..terms)
                    .map(|_| rng.field_element() * rng.field_element())
                    .sum()
            })
            .collect()
    }

    /// Scalar, chunked, and (under the feature) simd folds must agree
    /// bit-for-bit on every length that exercises chunk remainders.
    #[test]
    fn fold_paths_are_byte_identical() {
        for len in [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 64, 100] {
            let acc = random_acc(len, 12, len as u64 + 1);
            let mut scalar = vec![0u32; len];
            let mut chunked = vec![0u32; len];
            fold_scalar(&mut scalar, &acc);
            fold_chunked(&mut chunked, &acc);
            assert_eq!(scalar, chunked, "len {len}");
            #[cfg(feature = "simd")]
            {
                let mut simd = vec![0u32; len];
                fold_simd(&mut simd, &acc);
                assert_eq!(scalar, simd, "len {len} (simd)");
            }
            let mut dispatched = vec![0u32; len];
            fold(&mut dispatched, &acc, 12);
            assert_eq!(scalar, dispatched, "len {len} (dispatch)");
        }
    }

    /// Past MAX_FOLD_TERMS the dispatcher must take the full-range
    /// fallback and still agree with plain `mod p` — including on
    /// accumulator values REDC itself could not digest.
    #[test]
    fn fold_beyond_term_bound_falls_back_exactly() {
        let acc = vec![u64::MAX, u64::MAX - 1, P << 32, (P << 32) + 123, 0, 1];
        let mut out = vec![0u32; acc.len()];
        fold(&mut out, &acc, MAX_FOLD_TERMS + 1);
        for (&o, &a) in out.iter().zip(acc.iter()) {
            assert_eq!(o as u64, a % P);
        }
    }

    /// The worst legal accumulator — MAX_FOLD_TERMS maximal products —
    /// sits exactly at the REDC bound and must still reduce correctly.
    #[test]
    fn fold_at_exact_term_bound_is_valid() {
        let worst = MAX_FOLD_TERMS as u64 * ((P - 1) * (P - 1));
        assert!(worst < P << 32, "bound arithmetic drifted");
        let acc = vec![worst; 9];
        let mut out = vec![0u32; 9];
        fold(&mut out, &acc, MAX_FOLD_TERMS);
        assert_eq!(out, vec![(worst % P) as u32; 9]);
    }
}
