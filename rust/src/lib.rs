//! # cmpc — Coded Multi-Party Computation at Edge Networks
//!
//! Production-grade reproduction of *"Efficient Coded Multi-Party Computation
//! at Edge Networks"* (Vedadi, Keshtkarjahromi, Seferoglu, 2023).
//!
//! The library implements privacy-preserving distributed matrix multiplication
//! `Y = Aᵀ·B` over `GF(p)` in the BGW/Shamir style, with *coded* shares that
//! reduce the number of edge workers required in the presence of up to `z`
//! colluding workers. Two constructions from the paper are implemented in
//! full — **PolyDot-CMPC** and **AGE-CMPC** (Adaptive Gap Entangled polynomial
//! codes) — together with the **Entangled-CMPC** baseline (which coincides
//! with AGE at `λ = 0`) and formula-level models of the **SSMM** and
//! **GCSA-NA** baselines.
//!
//! ## Serving model
//!
//! The public API is **session-based**: provision a [`Deployment`] once per
//! `(scheme, s, t, z)` signature — that pays for Phase 0 scheme selection,
//! the α assignment, the O(N³) generalized-Vandermonde solve, backend
//! startup, **and the spawn of `N` persistent Phase-2 worker threads** —
//! then stream any number of (possibly concurrent) jobs through it. Jobs
//! are multiplexed over one long-lived fabric with job-tagged envelopes,
//! per-job traffic meters, and pooled payload buffers: a warm
//! [`Deployment::execute`] spawns zero threads and performs zero
//! fabric-payload allocations. Scheme families are named by [`SchemeSpec`]
//! and resolved through one registry (the same registry behind the
//! coordinator's adaptive policy). Everything fallible returns [`Result`]
//! with a typed [`CmpcError`]; a malformed job is a rejected request —
//! and a dead worker a typed timeout — never a crashed process.
//!
//! For multi-tenant batches, [`coordinator::Coordinator`] adds intake
//! validation ([`coordinator::Coordinator::submit`] → `JobHandle`),
//! signature-grouped deployment sharing, and per-job failure isolation
//! ([`coordinator::Coordinator::drain`] → `Vec<JobReport>`).
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordination layer: code constructions, secret
//!   term design, the three-phase MPC protocol over a simulated edge-network
//!   fabric, the serving coordinator, and the complete analysis + benchmark
//!   harness reproducing every figure in the paper.
//! * **L2 (JAX, build time)** — the per-worker compute graph
//!   `H(αₙ) = F_A(αₙ)·F_B(αₙ) mod p`, AOT-lowered to HLO text under
//!   `python/compile/`, loaded at runtime by [`runtime`].
//! * **L1 (Pallas, build time)** — the blocked modular matmul kernel the L2
//!   graph calls, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once, and the Rust binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use cmpc::codes::SchemeParams;
//! use cmpc::matrix::FpMat;
//! use cmpc::mpc::protocol::ProtocolConfig;
//! use cmpc::util::rng::ChaChaRng;
//! use cmpc::{Deployment, SchemeSpec};
//!
//! fn main() -> cmpc::Result<()> {
//!     // s=t=z=2: the paper's Example 1 — AGE needs 17 workers (λ* = 2).
//!     let params = SchemeParams::try_new(2, 2, 2)?;
//!     let deployment = Deployment::provision(
//!         SchemeSpec::Age { lambda: None }, // None = exact λ* scan
//!         params,
//!         ProtocolConfig::default(),
//!     )?;
//!     assert_eq!(deployment.n_workers(), 17);
//!
//!     // The expensive setup is now cached; stream jobs through it.
//!     let mut rng = ChaChaRng::seed_from_u64(7);
//!     let m = 64;
//!     for _ in 0..3 {
//!         let a = FpMat::random(&mut rng, m, m);
//!         let b = FpMat::random(&mut rng, m, m);
//!         let out = deployment.execute(&a, &b)?;
//!         assert_eq!(out.y, a.transpose().matmul(&b));
//!     }
//!     assert_eq!(deployment.jobs_executed(), 3);
//!     Ok(())
//! }
//! ```
//!
//! ## Persistent worker runtime (v0.4)
//!
//! [`mpc::runtime::WorkerRuntime`] realizes the paper's continuously
//! serving edge workers: worker threads live as long as the deployment and
//! serve a multi-job state machine keyed by
//! [`mpc::network::JobId`]-tagged envelopes. The runtime's control plane
//! ([`mpc::network::ControlMsg`]) starts jobs (per-job seed + counters),
//! acknowledges completion per worker, reports failures as typed errors,
//! and shuts down cleanly on drop (worker panics propagate). Outputs are
//! byte-identical for a given seed regardless of pool size or job
//! interleaving (`tests/parallel_core.rs`, `tests/runtime_reuse.rs`).
//!
//! ## Straggler-resilient runtime (v0.5)
//!
//! The runtime now *exploits* the code's redundancy instead of merely
//! carrying it. Every in-flight job has a **per-job deadline** at each
//! worker — a dead peer fails one job, never its healthy siblings. With
//! `ProtocolConfig::builder().early_decode(true)` the master reconstructs
//! from the **first `t²+z` evaluations** and cancels the straggler tail,
//! so up to `N−(t²+z)` workers can straggle on — or, once their G-exchange
//! contribution is delivered, crash before — their own `I(αₙ)` leg without
//! touching job latency or its result (a *pre*-exchange crash still fails
//! the in-flight job: every I-share needs all `N` G-shares; the respawned
//! worker serves the jobs after it). Dead worker threads are **evicted and
//! respawned** with the same worker index and re-derived rng streams
//! ([`mpc::runtime::WorkerRuntime::reap`]), so the thread count stays
//! flat and outputs stay byte-identical across failures;
//! [`Deployment::health`] meters it all. Every failure mode is
//! reproducibly exercised by the seed-driven [`mpc::chaos`] harness
//! (delay/drop/garble/kill at envelope granularity) in
//! `tests/fault_tolerance.rs`.
//!
//! ## Distributed edge transport (v0.6)
//!
//! The fabric is **pluggable over real networks**
//! ([`mpc::network::Transport`]): the in-process channel transport stays
//! the zero-cost default, and [`transport::tcp::TcpTransport`] runs the
//! same `serve_worker`/`run_master` state machines across OS processes on
//! real sockets — `cmpc node --role worker|master|source-a|source-b
//! --manifest <path>` runs one party per a
//! [`runtime::manifest::TopologyManifest`] (`cmpc topology` writes one).
//! Envelopes cross the wire in the hardened framed codec of
//! [`transport::wire`] (typed errors on truncated/corrupt/version-skewed
//! frames, never a panic), the transport meters the bytes it actually
//! sends per edge class (compared against the analytical ζ in
//! `tests/distributed.rs`), and [`transport::shaper::LinkShaper`] adds
//! per-link latency + token-bucket bandwidth emulation — non-blocking,
//! composable with both transports and with [`mpc::chaos`] — so LAN vs
//! WAN edge scenarios are reproducible in-tree. Early decode now drains
//! per-worker `AbortAck`s, making ξ/σ counters exact (not lower bounds)
//! on the fast path too.
//!
//! ## Serving gateway (v0.7)
//!
//! [`gateway`] is the multi-tenant **front door** over everything above:
//! untrusted clients speak the client plane of [`transport::wire`]
//! (`SubmitJob`/`JobResult`/`Reject` frames, versioned and
//! truncation-hardened like the fabric plane) to a
//! readiness-driven connection multiplexer ([`gateway::poller`]) that
//! serves thousands of connections on a **fixed** thread pool of
//! non-blocking sockets. Submissions pass per-tenant token-bucket +
//! queue-depth admission ([`gateway::admission`], quotas from `tenant`
//! manifest lines) with typed refusals, then batch by `(s, t, z, m)`
//! signature ([`gateway::batcher`]) onto one shared deployment —
//! in-process ([`gateway::LocalEngine`]) or a real multi-process cluster
//! ([`gateway::RemoteEngine`], which pushes each client's matrices to the
//! source nodes via `ControlMsg::JobInput`). `cmpc gateway --manifest F`
//! serves; `cmpc client` drives deterministic multi-tenant load whose
//! accepted digests diff 1:1 against `cmpc node --role reference`;
//! [`metrics::GatewayStats`] meters admission, batching, queue depth, and
//! latency histograms. Results are byte-identical to direct
//! [`Deployment::execute`] calls (`tests/gateway.rs`).
//!
//! ## Parallel compute core (v0.3)
//!
//! Every deployment owns a [`runtime::pool::WorkerPool`] (shared
//! process-wide by default, sized explicitly with
//! `ProtocolConfig::builder().threads(n)`): Phase-1 share encoding fans out
//! across workers with Horner/power-table evaluation, Phase-3
//! reconstruction fans out across output blocks, verify-mode products use
//! the parallel in-place matmul, and `Coordinator::drain` executes queued
//! jobs concurrently. The GF(p) kernels write into caller-owned buffers
//! with per-worker scratch, so steady-state jobs stay allocation-free in
//! the compute loops. Results are byte-for-byte identical at any pool size.
//!
//! ## Pipelines & private inference (v0.10)
//!
//! A [`mpc::pipeline::Pipeline`] chains secure matrix ops — matmul,
//! transpose, element-wise add/scale, fixed-point truncation — into **one
//! job** on an existing deployment. Between matmul rounds the workers
//! open each intermediate only under a one-time mask (`Z = Y + R`) and
//! re-share it over the same job-multiplexed fabric (stage-tagged
//! envelopes), so the master performs **exactly one Phase-3 decode**: the
//! final output ([`metrics::RuntimeHealthReport::phase3_decodes`] pins
//! it). [`Deployment::execute_pipeline`] runs one in-process;
//! [`coordinator::Coordinator::run_pipeline`] and
//! [`gateway::LocalEngine::run_pipeline`] reuse their deployment caches;
//! a `pipeline <spec>` manifest line runs the same chain across real
//! processes (`cmpc node`), byte-identical to the in-process run and to
//! the naive decode-re-encode reference (`tests/pipeline.rs`,
//! `examples/edge_ml_inference.rs` — a two-layer private inference
//! `truncate(Xᵀ·W₀)ᵀ·W₁`). Everything here is additive: single-matmul
//! jobs, wire frames, and every pre-0.10 API are unchanged.
//!
//! ## Adaptive provisioning (v0.11)
//!
//! The gap λ is AGE's whole advantage — but a λ chosen at provision time
//! is a bet about conditions the deployment only discovers while
//! serving. [`autoscale`] closes the loop: a pure **policy engine**
//! ([`autoscale::decide`]) consumes a telemetry window (Phase-2 traffic,
//! deadline misses, evictions, the Byzantine **strike ledger** of
//! [`metrics::RuntimeHealthReport::worker_strikes`]) plus the analytical
//! λ ↦ N curve ([`analysis::CostModel`], the same curve the paper
//! figures plot) and recommends `(scheme, λ, N, a)`;
//! [`Deployment::reconfigure`] applies it as a **zero-downtime
//! blue/green swap** (in-flight jobs finish on the generation they
//! started on — no job is dropped or moved, outputs stay byte-identical;
//! `tests/autoscale.rs` pins both); and the [`autoscale::Autoscaler`]
//! controller samples [`Deployment::health`] on an interval — with
//! hysteresis and post-swap cooldown so a borderline link cannot thrash
//! — recording every decision in a typed audit log surfaced through
//! [`autoscale::Autoscaler::health`]. An `autoscale` manifest line (or
//! `cmpc topology --autoscale`) attaches a controller to every
//! deployment the gateway's [`gateway::LocalEngine`] provisions.
//!
//! ## Where everything lives
//!
//! `docs/ARCHITECTURE.md` is the layer map — `ff → codes → mpc →
//! transport → gateway`, the life of a job and of a pipeline, and the
//! invariant each test file pins. Start there when navigating the crate.
//!
//! The pre-0.2 `run_protocol(...)` wrapper and `Coordinator::run_all()`
//! completed their deprecation window and are gone; use
//! [`Deployment::provision`] + [`Deployment::execute`] and
//! [`coordinator::Coordinator::drain`].

#![warn(missing_docs)]

pub mod analysis;
pub mod autoscale;
pub mod benchkit;
pub mod codes;
pub mod coordinator;
pub mod error;
pub mod ff;
pub mod gateway;
pub mod matrix;
pub mod metrics;
pub mod mpc;
pub mod poly;
pub mod runtime;
pub mod transport;
pub mod util;

pub use analysis::CostModel;
pub use autoscale::{AutoscaleConfig, Autoscaler};
pub use codes::SchemeSpec;
pub use error::{CmpcError, Result};
pub use ff::P;
pub use mpc::deployment::Deployment;
pub use mpc::pipeline::{Pipeline, PipelineOp, PipelineOutput};
