//! # cmpc — Coded Multi-Party Computation at Edge Networks
//!
//! Production-grade reproduction of *"Efficient Coded Multi-Party Computation
//! at Edge Networks"* (Vedadi, Keshtkarjahromi, Seferoglu, 2023).
//!
//! The library implements privacy-preserving distributed matrix multiplication
//! `Y = Aᵀ·B` over `GF(p)` in the BGW/Shamir style, with *coded* shares that
//! reduce the number of edge workers required in the presence of up to `z`
//! colluding workers. Two constructions from the paper are implemented in
//! full — **PolyDot-CMPC** and **AGE-CMPC** (Adaptive Gap Entangled polynomial
//! codes) — together with the **Entangled-CMPC** baseline (which coincides
//! with AGE at `λ = 0`) and formula-level models of the **SSMM** and
//! **GCSA-NA** baselines.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the coordination layer: code constructions, secret
//!   term design, the three-phase MPC protocol over a simulated edge-network
//!   fabric, a serving coordinator (job queue, adaptive scheme selection,
//!   batching, straggler-tolerant reconstruction), and the complete analysis
//!   + benchmark harness reproducing every figure in the paper.
//! * **L2 (JAX, build time)** — the per-worker compute graph
//!   `H(αₙ) = F_A(αₙ)·F_B(αₙ) mod p`, AOT-lowered to HLO text under
//!   `python/compile/`, loaded at runtime by [`runtime`].
//! * **L1 (Pallas, build time)** — the blocked modular matmul kernel the L2
//!   graph calls, validated against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` once, and the Rust binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use cmpc::codes::{AgeCmpc, CmpcScheme};
//! use cmpc::matrix::FpMat;
//! use cmpc::mpc::protocol::{run_protocol, ProtocolConfig};
//! use cmpc::util::rng::ChaChaRng;
//!
//! let mut rng = ChaChaRng::seed_from_u64(7);
//! let m = 64;
//! let a = FpMat::random(&mut rng, m, m);
//! let b = FpMat::random(&mut rng, m, m);
//! // s=t=z=2: the paper's Example 1 — AGE needs 17 workers (λ* = 2).
//! let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
//! assert_eq!(scheme.n_workers(), 17);
//! let out = run_protocol(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
//! assert_eq!(out.y, a.transpose().matmul(&b));
//! ```

pub mod analysis;
pub mod benchkit;
pub mod codes;
pub mod coordinator;
pub mod ff;
pub mod matrix;
pub mod metrics;
pub mod mpc;
pub mod poly;
pub mod runtime;
pub mod util;

pub use ff::P;
