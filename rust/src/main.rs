//! `cmpc` — command-line front end for the coded-MPC library.
//!
//! Subcommands:
//!
//! * `info    --s S --t T --z Z` — worker counts, λ*, and supports per scheme.
//! * `run     --m M --s S --t T --z Z [--scheme K] [--backend B]` — execute
//!   one privacy-preserving multiplication end to end and report metrics.
//! * `serve   --jobs J --m M ...` — batch serving demo through the
//!   coordinator (deployment caching, adaptive scheme selection, per-job
//!   failure isolation).
//! * `topology --scheme K --s S --t T --z Z --m M --base-port P --out F` —
//!   write a distributed-deployment manifest (prints the worker count);
//!   `--pipeline "matmul,truncate:4,matmul"` makes each job a chained
//!   pipeline instead of a single matmul (v0.10).
//! * `node    --role worker|master|source-a|source-b --manifest F` — run
//!   one CMPC party as this OS process, over TCP per the manifest
//!   (`--role reference` prints the in-process digests for comparison).
//! * `gateway --manifest F [--engine local|cluster]` — multi-tenant
//!   serving front door: admission control + batching over a local or
//!   distributed execution engine (v0.7).
//! * `client  --addr A --tenants 0,1 --jobs-per-tenant J ...` — load
//!   driver for a gateway; prints per-job digests in the reference format.
//! * `figures [--out DIR] [--zmax Z]` — regenerate every paper figure's
//!   data series (Figs. 2, 3, 4a–c + ablations) into CSVs.

use std::path::PathBuf;
use std::sync::Arc;

use cmpc::analysis::{self, figures, SchemeKind};
use cmpc::codes::{CmpcScheme, SchemeParams};
use cmpc::coordinator::{build_scheme, Coordinator, CoordinatorConfig, SchemePolicy};
use cmpc::gateway::client::{run_load, ClientReply, GatewayClient, LoadPlan};
use cmpc::gateway::{ExecuteEngine, Gateway, GatewayConfig, LocalEngine, RemoteEngine};
use cmpc::matrix::FpMat;
use cmpc::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
use cmpc::mpc::deployment::Deployment;
use cmpc::mpc::protocol::ProtocolConfig;
use cmpc::runtime::manifest::{AutoscaleSpec, TopologyManifest};
use cmpc::runtime::BackendChoice;
use cmpc::transport::node::{self, NodeRole};
use cmpc::util::cli::Args;
use cmpc::util::rng::ChaChaRng;
use cmpc::{CmpcError, Result, SchemeSpec};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("topology") => cmd_topology(&args),
        Some("node") => cmd_node(&args),
        Some("gateway") => cmd_gateway(&args),
        Some("client") => cmd_client(&args),
        Some("figures") => cmd_figures(&args),
        _ => {
            eprintln!(
                "usage: cmpc <info|run|serve|topology|node|gateway|client|figures> [options]\n\
                 \n\
                 info     --s S --t T --z Z [--a A]\n\
                 run      --m M --s S --t T --z Z [--a A]\n\
                 \x20        [--scheme age|polydot|entangled|adaptive]\n\
                 \x20        [--backend native|pjrt] [--artifacts DIR] [--seed N]\n\
                 serve    --jobs J --m M --s S --t T --z Z [--backend ...]\n\
                 topology --scheme age|polydot|entangled --s S --t T --z Z --m M [--seed N]\n\
                 \x20        [--jobs J] [--host H] --base-port P [--early-decode]\n\
                 \x20        [--a A] [--pipeline SPEC] [--gateway-token TOK]\n\
                 \x20        [--gateway H:P] [--autoscale [--autoscale-interval-ms MS]] --out FILE\n\
                 \x20        (prints the worker count N; manifest lists every node's host:port)\n\
                 node     --role worker|master|source-a|source-b|reference --manifest FILE\n\
                 \x20        [--index I] [--garble-ishare]   (worker role only)\n\
                 gateway  --manifest FILE [--engine local|cluster] [--listen H:P]\n\
                 \x20        [--pollers N] [--max-batch N] [--max-wait-ms MS] [--backend ...]\n\
                 \x20        (serves clients until one sends an authorized shutdown frame)\n\
                 client   --addr H:P [--tenants 0,1,..] [--jobs-per-tenant J] --m M\n\
                 \x20        --s S --t T --z Z [--a A] [--seed N] [--qps Q]\n\
                 \x20        [--shutdown] [--token TOK]\n\
                 figures  [--out DIR] [--zmax Z]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn parse_stz(args: &Args) -> (usize, usize, usize) {
    (
        args.get_parse("s", 2usize),
        args.get_parse("t", 2usize),
        args.get_parse("z", 2usize),
    )
}

fn parse_backend(args: &Args) -> BackendChoice {
    match args.get("backend").unwrap_or("native") {
        "native" => BackendChoice::Native,
        "pjrt" => BackendChoice::Pjrt {
            artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        },
        other => {
            eprintln!("error: unknown backend {other:?} (native|pjrt)");
            std::process::exit(2);
        }
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let (s, t, z) = parse_stz(args);
    let a: usize = args.get_parse("a", 0usize);
    if a == 0 {
        println!(
            "CMPC worker requirements at s={s}, t={t}, z={z}  (t²+z = {} shares to decode)\n",
            t * t + z
        );
    } else {
        println!(
            "CMPC worker requirements at s={s}, t={t}, z={z}, a={a}  \
             (recovery quota t²+z+2a = {} shares to locate {a} garbled and decode)\n",
            t * t + z + 2 * a
        );
    }
    println!("{:<18} {:>9}  notes", "scheme", "N");
    for kind in SchemeKind::ALL {
        let n = analysis::n_workers(kind, s, t, z);
        let note = match kind {
            SchemeKind::Age => {
                let (_, l) = analysis::n_age_enum(s, t, z);
                format!("λ* = {l}")
            }
            SchemeKind::PolyDot => format!("Thm 2: {}", analysis::n_polydot_formula(s, t, z)),
            SchemeKind::Entangled => "eq. (194)".into(),
            SchemeKind::Ssmm => "(t+1)(ts+z)−1".into(),
            SchemeKind::GcsaNa => "2st²+2z−1".into(),
        };
        println!("{:<18} {:>9}  {note}", kind.label(), n);
    }
    let sch = build_scheme(SchemeKind::Age, s, t, z)?;
    println!("\nAGE construction detail:");
    println!("  P(C_A) = {:?}", sch.coded_support_a());
    println!("  P(S_A) = {:?}", sch.secret_powers_a());
    println!("  P(C_B) = {:?}", sch.coded_support_b());
    println!("  P(S_B) = {:?}", sch.secret_powers_b());
    println!("  important = {:?}", sch.important_powers());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let (s, t, z) = parse_stz(args);
    let m: usize = args.get_parse("m", 64);
    let seed: u64 = args.get_parse("seed", 7);
    let adv: usize = args.get_parse("a", 0usize);
    let params = SchemeParams::try_new(s, t, z)?.with_adversary_tolerance(adv);
    let scheme: Arc<dyn CmpcScheme> = match args.get("scheme").unwrap_or("age") {
        "age" => SchemeSpec::Age { lambda: None }.resolve(params)?,
        "polydot" => SchemeSpec::PolyDot.resolve(params)?,
        "entangled" => SchemeSpec::Entangled.resolve(params)?,
        "adaptive" => SchemeSpec::resolve_adaptive(params)?,
        other => {
            return Err(CmpcError::InvalidParams(format!(
                "unknown scheme {other:?} (age|polydot|entangled|adaptive)"
            )))
        }
    };
    let mut rng = ChaChaRng::seed_from_u64(seed);
    let a = FpMat::random(&mut rng, m, m);
    let b = FpMat::random(&mut rng, m, m);
    let cfg = ProtocolConfig::builder()
        .backend(parse_backend(args))
        .seed(seed)
        .build();
    let deployment = Deployment::for_scheme(scheme, cfg)?;
    let out = deployment.execute(&a, &b)?;
    println!("scheme               {}", out.scheme_name);
    println!("workers              {}", out.n_workers);
    println!("stragglers tolerated {}", out.stragglers_tolerated);
    println!("verified Y = AᵀB     {}", out.verified);
    println!(
        "timings              setup={:?} phase1={:?} phase2={:?} phase3={:?}",
        out.timings.setup,
        out.timings.phase1_share,
        out.timings.phase2_compute,
        out.timings.phase3_reconstruct
    );
    let tr = out.traffic;
    println!(
        "traffic (scalars)    src→wkr={} wkr↔wkr={} wkr→master={} (ζ = {})",
        tr.source_to_worker,
        tr.worker_to_worker,
        tr.worker_to_master,
        analysis::communication_overhead(m, t, out.n_workers as u64)
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (s, t, z) = parse_stz(args);
    let m: usize = args.get_parse("m", 64);
    let jobs: usize = args.get_parse("jobs", 4);
    let mut coord = Coordinator::new(
        CoordinatorConfig::builder()
            .policy(SchemePolicy::Adaptive)
            .backend(parse_backend(args))
            .build(),
    );
    let mut rng = ChaChaRng::seed_from_u64(11);
    for _ in 0..jobs {
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        coord.submit(a, b, s, t, z)?;
    }
    let t0 = std::time::Instant::now();
    let reports = coord.drain();
    let wall = t0.elapsed();
    let mut ok = 0usize;
    for r in &reports {
        match &r.outcome {
            Ok(out) => {
                ok += 1;
                println!(
                    "job {:>3}  scheme={:<16} N={:<4} cache_hit={:<5} verified={} total={:?}",
                    r.id,
                    r.scheme,
                    r.n_workers,
                    r.setup_cache_hit,
                    out.verified,
                    out.timings.total()
                );
            }
            Err(e) => println!("job {:>3}  FAILED: {e}", r.id),
        }
    }
    println!(
        "\n{ok}/{} jobs succeeded in {wall:?} → {:.2} jobs/s",
        reports.len(),
        reports.len() as f64 / wall.as_secs_f64()
    );
    Ok(())
}

fn cmd_topology(args: &Args) -> Result<()> {
    let (s, t, z) = parse_stz(args);
    let scheme = args.get("scheme").unwrap_or("age");
    let m: usize = args.get_parse("m", 64);
    let seed: u64 = args.get_parse("seed", 7);
    let jobs: usize = args.get_parse("jobs", 2);
    let host = args.get("host").unwrap_or("127.0.0.1");
    let base_port: u16 = args.get_parse("base-port", 9300);
    let out = args.get("out").map(PathBuf::from);
    let mut manifest = TopologyManifest::template(scheme, s, t, z, m, seed, jobs, host, base_port)?;
    manifest.early_decode = args.flag("early-decode");
    manifest.adversary_tolerance = args.get_parse("a", 0usize);
    if let Some(spec) = args.get("pipeline") {
        manifest.pipeline_spec = Some(spec.to_string());
        manifest.validate()?; // reject bad specs before writing the file
    }
    if let Some(tok) = args.get("gateway-token") {
        manifest.gateway_token = Some(
            tok.parse()
                .map_err(|_| CmpcError::InvalidParams("bad --gateway-token".to_string()))?,
        );
    }
    if let Some(addr) = args.get("gateway") {
        manifest.gateway = Some(addr.to_string());
    }
    if args.flag("autoscale") {
        let defaults = cmpc::autoscale::AutoscaleConfig::default();
        manifest.autoscale = Some(AutoscaleSpec {
            interval_ms: args.get_parse("autoscale-interval-ms", 250u64),
            hysteresis_pct: defaults.policy.hysteresis_pct,
            strike_threshold: defaults.policy.strike_threshold,
            cooldown_ticks: defaults.cooldown_ticks,
        });
        manifest.validate()?; // autoscale needs a gateway line — fail before writing
    }
    if let Some(ms) = args.get("recv-timeout-ms") {
        manifest.recv_timeout = std::time::Duration::from_millis(
            ms.parse()
                .map_err(|_| CmpcError::InvalidParams("bad --recv-timeout-ms".to_string()))?,
        );
    }
    let rendered = manifest.render();
    match &out {
        Some(path) => std::fs::write(path, rendered)
            .map_err(|e| CmpcError::Io(format!("writing {}: {e}", path.display())))?,
        None => print!("{rendered}"),
    }
    if let Some(path) = &out {
        eprintln!(
            "wrote {} ({} workers + master + 2 sources on {host}:{base_port}..)",
            path.display(),
            manifest.n_workers()
        );
        // Worker count on stdout, alone, so scripts can spawn the right
        // number of `cmpc node --role worker` processes.
        println!("{}", manifest.n_workers());
    }
    Ok(())
}

fn cmd_node(args: &Args) -> Result<()> {
    let manifest_path = args
        .get("manifest")
        .ok_or_else(|| CmpcError::InvalidParams("node needs --manifest <file>".to_string()))?;
    let manifest = TopologyManifest::load(&PathBuf::from(manifest_path))?;
    let role = args
        .get("role")
        .ok_or_else(|| CmpcError::InvalidParams("node needs --role".to_string()))?;
    if role == "reference" {
        for (job, digest) in node::run_reference(&manifest)? {
            println!("job {job} digest 0x{digest:016x}");
        }
        println!("reference: {} in-process jobs decoded", manifest.jobs);
        return Ok(());
    }
    let index = args
        .get("index")
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| CmpcError::InvalidParams("bad --index".to_string()))
        })
        .transpose()?;
    let role = NodeRole::parse(role, index)?;
    let chaos = if args.flag("garble-ishare") {
        let NodeRole::Worker(i) = role else {
            return Err(CmpcError::InvalidParams(
                "--garble-ishare applies to the worker role only".to_string(),
            ));
        };
        Some(
            ChaosPlan::new()
                .rule(
                    FaultRule::new(FaultAction::Garble)
                        .from_node(i)
                        .class(PayloadClass::IShare)
                        .limit(1),
                )
                .into_shared(),
        )
    } else {
        None
    };
    match node::run_role(role, &manifest, chaos)? {
        Some(report) => {
            for j in &report.jobs {
                println!("job {} digest 0x{:016x}", j.job, j.digest);
            }
            for j in &report.jobs {
                if !j.blamed_workers.is_empty() {
                    println!("job {} blamed {:?}", j.job, j.blamed_workers);
                }
            }
            for j in &report.jobs {
                // Scalar traffic is metered where it is sent — worker
                // processes own the ζ legs; the master reports its wire
                // bytes below.
                eprintln!(
                    "job {}: verified={} early_decode={} elapsed={:?}",
                    j.job, j.verified, j.early_decoded, j.elapsed
                );
            }
            let w = report.wire;
            eprintln!(
                "master wire: {} frames, {} bytes (control {} B)",
                w.frames,
                w.total_bytes(),
                w.bytes_control
            );
            println!("master: {}/{} jobs verified", report.jobs.len(), manifest.jobs);
        }
        None => {
            // Long-running roles return after the master's shutdown.
        }
    }
    Ok(())
}

fn cmd_gateway(args: &Args) -> Result<()> {
    let manifest_path = args.get("manifest").ok_or_else(|| {
        CmpcError::InvalidParams("gateway needs --manifest <file>".to_string())
    })?;
    let manifest = TopologyManifest::load(&PathBuf::from(manifest_path))?;
    let listen = args
        .get("listen")
        .map(str::to_string)
        .or_else(|| manifest.gateway.clone())
        .ok_or_else(|| {
            CmpcError::InvalidParams(
                "gateway needs --listen or a manifest gateway line".to_string(),
            )
        })?;
    let mut config = GatewayConfig {
        tenants: manifest.tenants.clone(),
        shutdown_token: manifest.gateway_token,
        ..GatewayConfig::default()
    };
    config.poller_threads = args.get_parse("pollers", config.poller_threads);
    config.max_batch = args.get_parse("max-batch", config.max_batch);
    if let Some(ms) = args.get("max-wait-ms") {
        config.max_wait = std::time::Duration::from_millis(
            ms.parse()
                .map_err(|_| CmpcError::InvalidParams("bad --max-wait-ms".to_string()))?,
        );
    }
    let engine_kind = args.get("engine").unwrap_or("cluster");
    let mut local: Option<Arc<LocalEngine>> = None;
    let engine: Arc<dyn ExecuteEngine> = match engine_kind {
        "local" => {
            let eng = Arc::new(LocalEngine::with_autoscale(
                CoordinatorConfig::builder()
                    .backend(parse_backend(args))
                    .verify(manifest.verify)
                    .build(),
                manifest.autoscale.map(|spec| spec.to_config()),
            ));
            local = Some(eng.clone());
            eng
        }
        "cluster" => {
            let engine = RemoteEngine::connect(manifest.clone())?;
            config.shape_lock = Some(engine.shape());
            Arc::new(engine)
        }
        other => {
            return Err(CmpcError::InvalidParams(format!(
                "unknown engine {other:?} (local|cluster)"
            )))
        }
    };
    let gateway = Gateway::start(&listen, config, engine)?;
    // Announce the bound address immediately (port 0 resolves here) —
    // flushed explicitly because stdout is block-buffered under a pipe.
    println!("listening {}", gateway.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    eprintln!(
        "gateway: engine={engine_kind}, {} tenant quotas, serving on {}",
        manifest.tenants.len(),
        gateway.local_addr()
    );
    gateway.wait();
    let stats = gateway.shutdown();
    println!(
        "gateway: connections={} accepted={} completed={} failed={} rejected={}",
        stats.connections,
        stats.accepted,
        stats.completed,
        stats.failed,
        stats.rejected_total()
    );
    println!(
        "gateway: batches={} batched_jobs={} max_batch={} p50_us={} p99_us={}",
        stats.batches,
        stats.batched_jobs,
        stats.max_batch(),
        stats.p50_latency_us(),
        stats.p99_latency_us()
    );
    if let Some(eng) = local {
        // Controllers already stopped (the dispatcher's engine shutdown);
        // these are their final audit snapshots.
        for (i, h) in eng.autoscale_reports().iter().enumerate() {
            println!(
                "autoscale[{i}]: ticks={} reconfigurations={} holds={} failed={}",
                h.ticks, h.reconfigurations, h.holds, h.failed
            );
        }
    }
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr").ok_or_else(|| {
        CmpcError::InvalidParams("client needs --addr <host:port>".to_string())
    })?;
    let (s, t, z) = parse_stz(args);
    let tenants: Vec<u32> = match args.get("tenants") {
        None => vec![0],
        Some(list) => list
            .split(',')
            .map(|v| {
                v.trim()
                    .parse::<u32>()
                    .map_err(|_| CmpcError::InvalidParams(format!("bad tenant id {v:?}")))
            })
            .collect::<Result<_>>()?,
    };
    let qps = args
        .get("qps")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| CmpcError::InvalidParams("bad --qps".to_string()))
        })
        .transpose()?;
    let plan = LoadPlan {
        addr: addr.to_string(),
        tenants,
        jobs_per_tenant: args.get_parse("jobs-per-tenant", 4),
        m: args.get_parse("m", 64),
        s,
        t,
        z,
        adv: args.get_parse("a", 0usize),
        seed: args.get_parse("seed", 7),
        qps,
    };
    let report = run_load(&plan)?;
    for o in &report.outcomes {
        match &o.reply {
            // Same line format as `cmpc node`, so accepted digests diff
            // 1:1 against `--role reference` output.
            ClientReply::Accepted { digest, .. } => {
                println!("job {} digest 0x{digest:016x}", o.job)
            }
            ClientReply::Rejected { reason, detail, .. } => {
                println!("job {} rejected {reason} ({detail})", o.job)
            }
        }
    }
    eprintln!(
        "client: {} accepted, {} rejected in {:?} → {:.2} jobs/s, p50={:?} p99={:?}",
        report.accepted(),
        report.rejected(),
        report.elapsed,
        report.qps(),
        report.latency_percentile(0.5),
        report.latency_percentile(0.99)
    );
    println!(
        "client: {} accepted, {} rejected",
        report.accepted(),
        report.rejected()
    );
    if args.flag("shutdown") {
        let token: u64 = args.get_parse("token", 0u64);
        GatewayClient::connect(addr, 0)?.shutdown_gateway(token)?;
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let zmax: usize = args.get_parse("zmax", 300);
    std::fs::create_dir_all(&out)?;

    println!("[fig2] N vs z, s=4 t=15, z=1..{zmax} (exact enumeration for AGE/PolyDot)");
    let rows2 = figures::fig2_workers(4, 15, zmax);
    figures::write_fig2(&out, &rows2)?;
    for z in [1usize, 10, 48, 49, 100, 180, 181, 250, zmax] {
        if z <= rows2.len() {
            let r = &rows2[z - 1];
            println!(
                "  z={:<4} AGE={:<5} (λ*={:<3}) PolyDot={:<5} Entangled={:<5} SSMM={:<5} GCSA-NA={}",
                r.z, r.age, r.age_lambda, r.polydot, r.entangled, r.ssmm, r.gcsa_na
            );
        }
    }

    println!("\n[fig3] N vs s/t, st=36, z=42");
    let rows3 = figures::fig3_workers(36, 42);
    figures::write_fig3(&out, &rows3)?;
    for r in &rows3 {
        println!(
            "  (s,t)=({:>2},{:>2}) AGE={:<5} PolyDot={:<5} Entangled={:<5} SSMM={:<5} GCSA-NA={}",
            r.s, r.t, r.age, r.polydot, r.entangled, r.ssmm, r.gcsa_na
        );
    }

    println!("\n[fig4] per-worker overheads, m=36000, st=36, z=42 → fig4_overheads.csv");
    let rows4 = figures::fig4_overheads(36000, 36, 42);
    figures::write_fig4(&out, &rows4)?;
    for r in &rows4 {
        let age = &r.per_scheme[0];
        println!(
            "  (s,t)=({:>2},{:>2}) AGE: ξ={:.3e} σ={:.3e}B ζ={:.3e}B",
            r.s, r.t, age.2 as f64, age.3 as f64, age.4 as f64
        );
    }

    println!("\n[ablation] Γ(λ) gap curves → lambda_ablation.csv");
    figures::write_lambda_ablation(&out, &[(2, 2, 2), (4, 15, 42), (4, 9, 42), (6, 6, 42)])?;

    println!("[lemmas] PolyDot win regions (Lemmas 3–5 grid) → polydot_wins.csv");
    let wins = figures::polydot_win_regions(6, 6, 40);
    figures::write_polydot_wins(&out, &wins)?;

    println!("\nwrote CSVs to {}", out.display());
    Ok(())
}
