//! Admission control — the gateway's door policy.
//!
//! Every tenant gets a classic **token bucket** (capacity `burst`, refill
//! `rate_per_sec`) plus a **pending-job cap**: a submission consumes one
//! token at the door and one pending slot until its result (or internal
//! failure) goes back out. Refusals are *typed*
//! ([`RejectReason`]) so clients, tests, and the CI lane branch on the
//! cause instead of parsing prose.
//!
//! Buckets with `rate_per_sec == 0` never refill — with `burst = K`,
//! exactly the first `K` submissions are admitted no matter how fast or
//! slow they arrive. That degenerate mode is what makes the over-quota
//! set in `tests/gateway.rs` and the CI `gateway` lane deterministic.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::transport::wire::RejectReason;

/// One tenant's door policy, as declared by a manifest `tenant` line.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantQuota {
    /// Tenant id this quota applies to.
    pub id: u32,
    /// Token-bucket capacity (also its initial fill).
    pub burst: u32,
    /// Token refill rate; `0` disables refill (deterministic test mode).
    pub rate_per_sec: f64,
    /// Jobs this tenant may have in flight (queued or executing) at once.
    pub max_pending: usize,
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

struct TenantState {
    quota: TenantQuota,
    bucket: Mutex<Bucket>,
    pending: AtomicUsize,
}

impl TenantState {
    fn new(quota: TenantQuota, now: Instant) -> TenantState {
        TenantState {
            quota,
            bucket: Mutex::new(Bucket {
                tokens: quota.burst as f64,
                last: now,
            }),
            pending: AtomicUsize::new(0),
        }
    }

    fn try_take_token(&self, now: Instant) -> bool {
        let mut b = self.bucket.lock().unwrap();
        if self.quota.rate_per_sec > 0.0 {
            let dt = now.saturating_duration_since(b.last).as_secs_f64();
            b.tokens = (b.tokens + dt * self.quota.rate_per_sec).min(self.quota.burst as f64);
            b.last = now;
        }
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-tenant admission state for one gateway. An empty quota table means
/// **open admission**: any tenant id is served under one implicit
/// unlimited quota (the zero-config `cmpc gateway` demo path); with any
/// quota configured, unlisted tenants get [`RejectReason::UnknownTenant`].
pub struct Admission {
    tenants: HashMap<u32, TenantState>,
    open: Option<TenantState>,
}

impl Admission {
    /// Build the door policy from manifest `tenant` lines (empty = open
    /// admission, see the type docs).
    pub fn new(quotas: &[TenantQuota]) -> Admission {
        let now = Instant::now();
        let open = if quotas.is_empty() {
            Some(TenantState::new(
                TenantQuota {
                    id: 0,
                    burst: u32::MAX,
                    // Effectively unlimited: the bucket refills far faster
                    // than any loopback client can submit.
                    rate_per_sec: f64::from(u32::MAX),
                    max_pending: usize::MAX,
                },
                now,
            ))
        } else {
            None
        };
        Admission {
            tenants: quotas
                .iter()
                .map(|&q| (q.id, TenantState::new(q, now)))
                .collect(),
            open,
        }
    }

    fn state(&self, tenant: u32) -> Option<&TenantState> {
        self.tenants.get(&tenant).or(self.open.as_ref())
    }

    /// Decide a submission at the door. `Ok(())` takes one token and one
    /// pending slot; the caller owes a matching [`Admission::release`]
    /// once the job's response is on its way out.
    pub fn try_admit(&self, tenant: u32) -> std::result::Result<(), RejectReason> {
        let state = self.state(tenant).ok_or(RejectReason::UnknownTenant)?;
        // Claim the pending slot first: a rejected claim must not have
        // consumed a token.
        let prev = state.pending.fetch_add(1, Ordering::AcqRel);
        if prev >= state.quota.max_pending {
            state.pending.fetch_sub(1, Ordering::AcqRel);
            return Err(RejectReason::QueueFull);
        }
        if !state.try_take_token(Instant::now()) {
            state.pending.fetch_sub(1, Ordering::AcqRel);
            return Err(RejectReason::QuotaExceeded);
        }
        Ok(())
    }

    /// Return the pending slot taken by a successful [`Admission::try_admit`].
    /// Tokens are deliberately not returned — they meter *submissions*, not
    /// completions.
    pub fn release(&self, tenant: u32) {
        if let Some(state) = self.state(tenant) {
            state.pending.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Jobs currently holding a pending slot for `tenant` (0 for unknown).
    pub fn pending(&self, tenant: u32) -> usize {
        self.state(tenant)
            .map(|s| s.pending.load(Ordering::Acquire))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quota(id: u32, burst: u32, rate: f64, max_pending: usize) -> TenantQuota {
        TenantQuota {
            id,
            burst,
            rate_per_sec: rate,
            max_pending,
        }
    }

    #[test]
    fn zero_rate_bucket_admits_exactly_burst() {
        let adm = Admission::new(&[quota(7, 3, 0.0, 100)]);
        for _ in 0..3 {
            adm.try_admit(7).unwrap();
        }
        assert_eq!(adm.try_admit(7), Err(RejectReason::QuotaExceeded));
        // Releasing pending slots does not mint tokens.
        for _ in 0..3 {
            adm.release(7);
        }
        assert_eq!(adm.try_admit(7), Err(RejectReason::QuotaExceeded));
    }

    #[test]
    fn pending_cap_is_typed_and_recoverable() {
        let adm = Admission::new(&[quota(1, 100, 0.0, 2)]);
        adm.try_admit(1).unwrap();
        adm.try_admit(1).unwrap();
        assert_eq!(adm.try_admit(1), Err(RejectReason::QueueFull));
        assert_eq!(adm.pending(1), 2);
        adm.release(1);
        adm.try_admit(1).unwrap();
        assert_eq!(adm.pending(1), 2);
    }

    #[test]
    fn tenants_are_isolated() {
        let adm = Admission::new(&[quota(1, 1, 0.0, 10), quota(2, 10, 0.0, 10)]);
        adm.try_admit(1).unwrap();
        assert_eq!(adm.try_admit(1), Err(RejectReason::QuotaExceeded));
        // Tenant 2 is untouched by tenant 1 exhausting its bucket.
        for _ in 0..10 {
            adm.try_admit(2).unwrap();
        }
        assert_eq!(adm.try_admit(3), Err(RejectReason::UnknownTenant));
    }

    #[test]
    fn empty_table_is_open_admission() {
        let adm = Admission::new(&[]);
        for tenant in [0, 9, 4_000_000_000] {
            for _ in 0..64 {
                adm.try_admit(tenant).unwrap();
            }
        }
    }

    #[test]
    fn refill_restores_tokens_over_time() {
        let adm = Admission::new(&[quota(5, 2, 4000.0, 100)]);
        adm.try_admit(5).unwrap();
        adm.try_admit(5).unwrap();
        // Bucket drained; at 4000 tokens/s a few ms restores it.
        let t0 = Instant::now();
        loop {
            if adm.try_admit(5).is_ok() {
                break;
            }
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(5),
                "bucket never refilled"
            );
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}
