//! Readiness-driven connection multiplexer — thousands of client
//! connections on a fixed, small thread pool.
//!
//! The std library has no `epoll` binding, so readiness is approximated
//! the portable way: every socket is **non-blocking**, and each poller
//! thread sweeps its connection set — draining reads until `WouldBlock`,
//! flushing queued writes until `WouldBlock` — then parks briefly when a
//! sweep makes no progress. Latency stays sub-millisecond while idle CPU
//! stays near zero, and crucially the thread count is *constant*: an
//! accept thread plus `threads` pollers, no matter how many clients
//! connect (`tests/gateway.rs` pins this with a `/proc/self/status`
//! thread census at 64+ concurrent connections).
//!
//! The poller owns all socket I/O. Protocol logic lives behind the
//! [`Sink`] trait (implemented by the gateway core): the poller parses
//! [`ClientFrame`]s incrementally out of each connection's read buffer and
//! hands them up; responses come back through [`ConnHandle::send`], which
//! only appends bytes to the connection's outbox — the poller thread
//! flushes them on its next sweep. Oversized frames are detected from the
//! 23-byte header alone ([`peek_client_header`]), *before* any body is
//! buffered, so a hostile length prefix cannot balloon gateway memory.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::transport::wire::{
    decode_client_frame, encode_client_frame, peek_client_header, ClientFrame, ClientHeader,
};

/// How long a poller parks when a full sweep made no progress.
const IDLE_PARK: Duration = Duration::from_micros(300);

/// Read granularity per non-blocking `read` call.
const READ_BUF: usize = 64 * 1024;

/// Budget for the final outbox flush after `flush` is signalled. By then
/// the dispatcher has already joined, so every `Result`/`Reject` frame is
/// sitting in some connection's outbox — this deadline only bounds slow
/// or dead clients, not in-flight work.
const DRAIN_BUDGET: Duration = Duration::from_secs(2);

/// What the sink wants done with the connection after a callback.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum FrameOutcome {
    /// Keep serving the connection.
    Continue,
    /// Stop reading; close once every queued response byte is flushed.
    CloseAfterFlush,
}

/// Protocol logic the poller calls into. All methods run on poller
/// threads and must not block.
pub(crate) trait Sink: Send + Sync {
    /// A connection was accepted and registered.
    fn on_connect(&self, conn: &Arc<ConnHandle>);
    /// One complete, well-formed frame arrived.
    fn on_frame(&self, conn: &Arc<ConnHandle>, frame: ClientFrame) -> FrameOutcome;
    /// A header claims a payload above the gateway's cap; the body was
    /// (and will never be) buffered.
    fn on_oversize(&self, conn: &Arc<ConnHandle>, header: &ClientHeader) -> FrameOutcome;
    /// The stream produced bytes the codec rejects; it cannot be resynced.
    fn on_corrupt(&self, conn: &Arc<ConnHandle>, err: &CmpcError) -> FrameOutcome;
    /// The connection is gone (peer EOF, I/O error, or post-flush close).
    fn on_disconnect(&self, conn: &Arc<ConnHandle>);
}

/// The shared, thread-safe face of one client connection: response bytes
/// queue here (any thread), the owning poller flushes them. Dropping jobs
/// whose connection died early is detected via [`ConnHandle::is_closed`].
pub struct ConnHandle {
    id: u64,
    outbox: Mutex<Vec<u8>>,
    closing: AtomicBool,
    closed: AtomicBool,
}

impl ConnHandle {
    fn new(id: u64) -> Arc<ConnHandle> {
        Arc::new(ConnHandle {
            id,
            outbox: Mutex::new(Vec::new()),
            closing: AtomicBool::new(false),
            closed: AtomicBool::new(false),
        })
    }

    /// Stable connection id (assigned at accept; outlives the socket).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Queue `frame` for transmission (encoded directly into the outbox;
    /// the poller writes it out on its next sweep).
    pub fn send(&self, frame: &ClientFrame) {
        if self.closed.load(Ordering::Acquire) {
            return;
        }
        let mut out = self.outbox.lock().unwrap();
        encode_client_frame(frame, &mut out);
    }

    /// Ask the poller to close this connection once its outbox drains.
    pub fn close_after_flush(&self) {
        self.closing.store(true, Ordering::Release);
    }

    /// Whether the socket is gone (responses queued after this are
    /// silently dropped).
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }
}

/// One poller-owned connection: the socket plus its read-side state.
struct Conn {
    stream: TcpStream,
    handle: Arc<ConnHandle>,
    in_buf: Vec<u8>,
    /// Reads stop (corrupt stream, oversize, sink-requested close) while
    /// the outbox finishes flushing.
    read_done: bool,
}

/// A running accept + poller thread set. Thread count is fixed at
/// construction: `1 + threads`, independent of connection count.
pub(crate) struct PollerPool {
    threads: Vec<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl PollerPool {
    /// Bind-free constructor: the caller provides the listener (so tests
    /// bind port 0 and read the real address back).
    /// `stop` halts intake (the acceptor exits; new connections are no
    /// longer registered) while pollers keep sweeping, so responses to
    /// already-admitted jobs still flow. `flush` then moves the pollers
    /// into their final bounded outbox drain — the gateway sets it only
    /// after the dispatcher has joined, which is what makes teardown
    /// lossless for every queued response.
    pub(crate) fn spawn(
        listener: TcpListener,
        threads: usize,
        max_payload: usize,
        sink: Arc<dyn Sink>,
        stop: Arc<AtomicBool>,
        flush: Arc<AtomicBool>,
    ) -> Result<PollerPool> {
        let threads = threads.max(1);
        let local_addr = listener
            .local_addr()
            .map_err(|e| CmpcError::Io(format!("gateway listener address: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| CmpcError::Io(format!("gateway listener nonblocking: {e}")))?;
        let inboxes: Vec<Arc<Mutex<Vec<TcpStream>>>> =
            (0..threads).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
        let mut handles = Vec::with_capacity(threads + 1);
        {
            let inboxes = inboxes.clone();
            let stop = stop.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("cmpc-gw-accept".to_string())
                    .spawn(move || accept_loop(listener, inboxes, stop))
                    .map_err(|e| CmpcError::Io(format!("spawning gateway acceptor: {e}")))?,
            );
        }
        for (p, inbox) in inboxes.into_iter().enumerate() {
            let sink = sink.clone();
            let stop = stop.clone();
            let flush = flush.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("cmpc-gw-poll-{p}"))
                    .spawn(move || poll_loop(inbox, max_payload, sink, stop, flush))
                    .map_err(|e| CmpcError::Io(format!("spawning gateway poller {p}: {e}")))?,
            );
        }
        Ok(PollerPool {
            threads: handles,
            local_addr,
        })
    }

    pub(crate) fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Join every thread. The owner must have set the shared stop flag.
    pub(crate) fn join(self) {
        for h in self.threads {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    inboxes: Vec<Arc<Mutex<Vec<TcpStream>>>>,
    stop: Arc<AtomicBool>,
) {
    let mut next = 0usize;
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                // Round-robin across pollers keeps per-thread sweeps short.
                inboxes[next % inboxes.len()].lock().unwrap().push(stream);
                next += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(IDLE_PARK);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // Transient accept errors (e.g. aborted handshakes) must not
            // kill the front door.
            Err(_) => std::thread::sleep(IDLE_PARK),
        }
    }
}

fn poll_loop(
    inbox: Arc<Mutex<Vec<TcpStream>>>,
    max_payload: usize,
    sink: Arc<dyn Sink>,
    stop: Arc<AtomicBool>,
    flush: Arc<AtomicBool>,
) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; READ_BUF];
    // Main loop runs until the *flush* signal — under `stop` alone the
    // poller keeps serving existing connections (reads included, so
    // in-flight submissions get their ShuttingDown rejects), it just
    // registers no new ones. The dispatcher may still be producing
    // Result frames during this window; exiting here would lose them.
    while !flush.load(Ordering::Acquire) {
        let stopping = stop.load(Ordering::Acquire);
        let mut progress = false;
        if !stopping {
            let fresh = std::mem::take(&mut *inbox.lock().unwrap());
            for stream in fresh {
                let handle = ConnHandle::new(NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed));
                sink.on_connect(&handle);
                conns.push(Conn {
                    stream,
                    handle,
                    in_buf: Vec::new(),
                    read_done: false,
                });
                progress = true;
            }
        }
        conns.retain_mut(|conn| {
            let keep = sweep_conn(conn, max_payload, sink.as_ref(), &mut scratch, &mut progress);
            if !keep {
                conn.handle.closed.store(true, Ordering::Release);
                sink.on_disconnect(&conn.handle);
            }
            keep
        });
        if !progress {
            std::thread::sleep(IDLE_PARK);
        }
    }
    // Flush requested: every response is already queued (the dispatcher
    // joined before the signal), so give outboxes a bounded window to
    // reach their clients, then drop everything.
    let deadline = Instant::now() + DRAIN_BUDGET;
    while !conns.is_empty() && Instant::now() < deadline {
        let mut progress = false;
        conns.retain_mut(|conn| {
            conn.read_done = true;
            conn.handle.closing.store(true, Ordering::Release);
            sweep_conn(conn, max_payload, sink.as_ref(), &mut scratch, &mut progress)
        });
        if !progress {
            std::thread::sleep(IDLE_PARK);
        }
    }
    for conn in &conns {
        conn.handle.closed.store(true, Ordering::Release);
        sink.on_disconnect(&conn.handle);
    }
}

/// Monotonic connection ids, unique across every poller thread.
static NEXT_CONN_ID: AtomicU64 = AtomicU64::new(1);

/// A detached handle with no socket behind it — for queue-logic tests
/// that need something to address responses to.
#[cfg(test)]
pub(crate) fn test_handle() -> Arc<ConnHandle> {
    ConnHandle::new(NEXT_CONN_ID.fetch_add(1, Ordering::Relaxed))
}

/// One read-then-write sweep over a connection. Returns `false` once the
/// connection should be dropped.
fn sweep_conn(
    conn: &mut Conn,
    max_payload: usize,
    sink: &dyn Sink,
    scratch: &mut [u8],
    progress: &mut bool,
) -> bool {
    // ---- read side -----------------------------------------------------
    let mut peer_gone = false;
    while !conn.read_done {
        match conn.stream.read(scratch) {
            Ok(0) => {
                peer_gone = true;
                conn.read_done = true;
            }
            Ok(n) => {
                *progress = true;
                conn.in_buf.extend_from_slice(&scratch[..n]);
                if !parse_frames(conn, max_payload, sink) {
                    conn.read_done = true;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => peer_gone = true,
        }
        break;
    }
    // ---- write side ----------------------------------------------------
    let mut outbox = conn.handle.outbox.lock().unwrap();
    while !outbox.is_empty() {
        match conn.stream.write(&outbox) {
            Ok(0) => {
                peer_gone = true;
                break;
            }
            Ok(n) => {
                *progress = true;
                outbox.drain(..n);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                peer_gone = true;
                break;
            }
        }
    }
    let flushed = outbox.is_empty();
    drop(outbox);
    if peer_gone {
        return false;
    }
    let closing = conn.handle.closing.load(Ordering::Acquire);
    !(closing && flushed)
}

/// Parse every complete frame buffered on `conn`. Returns `false` when
/// reading should stop (corrupt stream, oversize, or sink-requested
/// close) — queued responses still flush.
fn parse_frames(conn: &mut Conn, max_payload: usize, sink: &dyn Sink) -> bool {
    loop {
        match peek_client_header(&conn.in_buf) {
            Ok(None) => return true,
            Ok(Some(h)) if h.payload_len > max_payload => {
                let outcome = sink.on_oversize(&conn.handle, &h);
                apply(conn, outcome);
                // The claimed body is never buffered; the stream cannot
                // be resynced past it, so reads end here either way.
                return false;
            }
            Ok(Some(_)) => {}
            Err(e) => {
                let outcome = sink.on_corrupt(&conn.handle, &e);
                apply(conn, outcome);
                return false;
            }
        }
        match decode_client_frame(&conn.in_buf) {
            Ok(None) => return true,
            Ok(Some((frame, used))) => {
                conn.in_buf.drain(..used);
                if sink.on_frame(&conn.handle, frame) == FrameOutcome::CloseAfterFlush {
                    conn.handle.closing.store(true, Ordering::Release);
                    return false;
                }
            }
            Err(e) => {
                let outcome = sink.on_corrupt(&conn.handle, &e);
                apply(conn, outcome);
                return false;
            }
        }
    }
}

fn apply(conn: &mut Conn, outcome: FrameOutcome) {
    if outcome == FrameOutcome::CloseAfterFlush {
        conn.handle.closing.store(true, Ordering::Release);
    }
}
