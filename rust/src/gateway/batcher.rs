//! Request batching — compatible in-flight submissions share a deployment.
//!
//! Admitted jobs queue under their **batch signature** `(s, t, z, adv, m)`
//! — the same key `Coordinator::drain` groups by, plus the adversary
//! tolerance (which fixes the recovery quota) and the matrix size (which
//! fixes the compute shape). The dispatcher thread pulls one batch
//! at a time: a queue flushes the moment it reaches `max_batch`, or when
//! its **oldest** job has waited `max_wait` (the batching window — a
//! lone request is never held hostage waiting for company), or
//! immediately once shutdown starts. Everything in one batch then
//! executes on one shared provisioned deployment, so the O(N³) setup
//! solve and the `N` persistent worker threads amortize across tenants
//! and connections exactly as they do across `Coordinator::drain` calls.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::matrix::FpMat;

use super::poller::ConnHandle;

/// The compatibility signature: jobs batch together iff these agree.
/// (The scheme policy is fixed per gateway, so `(s, t, z, adv)` determines
/// the resolved scheme — same argument as the coordinator's cache key.
/// `adv` is the adversary tolerance: jobs demanding different Byzantine
/// quotas must not share a deployment, since the quota is provisioned
/// into the master's receive loop.)
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    /// Row partition factor.
    pub s: usize,
    /// Column partition factor.
    pub t: usize,
    /// Collusion tolerance.
    pub z: usize,
    /// Adversary (Byzantine) tolerance.
    pub adv: usize,
    /// Square matrix dimension of the job.
    pub m: usize,
}

/// One job's inputs, as handed to the execution engine.
pub struct BatchInput {
    /// The client's `A` matrix.
    pub a: FpMat,
    /// The client's `B` matrix.
    pub b: FpMat,
}

/// One admitted, queued job: inputs plus everything needed to route the
/// response back out.
pub(crate) struct BatchJob {
    pub conn: Arc<ConnHandle>,
    pub corr: u64,
    pub tenant: u32,
    pub input: BatchInput,
    pub admitted_at: Instant,
}

/// One flushed batch, ready for the engine.
pub(crate) struct Batch {
    pub key: BatchKey,
    pub jobs: Vec<BatchJob>,
}

struct BatchState {
    queues: BTreeMap<BatchKey, VecDeque<BatchJob>>,
    stopped: bool,
}

/// Signature-keyed queues + the flush policy described in the module docs.
pub(crate) struct Batcher {
    state: Mutex<BatchState>,
    cv: Condvar,
    max_batch: usize,
    max_wait: Duration,
}

impl Batcher {
    pub(crate) fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher {
            state: Mutex::new(BatchState {
                queues: BTreeMap::new(),
                stopped: false,
            }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
        }
    }

    /// Enqueue an admitted job under its signature.
    pub(crate) fn push(&self, key: BatchKey, job: BatchJob) {
        let mut state = self.state.lock().unwrap();
        state.queues.entry(key).or_default().push_back(job);
        self.cv.notify_all();
    }

    /// Total jobs queued across every signature.
    pub(crate) fn queued(&self) -> usize {
        let state = self.state.lock().unwrap();
        state.queues.values().map(VecDeque::len).sum()
    }

    /// Start shutdown: wakes the dispatcher so it drains the remaining
    /// queues (each remaining [`Batcher::next_batch`] call returns them
    /// immediately, window or not) and then observes the end of stream.
    pub(crate) fn stop(&self) {
        let mut state = self.state.lock().unwrap();
        state.stopped = true;
        self.cv.notify_all();
    }

    /// Block until a batch is due, and pop it. Returns `None` only after
    /// [`Batcher::stop`] once every queue is empty.
    pub(crate) fn next_batch(&self) -> Option<Batch> {
        let mut state = self.state.lock().unwrap();
        loop {
            // A full queue flushes immediately.
            if let Some((&key, _)) = state
                .queues
                .iter()
                .find(|(_, q)| q.len() >= self.max_batch)
            {
                return Some(self.pop(&mut state, key));
            }
            // Otherwise the queue whose oldest job expires first decides
            // how long to wait.
            let oldest: Option<(BatchKey, Instant)> = state
                .queues
                .iter()
                .filter_map(|(&key, q)| q.front().map(|j| (key, j.admitted_at)))
                .min_by_key(|&(_, at)| at);
            match oldest {
                Some((key, at)) => {
                    if state.stopped || at.elapsed() >= self.max_wait {
                        return Some(self.pop(&mut state, key));
                    }
                    let wait = self.max_wait.saturating_sub(at.elapsed());
                    let (next, _) = self.cv.wait_timeout(state, wait).unwrap();
                    state = next;
                }
                None => {
                    if state.stopped {
                        return None;
                    }
                    state = self.cv.wait(state).unwrap();
                }
            }
        }
    }

    fn pop(&self, state: &mut BatchState, key: BatchKey) -> Batch {
        let queue = state.queues.get_mut(&key).expect("picked key exists");
        let take = queue.len().min(self.max_batch);
        let jobs: Vec<BatchJob> = queue.drain(..take).collect();
        if queue.is_empty() {
            state.queues.remove(&key);
        }
        Batch { key, jobs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize) -> BatchKey {
        BatchKey {
            s: 2,
            t: 2,
            z: 2,
            adv: 0,
            m,
        }
    }

    fn job(conn: &Arc<ConnHandle>, corr: u64, m: usize) -> BatchJob {
        BatchJob {
            conn: conn.clone(),
            corr,
            tenant: 0,
            input: BatchInput {
                a: FpMat::zeros(m, m),
                b: FpMat::zeros(m, m),
            },
            admitted_at: Instant::now(),
        }
    }

    /// A detached handle (no poller behind it) for queue-logic tests.
    fn conn() -> Arc<ConnHandle> {
        super::super::poller::test_handle()
    }

    #[test]
    fn full_queue_flushes_without_waiting_for_the_window() {
        let b = Batcher::new(3, Duration::from_secs(3600));
        let c = conn();
        for corr in 0..3 {
            b.push(key(8), job(&c, corr, 8));
        }
        let t0 = Instant::now();
        let batch = b.next_batch().expect("batch due");
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(batch.key, key(8));
        let corrs: Vec<u64> = batch.jobs.iter().map(|j| j.corr).collect();
        assert_eq!(corrs, vec![0, 1, 2]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn window_expiry_flushes_a_lone_job() {
        let b = Batcher::new(64, Duration::from_millis(20));
        let c = conn();
        b.push(key(4), job(&c, 9, 4));
        let batch = b.next_batch().expect("window flush");
        assert_eq!(batch.jobs.len(), 1);
        assert_eq!(batch.jobs[0].corr, 9);
    }

    #[test]
    fn signatures_do_not_mix() {
        let b = Batcher::new(2, Duration::from_millis(10));
        let c = conn();
        b.push(key(4), job(&c, 1, 4));
        b.push(key(8), job(&c, 2, 8));
        let first = b.next_batch().unwrap();
        let second = b.next_batch().unwrap();
        assert_ne!(first.key, second.key);
        assert_eq!(first.jobs.len(), 1);
        assert_eq!(second.jobs.len(), 1);
    }

    #[test]
    fn stop_drains_then_ends_the_stream() {
        let b = Batcher::new(64, Duration::from_secs(3600));
        let c = conn();
        b.push(key(4), job(&c, 1, 4));
        b.stop();
        assert!(b.next_batch().is_some());
        assert!(b.next_batch().is_none());
    }
}
