//! Async serving gateway — the multi-tenant front door (v0.7).
//!
//! Everything below the coordinator assumes a trusted in-process caller.
//! This module adds the missing serving tier for an edge deployment:
//! untrusted clients connect over TCP, speak the client plane of the
//! framed wire codec ([`crate::transport::wire::ClientFrame`], tags 6–9),
//! and are policed *at the door* — nothing malformed, over-quota, or
//! oversized ever touches a provisioned deployment.
//!
//! ```text
//!   clients ──TCP──▶ [poller threads]──▶ admission ──▶ batcher ──▶ dispatcher
//!   (many)            fixed pool          per-tenant    (s,t,z,m)     │
//!     ▲                nonblocking        token bucket  signature     ▼
//!     └── Result / Reject frames ◀── outboxes ◀─────────────── ExecuteEngine
//!                                                        (local deployments │
//!                                                         remote CMPC cluster)
//! ```
//!
//! * **Admission** ([`admission`]) — per-tenant token buckets + pending
//!   caps; refusals are typed ([`RejectReason`]) and leave the connection
//!   usable.
//! * **Batching** ([`batcher`]) — admitted jobs group by
//!   `(s, t, z, adv, m)` signature and execute as one batch on one shared
//!   [`Deployment`]
//!   (generalizing `Coordinator::drain`'s grouping to concurrent network
//!   clients), with a `max_wait` window so a lone request never stalls.
//! * **Multiplexing** ([`poller`]) — a fixed accept + poller thread set
//!   serves every connection with non-blocking sockets; thread count is
//!   independent of connection count.
//! * **Execution** ([`ExecuteEngine`]) — [`LocalEngine`] provisions
//!   in-process deployments per signature; [`RemoteEngine`] binds the
//!   master slot of a [`TopologyManifest`] and drives a real multi-process
//!   CMPC cluster, pushing each client's matrices to the source nodes via
//!   [`ControlMsg::JobInput`].
//!
//! [`metrics::GatewayStats`](crate::metrics::GatewayStats) meters it all:
//! accepted/rejected-by-reason/completed counts, queue depth, batch-size
//! and latency histograms — `tests/gateway.rs` asserts observable batching
//! through it, and the bench's `gateway[]` section reports sustained QPS
//! and p99 latency from the same counters.

pub mod admission;
pub mod batcher;
pub mod client;
pub mod poller;

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::autoscale::{AutoscaleConfig, AutoscaleHealth, Autoscaler};
use crate::codes::SchemeParams;
use crate::coordinator::{CoordinatorConfig, SchemePolicy};
use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::metrics::{GatewayCounters, GatewayStats, WorkerCounters};
use crate::mpc::deployment::Deployment;
use crate::mpc::master::run_master;
use crate::mpc::network::{
    ControlMsg, Fabric, FabricTuning, JobRouter, Payload, Transport, CONTROL_JOB,
};
use crate::mpc::protocol::{self, prepare_setup, ProtocolConfig, Setup};
use crate::runtime::manifest::TopologyManifest;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::BackendFactory;
use crate::transport::node::{digest_mat, job_secret_seed};
use crate::transport::tcp::TcpTransport;
use crate::transport::wire::{ClientFrame, ClientHeader, ClientMsg, RejectReason};

use admission::{Admission, TenantQuota};
use batcher::{Batch, BatchInput, BatchJob, BatchKey, Batcher};
use poller::{ConnHandle, FrameOutcome, PollerPool, Sink};

pub use admission::TenantQuota;
pub use batcher::{BatchInput, BatchKey};
pub use client::{ClientReply, GatewayClient};

/// Gateway-wide configuration (the serving-tier analogue of
/// [`CoordinatorConfig`]).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Poller threads multiplexing every client connection (≥ 1). The
    /// gateway's thread count is `pollers + 2` (accept + dispatcher),
    /// constant for its lifetime.
    pub poller_threads: usize,
    /// A signature queue flushes as soon as it holds this many jobs.
    pub max_batch: usize,
    /// …or once its oldest job has waited this long.
    pub max_wait: Duration,
    /// Submissions whose frame payload exceeds this are refused from the
    /// header alone ([`RejectReason::TooLarge`]) — the body is never read.
    pub max_payload_bytes: usize,
    /// Tenant quota table; empty = open admission (see
    /// [`admission::Admission`]).
    pub tenants: Vec<TenantQuota>,
    /// When set, only submissions matching this exact `(s, t, z, adv, m)`
    /// signature are accepted — the remote-cluster mode, where the
    /// provisioned worker set serves one manifest shape.
    pub shape_lock: Option<BatchKey>,
    /// When set, a client `Shutdown` frame must carry this token
    /// (`gateway_token` manifest line); mismatches are refused with
    /// [`RejectReason::Unauthorized`], the offending connection is
    /// dropped (each guess costs a reconnect), and the gateway keeps
    /// serving. `None` = any token stops the gateway (single-operator
    /// rigs).
    ///
    /// **Interim hardening only**: the client plane is neither encrypted
    /// nor authenticated yet (ROADMAP TLS/auth item), so the token rides
    /// the wire in cleartext and any on-path observer of a legitimate
    /// shutdown learns it. Treat it as protection against *accidental*
    /// and *drive-by* shutdowns on non-loopback binds, not against an
    /// eavesdropping adversary — keep non-loopback gateways on trusted
    /// segments until the transport is secured.
    pub shutdown_token: Option<u64>,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig {
            poller_threads: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            max_payload_bytes: 64 * 1024 * 1024,
            tenants: Vec::new(),
            shape_lock: None,
            shutdown_token: None,
        }
    }
}

/// One successfully executed job, as the engine hands it back.
pub struct EngineOutput {
    /// The reconstructed product `Y = AᵀB`.
    pub y: FpMat,
    /// FNV digest of `y` ([`digest_mat`]) — echoed to the client and
    /// diffed against `cmpc node --role reference` by the CI lane.
    pub digest: u64,
}

/// Where admitted batches execute. Implementations must return exactly
/// one result per input, in order; a per-job failure becomes a typed
/// [`RejectReason::Internal`] for that client only.
pub trait ExecuteEngine: Send + Sync {
    /// Run one admitted batch; same-signature inputs, one result per input.
    fn execute(&self, key: BatchKey, inputs: &[BatchInput]) -> Vec<Result<EngineOutput>>;

    /// Called once after the dispatcher drains, before the gateway's
    /// threads join — remote engines tear their cluster down here.
    fn shutdown(&self) {}
}

// ------------------------------------------------------------ local engine

/// In-process execution: one cached [`Deployment`] per `(s, t, z, adv)`
/// signature, batches fanned across the shared worker pool — the same
/// shape as `Coordinator::drain`, minus the intake queue (the gateway's
/// batcher replaced it).
pub struct LocalEngine {
    config: CoordinatorConfig,
    deployments: Mutex<BTreeMap<(usize, usize, usize, usize), Arc<Deployment>>>,
    factory: Mutex<Option<Arc<BackendFactory>>>,
    pool: Arc<WorkerPool>,
    /// When set, every deployment this engine provisions gets its own
    /// [`Autoscaler`] sampling thread (`autoscale` manifest line /
    /// `--autoscale` CLI flag).
    autoscale: Option<AutoscaleConfig>,
    scalers: Mutex<Vec<Autoscaler>>,
    /// Final audit snapshots, captured at [`ExecuteEngine::shutdown`]
    /// just before the controllers are dropped — so post-drain reporting
    /// (the `cmpc gateway` summary lines) still sees the full trail.
    final_reports: Mutex<Vec<AutoscaleHealth>>,
}

impl LocalEngine {
    /// Build an engine with an empty deployment cache.
    pub fn new(config: CoordinatorConfig) -> LocalEngine {
        LocalEngine::with_autoscale(config, None)
    }

    /// [`LocalEngine::new`], plus adaptive provisioning: each deployment
    /// the engine caches is attached to its own [`Autoscaler`] controller
    /// thread, which retunes `(scheme, λ, N, a)` from live telemetry via
    /// blue/green swap. Controllers stop at
    /// [`ExecuteEngine::shutdown`] (the gateway dispatcher calls it after
    /// draining) or when the engine drops.
    pub fn with_autoscale(
        config: CoordinatorConfig,
        autoscale: Option<AutoscaleConfig>,
    ) -> LocalEngine {
        let pool = WorkerPool::sized_or_global(config.threads);
        LocalEngine {
            config,
            deployments: Mutex::new(BTreeMap::new()),
            factory: Mutex::new(None),
            pool,
            autoscale,
            scalers: Mutex::new(Vec::new()),
            final_reports: Mutex::new(Vec::new()),
        }
    }

    /// Deployments provisioned so far (one per distinct signature served)
    /// — how `tests/gateway.rs` proves compatible requests shared one.
    pub fn provisioned(&self) -> usize {
        self.deployments.lock().unwrap().len()
    }

    /// Controller health for every attached autoscaler (one per cached
    /// deployment when autoscaling is on; empty otherwise) — counters,
    /// audit trail, and the active generation's runtime report. After
    /// [`ExecuteEngine::shutdown`] this returns the final snapshots taken
    /// as the controllers stopped.
    pub fn autoscale_reports(&self) -> Vec<AutoscaleHealth> {
        let live = self.scalers.lock().unwrap();
        if live.is_empty() {
            return self.final_reports.lock().unwrap().clone();
        }
        live.iter().map(|s| s.health()).collect()
    }

    /// Run a [`crate::mpc::pipeline::Pipeline`] on this engine's cached
    /// deployment for `(s, t, z)` (provisioning it on first use, exactly
    /// like a batch). Pipelines are interactive multi-round protocols, so
    /// they bypass the batcher and run to completion here; a client-plane
    /// frame for remote pipeline submission is a ROADMAP item. `adv` is
    /// pinned to 0 — pipelines decode intermediate stages at the exact
    /// `t²+z` quota, which leaves no Byzantine margin.
    pub fn run_pipeline(
        &self,
        pipe: &crate::mpc::pipeline::Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        s: usize,
        t: usize,
        z: usize,
        seed: u64,
    ) -> Result<crate::mpc::pipeline::PipelineOutput> {
        let dep = self.deployment_for(BatchKey {
            s,
            t,
            z,
            adv: 0,
            m: x.rows,
        })?;
        dep.execute_pipeline_seeded(pipe, x, weights, seed)
    }

    fn factory(&self) -> Result<Arc<BackendFactory>> {
        let mut slot = self.factory.lock().unwrap();
        if let Some(f) = slot.as_ref() {
            return Ok(f.clone());
        }
        let f = Arc::new(BackendFactory::new(&self.config.backend)?);
        *slot = Some(f.clone());
        Ok(f)
    }

    fn deployment_for(&self, key: BatchKey) -> Result<Arc<Deployment>> {
        let sig = (key.s, key.t, key.z, key.adv);
        if let Some(dep) = self.deployments.lock().unwrap().get(&sig) {
            return Ok(dep.clone());
        }
        let params =
            SchemeParams::try_new(key.s, key.t, key.z)?.with_adversary_tolerance(key.adv);
        let scheme = match self.config.policy {
            SchemePolicy::Fixed(spec) => spec.resolve(params)?,
            SchemePolicy::Adaptive => crate::codes::SchemeSpec::resolve_adaptive(params)?,
        };
        let proto = ProtocolConfig::builder()
            .backend(self.config.backend.clone())
            .verify(self.config.verify)
            .link_delay(self.config.link_delay)
            .threads(self.config.threads)
            .build();
        let dep = Arc::new(Deployment::for_scheme_shared(
            scheme,
            proto,
            self.factory()?,
            self.pool.clone(),
        )?);
        // Double-provision race: first insert wins, the loser's deployment
        // drops (admissible — provisioning is idempotent and rare). Only
        // the winner gets a controller, so scalers map 1:1 to cached
        // deployments.
        let mut cache = self.deployments.lock().unwrap();
        if let Some(existing) = cache.get(&sig) {
            return Ok(existing.clone());
        }
        cache.insert(sig, dep.clone());
        drop(cache);
        if let Some(cfg) = &self.autoscale {
            self.scalers
                .lock()
                .unwrap()
                .push(Autoscaler::spawn(dep.clone(), cfg.clone()));
        }
        Ok(dep)
    }
}

impl ExecuteEngine for LocalEngine {
    fn execute(&self, key: BatchKey, inputs: &[BatchInput]) -> Vec<Result<EngineOutput>> {
        let dep = match self.deployment_for(key) {
            Ok(dep) => dep,
            Err(e) => return inputs.iter().map(|_| Err(e.clone())).collect(),
        };
        // Multi-job batches take the fused fast path: the batcher groups
        // by (s, t, z, adv, m), so every input in a batch is same-shape by
        // construction and the k jobs run as one wide pass per worker
        // (`mpc::fused`). Identical outputs, k× fewer fixed costs.
        if inputs.len() >= 2 {
            let refs: Vec<(&FpMat, &FpMat)> =
                inputs.iter().map(|input| (&input.a, &input.b)).collect();
            // A batch-level refusal (bad shapes, insufficient workers)
            // falls through to the per-job path below so each client gets
            // its own typed error instead of a collective one.
            if let Ok(outs) = dep.execute_fused(&refs) {
                return outs
                    .into_iter()
                    .map(|out| {
                        Ok(EngineOutput {
                            digest: digest_mat(&out.y),
                            y: out.y,
                        })
                    })
                    .collect();
            }
        }
        // Jobs in a batch run concurrently on the one shared deployment —
        // the fabric multiplexes them by job tag, exactly as in
        // `Coordinator::drain`.
        self.pool.par_map(inputs, |_wid, _idx, input| {
            dep.execute(&input.a, &input.b).map(|out| EngineOutput {
                digest: digest_mat(&out.y),
                y: out.y,
            })
        })
    }

    fn shutdown(&self) {
        // Dropping a controller stops and joins its sampling thread; the
        // deployments themselves stay cached (in-flight responses may
        // still hold them). Final snapshots are kept for post-drain
        // reporting.
        let mut scalers = self.scalers.lock().unwrap();
        *self.final_reports.lock().unwrap() = scalers.iter().map(|s| s.health()).collect();
        scalers.clear();
    }
}

// ----------------------------------------------------------- remote engine

/// Distributed execution: this process binds the **master** slot of a
/// [`TopologyManifest`] whose workers and sources run as their own
/// processes (`cmpc node --role worker|source-a|source-b`). Each client
/// job's matrices are pushed to the sources with
/// [`ControlMsg::JobInput`] (control traffic — unmetered, same as
/// `JobStart`), then the standard master state machine reconstructs `Y`.
/// The cluster serves exactly the manifest's `(s, t, z, m)` shape; pair
/// with [`GatewayConfig::shape_lock`] so mismatches are refused at the
/// door.
pub struct RemoteEngine {
    manifest: TopologyManifest,
    fabric: Arc<Fabric>,
    router: JobRouter,
    setup: Setup,
    params: SchemeParams,
    pool: Arc<WorkerPool>,
    scratch: ScratchPool,
    next_job: AtomicU64,
    /// Jobs run one at a time through the cluster (batching still shares
    /// the provisioned worker set; pipelining is a ROADMAP item).
    drive: Mutex<()>,
}

impl RemoteEngine {
    /// Bind the manifest's master address and connect to the cluster.
    pub fn connect(manifest: TopologyManifest) -> Result<RemoteEngine> {
        manifest.validate()?;
        let scheme = manifest.resolve_scheme()?;
        let params = scheme.params();
        let setup = prepare_setup(scheme.as_ref())?;
        let (transport, endpoint) = TcpTransport::bind_manifest(&manifest, manifest.master_id())?;
        let t: Arc<dyn Transport> = transport;
        let fabric = Fabric::over_transport(
            t,
            FabricTuning {
                link_delay: None,
                chaos: None,
                shaper: manifest.shaper(),
            },
        );
        let router = JobRouter::new(endpoint);
        let pool = WorkerPool::sized_or_global(0);
        let scratch = ScratchPool::for_pool(&pool);
        Ok(RemoteEngine {
            manifest,
            fabric,
            router,
            setup,
            params,
            pool,
            scratch,
            next_job: AtomicU64::new(0),
            drive: Mutex::new(()),
        })
    }

    /// The one signature this cluster serves — hand it to
    /// [`GatewayConfig::shape_lock`].
    pub fn shape(&self) -> BatchKey {
        BatchKey {
            s: self.manifest.s,
            t: self.manifest.t,
            z: self.manifest.z,
            adv: self.manifest.adversary_tolerance,
            m: self.manifest.m,
        }
    }

    fn run_one(&self, a: &FpMat, b: &FpMat) -> Result<FpMat> {
        let _guard = self.drive.lock().unwrap();
        let job = self.next_job.fetch_add(1, Ordering::Relaxed);
        let n = self.setup.n_workers;
        let master_id = self.manifest.master_id();
        self.router.open(job);
        self.fabric.begin_job(job);
        let outcome = (|| -> Result<FpMat> {
            let seed = job_secret_seed(self.manifest.seed, job);
            let counters: Vec<Arc<WorkerCounters>> =
                (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
            for (wid, c) in counters.iter().enumerate() {
                self.fabric.send(
                    job,
                    master_id,
                    wid,
                    Payload::Control(ControlMsg::JobStart {
                        seed,
                        counters: c.clone(),
                    }),
                )?;
            }
            // The sources encode *these* matrices (not manifest-derived
            // demo data) — the seed keeps the mask fork order identical
            // to every other driver.
            self.fabric.send(
                job,
                master_id,
                self.manifest.source_a_id(),
                Payload::Control(ControlMsg::JobInput {
                    seed,
                    mat: a.clone(),
                }),
            )?;
            self.fabric.send(
                job,
                master_id,
                self.manifest.source_b_id(),
                Payload::Control(ControlMsg::JobInput {
                    seed,
                    mat: b.clone(),
                }),
            )?;
            let (m_out, _timings) = run_master(
                &self.router,
                &self.fabric,
                job,
                &self.setup.alphas,
                n,
                self.params.t,
                self.params.z,
                self.params.adversary_tolerance,
                self.manifest.recv_timeout,
                self.manifest.early_decode,
                &counters,
                &self.pool,
                &self.scratch,
            )?;
            if self.manifest.verify && m_out.y != a.transpose().matmul(b) {
                return Err(CmpcError::NotDecodable(format!(
                    "gateway job {job}: distributed reconstruction mismatch: Y != AᵀB"
                )));
            }
            Ok(m_out.y)
        })();
        self.fabric.end_job(job);
        self.router.close(job);
        if outcome.is_err() {
            // Free the workers' per-job state before reporting failure.
            for wid in 0..n {
                let _ = self.fabric.send(
                    job,
                    master_id,
                    wid,
                    Payload::Control(ControlMsg::JobAbort),
                );
            }
        }
        outcome
    }

    fn shutdown_cluster(&self) {
        let master_id = self.manifest.master_id();
        let mut peers: Vec<usize> = (0..self.setup.n_workers).collect();
        peers.push(self.manifest.source_a_id());
        peers.push(self.manifest.source_b_id());
        for peer in peers {
            // Two attempts, as in `run_master_node`: the first write onto
            // a connection that died since the last job marks it broken;
            // the retry reconnects.
            for _attempt in 0..2 {
                if self
                    .fabric
                    .send(
                        CONTROL_JOB,
                        master_id,
                        peer,
                        Payload::Control(ControlMsg::Shutdown),
                    )
                    .is_ok()
                {
                    break;
                }
            }
        }
    }
}

impl ExecuteEngine for RemoteEngine {
    fn execute(&self, _key: BatchKey, inputs: &[BatchInput]) -> Vec<Result<EngineOutput>> {
        inputs
            .iter()
            .map(|input| {
                self.run_one(&input.a, &input.b).map(|y| EngineOutput {
                    digest: digest_mat(&y),
                    y,
                })
            })
            .collect()
    }

    fn shutdown(&self) {
        self.shutdown_cluster();
    }
}

// ---------------------------------------------------------------- gateway

struct GatewayInner {
    admission: Admission,
    batcher: Batcher,
    counters: Arc<GatewayCounters>,
    engine: Arc<dyn ExecuteEngine>,
    stop: Arc<AtomicBool>,
    shape_lock: Option<BatchKey>,
    shutdown_token: Option<u64>,
}

impl GatewayInner {
    fn reject(
        &self,
        conn: &Arc<ConnHandle>,
        corr: u64,
        tenant: u32,
        reason: RejectReason,
        detail: String,
    ) {
        self.counters.note_rejected(reason.as_u8());
        conn.send(&ClientFrame {
            corr,
            tenant,
            msg: ClientMsg::Reject { reason, detail },
        });
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_submit(
        &self,
        conn: &Arc<ConnHandle>,
        corr: u64,
        tenant: u32,
        s: usize,
        t: usize,
        z: usize,
        adv: usize,
        a: FpMat,
        b: FpMat,
    ) {
        if self.stop.load(Ordering::Acquire) {
            return self.reject(
                conn,
                corr,
                tenant,
                RejectReason::ShuttingDown,
                "gateway is draining".to_string(),
            );
        }
        let key = BatchKey {
            s,
            t,
            z,
            adv,
            m: a.rows,
        };
        if let Some(lock) = self.shape_lock {
            if key != lock {
                return self.reject(
                    conn,
                    corr,
                    tenant,
                    RejectReason::Malformed,
                    format!(
                        "this gateway serves only (s={}, t={}, z={}, adv={}, m={}) \
                         (got s={s}, t={t}, z={z}, adv={adv}, m={})",
                        lock.s, lock.t, lock.z, lock.adv, lock.m, a.rows
                    ),
                );
            }
        }
        let validated = SchemeParams::try_new(s, t, z)
            .and_then(|params| protocol::validate_job_shapes(&a, &b, params));
        if let Err(e) = validated {
            return self.reject(conn, corr, tenant, RejectReason::Malformed, e.to_string());
        }
        if let Err(reason) = self.admission.try_admit(tenant) {
            return self.reject(
                conn,
                corr,
                tenant,
                reason,
                format!("tenant {tenant}: {reason}"),
            );
        }
        self.counters.note_accepted();
        self.counters.queue_enter();
        self.batcher.push(
            key,
            BatchJob {
                conn: conn.clone(),
                corr,
                tenant,
                input: BatchInput { a, b },
                admitted_at: Instant::now(),
            },
        );
    }

    fn dispatch(&self, batch: Batch) {
        let n = batch.jobs.len();
        for _ in 0..n {
            self.counters.queue_exit();
        }
        self.counters.note_batch(n);
        let (metas, inputs): (Vec<(Arc<ConnHandle>, u64, u32, Instant)>, Vec<BatchInput>) = batch
            .jobs
            .into_iter()
            .map(|j| ((j.conn, j.corr, j.tenant, j.admitted_at), j.input))
            .unzip();
        let mut results = self.engine.execute(batch.key, &inputs);
        debug_assert_eq!(results.len(), n, "engine must answer every job");
        while results.len() < metas.len() {
            results.push(Err(CmpcError::Fabric(
                "gateway: engine returned too few results".to_string(),
            )));
        }
        for ((conn, corr, tenant, admitted_at), result) in metas.into_iter().zip(results) {
            self.admission.release(tenant);
            match result {
                Ok(out) => {
                    let elapsed = admitted_at.elapsed();
                    self.counters.note_completed(elapsed);
                    conn.send(&ClientFrame {
                        corr,
                        tenant,
                        msg: ClientMsg::Result {
                            digest: out.digest,
                            elapsed_us: elapsed.as_micros() as u64,
                            y: out.y,
                        },
                    });
                }
                Err(e) => {
                    self.counters.note_failed();
                    self.reject(&conn, corr, tenant, RejectReason::Internal, e.to_string());
                }
            }
        }
    }
}

impl Sink for GatewayInner {
    fn on_connect(&self, _conn: &Arc<ConnHandle>) {
        self.counters.note_connection();
    }

    fn on_frame(&self, conn: &Arc<ConnHandle>, frame: ClientFrame) -> FrameOutcome {
        match frame.msg {
            ClientMsg::Submit { s, t, z, adv, a, b } => {
                self.handle_submit(conn, frame.corr, frame.tenant, s, t, z, adv, a, b);
                FrameOutcome::Continue
            }
            ClientMsg::Shutdown { token } => {
                if let Some(expected) = self.shutdown_token {
                    if token ^ expected != 0 {
                        // Wrong token: typed refusal, then *drop the
                        // connection* — the gateway keeps serving, but a
                        // guesser pays a full reconnect per attempt
                        // instead of streaming guesses down one socket.
                        // (The XOR-then-test compare touches every bit of
                        // the token before branching.)
                        self.reject(
                            conn,
                            frame.corr,
                            frame.tenant,
                            RejectReason::Unauthorized,
                            "shutdown refused: admin token mismatch".to_string(),
                        );
                        return FrameOutcome::CloseAfterFlush;
                    }
                }
                self.stop.store(true, Ordering::Release);
                self.batcher.stop();
                FrameOutcome::CloseAfterFlush
            }
            // Response-plane frames have no business arriving at the
            // gateway; refuse and drop the connection.
            ClientMsg::Result { .. } | ClientMsg::Reject { .. } => {
                self.reject(
                    conn,
                    frame.corr,
                    frame.tenant,
                    RejectReason::Malformed,
                    "response-plane frame sent to the gateway".to_string(),
                );
                FrameOutcome::CloseAfterFlush
            }
        }
    }

    fn on_oversize(&self, conn: &Arc<ConnHandle>, header: &ClientHeader) -> FrameOutcome {
        self.reject(
            conn,
            header.corr,
            header.tenant,
            RejectReason::TooLarge,
            format!("{}-byte payload exceeds the gateway cap", header.payload_len),
        );
        FrameOutcome::CloseAfterFlush
    }

    fn on_corrupt(&self, conn: &Arc<ConnHandle>, err: &CmpcError) -> FrameOutcome {
        // Corr/tenant are unknowable from a corrupt stream; echo zeros.
        self.reject(conn, 0, 0, RejectReason::Malformed, err.to_string());
        FrameOutcome::CloseAfterFlush
    }

    fn on_disconnect(&self, _conn: &Arc<ConnHandle>) {}
}

/// A running gateway: fixed thread set (accept + pollers + dispatcher),
/// admission/batching state, and the execution engine behind it.
pub struct Gateway {
    inner: Arc<GatewayInner>,
    pollers: Option<PollerPool>,
    dispatcher: Option<JoinHandle<()>>,
    /// Final-flush signal for the pollers — set only after the dispatcher
    /// joins, so teardown never races responses into a closed outbox.
    flush: Arc<AtomicBool>,
    local_addr: SocketAddr,
}

impl Gateway {
    /// Bind `listen` (`host:port`; port 0 picks a free one) and start
    /// serving.
    pub fn start(
        listen: &str,
        config: GatewayConfig,
        engine: Arc<dyn ExecuteEngine>,
    ) -> Result<Gateway> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| CmpcError::Io(format!("gateway bind {listen}: {e}")))?;
        Gateway::start_on(listener, config, engine)
    }

    /// Start on an already-bound listener.
    pub fn start_on(
        listener: TcpListener,
        config: GatewayConfig,
        engine: Arc<dyn ExecuteEngine>,
    ) -> Result<Gateway> {
        let inner = Arc::new(GatewayInner {
            admission: Admission::new(&config.tenants),
            batcher: Batcher::new(config.max_batch, config.max_wait),
            counters: GatewayCounters::shared(),
            engine,
            stop: Arc::new(AtomicBool::new(false)),
            shape_lock: config.shape_lock,
            shutdown_token: config.shutdown_token,
        });
        let flush = Arc::new(AtomicBool::new(false));
        let sink: Arc<dyn Sink> = inner.clone();
        let pollers = PollerPool::spawn(
            listener,
            config.poller_threads,
            config.max_payload_bytes.min(crate::transport::wire::MAX_FRAME_PAYLOAD),
            sink,
            inner.stop.clone(),
            flush.clone(),
        )?;
        let local_addr = pollers.local_addr();
        let dispatcher = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("cmpc-gw-dispatch".to_string())
                .spawn(move || {
                    while let Some(batch) = inner.batcher.next_batch() {
                        inner.dispatch(batch);
                    }
                    inner.engine.shutdown();
                })
                .map_err(|e| CmpcError::Io(format!("spawning gateway dispatcher: {e}")))?
        };
        Ok(Gateway {
            inner,
            pollers: Some(pollers),
            dispatcher: Some(dispatcher),
            flush,
            local_addr,
        })
    }

    /// The bound client-facing address (real port even when 0 was asked).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Point-in-time serving metrics.
    pub fn stats(&self) -> GatewayStats {
        self.inner.counters.snapshot()
    }

    /// Whether shutdown has been requested (client `Shutdown` frame or
    /// [`Gateway::shutdown`]).
    pub fn stopping(&self) -> bool {
        self.inner.stop.load(Ordering::Acquire)
    }

    /// Block until shutdown is requested — the `cmpc gateway` serve loop.
    pub fn wait(&self) {
        while !self.stopping() {
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Drain and stop: intake closes first, every queued job finishes and
    /// its `Result`/`Reject` frame is queued, and only then do the pollers
    /// run their bounded final flush and drop connections. Returns the
    /// final stats snapshot.
    pub fn shutdown(mut self) -> GatewayStats {
        self.stop_and_join();
        self.inner.counters.snapshot()
    }

    fn stop_and_join(&mut self) {
        // Phase 1 — stop intake: new submissions get ShuttingDown rejects,
        // the acceptor exits, and the batcher wakes the dispatcher to
        // drain its queues. Pollers keep sweeping (reads and writes), so
        // responses produced during the drain still reach their clients.
        self.inner.stop.store(true, Ordering::Release);
        self.inner.batcher.stop();
        // Phase 2 — wait for the dispatcher: once it joins, every admitted
        // job has executed and its response bytes sit in some outbox.
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Phase 3 — final flush: the pollers push the queued bytes out
        // (bounded by the drain budget for slow/dead clients) and drop
        // the connections. Nothing can race in behind the deadline,
        // because nothing upstream is still producing.
        self.flush.store(true, Ordering::Release);
        if let Some(p) = self.pollers.take() {
            p.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}
