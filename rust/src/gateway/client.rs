//! Gateway client + the `cmpc client` load driver.
//!
//! [`GatewayClient`] is the minimal blocking client: one TCP connection,
//! one frame out per submission, typed replies back ([`ClientReply`]).
//! [`run_load`] is the multi-tenant load driver behind `cmpc client`: one
//! thread per tenant, each driving a deterministic slice of the global
//! job sequence (`job_matrices(seed, k, m)` for `k` in the tenant's
//! contiguous range), so accepted digests diff 1:1 against
//! `cmpc node --role reference` no matter how the gateway interleaved or
//! batched the tenants.

use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::transport::node::job_matrices;
use crate::transport::wire::{
    read_client_frame, write_client_frame, ClientFrame, ClientMsg, RejectReason,
};

/// A gateway's answer to one submission, keyed by the echoed correlation
/// id.
#[derive(Debug, Clone)]
pub enum ClientReply {
    /// The job ran; `digest` is the FNV digest of `y` (what the CI lane
    /// diffs against the reference).
    Accepted {
        /// The submission's correlation id, echoed back.
        corr: u64,
        /// FNV digest of `y`.
        digest: u64,
        /// Admission→decode latency as the gateway measured it.
        elapsed_us: u64,
        /// The decoded product.
        y: FpMat,
    },
    /// The typed refusal, verbatim from the gateway's door (or engine,
    /// for [`RejectReason::Internal`]).
    Rejected {
        /// The submission's correlation id, echoed back.
        corr: u64,
        /// The typed cause.
        reason: RejectReason,
        /// Free-form human-readable context.
        detail: String,
    },
}

impl ClientReply {
    /// The correlation id this reply answers, whatever the outcome.
    pub fn corr(&self) -> u64 {
        match self {
            ClientReply::Accepted { corr, .. } | ClientReply::Rejected { corr, .. } => *corr,
        }
    }
}

/// Blocking client for one tenant over one connection.
pub struct GatewayClient {
    stream: TcpStream,
    scratch: Vec<u8>,
    tenant: u32,
}

impl GatewayClient {
    /// Open one TCP connection to the gateway at `addr`, identifying as
    /// `tenant` on every frame.
    pub fn connect(addr: &str, tenant: u32) -> Result<GatewayClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| CmpcError::Io(format!("connecting to gateway {addr}: {e}")))?;
        let _ = stream.set_nodelay(true);
        Ok(GatewayClient {
            stream,
            scratch: Vec::new(),
            tenant,
        })
    }

    /// The tenant id this client stamps on its submissions.
    pub fn tenant(&self) -> u32 {
        self.tenant
    }

    /// Fire one submission; the reply (matched by `corr`) comes back via
    /// [`GatewayClient::recv`]. `adv` is the adversary tolerance the
    /// decode must honor (0 = plain crash-fault decoding).
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &mut self,
        corr: u64,
        s: usize,
        t: usize,
        z: usize,
        adv: usize,
        a: FpMat,
        b: FpMat,
    ) -> Result<()> {
        write_client_frame(
            &mut self.stream,
            &ClientFrame {
                corr,
                tenant: self.tenant,
                msg: ClientMsg::Submit { s, t, z, adv, a, b },
            },
            &mut self.scratch,
        )?;
        Ok(())
    }

    /// Block for the next reply on this connection.
    pub fn recv(&mut self) -> Result<ClientReply> {
        let frame = read_client_frame(&mut self.stream)?.ok_or_else(|| {
            CmpcError::Io("gateway closed the connection mid-conversation".to_string())
        })?;
        match frame.msg {
            ClientMsg::Result {
                digest,
                elapsed_us,
                y,
            } => Ok(ClientReply::Accepted {
                corr: frame.corr,
                digest,
                elapsed_us,
                y,
            }),
            ClientMsg::Reject { reason, detail } => Ok(ClientReply::Rejected {
                corr: frame.corr,
                reason,
                detail,
            }),
            ClientMsg::Submit { .. } | ClientMsg::Shutdown { .. } => Err(CmpcError::Io(
                "gateway sent a request-plane frame to a client".to_string(),
            )),
        }
    }

    /// Submit one job and block for its reply (closed-loop convenience).
    #[allow(clippy::too_many_arguments)]
    pub fn call(
        &mut self,
        corr: u64,
        s: usize,
        t: usize,
        z: usize,
        adv: usize,
        a: FpMat,
        b: FpMat,
    ) -> Result<ClientReply> {
        self.submit(corr, s, t, z, adv, a, b)?;
        self.recv()
    }

    /// Ask the gateway to drain and stop (the CI lane's clean teardown).
    /// `token` must match the gateway's `gateway_token` manifest line; a
    /// mismatch comes back as a [`RejectReason::Unauthorized`] reply on
    /// [`GatewayClient::recv`] and the gateway keeps serving. Consumes
    /// the client by value: an accepted shutdown closes the connection.
    pub fn shutdown_gateway(mut self, token: u64) -> Result<()> {
        write_client_frame(
            &mut self.stream,
            &ClientFrame {
                corr: 0,
                tenant: self.tenant,
                msg: ClientMsg::Shutdown { token },
            },
            &mut self.scratch,
        )?;
        Ok(())
    }

    /// Like [`GatewayClient::shutdown_gateway`] but keeps the client, so
    /// callers can observe the gateway's answer to a rejected (or
    /// accepted) shutdown on the same connection.
    pub fn request_shutdown(&mut self, token: u64) -> Result<()> {
        write_client_frame(
            &mut self.stream,
            &ClientFrame {
                corr: 0,
                tenant: self.tenant,
                msg: ClientMsg::Shutdown { token },
            },
            &mut self.scratch,
        )?;
        Ok(())
    }
}

// ------------------------------------------------------------ load driver

/// What `cmpc client` runs: a per-tenant slice of the deterministic
/// global job sequence against one gateway.
#[derive(Clone, Debug)]
pub struct LoadPlan {
    /// Gateway address (`host:port`).
    pub addr: String,
    /// Tenant ids; tenant at index `i` drives global jobs
    /// `[i·jobs_per_tenant, (i+1)·jobs_per_tenant)`.
    pub tenants: Vec<u32>,
    /// Jobs each tenant submits.
    pub jobs_per_tenant: usize,
    /// Square matrix dimension of every job.
    pub m: usize,
    /// Row partition factor every submission carries.
    pub s: usize,
    /// Column partition factor every submission carries.
    pub t: usize,
    /// Collusion tolerance every submission carries.
    pub z: usize,
    /// Adversary tolerance every submission carries (must match the
    /// serving manifest's `adversary_tolerance` under a shape lock).
    pub adv: usize,
    /// Must match the reference's manifest seed for digests to diff.
    pub seed: u64,
    /// `None` = closed loop (submit → wait → next; deterministic order,
    /// what the CI lane uses). `Some(q)` = open loop: each tenant paces
    /// submissions at `q` jobs/sec without waiting, then drains replies.
    pub qps: Option<f64>,
}

/// One job's outcome as the client observed it.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    /// The submitting tenant.
    pub tenant: u32,
    /// Global job index (also the correlation id on the wire).
    pub job: u64,
    /// The gateway's typed answer.
    pub reply: ClientReply,
    /// Submit→reply latency at the client.
    pub latency: Duration,
}

/// Aggregate of one [`run_load`] drive.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Every outcome, sorted by global job index.
    pub outcomes: Vec<JobOutcome>,
    /// Wall-clock time of the whole drive (first submit → last reply).
    pub elapsed: Duration,
}

impl LoadReport {
    /// Outcomes the gateway accepted (decoded and returned a product).
    pub fn accepted(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| matches!(o.reply, ClientReply::Accepted { .. }))
            .count()
    }

    /// Outcomes the gateway refused (any [`RejectReason`]).
    pub fn rejected(&self) -> usize {
        self.outcomes.len() - self.accepted()
    }

    /// Client-observed completion rate over the whole drive.
    pub fn qps(&self) -> f64 {
        if self.elapsed.as_secs_f64() > 0.0 {
            self.outcomes.len() as f64 / self.elapsed.as_secs_f64()
        } else {
            0.0
        }
    }

    /// Client-observed latency percentile over **accepted** jobs
    /// (`p` in `[0, 1]`); zero when nothing was accepted.
    pub fn latency_percentile(&self, p: f64) -> Duration {
        let mut lats: Vec<Duration> = self
            .outcomes
            .iter()
            .filter(|o| matches!(o.reply, ClientReply::Accepted { .. }))
            .map(|o| o.latency)
            .collect();
        if lats.is_empty() {
            return Duration::ZERO;
        }
        lats.sort_unstable();
        let rank = ((p.clamp(0.0, 1.0) * lats.len() as f64).ceil() as usize)
            .clamp(1, lats.len());
        lats[rank - 1]
    }
}

fn drive_tenant(plan: &LoadPlan, tenant_idx: usize) -> Result<Vec<JobOutcome>> {
    let tenant = plan.tenants[tenant_idx];
    let mut client = GatewayClient::connect(&plan.addr, tenant)?;
    let base = (tenant_idx * plan.jobs_per_tenant) as u64;
    let jobs: Vec<u64> = (0..plan.jobs_per_tenant as u64).map(|k| base + k).collect();
    let mut outcomes = Vec::with_capacity(jobs.len());
    match plan.qps {
        // Closed loop: strictly sequential per tenant, so token-bucket
        // admission decisions are deterministic in job order.
        None => {
            for &job in &jobs {
                let (a, b) = job_matrices(plan.seed, job, plan.m);
                let t0 = Instant::now();
                let reply = client.call(job, plan.s, plan.t, plan.z, plan.adv, a, b)?;
                if reply.corr() != job {
                    return Err(CmpcError::Io(format!(
                        "gateway answered corr {} to submission {job}",
                        reply.corr()
                    )));
                }
                outcomes.push(JobOutcome {
                    tenant,
                    job,
                    reply,
                    latency: t0.elapsed(),
                });
            }
        }
        // Open loop: pace submissions at `q`/sec regardless of replies,
        // then drain — replies may arrive in any order; match by corr.
        Some(q) => {
            let interval = Duration::from_secs_f64(1.0 / q.max(1e-6));
            let start = Instant::now();
            let mut submitted_at = std::collections::HashMap::new();
            for (k, &job) in jobs.iter().enumerate() {
                let due = start + interval * k as u32;
                if let Some(wait) = due.checked_duration_since(Instant::now()) {
                    std::thread::sleep(wait);
                }
                let (a, b) = job_matrices(plan.seed, job, plan.m);
                submitted_at.insert(job, Instant::now());
                client.submit(job, plan.s, plan.t, plan.z, plan.adv, a, b)?;
            }
            for _ in 0..jobs.len() {
                let reply = client.recv()?;
                let job = reply.corr();
                let t0 = submitted_at.remove(&job).ok_or_else(|| {
                    CmpcError::Io(format!("gateway answered unknown corr {job}"))
                })?;
                outcomes.push(JobOutcome {
                    tenant,
                    job,
                    reply,
                    latency: t0.elapsed(),
                });
            }
        }
    }
    Ok(outcomes)
}

/// Drive the plan: one thread per tenant, all concurrent. Outcomes come
/// back sorted by global job index.
pub fn run_load(plan: &LoadPlan) -> Result<LoadReport> {
    if plan.tenants.is_empty() || plan.jobs_per_tenant == 0 {
        return Ok(LoadReport::default());
    }
    let t0 = Instant::now();
    let all: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::new());
    let first_err: Mutex<Option<CmpcError>> = Mutex::new(None);
    std::thread::scope(|scope| {
        for tenant_idx in 0..plan.tenants.len() {
            let all = &all;
            let first_err = &first_err;
            scope.spawn(move || match drive_tenant(plan, tenant_idx) {
                Ok(mut outcomes) => all.lock().unwrap().append(&mut outcomes),
                Err(e) => {
                    first_err.lock().unwrap().get_or_insert(e);
                }
            });
        }
    });
    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    let mut outcomes = all.into_inner().unwrap();
    outcomes.sort_by_key(|o| o.job);
    Ok(LoadReport {
        outcomes,
        elapsed: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_a_noop() {
        let report = run_load(&LoadPlan {
            addr: "127.0.0.1:1".to_string(),
            tenants: Vec::new(),
            jobs_per_tenant: 0,
            m: 4,
            s: 2,
            t: 2,
            z: 2,
            adv: 0,
            seed: 7,
            qps: None,
        })
        .unwrap();
        assert!(report.outcomes.is_empty());
        assert_eq!(report.accepted(), 0);
        assert_eq!(report.latency_percentile(0.99), Duration::ZERO);
    }

    #[test]
    fn percentiles_rank_correctly() {
        let mk = |job: u64, us: u64| JobOutcome {
            tenant: 0,
            job,
            reply: ClientReply::Accepted {
                corr: job,
                digest: 0,
                elapsed_us: us,
                y: FpMat::zeros(1, 1),
            },
            latency: Duration::from_micros(us),
        };
        let report = LoadReport {
            outcomes: (1..=100).map(|i| mk(i, i * 10)).collect(),
            elapsed: Duration::from_secs(1),
        };
        assert_eq!(report.accepted(), 100);
        assert_eq!(report.latency_percentile(0.5), Duration::from_micros(500));
        assert_eq!(report.latency_percentile(0.99), Duration::from_micros(990));
        assert_eq!(report.latency_percentile(1.0), Duration::from_micros(1000));
    }
}
