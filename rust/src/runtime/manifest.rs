//! Plain-text manifests: compiled-artifact maps and distributed-topology
//! descriptions.
//!
//! **Artifact manifest** ([`Manifest`]): `python/compile/aot.py` writes
//! `artifacts/manifest.txt` with one record per lowered executable:
//!
//! ```text
//! # model  M  K  N  path
//! matmul_mod 128 128 128 matmul_mod_128x128x128.hlo.txt
//! ```
//!
//! **Topology manifest** ([`TopologyManifest`]): describes one distributed
//! CMPC deployment — scheme, job parameters, one `host:port` per node, and
//! optional link-shaping rules — consumed by `cmpc node` (every party
//! process reads the same file) and by the loopback cluster harness:
//!
//! ```text
//! # cmpc topology v1
//! scheme age
//! params 2 2 2
//! m 64
//! seed 7
//! jobs 2
//! worker 0 10.0.0.10:9300
//! worker 1 10.0.0.11:9300
//! master 10.0.0.2:9300
//! source-a 10.0.0.3:9300
//! source-b 10.0.0.4:9300
//! shape * * 40000 12500000 65536 gshare
//! gateway 10.0.0.2:9400
//! tenant 0 100 50 64
//! tenant 1 2 0 64
//! ```
//!
//! The optional `gateway` line is the client-facing listen address for
//! `cmpc gateway` (v0.7); each `tenant` line is
//! `tenant <id> <burst> <rate_per_sec> <max_pending>` — a
//! [`TenantQuota`] for its admission table (no `tenant` lines = open
//! admission).
//!
//! The optional `autoscale` line (v0.11) attaches an adaptive
//! provisioning controller to the gateway's local engine:
//! `autoscale <interval_ms> <hysteresis_pct> <strike_threshold>
//! <cooldown_ticks>` — see [`AutoscaleSpec`] and
//! [`crate::autoscale::Autoscaler`]. It requires a `gateway` line (a
//! remote cluster's worker *processes* cannot be blue/green-swapped from
//! a manifest).
//!
//! The optional `pipeline` line (v0.10) carries a
//! [`Pipeline`](crate::mpc::pipeline::Pipeline) spec string, e.g.
//! `pipeline matmul,truncate:8,matmul`. When present, each of the
//! manifest's `jobs` is one full pipeline run over seed-derived demo data
//! instead of a single `Y = AᵀB` product; `adversary_tolerance` must stay
//! 0 (intermediate stages decode at the exact `t²+z` quota, leaving no
//! Byzantine margin).
//!
//! A plain line format is used instead of JSON because the offline build has
//! no serde; the formats are versioned by their header comments.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::autoscale::{AutoscaleConfig, PolicyConfig};
use crate::codes::{CmpcScheme, SchemeParams, SchemeSpec};
use crate::error::{CmpcError, Result};
use crate::gateway::admission::TenantQuota;
use crate::mpc::chaos::PayloadClass;
use crate::mpc::network::NodeId;
use crate::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};

/// Shape key for a modular matmul artifact: `(M, K, N)`.
pub type MatmulShape = (usize, usize, usize);

/// Parsed artifact manifest.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    /// matmul_mod artifacts by shape.
    pub matmul: HashMap<MatmulShape, PathBuf>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`; missing file yields an empty manifest
    /// (every shape falls back to native compute).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let mut manifest = Manifest::default();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(manifest),
            Err(e) => return Err(CmpcError::Io(format!("reading {}: {e}", path.display()))),
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.as_slice() {
                ["matmul_mod", m, k, n, rel] => {
                    let shape: MatmulShape = (
                        m.parse().map_err(|e| bad_line(lineno, &e))?,
                        k.parse().map_err(|e| bad_line(lineno, &e))?,
                        n.parse().map_err(|e| bad_line(lineno, &e))?,
                    );
                    manifest.matmul.insert(shape, dir.join(rel));
                }
                _ => {
                    return Err(CmpcError::BackendUnavailable(format!(
                        "manifest.txt line {}: unrecognized record {line:?}",
                        lineno + 1
                    )))
                }
            }
        }
        Ok(manifest)
    }

    /// Look up the lowered artifact for a `(M, K, N)` matmul shape.
    pub fn matmul_artifact(&self, shape: MatmulShape) -> Option<&PathBuf> {
        self.matmul.get(&shape)
    }
}

fn bad_line(lineno: usize, e: &std::num::ParseIntError) -> CmpcError {
    CmpcError::BackendUnavailable(format!("manifest.txt line {}: {e}", lineno + 1))
}

// ------------------------------------------------------------- topology

/// One parsed `shape` line: a link-matching rule for the
/// [`LinkShaper`] built by [`TopologyManifest::shaper`]. `None` = `*`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeLine {
    /// Sender node the rule matches (`None` = any).
    pub from: Option<NodeId>,
    /// Receiver node the rule matches (`None` = any).
    pub to: Option<NodeId>,
    /// One-way propagation delay added per envelope.
    pub latency_us: u64,
    /// Serialization rate in bits/s (`0` = unlimited).
    pub rate_bps: u64,
    /// Token-bucket burst allowance in bytes.
    pub burst_bytes: u64,
    /// Payload class the rule matches (`None` = any).
    pub class: Option<PayloadClass>,
}

/// One parsed `autoscale` line: the adaptive-provisioning knobs a
/// manifest pins for the gateway's local engine. Fields mirror the
/// [`AutoscaleConfig`]/[`PolicyConfig`] they configure; everything not on
/// the line (window size, miss budget, adversary ceiling) keeps its
/// library default.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscaleSpec {
    /// Controller sampling interval, milliseconds (≥ 1).
    pub interval_ms: u64,
    /// Minimum predicted ζ gain (percent) before a communication-cost
    /// reconfiguration fires.
    pub hysteresis_pct: f64,
    /// Cumulative Byzantine strikes at one worker slot before the policy
    /// escalates the adversary tolerance instead of retrying.
    pub strike_threshold: u64,
    /// Ticks the controller holds after a swap lands.
    pub cooldown_ticks: u64,
}

impl AutoscaleSpec {
    /// The controller configuration this line describes.
    pub fn to_config(self) -> AutoscaleConfig {
        AutoscaleConfig {
            interval: Duration::from_millis(self.interval_ms),
            cooldown_ticks: self.cooldown_ticks,
            policy: PolicyConfig {
                hysteresis_pct: self.hysteresis_pct,
                strike_threshold: self.strike_threshold,
                ..PolicyConfig::default()
            },
        }
    }
}

/// A distributed CMPC deployment description: scheme + job parameters +
/// one address per node + optional link shaping. Every party process
/// reads the same manifest, so the whole cluster derives identical setup
/// (α assignment, reconstruction coefficients, per-job seeds and data).
///
/// Node-id layout matches the fabric: `0..N` → workers, `N` → master,
/// `N+1` → source A, `N+2` → source B.
#[derive(Debug, Clone)]
pub struct TopologyManifest {
    /// Scheme family: `age`, `polydot`, or `entangled`.
    pub scheme: String,
    /// Per-source partition count.
    pub s: usize,
    /// Colluding-worker privacy threshold.
    pub t: usize,
    /// Random masking terms per share polynomial.
    pub z: usize,
    /// Job matrix size (m×m).
    pub m: usize,
    /// Base seed: per-job secret seeds and the demo job data derive from it
    /// identically in every process (and in the in-process reference).
    pub seed: u64,
    /// Jobs the master drives before shutting the cluster down.
    pub jobs: usize,
    /// Master decodes at the recovery quota and aborts the straggler tail.
    pub early_decode: bool,
    /// Byzantine adversary tolerance `a`: the master collects `t²+z+2a`
    /// I-shares and locates/excludes up to `a` garbled ones (0 = classic
    /// erasure-only decode). Every party derives the same raised quota
    /// from this line, so the cluster stays self-consistent.
    pub adversary_tolerance: usize,
    /// Master checks `Y == AᵀB` before reporting each job.
    pub verify: bool,
    /// Outbound connect retry budget (peers may start in any order).
    pub connect_timeout: Duration,
    /// Per-receive bound while a job is in flight (same meaning as
    /// `ProtocolConfig::recv_timeout`).
    pub recv_timeout: Duration,
    /// When set, the spec string of the [`crate::mpc::pipeline::Pipeline`]
    /// each of this cluster's `jobs` runs (over seed-derived demo data)
    /// instead of a single product — see [`TopologyManifest::pipeline`].
    pub pipeline_spec: Option<String>,
    /// Worker addresses, indexed by worker id.
    pub workers: Vec<String>,
    /// Master (decoder) address.
    pub master: String,
    /// Source-A address.
    pub source_a: String,
    /// Source-B address.
    pub source_b: String,
    /// Link-shaping rules (empty = unshaped).
    pub shapes: Vec<ShapeLine>,
    /// Client-facing listen address for `cmpc gateway` (`None` = this
    /// topology has no serving tier).
    pub gateway: Option<String>,
    /// Shared secret required by gateway `Shutdown` frames (`None` = any
    /// client may stop the gateway — the pre-v0.8 behavior). A frame with
    /// a non-matching token is rejected with a typed `Unauthorized` and
    /// its connection dropped, instead of killing the serving tier.
    ///
    /// The client plane is not yet encrypted or authenticated, so the
    /// token travels in cleartext: it guards against accidental and
    /// drive-by shutdowns, not an on-path eavesdropper. Keep non-loopback
    /// gateways on trusted network segments until the TLS/auth ROADMAP
    /// item lands.
    pub gateway_token: Option<u64>,
    /// Gateway admission table (empty = open admission).
    pub tenants: Vec<TenantQuota>,
    /// Adaptive provisioning controller for the gateway's local engine
    /// (`None` = static provisioning, the pre-v0.11 behavior).
    pub autoscale: Option<AutoscaleSpec>,
}

fn topo_err(lineno: usize, msg: impl std::fmt::Display) -> CmpcError {
    CmpcError::InvalidParams(format!("topology manifest line {}: {msg}", lineno + 1))
}

fn parse_field<T: std::str::FromStr>(lineno: usize, name: &str, v: &str) -> Result<T> {
    v.parse()
        .map_err(|_| topo_err(lineno, format!("bad {name} value {v:?}")))
}

fn parse_wild(lineno: usize, name: &str, v: &str) -> Result<Option<usize>> {
    if v == "*" {
        Ok(None)
    } else {
        Ok(Some(parse_field(lineno, name, v)?))
    }
}

impl TopologyManifest {
    /// Build a loopback/demo manifest for `scheme` at `(s,t,z)`:
    /// `host:base_port+node_id` per node (`base_port == 0` leaves every
    /// port 0, for harnesses that bind first and learn real ports).
    #[allow(clippy::too_many_arguments)]
    pub fn template(
        scheme: &str,
        s: usize,
        t: usize,
        z: usize,
        m: usize,
        seed: u64,
        jobs: usize,
        host: &str,
        base_port: u16,
    ) -> Result<TopologyManifest> {
        let mut manifest = TopologyManifest {
            scheme: scheme.to_string(),
            s,
            t,
            z,
            m,
            seed,
            jobs,
            early_decode: false,
            adversary_tolerance: 0,
            verify: true,
            connect_timeout: Duration::from_secs(10),
            recv_timeout: Duration::from_secs(30),
            pipeline_spec: None,
            workers: Vec::new(),
            master: String::new(),
            source_a: String::new(),
            source_b: String::new(),
            shapes: Vec::new(),
            gateway: None,
            gateway_token: None,
            tenants: Vec::new(),
            autoscale: None,
        };
        let n = manifest.resolve_scheme()?.n_workers();
        if base_port != 0 && (base_port as usize) + n + 2 > u16::MAX as usize {
            return Err(CmpcError::InvalidParams(format!(
                "base port {base_port} leaves no room for {} node ports",
                n + 3
            )));
        }
        let addr = |i: usize| {
            if base_port == 0 {
                format!("{host}:0")
            } else {
                format!("{host}:{}", base_port as usize + i)
            }
        };
        manifest.workers = (0..n).map(&addr).collect();
        manifest.master = addr(n);
        manifest.source_a = addr(n + 1);
        manifest.source_b = addr(n + 2);
        Ok(manifest)
    }

    /// Parse the line format shown in the module docs. Unknown keys are
    /// errors (typos must not silently reconfigure a cluster).
    pub fn parse(text: &str) -> Result<TopologyManifest> {
        let mut scheme = None;
        let mut params: Option<(usize, usize, usize)> = None;
        let (mut m, mut seed, mut jobs) = (None, None, None);
        let mut early_decode = false;
        let mut adversary_tolerance = 0usize;
        let mut verify = true;
        let mut connect_timeout = Duration::from_secs(10);
        let mut recv_timeout = Duration::from_secs(30);
        let mut pipeline_spec: Option<String> = None;
        let mut workers: HashMap<usize, String> = HashMap::new();
        let (mut master, mut source_a, mut source_b) = (None, None, None);
        let mut shapes = Vec::new();
        let mut gateway = None;
        let mut gateway_token = None;
        let mut tenants: Vec<TenantQuota> = Vec::new();
        let mut autoscale: Option<AutoscaleSpec> = None;
        // Duplicate identity/parameter lines are errors, same as unknown
        // keys: a stale line left in a hand-edited manifest must not
        // silently win (or lose) over the intended one.
        fn no_dup<T>(lineno: usize, key: &str, slot: &Option<T>) -> Result<()> {
            if slot.is_some() {
                return Err(topo_err(lineno, format!("duplicate {key} line")));
            }
            Ok(())
        }
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.as_slice() {
                ["scheme", v] => {
                    no_dup(lineno, "scheme", &scheme)?;
                    scheme = Some(v.to_string());
                }
                ["params", s, t, z] => {
                    no_dup(lineno, "params", &params)?;
                    params = Some((
                        parse_field(lineno, "s", s)?,
                        parse_field(lineno, "t", t)?,
                        parse_field(lineno, "z", z)?,
                    ))
                }
                ["m", v] => {
                    no_dup(lineno, "m", &m)?;
                    m = Some(parse_field(lineno, "m", v)?);
                }
                ["seed", v] => {
                    no_dup(lineno, "seed", &seed)?;
                    seed = Some(parse_field(lineno, "seed", v)?);
                }
                ["jobs", v] => {
                    no_dup(lineno, "jobs", &jobs)?;
                    jobs = Some(parse_field(lineno, "jobs", v)?);
                }
                ["early_decode", v] => {
                    early_decode = parse_field::<u8>(lineno, "early_decode", v)? != 0
                }
                ["adversary_tolerance", v] => {
                    adversary_tolerance = parse_field(lineno, "adversary_tolerance", v)?
                }
                ["verify", v] => verify = parse_field::<u8>(lineno, "verify", v)? != 0,
                ["connect_timeout_ms", v] => {
                    connect_timeout =
                        Duration::from_millis(parse_field(lineno, "connect_timeout_ms", v)?)
                }
                ["recv_timeout_ms", v] => {
                    recv_timeout =
                        Duration::from_millis(parse_field(lineno, "recv_timeout_ms", v)?)
                }
                ["pipeline", v] => {
                    no_dup(lineno, "pipeline", &pipeline_spec)?;
                    pipeline_spec = Some(v.to_string());
                }
                ["worker", idx, addr] => {
                    let idx: usize = parse_field(lineno, "worker index", idx)?;
                    if workers.insert(idx, addr.to_string()).is_some() {
                        return Err(topo_err(lineno, format!("duplicate worker {idx}")));
                    }
                }
                ["master", addr] => {
                    no_dup(lineno, "master", &master)?;
                    master = Some(addr.to_string());
                }
                ["source-a", addr] => {
                    no_dup(lineno, "source-a", &source_a)?;
                    source_a = Some(addr.to_string());
                }
                ["source-b", addr] => {
                    no_dup(lineno, "source-b", &source_b)?;
                    source_b = Some(addr.to_string());
                }
                ["gateway", addr] => {
                    no_dup(lineno, "gateway", &gateway)?;
                    gateway = Some(addr.to_string());
                }
                ["gateway_token", v] => {
                    no_dup(lineno, "gateway_token", &gateway_token)?;
                    gateway_token = Some(parse_field::<u64>(lineno, "gateway_token", v)?);
                }
                ["autoscale", interval_ms, hysteresis_pct, strike_threshold, cooldown_ticks] => {
                    no_dup(lineno, "autoscale", &autoscale)?;
                    autoscale = Some(AutoscaleSpec {
                        interval_ms: parse_field(lineno, "autoscale interval_ms", interval_ms)?,
                        hysteresis_pct: parse_field(
                            lineno,
                            "autoscale hysteresis_pct",
                            hysteresis_pct,
                        )?,
                        strike_threshold: parse_field(
                            lineno,
                            "autoscale strike_threshold",
                            strike_threshold,
                        )?,
                        cooldown_ticks: parse_field(
                            lineno,
                            "autoscale cooldown_ticks",
                            cooldown_ticks,
                        )?,
                    });
                }
                ["tenant", id, burst, rate, max_pending] => {
                    let id: u32 = parse_field(lineno, "tenant id", id)?;
                    if tenants.iter().any(|q| q.id == id) {
                        return Err(topo_err(lineno, format!("duplicate tenant {id}")));
                    }
                    tenants.push(TenantQuota {
                        id,
                        burst: parse_field(lineno, "tenant burst", burst)?,
                        rate_per_sec: parse_field(lineno, "tenant rate_per_sec", rate)?,
                        max_pending: parse_field(lineno, "tenant max_pending", max_pending)?,
                    });
                }
                ["shape", rest @ ..] if (4..=6usize).contains(&rest.len()) => {
                    let from = parse_wild(lineno, "shape from", rest[0])?;
                    let to = parse_wild(lineno, "shape to", rest[1])?;
                    let latency_us = parse_field(lineno, "latency_us", rest[2])?;
                    let rate_bps = parse_field(lineno, "rate_bps", rest[3])?;
                    let burst_bytes = if rest.len() >= 5 {
                        parse_field(lineno, "burst_bytes", rest[4])?
                    } else {
                        0
                    };
                    let class = if rest.len() == 6 {
                        match rest[5] {
                            "*" => None,
                            "shares" => Some(PayloadClass::Shares),
                            "gshare" => Some(PayloadClass::GShare),
                            "ishare" => Some(PayloadClass::IShare),
                            other => {
                                return Err(topo_err(
                                    lineno,
                                    format!("unknown shape class {other:?}"),
                                ))
                            }
                        }
                    } else {
                        None
                    };
                    shapes.push(ShapeLine {
                        from,
                        to,
                        latency_us,
                        rate_bps,
                        burst_bytes,
                        class,
                    });
                }
                _ => return Err(topo_err(lineno, format!("unrecognized record {line:?}"))),
            }
        }
        let missing = |what: &str| {
            CmpcError::InvalidParams(format!("topology manifest: missing {what}"))
        };
        let (s, t, z) = params.ok_or_else(|| missing("params"))?;
        let n = workers.len();
        let mut worker_addrs = Vec::with_capacity(n);
        for i in 0..n {
            worker_addrs.push(workers.remove(&i).ok_or_else(|| {
                CmpcError::InvalidParams(format!(
                    "topology manifest: worker ids must be contiguous (missing worker {i})"
                ))
            })?);
        }
        let manifest = TopologyManifest {
            scheme: scheme.ok_or_else(|| missing("scheme"))?,
            s,
            t,
            z,
            m: m.ok_or_else(|| missing("m"))?,
            seed: seed.ok_or_else(|| missing("seed"))?,
            jobs: jobs.ok_or_else(|| missing("jobs"))?,
            early_decode,
            adversary_tolerance,
            verify,
            connect_timeout,
            recv_timeout,
            pipeline_spec,
            workers: worker_addrs,
            master: master.ok_or_else(|| missing("master address"))?,
            source_a: source_a.ok_or_else(|| missing("source-a address"))?,
            source_b: source_b.ok_or_else(|| missing("source-b address"))?,
            shapes,
            gateway,
            gateway_token,
            tenants,
            autoscale,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Load and parse a manifest file.
    pub fn load(path: &Path) -> Result<TopologyManifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| CmpcError::Io(format!("reading {}: {e}", path.display())))?;
        TopologyManifest::parse(&text)
    }

    /// Serialize back to the line format ([`TopologyManifest::parse`] is
    /// its inverse).
    pub fn render(&self) -> String {
        let mut out = String::from("# cmpc topology v1\n");
        out.push_str(&format!("scheme {}\n", self.scheme));
        out.push_str(&format!("params {} {} {}\n", self.s, self.t, self.z));
        out.push_str(&format!("m {}\n", self.m));
        out.push_str(&format!("seed {}\n", self.seed));
        out.push_str(&format!("jobs {}\n", self.jobs));
        out.push_str(&format!("early_decode {}\n", u8::from(self.early_decode)));
        out.push_str(&format!(
            "adversary_tolerance {}\n",
            self.adversary_tolerance
        ));
        out.push_str(&format!("verify {}\n", u8::from(self.verify)));
        out.push_str(&format!(
            "connect_timeout_ms {}\n",
            self.connect_timeout.as_millis()
        ));
        out.push_str(&format!(
            "recv_timeout_ms {}\n",
            self.recv_timeout.as_millis()
        ));
        if let Some(spec) = &self.pipeline_spec {
            out.push_str(&format!("pipeline {spec}\n"));
        }
        for (i, addr) in self.workers.iter().enumerate() {
            out.push_str(&format!("worker {i} {addr}\n"));
        }
        out.push_str(&format!("master {}\n", self.master));
        out.push_str(&format!("source-a {}\n", self.source_a));
        out.push_str(&format!("source-b {}\n", self.source_b));
        for sh in &self.shapes {
            let wild = |v: Option<usize>| match v {
                Some(n) => n.to_string(),
                None => "*".to_string(),
            };
            let class = match sh.class {
                None => "*",
                Some(PayloadClass::Shares) => "shares",
                Some(PayloadClass::GShare) => "gshare",
                Some(PayloadClass::IShare) => "ishare",
                Some(PayloadClass::Control) => "*",
            };
            out.push_str(&format!(
                "shape {} {} {} {} {} {class}\n",
                wild(sh.from),
                wild(sh.to),
                sh.latency_us,
                sh.rate_bps,
                sh.burst_bytes
            ));
        }
        if let Some(gw) = &self.gateway {
            out.push_str(&format!("gateway {gw}\n"));
        }
        if let Some(token) = self.gateway_token {
            out.push_str(&format!("gateway_token {token}\n"));
        }
        for q in &self.tenants {
            // f64 Display round-trips through FromStr (shortest repr), so
            // render ∘ parse stays the identity for rate_per_sec.
            out.push_str(&format!(
                "tenant {} {} {} {}\n",
                q.id, q.burst, q.rate_per_sec, q.max_pending
            ));
        }
        if let Some(auto) = self.autoscale {
            // hysteresis_pct is f64: same Display/FromStr identity as
            // tenant rate_per_sec above.
            out.push_str(&format!(
                "autoscale {} {} {} {}\n",
                auto.interval_ms, auto.hysteresis_pct, auto.strike_threshold, auto.cooldown_ticks
            ));
        }
        out
    }

    /// Cross-field validation: the scheme must resolve and its worker
    /// count must match the declared addresses.
    pub fn validate(&self) -> Result<()> {
        if self.jobs == 0 {
            return Err(CmpcError::InvalidParams(
                "topology manifest: jobs must be ≥ 1".to_string(),
            ));
        }
        let scheme = self.resolve_scheme()?;
        if scheme.n_workers() != self.workers.len() {
            return Err(CmpcError::InvalidParams(format!(
                "topology manifest: {} needs {} workers at (s={}, t={}, z={}) but {} worker \
                 addresses are declared",
                scheme.name(),
                scheme.n_workers(),
                self.s,
                self.t,
                self.z,
                self.workers.len()
            )));
        }
        let quota = self.t * self.t + self.z + 2 * self.adversary_tolerance;
        if quota > scheme.n_workers() {
            return Err(CmpcError::InvalidParams(format!(
                "topology manifest: adversary_tolerance {} raises the recovery quota to \
                 {quota} but {} provisions only {} workers",
                self.adversary_tolerance,
                scheme.name(),
                scheme.n_workers()
            )));
        }
        if let Some(spec) = &self.pipeline_spec {
            let pipe = crate::mpc::pipeline::Pipeline::parse_spec(spec)?;
            if self.adversary_tolerance != 0 {
                return Err(CmpcError::InvalidParams(
                    "topology manifest: pipeline requires adversary_tolerance 0 \
                     (intermediate stages decode at the exact t²+z quota)"
                        .to_string(),
                ));
            }
            // Shapes and per-stage quotas are re-checked by the pipeline
            // driver; catch weight-count/shape mismatches that are already
            // decidable from (m, s, t) here, at parse/validate time.
            crate::mpc::pipeline::validate_pipeline_shape(&pipe, self.m, self.s, self.t)?;
        }
        if !self.tenants.is_empty() && self.gateway.is_none() {
            return Err(CmpcError::InvalidParams(
                "topology manifest: tenant quotas declared without a gateway line".to_string(),
            ));
        }
        if self.gateway_token.is_some() && self.gateway.is_none() {
            return Err(CmpcError::InvalidParams(
                "topology manifest: gateway_token declared without a gateway line".to_string(),
            ));
        }
        if let Some(auto) = self.autoscale {
            if self.gateway.is_none() {
                return Err(CmpcError::InvalidParams(
                    "topology manifest: autoscale declared without a gateway line (only the \
                     gateway's local engine can blue/green-swap deployments)"
                        .to_string(),
                ));
            }
            if auto.interval_ms == 0 {
                return Err(CmpcError::InvalidParams(
                    "topology manifest: autoscale interval_ms must be ≥ 1".to_string(),
                ));
            }
        }
        Ok(())
    }

    /// The registry spec named by the `scheme` line.
    pub fn spec(&self) -> Result<SchemeSpec> {
        match self.scheme.as_str() {
            "age" => Ok(SchemeSpec::Age { lambda: None }),
            "polydot" => Ok(SchemeSpec::PolyDot),
            "entangled" => Ok(SchemeSpec::Entangled),
            other => Err(CmpcError::InvalidParams(format!(
                "topology manifest: unknown scheme {other:?} (age|polydot|entangled)"
            ))),
        }
    }

    /// Resolve the manifest's scheme instance (the Byzantine tolerance
    /// rides along, so every party derives the same raised quota).
    pub fn resolve_scheme(&self) -> Result<Arc<dyn CmpcScheme>> {
        self.spec()?.resolve(
            SchemeParams::try_new(self.s, self.t, self.z)?
                .with_adversary_tolerance(self.adversary_tolerance),
        )
    }

    /// Resolve the optional `pipeline` line into a parsed
    /// [`Pipeline`](crate::mpc::pipeline::Pipeline); `None` when this
    /// topology runs ordinary single-product jobs.
    pub fn pipeline(&self) -> Result<Option<crate::mpc::pipeline::Pipeline>> {
        match &self.pipeline_spec {
            Some(spec) => Ok(Some(crate::mpc::pipeline::Pipeline::parse_spec(spec)?)),
            None => Ok(None),
        }
    }

    /// Declared worker count.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Total party count: workers + master + two sources.
    pub fn n_nodes(&self) -> usize {
        self.workers.len() + 3
    }

    /// Node id of the master (`N` in the fabric layout).
    pub fn master_id(&self) -> NodeId {
        self.workers.len()
    }

    /// Node id of source A (`N+1`).
    pub fn source_a_id(&self) -> NodeId {
        self.workers.len() + 1
    }

    /// Node id of source B (`N+2`).
    pub fn source_b_id(&self) -> NodeId {
        self.workers.len() + 2
    }

    /// Every node's address, indexed by node id (what the TCP transport
    /// consumes).
    pub fn addrs(&self) -> Vec<String> {
        let mut v = self.workers.clone();
        v.push(self.master.clone());
        v.push(self.source_a.clone());
        v.push(self.source_b.clone());
        v
    }

    /// Build the [`LinkShaper`] described by the `shape` lines (`None`
    /// when there are none).
    pub fn shaper(&self) -> Option<Arc<LinkShaper>> {
        if self.shapes.is_empty() {
            return None;
        }
        let mut shaper = LinkShaper::new();
        for sh in &self.shapes {
            // Ceiling division: a tiny nonzero bit rate must never round
            // to 0, which LinkSpec treats as the *unlimited* sentinel —
            // that would silently invert a worst-case-WAN experiment.
            let rate_bytes = if sh.rate_bps == 0 {
                0
            } else {
                sh.rate_bps.div_ceil(8)
            };
            let spec = LinkSpec::new(
                Duration::from_micros(sh.latency_us),
                rate_bytes,
                sh.burst_bytes,
            );
            let mut rule = ShapeRule::new(spec);
            if let Some(f) = sh.from {
                rule = rule.from_node(f);
            }
            if let Some(t) = sh.to {
                rule = rule.to_node(t);
            }
            if let Some(c) = sh.class {
                rule = rule.class(c);
            }
            shaper = shaper.rule(rule);
        }
        Some(shaper.into_shared())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_records_and_comments() {
        let dir = std::env::temp_dir().join("cmpc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# model M K N path\nmatmul_mod 128 64 128 a.hlo.txt\n\nmatmul_mod 256 256 256 b.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.matmul.len(), 2);
        assert_eq!(
            m.matmul_artifact((128, 64, 128)).unwrap(),
            &dir.join("a.hlo.txt")
        );
        assert!(m.matmul_artifact((1, 2, 3)).is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("cmpc_manifest_none");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("manifest.txt")).ok();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.matmul.is_empty());
    }

    #[test]
    fn rejects_garbage_lines() {
        let dir = std::env::temp_dir().join("cmpc_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "bogus record here\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn topology_template_roundtrips_through_render_and_parse() {
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9300).unwrap();
        m.shapes.push(ShapeLine {
            from: None,
            to: Some(3),
            latency_us: 500,
            rate_bps: 8_000_000,
            burst_bytes: 4096,
            class: Some(PayloadClass::GShare),
        });
        assert_eq!(m.n_workers(), 17); // AGE(2,2,2)
        assert_eq!(m.master_id(), 17);
        assert_eq!(m.addrs().len(), 20);
        assert_eq!(m.workers[0], "127.0.0.1:9300");
        assert_eq!(m.source_b, "127.0.0.1:9319");
        m.adversary_tolerance = 2;
        let back = TopologyManifest::parse(&m.render()).unwrap();
        assert_eq!(back.scheme, "age");
        assert_eq!((back.s, back.t, back.z, back.m), (2, 2, 2, 8));
        assert_eq!(back.seed, 7);
        assert_eq!(back.jobs, 2);
        assert_eq!(back.adversary_tolerance, 2);
        assert_eq!(back.resolve_scheme().unwrap().params().recovery_quota(), 10);
        assert_eq!(back.workers, m.workers);
        assert_eq!(back.master, m.master);
        assert_eq!(back.shapes, m.shapes);
        assert!(back.shaper().is_some());
        assert!(back.spec().is_ok());
    }

    #[test]
    fn topology_rejects_inconsistent_files() {
        let good = TopologyManifest::template("age", 2, 2, 2, 8, 7, 1, "127.0.0.1", 9400)
            .unwrap()
            .render();
        // a missing worker id breaks contiguity
        let holey: String = good
            .lines()
            .filter(|l| !l.starts_with("worker 3 "))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = TopologyManifest::parse(&holey).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)), "{err}");
        // wrong worker count for the scheme
        let short: String = good
            .lines()
            .filter(|l| !l.starts_with("worker 16 "))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = TopologyManifest::parse(&short).unwrap_err();
        assert!(err.to_string().contains("worker"), "{err}");
        // unknown keys are typed errors, not silence
        let err = TopologyManifest::parse(&format!("{good}warp_drive on\n")).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)), "{err}");
        // …and so are duplicated identity lines (no silent last-wins)
        let err =
            TopologyManifest::parse(&format!("{good}master 10.0.0.9:1234\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        let err = TopologyManifest::parse(&format!("{good}seed 8\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn topology_adversary_tolerance_must_fit_the_worker_count() {
        // AGE(2,2,2) provisions 17 workers; a=6 needs t²+z+2a = 18 shares.
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 1, "127.0.0.1", 9800).unwrap();
        m.adversary_tolerance = 6;
        let err = m.validate().unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)), "{err}");
        assert!(err.to_string().contains("recovery quota"), "{err}");
        m.adversary_tolerance = 5; // quota 16 ≤ 17: fine
        m.validate().unwrap();
    }

    #[test]
    fn topology_gateway_and_tenant_lines_round_trip() {
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9600).unwrap();
        m.gateway = Some("127.0.0.1:9650".to_string());
        m.gateway_token = Some(0xDEAD_BEEF);
        m.tenants = vec![
            TenantQuota {
                id: 0,
                burst: 100,
                rate_per_sec: 50.5,
                max_pending: 64,
            },
            TenantQuota {
                id: 1,
                burst: 2,
                rate_per_sec: 0.0,
                max_pending: 64,
            },
        ];
        let rendered = m.render();
        assert!(rendered.contains("gateway 127.0.0.1:9650"));
        assert!(rendered.contains(&format!("gateway_token {}", 0xDEAD_BEEFu64)));
        assert!(rendered.contains("tenant 1 2 0 64"));
        let back = TopologyManifest::parse(&rendered).unwrap();
        assert_eq!(back.gateway.as_deref(), Some("127.0.0.1:9650"));
        assert_eq!(back.gateway_token, Some(0xDEAD_BEEF));
        assert_eq!(back.tenants, m.tenants);

        // A shutdown token without a gateway to guard is a typo (checked
        // on its own, without tenant lines masking the error).
        let mut orphan_token =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9600).unwrap();
        orphan_token.gateway_token = Some(1);
        let err = orphan_token.validate().unwrap_err();
        assert!(err.to_string().contains("gateway_token"), "{err}");

        // Duplicate tenant ids are typed errors, not silent last-wins.
        let err =
            TopologyManifest::parse(&format!("{rendered}tenant 1 9 9 9\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate tenant"), "{err}");
        // A quota table without a gateway to enforce it is a typo.
        let orphaned: String = rendered
            .lines()
            .filter(|l| !l.starts_with("gateway "))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = TopologyManifest::parse(&orphaned).unwrap_err();
        assert!(err.to_string().contains("gateway"), "{err}");
        // Untouched templates stay gateway-free.
        assert!(TopologyManifest::parse(
            &TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9700)
                .unwrap()
                .render()
        )
        .unwrap()
        .gateway
        .is_none());
    }

    #[test]
    fn topology_autoscale_line_round_trips_and_validates() {
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9620).unwrap();
        m.gateway = Some("127.0.0.1:9670".to_string());
        m.autoscale = Some(AutoscaleSpec {
            interval_ms: 250,
            hysteresis_pct: 12.5,
            strike_threshold: 3,
            cooldown_ticks: 2,
        });
        m.validate().unwrap();
        let rendered = m.render();
        assert!(rendered.contains("autoscale 250 12.5 3 2"));
        let back = TopologyManifest::parse(&rendered).unwrap();
        assert_eq!(back.autoscale, m.autoscale);
        let config = back.autoscale.unwrap().to_config();
        assert_eq!(config.interval, Duration::from_millis(250));
        assert_eq!(config.cooldown_ticks, 2);
        assert!((config.policy.hysteresis_pct - 12.5).abs() < 1e-12);
        assert_eq!(config.policy.strike_threshold, 3);
        // unspecified policy knobs keep their library defaults
        assert_eq!(config.policy.min_window_jobs, 4);

        // an autoscaler with nothing to steer is a typo
        let mut orphan =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 2, "127.0.0.1", 9620).unwrap();
        orphan.autoscale = m.autoscale;
        let err = orphan.validate().unwrap_err();
        assert!(err.to_string().contains("autoscale"), "{err}");
        // a zero interval would spin the controller
        m.autoscale = Some(AutoscaleSpec {
            interval_ms: 0,
            hysteresis_pct: 10.0,
            strike_threshold: 3,
            cooldown_ticks: 2,
        });
        let err = m.validate().unwrap_err();
        assert!(err.to_string().contains("interval_ms"), "{err}");
        // duplicate autoscale lines are rejected like any identity line
        let err =
            TopologyManifest::parse(&format!("{rendered}autoscale 9 9 9 9\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn topology_pipeline_line_round_trips_and_validates() {
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 1, "127.0.0.1", 9900).unwrap();
        m.pipeline_spec = Some("matmul,truncate:8,matmul".to_string());
        m.validate().unwrap();
        let rendered = m.render();
        assert!(rendered.contains("pipeline matmul,truncate:8,matmul"));
        let back = TopologyManifest::parse(&rendered).unwrap();
        assert_eq!(back.pipeline_spec, m.pipeline_spec);
        let pipe = back.pipeline().unwrap().expect("pipeline resolves");
        assert_eq!(pipe.rounds(), 2);
        // a garbage spec is a typed parse error, not silence
        m.pipeline_spec = Some("matmul,warp:9".to_string());
        assert!(m.validate().is_err());
        // pipelines leave no Byzantine margin
        m.pipeline_spec = Some("matmul,matmul".to_string());
        m.adversary_tolerance = 1;
        let err = m.validate().unwrap_err();
        assert!(err.to_string().contains("adversary_tolerance"), "{err}");
        // the partition must divide the stage size
        m.adversary_tolerance = 0;
        m.m = 9;
        assert!(m.validate().is_err());
        // duplicate pipeline lines are rejected
        let err =
            TopologyManifest::parse(&format!("{rendered}pipeline matmul\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn topology_shape_rate_never_rounds_to_unlimited() {
        let mut m =
            TopologyManifest::template("age", 2, 2, 2, 8, 7, 1, "127.0.0.1", 9500).unwrap();
        m.shapes.push(ShapeLine {
            from: None,
            to: None,
            latency_us: 0,
            rate_bps: 4, // sub-byte bit rate: must shape, not become ∞
            burst_bytes: 0,
            class: None,
        });
        let shaper = m.shaper().expect("shaper built");
        let at = shaper.release_at(
            0,
            1,
            PayloadClass::GShare,
            1024,
            std::time::Instant::now(),
        );
        assert!(at.is_some(), "tiny bit rate was treated as unlimited");
    }
}
