//! Artifact manifest: maps compiled HLO graphs to the shapes they serve.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.txt` with one record
//! per lowered executable:
//!
//! ```text
//! # model  M  K  N  path
//! matmul_mod 128 128 128 matmul_mod_128x128x128.hlo.txt
//! ```
//!
//! A plain line format is used instead of JSON because the offline build has
//! no serde; the format is versioned by the header comment.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{CmpcError, Result};

/// Shape key for a modular matmul artifact: `(M, K, N)`.
pub type MatmulShape = (usize, usize, usize);

/// Parsed artifact manifest.
#[derive(Debug, Default, Clone)]
pub struct Manifest {
    /// matmul_mod artifacts by shape.
    pub matmul: HashMap<MatmulShape, PathBuf>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`; missing file yields an empty manifest
    /// (every shape falls back to native compute).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let mut manifest = Manifest::default();
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(manifest),
            Err(e) => return Err(CmpcError::Io(format!("reading {}: {e}", path.display()))),
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            match fields.as_slice() {
                ["matmul_mod", m, k, n, rel] => {
                    let shape: MatmulShape = (
                        m.parse().map_err(|e| bad_line(lineno, &e))?,
                        k.parse().map_err(|e| bad_line(lineno, &e))?,
                        n.parse().map_err(|e| bad_line(lineno, &e))?,
                    );
                    manifest.matmul.insert(shape, dir.join(rel));
                }
                _ => {
                    return Err(CmpcError::BackendUnavailable(format!(
                        "manifest.txt line {}: unrecognized record {line:?}",
                        lineno + 1
                    )))
                }
            }
        }
        Ok(manifest)
    }

    pub fn matmul_artifact(&self, shape: MatmulShape) -> Option<&PathBuf> {
        self.matmul.get(&shape)
    }
}

fn bad_line(lineno: usize, e: &std::num::ParseIntError) -> CmpcError {
    CmpcError::BackendUnavailable(format!("manifest.txt line {}: {e}", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_records_and_comments() {
        let dir = std::env::temp_dir().join("cmpc_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# model M K N path\nmatmul_mod 128 64 128 a.hlo.txt\n\nmatmul_mod 256 256 256 b.hlo.txt\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.matmul.len(), 2);
        assert_eq!(
            m.matmul_artifact((128, 64, 128)).unwrap(),
            &dir.join("a.hlo.txt")
        );
        assert!(m.matmul_artifact((1, 2, 3)).is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("cmpc_manifest_none");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("manifest.txt")).ok();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.matmul.is_empty());
    }

    #[test]
    fn rejects_garbage_lines() {
        let dir = std::env::temp_dir().join("cmpc_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "bogus record here\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
