//! Dependency-free parallel compute core: a scoped-thread worker pool plus
//! per-worker scratch storage.
//!
//! The protocol's per-job hot path has four CPU-bound stages that are
//! independent across items — Phase-1 share encoding (independent per
//! worker α), the verify-mode `AᵀB` reference product (independent per
//! output row band), Phase-3 reconstruction (independent per output block),
//! and the coordinator's `drain` (independent per job). [`WorkerPool`]
//! parallelizes all four with nothing but `std::thread::scope`:
//!
//! * [`WorkerPool::par_for`] — dynamic (atomic-counter) index scheduling,
//! * [`WorkerPool::par_chunks_mut`] — disjoint `&mut` chunk scheduling
//!   (a `Mutex`-shared `chunks_mut` iterator, so no `unsafe` anywhere),
//! * [`WorkerPool::par_map`] — order-preserving map into a fresh `Vec`.
//!
//! Every closure receives the **worker slot id** (`0..threads`) of the
//! thread running it; [`ScratchPool`] keys its reusable buffers by that id,
//! so two items never contend for one scratch slot and the buffers persist
//! across jobs (allocation happens once at warmup — see the
//! `alloc_discipline` test suite).
//!
//! The pool is deliberately *not* a long-lived thread farm: threads are
//! scoped to each call, which keeps the API safe over borrowed data and
//! makes a 1-thread pool literally sequential (the caller's thread runs
//! every item) — the property the determinism tests pin down.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A sized handle describing how many worker slots parallel sections may
/// use. `threads == 1` runs everything inline on the caller's thread.
#[derive(Debug)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Pool with exactly `threads` worker slots (clamped to ≥ 1).
    pub fn new(threads: usize) -> WorkerPool {
        WorkerPool {
            threads: threads.max(1),
        }
    }

    /// Pool sized from [`std::thread::available_parallelism`].
    pub fn with_default_parallelism() -> WorkerPool {
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        WorkerPool::new(threads)
    }

    /// Process-wide shared pool at default parallelism. Deployments built
    /// with `ProtocolConfig::threads == 0` all share this instance.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(WorkerPool::with_default_parallelism()))
    }

    /// Number of worker slots.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Resolve a `threads` config knob: `0` means the shared
    /// [`WorkerPool::global`] pool at default parallelism, anything else a
    /// dedicated pool of exactly that size.
    pub fn sized_or_global(threads: usize) -> Arc<WorkerPool> {
        if threads == 0 {
            WorkerPool::global().clone()
        } else {
            Arc::new(WorkerPool::new(threads))
        }
    }

    /// Run `f(worker_id, index)` for every `index` in `0..n`, distributing
    /// indices dynamically in chunks of `grain`. `worker_id < threads` is
    /// stable for the duration of one call and indexes [`ScratchPool`]
    /// slots without contention.
    pub fn par_for<F>(&self, n: usize, grain: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        let grain = grain.max(1);
        let n_tasks = n.div_ceil(grain);
        let workers = self.threads.min(n_tasks).max(1);
        if workers == 1 {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let run = |wid: usize| loop {
            let start = next.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + grain).min(n);
            for i in start..end {
                f(wid, i);
            }
        };
        std::thread::scope(|s| {
            for wid in 1..workers {
                let run = &run;
                s.spawn(move || run(wid));
            }
            run(0);
        });
    }

    /// Run `f(worker_id, chunk_index, chunk)` over disjoint mutable chunks
    /// of `data`, `chunk_len` elements each (the last may be shorter).
    /// Chunks are handed out dynamically through a shared iterator, so no
    /// `unsafe` is needed for the disjoint `&mut` access.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let n_chunks = data.len().div_ceil(chunk_len);
        let workers = self.threads.min(n_chunks).max(1);
        if workers == 1 {
            for (i, c) in data.chunks_mut(chunk_len).enumerate() {
                f(0, i, c);
            }
            return;
        }
        let it = Mutex::new(data.chunks_mut(chunk_len).enumerate());
        let run = |wid: usize| loop {
            let item = it.lock().unwrap().next();
            match item {
                Some((i, c)) => f(wid, i, c),
                None => break,
            }
        };
        std::thread::scope(|s| {
            for wid in 1..workers {
                let run = &run;
                s.spawn(move || run(wid));
            }
            run(0);
        });
    }

    /// Map every item of `items` through `f(worker_id, index, item)`,
    /// preserving order. With one worker slot this is a plain sequential
    /// map on the caller's thread.
    pub fn par_map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, usize, &T) -> R + Sync,
    {
        let workers = self.threads.min(items.len()).max(1);
        if workers == 1 {
            return items.iter().enumerate().map(|(i, t)| f(0, i, t)).collect();
        }
        let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        {
            let it = Mutex::new(out.chunks_mut(1).enumerate());
            let run = |wid: usize| loop {
                let item = it.lock().unwrap().next();
                match item {
                    Some((i, slot)) => slot[0] = Some(f(wid, i, &items[i])),
                    None => break,
                }
            };
            std::thread::scope(|s| {
                for wid in 1..workers {
                    let run = &run;
                    s.spawn(move || run(wid));
                }
                run(0);
            });
        }
        out.into_iter()
            .map(|o| o.expect("par_map: every slot filled"))
            .collect()
    }
}

/// Reusable per-worker buffers for the delayed-reduction kernels.
///
/// `acc` holds unreduced `u64` partial sums; `powers` holds a share point's
/// precomputed power table `α^e` over a polynomial support. Both grow to
/// their steady-state capacity on first use and are only `clear()`ed after
/// that, so the kernels they back allocate nothing in steady state.
#[derive(Default, Debug)]
pub struct Scratch {
    /// Unreduced accumulator row (matmul, weighted sums).
    pub acc: Vec<u64>,
    /// Power table `α^{e}` for `e` over a polynomial support.
    pub powers: Vec<u64>,
}

/// A scratch slot padded out to its own cache line (128 bytes covers the
/// adjacent-line prefetcher on x86): neighboring slots' `Mutex` state
/// words never share a line, so two workers locking adjacent slots under
/// heavy cross-job drain stop bouncing one line between cores.
#[repr(align(128))]
#[derive(Debug, Default)]
struct PaddedSlot(Mutex<Scratch>);

/// One [`Scratch`] per pool worker slot, indexed by the `worker_id` the
/// pool primitives pass to their closures.
///
/// Slots are cache-line padded ([`PaddedSlot`]) and [`ScratchPool::with`]
/// *probes* rather than blocks: a worker whose home slot is held by a
/// concurrent job takes any other free slot instead of queueing. This is
/// sound because every kernel clears/resizes the buffers before use — a
/// scratch slot carries capacity, never data, between borrows.
#[derive(Debug)]
pub struct ScratchPool {
    slots: Vec<PaddedSlot>,
}

impl ScratchPool {
    /// `slots` independent scratch buffers (clamped to ≥ 1).
    pub fn new(slots: usize) -> ScratchPool {
        ScratchPool {
            slots: (0..slots.max(1)).map(|_| PaddedSlot::default()).collect(),
        }
    }

    /// One slot per worker of `pool` — the pairing used on the job path.
    pub fn for_pool(pool: &WorkerPool) -> ScratchPool {
        ScratchPool::new(pool.threads())
    }

    /// Number of independent scratch slots.
    pub fn slots(&self) -> usize {
        self.slots.len()
    }

    /// Borrow worker `wid`'s scratch for the duration of `f`. Indices wrap,
    /// so any `wid` is safe; pool-provided worker ids never contend within
    /// one parallel section. When a *different* job's section holds the
    /// home slot, the borrow probes the remaining slots for a free one and
    /// only blocks when every slot is busy — cross-job contention costs a
    /// failed `try_lock`, not a queue wait.
    pub fn with<R>(&self, wid: usize, f: impl FnOnce(&mut Scratch) -> R) -> R {
        let n = self.slots.len();
        let home = wid % n;
        if let Ok(mut guard) = self.slots[home].0.try_lock() {
            return f(&mut guard);
        }
        for off in 1..n {
            if let Ok(mut guard) = self.slots[(home + off) % n].0.try_lock() {
                return f(&mut guard);
            }
        }
        let mut guard = self.slots[home].0.lock().unwrap();
        f(&mut guard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_every_index_once() {
        for threads in [1, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            let n = 103;
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.par_for(n, 4, |_wid, i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn par_chunks_mut_covers_disjointly() {
        for threads in [1, 3, 8] {
            let pool = WorkerPool::new(threads);
            let mut data = vec![0u32; 257];
            pool.par_chunks_mut(&mut data, 10, |_wid, idx, chunk| {
                for (k, v) in chunk.iter_mut().enumerate() {
                    *v = (idx * 10 + k) as u32 + 1;
                }
            });
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, i as u32 + 1, "{threads} threads");
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..200).collect();
        for threads in [1, 2, 5] {
            let pool = WorkerPool::new(threads);
            let out = pool.par_map(&items, |_wid, i, &x| x * 2 + i as u64);
            let expect: Vec<u64> = (0..200).map(|x| x * 3).collect();
            assert_eq!(out, expect, "{threads} threads");
        }
    }

    #[test]
    fn worker_ids_stay_in_range() {
        let pool = WorkerPool::new(3);
        let max_wid = AtomicUsize::new(0);
        pool.par_for(64, 1, |wid, _i| {
            max_wid.fetch_max(wid, Ordering::Relaxed);
        });
        assert!(max_wid.load(Ordering::Relaxed) < 3);
    }

    #[test]
    fn empty_inputs_are_noops() {
        let pool = WorkerPool::new(4);
        pool.par_for(0, 1, |_, _| panic!("no items"));
        let mut empty: [u32; 0] = [];
        pool.par_chunks_mut(&mut empty, 5, |_, _, _| panic!("no chunks"));
        let out: Vec<u32> = pool.par_map(&[] as &[u32], |_, _, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scratch_slots_persist_capacity() {
        let scratch = ScratchPool::new(2);
        scratch.with(0, |s| {
            s.acc.resize(1024, 0);
        });
        let cap = scratch.with(0, |s| {
            s.acc.clear();
            s.acc.capacity()
        });
        assert!(cap >= 1024);
        assert_eq!(scratch.slots(), 2);
    }

    /// A held home slot must not block a concurrent borrower: the probe
    /// hands out any free slot instead (the cross-job drain contract).
    #[test]
    fn contended_home_slot_is_dodged_not_queued() {
        let scratch = ScratchPool::new(2);
        scratch.with(1, |s| s.acc.resize(77, 0)); // mark slot 1
        // Hold slot 0 for the whole test…
        let guard = scratch.slots[0].0.lock().unwrap();
        // …and borrow "slot 0" from another thread: it must complete by
        // probing onto slot 1 rather than deadlocking on the held mutex.
        std::thread::scope(|s| {
            let h = s.spawn(|| scratch.with(0, |sc| sc.acc.capacity()));
            let cap = h.join().unwrap();
            assert!(cap >= 77, "probe took the free slot, not the held one");
        });
        drop(guard);
    }

    #[test]
    fn pool_clamps_to_one_thread() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert!(WorkerPool::global().threads() >= 1);
    }
}
