//! Artifact executor service: loads the AOT-lowered L2 graphs and serves
//! worker matmul requests from dedicated executor lanes.
//!
//! Interchange is **HLO text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that older XLA runtimes reject, while text
//! round-trips cleanly. `python/compile/aot.py` lowers the L2 graph
//! `H = F_A·F_B mod p` once per shape and records it in
//! `artifacts/manifest.txt`.
//!
//! **Offline substitution.** The build environment vendors no XLA FFI crate,
//! so this service cannot hand the artifact to a real PJRT client. It keeps
//! the full deployment topology honest instead: per-shape artifacts are
//! *loaded, validated, and cached* exactly once per executor lane
//! ("compilation"), requests for covered shapes are served through that
//! cache (`pjrt_calls`), uncovered shapes fall back to native compute
//! (`native_fallback_calls`), and the arithmetic itself runs the same
//! delayed-reduction kernel the artifact encodes. Swapping `execute_artifact`
//! for a real `xla::PjRtLoadedExecutable::execute` is the only change needed
//! when an XLA runtime is vendored; every cache/stats/threading contract
//! stays as-is.
//!
//! Threading: each executor lane owns its artifact cache on one thread;
//! worker threads talk to it through an mpsc request channel
//! ([`PjrtBackend`]). Compiled artifacts are cached per shape for the
//! lifetime of the service (100 % steady-state hit rate — loading happens
//! once per model variant, matching the AOT deployment story).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::runtime::manifest::{Manifest, MatmulShape};
use crate::runtime::{MatmulBackend, NativeBackend};

enum Request {
    Matmul {
        a: FpMat,
        b: FpMat,
        reply: Sender<Result<FpMat>>,
    },
    Shutdown,
}

/// Execution statistics for the service (observable by tests/benches).
#[derive(Default, Debug)]
pub struct PjrtStats {
    /// Requests served through a loaded artifact.
    pub pjrt_calls: AtomicU64,
    /// Requests served by the native fallback (no artifact for the shape).
    pub native_fallback_calls: AtomicU64,
    /// Artifact loads performed (should equal #distinct shapes used).
    pub compilations: AtomicU64,
}

/// Handle to the executor pool; cheap to clone into worker threads.
///
/// §Perf P2: a single executor thread serializes every worker's Phase-2
/// matmul (N per job). The service therefore runs a small pool of executor
/// lanes — each with its own artifact cache — and deals requests
/// round-robin, modelling an edge site with a few shared accelerator queues.
pub struct PjrtService {
    lanes: Vec<Sender<Request>>,
    next_lane: std::sync::atomic::AtomicUsize,
    joins: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<PjrtStats>,
}

/// Default executor lanes: enough to overlap compute without oversubscribing
/// the CPU that also hosts the worker threads.
fn default_lanes() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get() / 2)
        .unwrap_or(2)
        .clamp(1, 4)
}

impl PjrtService {
    /// Start the executor pool over an artifact directory.
    pub fn start(artifacts_dir: PathBuf) -> Result<PjrtService> {
        Self::start_with_lanes(artifacts_dir, default_lanes())
    }

    /// Start with an explicit number of executor lanes.
    pub fn start_with_lanes(artifacts_dir: PathBuf, lanes: usize) -> Result<PjrtService> {
        if lanes < 1 {
            return Err(CmpcError::InvalidParams(
                "executor service needs at least one lane".to_string(),
            ));
        }
        let manifest = Manifest::load(&artifacts_dir)?;
        let stats = Arc::new(PjrtStats::default());
        let mut txs = Vec::with_capacity(lanes);
        let mut joins = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let (tx, rx) = channel::<Request>();
            let stats2 = stats.clone();
            let manifest2 = manifest.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-executor-{lane}"))
                    .spawn(move || executor_main(rx, manifest2, stats2))
                    .map_err(|e| {
                        CmpcError::BackendUnavailable(format!("spawn executor lane {lane}: {e}"))
                    })?,
            );
            txs.push(tx);
        }
        Ok(PjrtService {
            lanes: txs,
            next_lane: std::sync::atomic::AtomicUsize::new(0),
            joins,
            stats,
        })
    }

    /// A backend handle for one worker (pinned to a lane round-robin, so a
    /// worker's shapes compile in one lane's cache).
    pub fn handle(&self) -> PjrtBackend {
        let lane = self.next_lane.fetch_add(1, Ordering::Relaxed) % self.lanes.len();
        PjrtBackend {
            tx: self.lanes[lane].clone(),
        }
    }

    /// Service-wide execution/cache counters.
    pub fn stats(&self) -> &PjrtStats {
        &self.stats
    }

    /// Number of executor lanes the service started with.
    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        for tx in &self.lanes {
            let _ = tx.send(Request::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Worker-side handle implementing [`MatmulBackend`] via the service.
pub struct PjrtBackend {
    tx: Sender<Request>,
}

impl MatmulBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> Result<FpMat> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Matmul {
                a: a.clone(),
                b: b.clone(),
                reply,
            })
            .map_err(|_| {
                CmpcError::BackendUnavailable("executor thread gone".to_string())
            })?;
        rx.recv().map_err(|_| {
            CmpcError::BackendUnavailable("executor dropped reply".to_string())
        })?
    }
}

/// A loaded (validated, memory-resident) artifact for one matmul shape.
struct LoadedArtifact {
    /// HLO text kept resident for the lane's lifetime, like a compiled
    /// executable would be.
    #[allow(dead_code)]
    hlo_text: String,
}

fn executor_main(rx: Receiver<Request>, manifest: Manifest, stats: Arc<PjrtStats>) {
    // The artifact cache never leaves this thread.
    let mut cache: HashMap<MatmulShape, LoadedArtifact> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Matmul { a, b, reply } => {
                let shape: MatmulShape = (a.rows, a.cols, b.cols);
                let result = match manifest.matmul_artifact(shape) {
                    None => {
                        stats.native_fallback_calls.fetch_add(1, Ordering::Relaxed);
                        NativeBackend.matmul_mod(&a, &b)
                    }
                    Some(path) => {
                        let loaded = match cache.entry(shape) {
                            std::collections::hash_map::Entry::Occupied(e) => Ok(e.into_mut()),
                            std::collections::hash_map::Entry::Vacant(v) => {
                                load_artifact(path).map(|art| {
                                    stats.compilations.fetch_add(1, Ordering::Relaxed);
                                    v.insert(art)
                                })
                            }
                        };
                        loaded.and_then(|art| {
                            stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                            execute_artifact(art, &a, &b)
                        })
                    }
                };
                let _ = reply.send(result);
            }
        }
    }
}

/// Load and validate one HLO text artifact ("compilation").
fn load_artifact(path: &std::path::Path) -> Result<LoadedArtifact> {
    let hlo_text = std::fs::read_to_string(path)
        .map_err(|e| CmpcError::BackendUnavailable(format!("read {}: {e}", path.display())))?;
    if !hlo_text.contains("HloModule") {
        return Err(CmpcError::BackendUnavailable(format!(
            "{} is not an HLO text artifact",
            path.display()
        )));
    }
    Ok(LoadedArtifact { hlo_text })
}

/// Run one request through a loaded artifact. The arithmetic is the same
/// i64-accumulate/fold-reduce program the artifact encodes; see the module
/// docs for the offline-substitution contract.
fn execute_artifact(_artifact: &LoadedArtifact, a: &FpMat, b: &FpMat) -> Result<FpMat> {
    NativeBackend.matmul_mod(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;

    fn write_artifact_dir(tag: &str, shapes: &[MatmulShape]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("cmpc_pjrt_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut manifest = String::from("# model M K N path\n");
        for &(m, k, n) in shapes {
            let rel = format!("matmul_mod_{m}x{k}x{n}.hlo.txt");
            std::fs::write(
                dir.join(&rel),
                format!("HloModule matmul_mod_{m}x{k}x{n}\nROOT stub\n"),
            )
            .unwrap();
            manifest.push_str(&format!("matmul_mod {m} {k} {n} {rel}\n"));
        }
        std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
        dir
    }

    #[test]
    fn covered_shape_served_through_artifact_cache() {
        let dir = write_artifact_dir("covered", &[(8, 8, 8)]);
        let svc = PjrtService::start_with_lanes(dir.clone(), 1).unwrap();
        let mut be = svc.handle();
        let mut rng = ChaChaRng::seed_from_u64(1);
        for _ in 0..4 {
            let a = FpMat::random(&mut rng, 8, 8);
            let b = FpMat::random(&mut rng, 8, 8);
            assert_eq!(be.matmul_mod(&a, &b).unwrap(), a.matmul(&b));
        }
        assert_eq!(svc.stats().pjrt_calls.load(Ordering::Relaxed), 4);
        assert_eq!(svc.stats().compilations.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().native_fallback_calls.load(Ordering::Relaxed), 0);
        drop(svc);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn uncovered_shape_falls_back_to_native() {
        let dir = write_artifact_dir("fallback", &[(8, 8, 8)]);
        let svc = PjrtService::start_with_lanes(dir.clone(), 1).unwrap();
        let mut be = svc.handle();
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 5, 7);
        let b = FpMat::random(&mut rng, 7, 3);
        assert_eq!(be.matmul_mod(&a, &b).unwrap(), a.matmul(&b));
        assert_eq!(svc.stats().native_fallback_calls.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().pjrt_calls.load(Ordering::Relaxed), 0);
        drop(svc);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_artifact_file_is_backend_unavailable() {
        let dir = std::env::temp_dir().join("cmpc_pjrt_missing");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "matmul_mod 4 4 4 gone.hlo.txt\n").unwrap();
        let svc = PjrtService::start_with_lanes(dir.clone(), 1).unwrap();
        let mut be = svc.handle();
        let mut rng = ChaChaRng::seed_from_u64(3);
        let a = FpMat::random(&mut rng, 4, 4);
        let b = FpMat::random(&mut rng, 4, 4);
        let err = be.matmul_mod(&a, &b).unwrap_err();
        assert!(matches!(err, CmpcError::BackendUnavailable(_)));
        drop(svc);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn zero_lanes_rejected() {
        let err = PjrtService::start_with_lanes(std::env::temp_dir(), 0).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }
}
