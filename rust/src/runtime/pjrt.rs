//! PJRT executor service: loads the AOT-lowered L2 graphs and runs them on
//! the XLA CPU client.
//!
//! Interchange is **HLO text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see `/opt/xla-example/README.md`). The graph was
//! lowered with `return_tuple=True`, so results unwrap via `to_tuple1`.
//!
//! Threading: `xla::PjRtClient` lives entirely on one executor thread;
//! worker threads talk to it through an mpsc request channel
//! ([`PjrtBackend`]). Compiled executables are cached per shape for the
//! lifetime of the service (100 % steady-state hit rate — compilation
//! happens once per model variant, matching the AOT deployment story).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::ff::P;
use crate::matrix::FpMat;
use crate::runtime::manifest::{Manifest, MatmulShape};
use crate::runtime::MatmulBackend;

enum Request {
    Matmul {
        a: FpMat,
        b: FpMat,
        reply: Sender<anyhow::Result<FpMat>>,
    },
    Shutdown,
}

/// Execution statistics for the service (observable by tests/benches).
#[derive(Default, Debug)]
pub struct PjrtStats {
    /// Requests served by a compiled PJRT executable.
    pub pjrt_calls: AtomicU64,
    /// Requests served by the native fallback (no artifact for the shape).
    pub native_fallback_calls: AtomicU64,
    /// Artifact compilations performed (should equal #distinct shapes used).
    pub compilations: AtomicU64,
}

/// Handle to the executor pool; cheap to clone into worker threads.
///
/// §Perf P2: a single executor thread serializes every worker's Phase-2
/// matmul (N per job). The service therefore runs a small pool of executor
/// lanes — each with its own PJRT client and executable cache — and deals
/// requests round-robin, modelling an edge site with a few shared
/// accelerator queues.
pub struct PjrtService {
    lanes: Vec<Sender<Request>>,
    next_lane: std::sync::atomic::AtomicUsize,
    joins: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<PjrtStats>,
}

/// Default executor lanes: enough to overlap compute without oversubscribing
/// the CPU that also hosts the worker threads.
fn default_lanes() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get() / 2)
        .unwrap_or(2)
        .clamp(1, 4)
}

impl PjrtService {
    /// Start the executor pool over an artifact directory.
    pub fn start(artifacts_dir: PathBuf) -> anyhow::Result<PjrtService> {
        Self::start_with_lanes(artifacts_dir, default_lanes())
    }

    /// Start with an explicit number of executor lanes.
    pub fn start_with_lanes(artifacts_dir: PathBuf, lanes: usize) -> anyhow::Result<PjrtService> {
        assert!(lanes >= 1);
        let manifest = Manifest::load(&artifacts_dir)?;
        let stats = Arc::new(PjrtStats::default());
        let mut txs = Vec::with_capacity(lanes);
        let mut joins = Vec::with_capacity(lanes);
        for lane in 0..lanes {
            let (tx, rx) = channel::<Request>();
            let stats2 = stats.clone();
            let manifest2 = manifest.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-executor-{lane}"))
                    .spawn(move || executor_main(rx, manifest2, stats2))
                    .expect("spawn pjrt executor"),
            );
            txs.push(tx);
        }
        Ok(PjrtService {
            lanes: txs,
            next_lane: std::sync::atomic::AtomicUsize::new(0),
            joins,
            stats,
        })
    }

    /// A backend handle for one worker (pinned to a lane round-robin, so a
    /// worker's shapes compile in one lane's cache).
    pub fn handle(&self) -> PjrtBackend {
        let lane = self.next_lane.fetch_add(1, Ordering::Relaxed) % self.lanes.len();
        PjrtBackend {
            tx: self.lanes[lane].clone(),
        }
    }

    pub fn stats(&self) -> &PjrtStats {
        &self.stats
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }
}

impl Drop for PjrtService {
    fn drop(&mut self) {
        for tx in &self.lanes {
            let _ = tx.send(Request::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

/// Worker-side handle implementing [`MatmulBackend`] via the service.
pub struct PjrtBackend {
    tx: Sender<Request>,
}

impl MatmulBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> anyhow::Result<FpMat> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Matmul {
                a: a.clone(),
                b: b.clone(),
                reply,
            })
            .map_err(|_| anyhow::anyhow!("pjrt executor thread gone"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("pjrt executor dropped reply"))?
    }
}

fn executor_main(rx: Receiver<Request>, manifest: Manifest, stats: Arc<PjrtStats>) {
    // The client and executable cache never leave this thread.
    let client = xla::PjRtClient::cpu().expect("create PJRT CPU client");
    let mut cache: HashMap<MatmulShape, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Matmul { a, b, reply } => {
                let shape: MatmulShape = (a.rows, a.cols, b.cols);
                let result = match manifest.matmul_artifact(shape) {
                    None => {
                        stats.native_fallback_calls.fetch_add(1, Ordering::Relaxed);
                        Ok(a.matmul(&b))
                    }
                    Some(path) => {
                        let exe = match cache.entry(shape) {
                            std::collections::hash_map::Entry::Occupied(e) => Ok(e.into_mut()),
                            std::collections::hash_map::Entry::Vacant(v) => {
                                compile_artifact(&client, path).map(|e| {
                                    stats.compilations.fetch_add(1, Ordering::Relaxed);
                                    v.insert(e)
                                })
                            }
                        };
                        exe.and_then(|exe| {
                            stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                            execute_matmul(exe, &a, &b)
                        })
                    }
                };
                let _ = reply.send(result);
            }
        }
    }
}

fn compile_artifact(
    client: &xla::PjRtClient,
    path: &std::path::Path,
) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
    )
    .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
    }

fn execute_matmul(
    exe: &xla::PjRtLoadedExecutable,
    a: &FpMat,
    b: &FpMat,
) -> anyhow::Result<FpMat> {
    let lit_a = to_i64_literal(a)?;
    let lit_b = to_i64_literal(b)?;
    let result = exe
        .execute::<xla::Literal>(&[lit_a, lit_b])
        .map_err(|e| anyhow::anyhow!("pjrt execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("pjrt fetch: {e:?}"))?;
    // The L2 graph is lowered with return_tuple=True → 1-tuple.
    let out = result
        .to_tuple1()
        .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
    let values = out
        .to_vec::<i64>()
        .map_err(|e| anyhow::anyhow!("to_vec<i64>: {e:?}"))?;
    anyhow::ensure!(
        values.len() == a.rows * b.cols,
        "artifact returned {} values, expected {}",
        values.len(),
        a.rows * b.cols
    );
    let mut m = FpMat::zeros(a.rows, b.cols);
    for (dst, &v) in m.data.iter_mut().zip(values.iter()) {
        anyhow::ensure!(
            (0..P as i64).contains(&v),
            "artifact returned out-of-field value {v}"
        );
        *dst = v as u32;
    }
    Ok(m)
}

fn to_i64_literal(m: &FpMat) -> anyhow::Result<xla::Literal> {
    let vals: Vec<i64> = m.data.iter().map(|&v| v as i64).collect();
    xla::Literal::vec1(&vals)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow::anyhow!("literal reshape: {e:?}"))
}
