//! Compute backends for the worker hot path `H(αₙ) = F_A(αₙ)·F_B(αₙ) mod p`.
//!
//! Two implementations of [`MatmulBackend`]:
//!
//! * [`NativeBackend`] — the cache-blocked Rust matmul from
//!   [`crate::matrix`]; always available.
//! * [`pjrt::PjrtBackend`] — executes the AOT-compiled L2 graph
//!   (`artifacts/*.hlo.txt`, produced once by `make artifacts` from the JAX
//!   model that calls the L1 Pallas kernel) on the PJRT CPU client via the
//!   `xla` crate. Artifacts are shape-specialized; requests for shapes
//!   without an artifact fall back to native and are recorded.
//!
//! The PJRT client is not thread-safe to share, so [`pjrt::PjrtService`]
//! runs it on a dedicated executor thread; workers hold cheap cloneable
//! [`pjrt::PjrtBackend`] channel handles — the same "accelerator service"
//! topology a real edge worker with one attached accelerator would use.

pub mod manifest;
pub mod pjrt;

use crate::matrix::FpMat;

/// A modular-matmul compute engine used by Phase 2 workers.
pub trait MatmulBackend: Send {
    fn name(&self) -> &'static str;

    /// `(a · b) mod p`.
    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> anyhow::Result<FpMat>;
}

/// Pure-Rust backend (delayed-reduction blocked matmul).
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeBackend;

impl MatmulBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> anyhow::Result<FpMat> {
        Ok(a.matmul(b))
    }
}

/// How the protocol should obtain per-worker backends.
#[derive(Clone, Debug, Default)]
pub enum BackendChoice {
    /// Native Rust matmul in every worker.
    #[default]
    Native,
    /// Shared PJRT executor service loaded from an artifact directory
    /// (falls back to native per shape when no artifact matches).
    Pjrt {
        artifacts_dir: std::path::PathBuf,
    },
}

/// Factory producing one backend handle per worker thread.
pub enum BackendFactory {
    Native,
    Pjrt(pjrt::PjrtService),
}

impl BackendFactory {
    pub fn new(choice: &BackendChoice) -> anyhow::Result<BackendFactory> {
        Ok(match choice {
            BackendChoice::Native => BackendFactory::Native,
            BackendChoice::Pjrt { artifacts_dir } => {
                BackendFactory::Pjrt(pjrt::PjrtService::start(artifacts_dir.clone())?)
            }
        })
    }

    pub fn make(&self) -> Box<dyn MatmulBackend> {
        match self {
            BackendFactory::Native => Box::new(NativeBackend),
            BackendFactory::Pjrt(svc) => Box::new(svc.handle()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;

    #[test]
    fn native_backend_matches_matrix_matmul() {
        let mut rng = ChaChaRng::seed_from_u64(1);
        let a = FpMat::random(&mut rng, 7, 5);
        let b = FpMat::random(&mut rng, 5, 9);
        let mut be = NativeBackend;
        assert_eq!(be.matmul_mod(&a, &b).unwrap(), a.matmul(&b));
    }
}
