//! Compute backends for the worker hot path `H(αₙ) = F_A(αₙ)·F_B(αₙ) mod p`.
//!
//! Two implementations of [`MatmulBackend`]:
//!
//! * [`NativeBackend`] — the cache-blocked Rust matmul from
//!   [`crate::matrix`]; always available.
//! * [`pjrt::PjrtBackend`] — handles into the artifact executor service
//!   ([`pjrt::PjrtService`]), which serves shapes covered by the AOT-lowered
//!   L2 graphs (`artifacts/*.hlo.txt`, produced once by `make artifacts`
//!   from the JAX model that calls the L1 Pallas kernel). Shapes without an
//!   artifact fall back to native and are recorded in the service stats.
//!
//! The executor service runs on dedicated lanes (threads); workers hold
//! cheap cloneable channel handles — the "accelerator service" topology a
//! real edge worker with one attached accelerator would use. The offline
//! build vendors no XLA FFI crate, so the executor *validates and caches*
//! each artifact once per shape and runs the arithmetic with the native
//! kernel; see [`pjrt`] for the exact substitution story.

pub mod manifest;
pub mod pjrt;
pub mod pool;

pub use pool::{Scratch, ScratchPool, WorkerPool};

use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;

/// A modular-matmul compute engine used by Phase 2 workers.
pub trait MatmulBackend: Send {
    /// Short backend identifier (e.g. `"native"`), for logs and reports.
    fn name(&self) -> &'static str;

    /// `(a · b) mod p`.
    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> Result<FpMat>;
}

/// Pure-Rust backend (delayed-reduction blocked matmul).
#[derive(Default, Clone, Copy, Debug)]
pub struct NativeBackend;

/// Scalar multiply–adds (`rows · inner · cols`) above which the native
/// backend fans the product across the process-global [`WorkerPool`].
/// Below it — every per-worker `H(αₙ)` block product in a provisioned
/// deployment, where N workers already run concurrently — the scoped
/// spawn overhead (~10µs/section) exceeds the win and the sequential
/// kernel runs on the caller's thread. The parallel path matters in the
/// single-huge-job regime (one worker thread, one big product).
const PAR_MATMUL_THRESHOLD: u64 = 1 << 18;

impl MatmulBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn matmul_mod(&mut self, a: &FpMat, b: &FpMat) -> Result<FpMat> {
        if a.cols != b.rows {
            return Err(CmpcError::ShapeMismatch(format!(
                "matmul inner dimensions disagree: {}x{} · {}x{}",
                a.rows, a.cols, b.rows, b.cols
            )));
        }
        let work = a.rows as u64 * a.cols as u64 * b.cols as u64;
        if work >= PAR_MATMUL_THRESHOLD {
            // Big products go wide over the shared pool; byte-identical
            // to the sequential kernel (same per-row delayed-reduction
            // fold, pinned by `matmul_into_and_parallel_match_schoolbook`
            // and the backend test below).
            static SCRATCH: std::sync::OnceLock<ScratchPool> = std::sync::OnceLock::new();
            let pool = WorkerPool::global();
            let scratch = SCRATCH.get_or_init(|| ScratchPool::for_pool(pool));
            let mut out = FpMat::zeros(0, 0);
            a.par_matmul_into(b, &mut out, pool, scratch);
            Ok(out)
        } else {
            Ok(a.matmul(b))
        }
    }
}

/// How the protocol should obtain per-worker backends.
#[derive(Clone, Debug, Default)]
pub enum BackendChoice {
    /// Native Rust matmul in every worker.
    #[default]
    Native,
    /// Shared artifact executor service loaded from an artifact directory
    /// (falls back to native per shape when no artifact matches).
    Pjrt {
        /// Directory of AOT artifacts (`make artifacts`).
        artifacts_dir: std::path::PathBuf,
    },
}

/// Factory producing one backend handle per worker thread.
pub enum BackendFactory {
    /// Hand out [`NativeBackend`] instances.
    Native,
    /// Hand out lanes of a shared artifact executor service.
    Pjrt(pjrt::PjrtService),
}

impl BackendFactory {
    /// Resolve a [`BackendChoice`] (starting the executor service for
    /// [`BackendChoice::Pjrt`]).
    pub fn new(choice: &BackendChoice) -> Result<BackendFactory> {
        Ok(match choice {
            BackendChoice::Native => BackendFactory::Native,
            BackendChoice::Pjrt { artifacts_dir } => {
                BackendFactory::Pjrt(pjrt::PjrtService::start(artifacts_dir.clone())?)
            }
        })
    }

    /// Mint one backend handle (called per worker thread, and per respawn).
    pub fn make(&self) -> Box<dyn MatmulBackend> {
        match self {
            BackendFactory::Native => Box::new(NativeBackend),
            BackendFactory::Pjrt(svc) => Box::new(svc.handle()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;

    #[test]
    fn native_backend_matches_matrix_matmul() {
        let mut rng = ChaChaRng::seed_from_u64(1);
        let a = FpMat::random(&mut rng, 7, 5);
        let b = FpMat::random(&mut rng, 5, 9);
        let mut be = NativeBackend;
        assert_eq!(be.matmul_mod(&a, &b).unwrap(), a.matmul(&b));
    }

    /// A product big enough to cross [`PAR_MATMUL_THRESHOLD`] must still
    /// be byte-identical to the sequential kernel.
    #[test]
    fn native_backend_parallel_path_matches_sequential() {
        let mut rng = ChaChaRng::seed_from_u64(3);
        let n = 72; // 72³ = 373248 ≥ 2¹⁸: takes the pooled path
        assert!((n as u64).pow(3) >= PAR_MATMUL_THRESHOLD);
        let a = FpMat::random(&mut rng, n, n);
        let b = FpMat::random(&mut rng, n, n);
        assert_eq!(NativeBackend.matmul_mod(&a, &b).unwrap(), a.matmul(&b));
    }

    #[test]
    fn native_backend_rejects_bad_inner_dims() {
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 4, 5);
        let b = FpMat::random(&mut rng, 6, 3);
        let err = NativeBackend.matmul_mod(&a, &b).unwrap_err();
        assert!(matches!(err, CmpcError::ShapeMismatch(_)));
    }
}
