//! Per-worker overhead model — Corollaries 10, 11, 12.
//!
//! All three overheads apply to *any* of the coded MPC methods (Entangled,
//! PolyDot, AGE, and the batch baselines at batch 1): the phases are
//! identical, so the scheme enters only through its worker count `N`.
//!
//! * computation ξ (eq. 32) — scalar multiplications per worker,
//! * storage σ (eq. 33) — scalars resident per worker,
//! * communication ζ (eq. 34) — scalars exchanged between workers in Phase 2.
//!
//! Counts are exact integers (`u128`): the divisibility conditions `s|m`,
//! `t|m` make every division integral.

/// Computation overhead per worker (eq. 32):
/// `ξ = m³/(st²) + m² + N(t²+z−1)·m²/t²` scalar multiplications.
///
/// Terms: the `F_A(αₙ)·F_B(αₙ)` product, the `rₙ^{(i,l)}·H(αₙ)` scaling, and
/// evaluating `Gₙ` at all `N` peer points.
pub fn computation_overhead(m: usize, s: usize, t: usize, z: usize, n: u64) -> u128 {
    assert!(m % s == 0 && m % t == 0, "need s|m and t|m");
    let (m, s, t, z, n) = (m as u128, s as u128, t as u128, z as u128, n as u128);
    let block = (m / t) * (m / t);
    (m / s) * (m / t) * (m / t) + m * m + n * (t * t + z - 1) * block
}

/// Storage overhead per worker (eq. 33):
/// `σ = (2N+z+1)·m²/t² + 2m²/(st) + t²` stored scalars.
///
/// Terms: received/produced `Gₙ` shares and `H(αₙ)`/`I(αₙ)` blocks, the two
/// input shares `F_A(αₙ), F_B(αₙ)`, and the `t²` Lagrange coefficients.
pub fn storage_overhead(m: usize, s: usize, t: usize, z: usize, n: u64) -> u128 {
    assert!(m % s == 0 && m % t == 0, "need s|m and t|m");
    let (m, s, t, z, n) = (m as u128, s as u128, t as u128, z as u128, n as u128);
    let block = (m / t) * (m / t);
    (2 * n + z + 1) * block + 2 * (m / s) * (m / t) + t * t
}

/// Communication overhead among workers (eq. 34):
/// `ζ = N(N−1)·m²/t²` scalars exchanged in Phase 2 (each worker sends its
/// `Gₙ(αₙ')` block to every peer).
pub fn communication_overhead(m: usize, t: usize, n: u64) -> u128 {
    assert!(m % t == 0, "need t|m");
    let (m, t, n) = (m as u128, t as u128, n as u128);
    n * (n - 1) * (m / t) * (m / t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_parameter_sanity() {
        // Fig. 4 parameters: m = 36000, st = 36, z = 42. Spot-check one pair
        // (s,t) = (6,6) against a hand-expanded eq. (32)–(34).
        let (m, s, t, z) = (36000usize, 6usize, 6usize, 42usize);
        let n = 200u64; // arbitrary N for the identity check
        let block = (36000u128 / 6) * (36000 / 6); // 6000² = 3.6e7
        assert_eq!(
            computation_overhead(m, s, t, z, n),
            (36000u128 / 6) * block + 36000u128 * 36000 + 200 * (36 + 42 - 1) * block
        );
        assert_eq!(
            storage_overhead(m, s, t, z, n),
            (2 * 200 + 42 + 1) * block + 2 * (36000u128 / 6) * (36000 / 6) + 36
        );
        assert_eq!(communication_overhead(m, t, n), 200 * 199 * block);
    }

    #[test]
    fn overheads_monotone_in_n() {
        // All three overheads grow with N — the mechanism by which AGE's
        // smaller worker count wins Figs. 4(a)–(c).
        let (m, s, t, z) = (3600, 4, 9, 42);
        for n in [100u64, 200, 400] {
            assert!(computation_overhead(m, s, t, z, n) < computation_overhead(m, s, t, z, n + 1));
            assert!(storage_overhead(m, s, t, z, n) < storage_overhead(m, s, t, z, n + 1));
            assert!(communication_overhead(m, t, n) < communication_overhead(m, t, n + 1));
        }
    }

    #[test]
    #[should_panic(expected = "need s|m")]
    fn divisibility_enforced() {
        computation_overhead(10, 3, 2, 1, 5);
    }
}
