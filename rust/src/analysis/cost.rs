//! The reusable λ-tradeoff cost model — one source of truth for the
//! figures *and* the autoscaler policy.
//!
//! The paper's Phase-0 optimization picks the gap `λ*` minimizing the
//! worker count `N = |P(H)|` (eq. 30), and Corollaries 10–12 make every
//! per-worker overhead (ξ, σ, ζ) *monotone increasing in `N`* — so the
//! same λ* minimizes all three. What a λ ≠ λ* buys instead is margin: a
//! larger `N` leaves more headroom for stragglers (early decode needs
//! only the `t²+z+2a` quota) and for Byzantine exclusion (the quota
//! itself grows by `2a`). [`CostModel`] exposes both sides of that
//! tradeoff as data, so a *policy* — live telemetry in hand — can walk
//! the curve instead of re-deriving it.
//!
//! Everything here is exact enumeration ([`crate::analysis::gamma_age_enum`]
//! under the hood), not the conservative closed forms, because the policy
//! provisions real runtimes and must agree with what
//! [`crate::codes::AgeCmpc`] actually builds.

use super::{communication_overhead, computation_overhead, gamma_age_enum, storage_overhead};

/// One point on the λ curve: the AGE instance at gap `lambda` and its
/// analytical per-worker overheads for a given matrix size `m`.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct LambdaPoint {
    /// The gap parameter `λ ∈ [0, z]`.
    pub lambda: u64,
    /// Workers provisioned: `Γ(λ) = |P(H)|` by exact enumeration.
    pub n_workers: u64,
    /// Computation overhead ξ per worker (eq. 32).
    pub xi: u128,
    /// Storage overhead σ per worker (eq. 33).
    pub sigma: u128,
    /// Communication overhead ζ among workers (eq. 34).
    pub zeta: u128,
}

/// The full λ ∈ [0, z] tradeoff curve for one `(s, t, z)` triple,
/// computed once and queried cheaply (the enumeration behind each point
/// builds a scheme instance; callers should construct a `CostModel` per
/// deployment, not per decision).
#[derive(Clone, Debug)]
pub struct CostModel {
    s: usize,
    t: usize,
    z: usize,
    /// `(λ, N(λ))`, ascending in λ — the curve every query walks.
    curve: Vec<(u64, u64)>,
}

impl CostModel {
    /// Enumerate the λ curve for `(s, t, z)`. `t = 1` still yields a
    /// well-formed (flat) curve: every λ reduces to polynomial-code
    /// sharing with `N = 2s + 2z − 1`.
    pub fn new(s: usize, t: usize, z: usize) -> CostModel {
        let curve = (0..=z as u64)
            .map(|l| (l, gamma_age_enum(s, t, z, l)))
            .collect();
        CostModel { s, t, z, curve }
    }

    /// The `(λ, N(λ))` curve, ascending in λ — exactly the table the
    /// Fig. 2 λ-ablation plots.
    pub fn worker_counts(&self) -> &[(u64, u64)] {
        &self.curve
    }

    /// `(λ*, N(λ*))`: the gap minimizing the worker count, ties toward
    /// smaller λ (lower degree) — Phase 0 of Algorithm 3.
    pub fn optimal_lambda(&self) -> (u64, u64) {
        let mut best = self.curve[0];
        for &(l, n) in &self.curve[1..] {
            if n < best.1 {
                best = (l, n);
            }
        }
        best
    }

    /// The largest worker count on the curve — what a standby draft can
    /// reach without changing `(s, t, z)`.
    pub fn max_workers(&self) -> u64 {
        self.curve.iter().map(|&(_, n)| n).max().unwrap()
    }

    /// The λ with the *smallest* `N(λ) ≥ min_workers`, or `None` when no
    /// gap reaches that count. Ties toward smaller λ. This is the standby
    /// draft query: "give me the cheapest config with at least this much
    /// straggler margin".
    pub fn smallest_with_margin(&self, min_workers: u64) -> Option<(u64, u64)> {
        self.curve
            .iter()
            .copied()
            .filter(|&(_, n)| n >= min_workers)
            .min_by_key(|&(l, n)| (n, l))
    }

    /// The master's recovery quota at adversary tolerance `a`:
    /// `t² + z + 2a` shares (Reed–Solomon unique decoding).
    pub fn quota(&self, adversary_tolerance: usize) -> u64 {
        (self.t * self.t + self.z + 2 * adversary_tolerance) as u64
    }

    /// Full analytical points for a concrete matrix size `m` (requires
    /// `s|m` and `t|m`, like the overhead formulas themselves).
    pub fn points(&self, m: usize) -> Vec<LambdaPoint> {
        self.curve
            .iter()
            .map(|&(lambda, n)| LambdaPoint {
                lambda,
                n_workers: n,
                xi: computation_overhead(m, self.s, self.t, self.z, n),
                sigma: storage_overhead(m, self.s, self.t, self.z, n),
                zeta: communication_overhead(m, self.t, n),
            })
            .collect()
    }

    /// Relative ζ saving (percent) of moving from `n_cur` workers to
    /// `n_best`. ζ = N(N−1)·m²/t², so the *ratio* is m-independent —
    /// which is what lets a policy compare configurations without
    /// knowing the workload's matrix size:
    /// `gain = (1 − n_best(n_best−1)/(n_cur(n_cur−1))) × 100`.
    /// Zero when the move does not shrink the worker count.
    pub fn gain_pct(n_cur: u64, n_best: u64) -> f64 {
        if n_best >= n_cur || n_cur < 2 {
            return 0.0;
        }
        let cur = (n_cur * (n_cur - 1)) as f64;
        let best = (n_best * (n_best - 1)) as f64;
        (1.0 - best / cur) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::n_age_enum;

    #[test]
    fn example1_curve_and_optimum() {
        // Paper Example 1 (s=t=z=2): Γ = [18, 18, 17], λ* = 2.
        let model = CostModel::new(2, 2, 2);
        assert_eq!(model.worker_counts(), &[(0, 18), (1, 18), (2, 17)]);
        assert_eq!(model.optimal_lambda(), (2, 17));
        assert_eq!(model.max_workers(), 18);
        assert_eq!(model.quota(0), 6);
        assert_eq!(model.quota(1), 8);
    }

    #[test]
    fn optimal_lambda_matches_analytical_table() {
        // The satellite pin: CostModel::optimal_lambda against the
        // analytical enumeration (n_age_enum) over a parameter sweep.
        for s in 1..=5 {
            for t in 1..=5 {
                for z in 1..=8 {
                    let model = CostModel::new(s, t, z);
                    let (n, l) = n_age_enum(s, t, z);
                    assert_eq!(
                        model.optimal_lambda(),
                        (l, n),
                        "optimal_lambda mismatch at s={s} t={t} z={z}"
                    );
                }
            }
        }
    }

    #[test]
    fn margin_query_walks_the_curve() {
        let model = CostModel::new(2, 2, 2);
        // Cheapest config with ≥ 18 workers is λ=0 (ties toward smaller λ).
        assert_eq!(model.smallest_with_margin(18), Some((0, 18)));
        // Anything ≥ 17 is satisfied by the optimum itself.
        assert_eq!(model.smallest_with_margin(17), Some((2, 17)));
        // No gap reaches 19 workers at (2,2,2).
        assert_eq!(model.smallest_with_margin(19), None);
    }

    #[test]
    fn points_agree_with_overhead_formulas() {
        let model = CostModel::new(2, 2, 2);
        let pts = model.points(32);
        assert_eq!(pts.len(), 3);
        let p = &pts[2];
        assert_eq!(p.lambda, 2);
        assert_eq!(p.n_workers, 17);
        assert_eq!(p.xi, computation_overhead(32, 2, 2, 2, 17));
        assert_eq!(p.sigma, storage_overhead(32, 2, 2, 2, 17));
        assert_eq!(p.zeta, communication_overhead(32, 2, 17));
        // ξ, σ, ζ all monotone in N along the curve.
        assert!(pts[0].zeta > pts[2].zeta);
        assert!(pts[0].xi > pts[2].xi);
        assert!(pts[0].sigma > pts[2].sigma);
    }

    #[test]
    fn gain_pct_is_m_independent_and_pinned() {
        // 18 → 17 workers: 1 − (17·16)/(18·17) = 34/306 ≈ 11.11 %.
        let g = CostModel::gain_pct(18, 17);
        assert!((g - 100.0 * 34.0 / 306.0).abs() < 1e-9, "got {g}");
        // Entangled(19) → AGE(17): 1 − 272/342 ≈ 20.47 %.
        let g = CostModel::gain_pct(19, 17);
        assert!((g - 100.0 * 70.0 / 342.0).abs() < 1e-9, "got {g}");
        // No shrink → no gain.
        assert_eq!(CostModel::gain_pct(17, 17), 0.0);
        assert_eq!(CostModel::gain_pct(17, 18), 0.0);
    }
}
