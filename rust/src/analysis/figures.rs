//! Figure regeneration — the data series behind every figure in §VII.
//!
//! Each function returns the rows it writes, so the benches can time pure
//! generation and tests can assert the paper's qualitative claims
//! (crossovers, dominance, non-monotonicity) directly on the series.
//!
//! | Paper artifact | Generator | Output |
//! |---|---|---|
//! | Fig. 2 (N vs z; s=4, t=15) | [`fig2_workers`] | `fig2_workers.csv` |
//! | Fig. 3 (N vs s/t; st=36, z=42) | [`fig3_workers`] | `fig3_workers.csv` |
//! | Fig. 4a (computation/worker) | [`fig4_overheads`] | `fig4_overheads.csv` |
//! | Fig. 4b (storage/worker) | [`fig4_overheads`] | same file |
//! | Fig. 4c (communication) | [`fig4_overheads`] | same file |
//! | λ-gap ablation (§V motivation) | [`lambda_ablation`] | `lambda_ablation.csv` |
//! | Lemma 3/4/5 win regions | [`polydot_win_regions`] | `polydot_wins.csv` |
//!
//! AGE and PolyDot columns are *exact* (construction enumeration); the
//! baselines use their published formulas, exactly as the paper's own
//! simulation does.

use std::path::Path;

use crate::analysis::{
    communication_overhead, computation_overhead, n_age_enum, n_age_formula, n_entangled,
    n_polydot_enum, n_polydot_formula, partition_pairs, storage_overhead, CostModel,
};
use crate::codes::{n_gcsa_na, n_ssmm};
use crate::csv_row;
use crate::util::csv::CsvWriter;

/// One Fig. 2 row: worker counts at a given number of colluding workers.
#[derive(Debug, Clone, Copy)]
pub struct Fig2Row {
    /// Number of colluding workers (the x-axis).
    pub z: usize,
    /// AGE-CMPC workers (exact enumeration, optimal λ).
    pub age: u64,
    /// The λ* the AGE enumeration selected.
    pub age_lambda: u64,
    /// PolyDot-CMPC workers (exact enumeration).
    pub polydot: u64,
    /// Entangled-CMPC workers (published formula).
    pub entangled: u64,
    /// SSMM workers (published formula).
    pub ssmm: u64,
    /// GCSA-NA workers (published formula).
    pub gcsa_na: u64,
    /// Paper-formula overlay for AGE (Theorem 2), for parity checking.
    pub age_formula: u64,
    /// Paper-formula overlay for PolyDot (Theorem 8), for parity checking.
    pub polydot_formula: u64,
}

/// Fig. 2: required workers versus `z` for `s = 4`, `t = 15`,
/// `1 ≤ z ≤ z_max` (paper: 300).
pub fn fig2_workers(s: usize, t: usize, z_max: usize) -> Vec<Fig2Row> {
    (1..=z_max)
        .map(|z| {
            let (age, age_lambda) = n_age_enum(s, t, z);
            Fig2Row {
                z,
                age,
                age_lambda,
                polydot: n_polydot_enum(s, t, z),
                entangled: n_entangled(s, t, z),
                ssmm: n_ssmm(s, t, z),
                gcsa_na: n_gcsa_na(s, t, z),
                age_formula: n_age_formula(s, t, z).0,
                polydot_formula: n_polydot_formula(s, t, z),
            }
        })
        .collect()
}

/// Dump Fig. 2 rows to `fig2_workers.csv` under `dir`.
pub fn write_fig2(dir: &Path, rows: &[Fig2Row]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        dir.join("fig2_workers.csv"),
        &[
            "z",
            "age",
            "age_lambda",
            "polydot",
            "entangled",
            "ssmm",
            "gcsa_na",
            "age_formula",
            "polydot_formula",
        ],
    )?;
    for r in rows {
        csv_row!(
            w,
            r.z,
            r.age,
            r.age_lambda,
            r.polydot,
            r.entangled,
            r.ssmm,
            r.gcsa_na,
            r.age_formula,
            r.polydot_formula
        );
    }
    w.flush()
}

/// One Fig. 3 / Fig. 4 row: a partition pair and the per-scheme counts.
#[derive(Debug, Clone, Copy)]
pub struct Fig3Row {
    /// Row partition factor of the `(s, t)` pair.
    pub s: usize,
    /// Column partition factor of the `(s, t)` pair.
    pub t: usize,
    /// AGE-CMPC workers (exact enumeration, optimal λ).
    pub age: u64,
    /// PolyDot-CMPC workers (exact enumeration).
    pub polydot: u64,
    /// Entangled-CMPC workers (published formula).
    pub entangled: u64,
    /// SSMM workers (published formula).
    pub ssmm: u64,
    /// GCSA-NA workers (published formula).
    pub gcsa_na: u64,
}

/// Fig. 3: required workers versus `s/t` with `s·t = st_total` (paper: 36)
/// and fixed `z` (paper: 42).
pub fn fig3_workers(st_total: usize, z: usize) -> Vec<Fig3Row> {
    partition_pairs(st_total)
        .into_iter()
        .map(|(s, t)| Fig3Row {
            s,
            t,
            age: n_age_enum(s, t, z).0,
            polydot: n_polydot_enum(s, t, z),
            entangled: n_entangled(s, t, z),
            ssmm: n_ssmm(s, t, z),
            gcsa_na: n_gcsa_na(s, t, z),
        })
        .collect()
}

/// Dump Fig. 3 rows to `fig3_workers.csv` under `dir`.
pub fn write_fig3(dir: &Path, rows: &[Fig3Row]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        dir.join("fig3_workers.csv"),
        &["s", "t", "s_over_t", "age", "polydot", "entangled", "ssmm", "gcsa_na"],
    )?;
    for r in rows {
        csv_row!(
            w,
            r.s,
            r.t,
            format!("{:.4}", r.s as f64 / r.t as f64),
            r.age,
            r.polydot,
            r.entangled,
            r.ssmm,
            r.gcsa_na
        );
    }
    w.flush()
}

/// One Fig. 4 row: per-worker overheads (bytes at 1 B/scalar, following the
/// paper's plots) for every scheme at one partition pair.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    /// Row partition factor of the `(s, t)` pair.
    pub s: usize,
    /// Column partition factor of the `(s, t)` pair.
    pub t: usize,
    /// (scheme label, N, ξ, σ, ζ)
    pub per_scheme: Vec<(&'static str, u64, u128, u128, u128)>,
}

/// Fig. 4(a–c): computation, storage and communication loads versus `s/t`
/// for `m = 36000`, `st = 36`, `z = 42` (paper parameters).
pub fn fig4_overheads(m: usize, st_total: usize, z: usize) -> Vec<Fig4Row> {
    fig3_workers(st_total, z)
        .into_iter()
        .map(|r| {
            let mk = |label: &'static str, n: u64| {
                (
                    label,
                    n,
                    computation_overhead(m, r.s, r.t, z, n),
                    storage_overhead(m, r.s, r.t, z, n),
                    communication_overhead(m, r.t, n),
                )
            };
            Fig4Row {
                s: r.s,
                t: r.t,
                per_scheme: vec![
                    mk("AGE-CMPC", r.age),
                    mk("PolyDot-CMPC", r.polydot),
                    mk("Entangled-CMPC", r.entangled),
                    mk("SSMM", r.ssmm),
                    mk("GCSA-NA", r.gcsa_na),
                ],
            }
        })
        .collect()
}

/// Dump Fig. 4 rows to `fig4_overheads.csv` under `dir`.
pub fn write_fig4(dir: &Path, rows: &[Fig4Row]) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        dir.join("fig4_overheads.csv"),
        &[
            "s",
            "t",
            "scheme",
            "n_workers",
            "computation_scalar_mults",
            "storage_bytes",
            "communication_bytes",
        ],
    )?;
    for r in rows {
        for (label, n, xi, sigma, zeta) in &r.per_scheme {
            csv_row!(w, r.s, r.t, label, n, xi, sigma, zeta);
        }
    }
    w.flush()
}

/// λ ablation: `Γ(λ)` across the full gap range for one `(s,t,z)` — the
/// evidence behind §V's "wider gaps can shrink |P(H)|" insight.
pub fn lambda_ablation(s: usize, t: usize, z: usize) -> Vec<(u64, u64)> {
    // Delegates to the shared CostModel so the figure and the autoscaler
    // policy can never disagree about the curve.
    CostModel::new(s, t, z).worker_counts().to_vec()
}

/// Dump λ-ablation series for each `(s, t, z)` case to
/// `lambda_ablation.csv` under `dir`.
pub fn write_lambda_ablation(
    dir: &Path,
    cases: &[(usize, usize, usize)],
) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        dir.join("lambda_ablation.csv"),
        &["s", "t", "z", "lambda", "n_workers"],
    )?;
    for &(s, t, z) in cases {
        for (l, n) in lambda_ablation(s, t, z) {
            csv_row!(w, s, t, z, l, n);
        }
    }
    w.flush()
}

/// Lemma 3/4/5 reproduction: for each `(s,t,z)` in a grid, who PolyDot
/// beats. Returns `(s, t, z, beats_entangled, beats_ssmm, beats_gcsa)`.
pub fn polydot_win_regions(
    max_s: usize,
    max_t: usize,
    max_z: usize,
) -> Vec<(usize, usize, usize, bool, bool, bool)> {
    let mut out = Vec::new();
    for s in 1..=max_s {
        for t in 1..=max_t {
            for z in 1..=max_z {
                let pd = n_polydot_enum(s, t, z);
                out.push((
                    s,
                    t,
                    z,
                    pd < n_entangled(s, t, z),
                    pd < n_ssmm(s, t, z),
                    pd < n_gcsa_na(s, t, z),
                ));
            }
        }
    }
    out
}

/// Dump the win-region grid to `polydot_wins.csv` under `dir`.
pub fn write_polydot_wins(
    dir: &Path,
    rows: &[(usize, usize, usize, bool, bool, bool)],
) -> std::io::Result<()> {
    let mut w = CsvWriter::create(
        dir.join("polydot_wins.csv"),
        &["s", "t", "z", "beats_entangled", "beats_ssmm", "beats_gcsa_na"],
    )?;
    for &(s, t, z, be, bs, bg) in rows {
        csv_row!(w, s, t, z, be, bs, bg);
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_crossover_structure_matches_paper() {
        // §VII on Fig. 2 (s=4, t=15): AGE best everywhere; SSMM second-best
        // for small z (1..≈48); PolyDot second-best mid-range (≈49..180);
        // GCSA-NA/Entangled tie and win for large z (≈181..300).
        let rows = fig2_workers(4, 15, 300);
        for r in &rows {
            let others = [r.polydot, r.entangled, r.ssmm, r.gcsa_na];
            assert!(
                others.iter().all(|&o| r.age <= o),
                "AGE not minimal at z={}",
                r.z
            );
            if r.z > 4 * 15 - 4 {
                // Entangled's large-z branch coincides with GCSA-NA — the
                // "similar performance" the paper notes in the 181..300 band.
                assert_eq!(r.entangled, r.gcsa_na, "tie expected at z={}", r.z);
            }
        }
        let second_best = |r: &Fig2Row| -> &'static str {
            let cands = [
                ("polydot", r.polydot),
                ("entangled", r.entangled),
                ("ssmm", r.ssmm),
            ];
            cands.iter().min_by_key(|&&(_, v)| v).unwrap().0
        };
        // Spot the three regimes at paper-stated sample points.
        assert_eq!(second_best(&rows[10 - 1]), "ssmm");
        assert_eq!(second_best(&rows[40 - 1]), "ssmm");
        assert_eq!(second_best(&rows[100 - 1]), "polydot");
        assert_eq!(second_best(&rows[150 - 1]), "polydot");
        assert_eq!(second_best(&rows[250 - 1]), "entangled");
        assert_eq!(second_best(&rows[300 - 1]), "entangled");
    }

    #[test]
    fn fig3_age_minimal_and_polydot_pattern() {
        let rows = fig3_workers(36, 42);
        assert_eq!(rows.len(), 9);
        for r in &rows {
            for other in [r.polydot, r.entangled, r.ssmm, r.gcsa_na] {
                assert!(r.age <= other, "(s,t)=({},{})", r.s, r.t);
            }
        }
    }

    #[test]
    fn fig4_computation_nonmonotonic_with_minimum_interior() {
        // §VII on Fig. 4(a): computation load per worker first falls then
        // rises as s/t grows (N-effect vs 1/t-effect).
        let rows = fig4_overheads(36000, 36, 42);
        let age_comp: Vec<u128> = rows
            .iter()
            .map(|r| r.per_scheme[0].2)
            .collect();
        let min_idx = age_comp
            .iter()
            .enumerate()
            .min_by_key(|&(_, v)| v)
            .unwrap()
            .0;
        assert!(
            min_idx > 0 && min_idx < age_comp.len() - 1,
            "minimum must be interior, got index {min_idx} of {age_comp:?}"
        );
    }

    #[test]
    fn fig4_storage_and_comm_follow_worker_count() {
        // Fig. 4(b,c): with (s,t,z,m) fixed, σ and ζ are increasing in N —
        // so AGE (minimal N) is minimal per partition pair.
        for r in fig4_overheads(36000, 36, 42) {
            let (age_sigma, age_zeta) = (r.per_scheme[0].3, r.per_scheme[0].4);
            for (_, _, _, sigma, zeta) in &r.per_scheme[1..] {
                assert!(age_sigma <= *sigma && age_zeta <= *zeta);
            }
        }
    }

    #[test]
    fn lambda_ablation_optimum_matches_example1() {
        let curve = lambda_ablation(2, 2, 2);
        assert_eq!(curve, vec![(0, 18), (1, 18), (2, 17)]);
    }

    #[test]
    fn win_regions_nonempty_both_ways() {
        let rows = polydot_win_regions(4, 4, 20);
        assert!(rows.iter().any(|r| r.3), "PolyDot beats Entangled somewhere");
        assert!(
            rows.iter().any(|r| !r.3),
            "Entangled beats PolyDot somewhere"
        );
    }
}
