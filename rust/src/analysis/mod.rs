//! Closed-form analysis: required worker counts (Theorems 2 and 8, plus
//! baseline formulas) and per-worker overheads (Corollaries 10–12).
//!
//! Ground truth for our constructible schemes is *enumeration*: build the
//! scheme and count `|P(H)|` via eq. (23) ([`CmpcScheme::n_workers`]). The
//! closed forms below reproduce the paper's published expressions; the test
//! suite cross-checks them against enumeration over parameter sweeps. Where
//! the paper's piecewise formulas are conservative (they occasionally count a
//! gap power that the actual support skips — e.g. `Υ₂(0)` inherits [15]'s
//! degree-based count), the library keeps *both* numbers: `*_formula` for
//! figure parity with the paper, enumeration for the protocol itself.

pub mod cost;
pub mod figures;
pub mod overheads;

pub use cost::{CostModel, LambdaPoint};
pub use overheads::{communication_overhead, computation_overhead, storage_overhead};

use crate::codes::{n_gcsa_na, n_ssmm, AgeCmpc, CmpcScheme, PolyDotCmpc};

/// Scheme selector used by figures, benches and the coordinator.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SchemeKind {
    /// AGE-CMPC (§V) at the optimal gap λ*.
    Age,
    /// PolyDot-CMPC (§IV).
    PolyDot,
    /// Entangled-CMPC baseline \[15\].
    Entangled,
    /// SSMM formula baseline \[16\].
    Ssmm,
    /// GCSA-NA formula baseline \[17\].
    GcsaNa,
}

impl SchemeKind {
    /// Every scheme, in the order the paper's figures plot them.
    pub const ALL: [SchemeKind; 5] = [
        SchemeKind::Age,
        SchemeKind::PolyDot,
        SchemeKind::Entangled,
        SchemeKind::Ssmm,
        SchemeKind::GcsaNa,
    ];

    /// Display name used in figure legends and CSV columns.
    pub fn label(&self) -> &'static str {
        match self {
            SchemeKind::Age => "AGE-CMPC",
            SchemeKind::PolyDot => "PolyDot-CMPC",
            SchemeKind::Entangled => "Entangled-CMPC",
            SchemeKind::Ssmm => "SSMM",
            SchemeKind::GcsaNa => "GCSA-NA",
        }
    }
}

/// Required workers for `kind` at `(s, t, z)` — the quantity plotted in
/// Figs. 2–3. Constructible schemes (AGE, PolyDot) use exact enumeration;
/// baselines use their published formulas, as the paper's evaluation does.
pub fn n_workers(kind: SchemeKind, s: usize, t: usize, z: usize) -> u64 {
    match kind {
        SchemeKind::Age => AgeCmpc::with_optimal_lambda(s, t, z).n_workers() as u64,
        SchemeKind::PolyDot => PolyDotCmpc::new(s, t, z).n_workers() as u64,
        SchemeKind::Entangled => n_entangled(s, t, z),
        SchemeKind::Ssmm => n_ssmm(s, t, z),
        SchemeKind::GcsaNa => n_gcsa_na(s, t, z),
    }
}

/// Entangled-CMPC worker count, eq. (194) = Theorem 1 of [15].
pub fn n_entangled(s: usize, t: usize, z: usize) -> u64 {
    let (su, tu, zu) = (s as u64, t as u64, z as u64);
    if z > t * s - s {
        2 * su * tu * tu + 2 * zu - 1
    } else {
        su * tu * tu + 3 * su * tu - 2 * su + tu * zu - tu + 1
    }
}

/// PolyDot-CMPC worker count — Theorem 2 (ψ₁…ψ₆ with Lemmas 32/33 for the
/// `s=1` / `t=1` degenerate partitions).
pub fn n_polydot_formula(s: usize, t: usize, z: usize) -> u64 {
    let (su, tu, zu) = (s as u64, t as u64, z as u64);
    if t == 1 {
        // Lemma 32 — reduces to polynomial-code sharing.
        return 2 * su + 2 * zu - 1;
    }
    if s == 1 {
        // Lemma 33.
        return if z > t {
            2 * tu * tu + 2 * zu - 1
        } else {
            tu * tu + 2 * tu + tu * zu - 1
        };
    }
    let theta = tu * (2 * su - 1); // θ' = t(2s−1)
    let ts = tu * su;
    // p = min{⌊(z−1)/(θ'−ts)⌋, t−1}; θ'−ts = ts−t > 0 for s,t ≥ 2.
    let p = ((zu - 1) / (theta - ts)).min(tu - 1);
    if zu > ts {
        // ψ₁
        (p + 2) * ts + theta * (tu - 1) + 2 * zu - 1
    } else if zu > ts - tu {
        // ψ₂
        2 * ts + theta * (tu - 1) + 3 * zu - 1
    } else if zu + 2 * tu > ts {
        // ψ₃ (ts−2t < z ≤ ts−t)
        2 * ts + theta * (tu - 1) + 2 * zu - 1
    } else {
        // v' = max{ts−2t−s+2, (ts−2t+1)/2} — compare via 2z to avoid
        // fractional arithmetic. z ≤ v' ⟺ (z ≤ ts−2t−s+2 or 2z ≤ ts−2t+1).
        let above_first = zu + 2 * tu + su > ts + 2; // z > ts−2t−s+2
        let above_half = 2 * zu > ts - 2 * tu + 1; // z > (ts−2t+1)/2
        if above_first && above_half {
            // ψ₄
            (tu + 1) * ts + (tu - 1) * (zu + tu - 1) + 2 * zu - 1
        } else {
            // ψ₅
            theta * tu + zu
        }
    }
}

/// `Γ(λ)` of Theorem 8 — AGE-CMPC worker count at a fixed gap `λ`, as
/// published (Υ₁…Υ₉). `t = 1` returns `2s+2z−1` regardless of λ.
pub fn gamma_age_formula(s: usize, t: usize, z: usize, lambda: u64) -> u64 {
    let (su, tu, zu) = (s as u64, t as u64, z as u64);
    assert!(lambda <= zu);
    if t == 1 {
        return 2 * su + 2 * zu - 1;
    }
    let ts = tu * su;
    let theta = ts + lambda;
    if lambda == 0 {
        return if zu > ts - su {
            2 * su * tu * tu + 2 * zu - 1 // Υ₁
        } else {
            su * tu * tu + 3 * su * tu - 2 * su + tu * (zu - 1) + 1 // Υ₂
        };
    }
    if lambda == zu {
        // Υ₃
        return 2 * ts + (ts + zu) * (tu - 1) + 2 * zu - 1;
    }
    let q = ((zu - 1) / lambda).min(tu - 1);
    if zu > ts {
        // Υ₄
        return (q + 2) * ts + theta * (tu - 1) + 2 * zu - 1;
    }
    if ts < lambda + su - 1 {
        // Υ₅
        return 3 * ts + theta * (tu - 1) + 2 * zu - 1;
    }
    let i = |x: i128| x;
    let (si, ti, zi, li, qi, thi, tsi) = (
        i(su as i128),
        i(tu as i128),
        i(zu as i128),
        i(lambda as i128),
        i(q as i128),
        i(theta as i128),
        i(ts as i128),
    );
    let val = if zu > lambda + su - 1 {
        if q * lambda >= su as u64 {
            // Υ₆
            2 * tsi + thi * (ti - 1) + (qi + 2) * zi - qi - 1
        } else {
            // Υ₇
            thi * (ti + qi + 1) + qi * (zi - 1) - 2 * li + zi + tsi
                + 0.min(zi + si * (1 - ti) - li * qi - 1)
        }
    } else {
        // z ≤ λ+s−1 ≤ ts
        if q * lambda >= su as u64 {
            // Υ₈
            2 * tsi + thi * (ti - 1) + 3 * zi + (li + si - 1) * qi - li - si - 1
        } else {
            // Υ₉
            thi * (ti + 1) + qi * (si - 1) - 3 * li + 3 * zi - 1
                + 0.min(tsi - zi + 1 + li * qi - si)
        }
    };
    val.max(1) as u64
}

/// Paper-formula AGE count: `min_λ Γ(λ)` (eq. 30). Returns `(N, λ*)`.
pub fn n_age_formula(s: usize, t: usize, z: usize) -> (u64, u64) {
    if t == 1 {
        return (2 * s as u64 + 2 * z as u64 - 1, 0);
    }
    (0..=z as u64)
        .map(|l| (gamma_age_formula(s, t, z, l), l))
        .min()
        .unwrap()
}

/// Exact AGE count via construction enumeration. Returns `(N, λ*)`.
pub fn n_age_enum(s: usize, t: usize, z: usize) -> (u64, u64) {
    let sch = AgeCmpc::with_optimal_lambda(s, t, z);
    (sch.n_workers() as u64, sch.lambda)
}

/// Exact AGE count at a fixed λ via construction enumeration.
pub fn gamma_age_enum(s: usize, t: usize, z: usize, lambda: u64) -> u64 {
    AgeCmpc::new(s, t, z, lambda).n_workers() as u64
}

/// Exact PolyDot count via construction enumeration.
pub fn n_polydot_enum(s: usize, t: usize, z: usize) -> u64 {
    PolyDotCmpc::new(s, t, z).n_workers() as u64
}

/// The `(s, t)` factor pairs with `s·t = st_total` — the Fig. 3 / Fig. 4
/// x-axis (plotted as the ratio `s/t`).
pub fn partition_pairs(st_total: usize) -> Vec<(usize, usize)> {
    let mut v: Vec<(usize, usize)> = (1..=st_total)
        .filter(|s| st_total % s == 0)
        .map(|s| (s, st_total / s))
        .collect();
    // ascending s/t
    v.sort_by(|a, b| (a.0 * b.1).cmp(&(b.0 * a.1)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::property;

    #[test]
    fn polydot_formula_matches_enumeration() {
        // Theorem 2 against the exact support count of the construction.
        //
        // Exhaustive sweep result (documented in EXPERIMENTS.md): the only
        // region where ψ disagrees with the exact |P(H)| is the degenerate
        // corner s=1 ∧ z<t, where ψ₆ = t²+2t+tz−1 overcounts by exactly t−z
        // (the true support is (t+1)(t+z)−1 — the top coded-secret cross
        // band has a gap the lemma's dense count misses).
        let mut checked = 0usize;
        for s in 1..=6 {
            for t in 1..=6 {
                for z in 1..=(2 * s * t + 4) {
                    let f = n_polydot_formula(s, t, z);
                    let e = n_polydot_enum(s, t, z);
                    if s == 1 && z < t {
                        assert_eq!(
                            f - e,
                            (t - z) as u64,
                            "s=1 corner gap changed at t={t} z={z}: formula {f}, enum {e}"
                        );
                        assert_eq!(e, ((t + 1) * (t + z) - 1) as u64);
                    } else {
                        assert_eq!(
                            f, e,
                            "Theorem 2 mismatch at s={s} t={t} z={z}: formula {f}, enum {e}"
                        );
                    }
                    checked += 1;
                }
            }
        }
        assert!(checked > 500);
    }

    #[test]
    fn age_gamma_matches_enumeration_on_clean_regions() {
        // Υ₃ (λ=z) and Υ₄ (z>ts) have unambiguous derivations; assert exact.
        for s in 1..=5 {
            for t in 2..=5 {
                for z in 1..=(2 * s * t + 3) {
                    let l = z as u64;
                    assert_eq!(
                        gamma_age_formula(s, t, z, l),
                        gamma_age_enum(s, t, z, l),
                        "Υ₃ s={s} t={t} z={z}"
                    );
                    if z > s * t {
                        for l in 1..z as u64 {
                            assert_eq!(
                                gamma_age_formula(s, t, z, l),
                                gamma_age_enum(s, t, z, l),
                                "Υ₄ s={s} t={t} z={z} λ={l}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn age_formula_min_matches_enumeration() {
        // Individual Γ(λ) branches (Υ₆–Υ₉) are conservative in scattered
        // interior regions (audited in EXPERIMENTS.md), but the *optimized*
        // count min_λ Γ(λ) — the quantity Theorem 8 actually asserts and the
        // figures plot — agrees exactly with enumeration across the sweep.
        for s in 1..=5 {
            for t in 2..=5 {
                for z in 1..=(2 * s * t + 4) {
                    let (fe, _) = n_age_formula(s, t, z);
                    let (ee, _) = n_age_enum(s, t, z);
                    assert_eq!(fe, ee, "Theorem 8 min mismatch at s={s} t={t} z={z}");
                }
            }
        }
    }

    #[test]
    fn age_formula_min_upper_bounds_enumeration() {
        // The paper's Γ may overcount individual λ (it inherits [15]'s
        // degree-based Υ₁/Υ₂ at λ=0), but the enumerated optimum can never
        // exceed the formula optimum: the construction realizes every λ.
        property("enum N_AGE <= formula N_AGE", 250, |rng| {
            let s = rng.gen_index(5) + 1;
            let t = rng.gen_index(5) + 1;
            let z = rng.gen_index(12) + 1;
            let (fe, _) = n_age_formula(s, t, z);
            let (ee, _) = n_age_enum(s, t, z);
            if ee > fe {
                return Err(format!("s={s} t={t} z={z}: enum {ee} > formula {fe}"));
            }
            Ok(())
        });
    }

    #[test]
    fn example1_counts() {
        assert_eq!(n_age_enum(2, 2, 2), (17, 2));
        assert_eq!(n_age_formula(2, 2, 2).0, 17);
        assert_eq!(n_entangled(2, 2, 2), 19);
    }

    #[test]
    fn lemma9_age_dominates_all_baselines() {
        // Lemma 9: N_AGE ≤ every other scheme, everywhere.
        property("Lemma 9 dominance", 120, |rng| {
            let s = rng.gen_index(6) + 1;
            let t = rng.gen_index(6) + 1;
            let z = rng.gen_index(20) + 1;
            let (age, _) = n_age_enum(s, t, z);
            for kind in [
                SchemeKind::PolyDot,
                SchemeKind::Entangled,
                SchemeKind::Ssmm,
                SchemeKind::GcsaNa,
            ] {
                let other = n_workers(kind, s, t, z);
                if age > other {
                    return Err(format!(
                        "s={s} t={t} z={z}: AGE {age} > {} {other}",
                        kind.label()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fig3_polydot_win_pattern_at_z42() {
        // §VII (Fig. 3): at st=36, z=42 PolyDot beats Entangled/SSMM/GCSA-NA
        // for (s,t) ∈ {(2,18),(3,12),(4,9)} and not for the other pairs.
        let winners = [(2usize, 18usize), (3, 12), (4, 9)];
        for (s, t) in partition_pairs(36) {
            let pd = n_polydot_formula(s, t, 42);
            let others = [
                n_entangled(s, t, 42),
                n_ssmm(s, t, 42),
                n_gcsa_na(s, t, 42),
            ];
            let beats_all = others.iter().all(|&o| pd < o);
            assert_eq!(
                beats_all,
                winners.contains(&(s, t)),
                "(s,t)=({s},{t}): PolyDot={pd} others={others:?}"
            );
        }
    }

    #[test]
    fn partition_pairs_cover_divisors() {
        let pairs = partition_pairs(36);
        assert_eq!(
            pairs,
            vec![
                (1, 36),
                (2, 18),
                (3, 12),
                (4, 9),
                (6, 6),
                (9, 4),
                (12, 3),
                (18, 2),
                (36, 1)
            ]
        );
    }
}
