//! Crate-wide typed error handling.
//!
//! Every fallible public entry point returns [`Result`] with [`CmpcError`],
//! so a serving process can reject a malformed request, report the failure in
//! its [`crate::coordinator::JobReport`], and keep draining the rest of the
//! batch — instead of crashing on an `assert!` deep inside the protocol.
//!
//! The variants mirror the failure classes of the serving pipeline:
//!
//! * [`CmpcError::InvalidParams`] — a `(s, t, z)` triple or config knob that
//!   no scheme can be constructed for (e.g. `z = 0`, `λ > z`, a
//!   `worker_delays` vector whose length disagrees with the deployment).
//! * [`CmpcError::ShapeMismatch`] — job matrices that are not square, not of
//!   equal size, or not divisible by the `(s, t)` partition.
//! * [`CmpcError::NotDecodable`] — reconstruction cannot proceed (singular
//!   generalized Vandermonde after re-draws, an important power missing from
//!   the reconstruction support, or a verify-mode product mismatch).
//! * [`CmpcError::InsufficientWorkers`] — fewer shares than the `t²+z`
//!   reconstruction threshold.
//! * [`CmpcError::BackendUnavailable`] — the requested compute backend (or
//!   its artifacts) cannot be used.
//! * [`CmpcError::Fabric`] — a network-fabric endpoint disappeared at a
//!   point the protocol cannot tolerate.
//! * [`CmpcError::Io`] — an underlying filesystem error (artifact manifests,
//!   CSV output).

/// Crate-wide result alias; `E` defaults to [`CmpcError`].
pub type Result<T, E = CmpcError> = std::result::Result<T, E>;

/// Typed error for every fallible operation in the crate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CmpcError {
    /// Scheme or config parameters that cannot be satisfied.
    InvalidParams(String),
    /// Job matrices incompatible with each other or with the partition.
    ShapeMismatch(String),
    /// Reconstruction is impossible or produced a wrong product.
    NotDecodable(String),
    /// Fewer worker shares than the `t²+z` reconstruction threshold.
    InsufficientWorkers {
        /// Shares the decoder needs (the recovery threshold).
        needed: usize,
        /// Workers the deployment actually provisioned.
        provisioned: usize,
    },
    /// The requested compute backend cannot serve the job.
    BackendUnavailable(String),
    /// A fabric endpoint vanished at an intolerable point of the protocol.
    Fabric(String),
    /// Underlying I/O failure (message keeps the error `Clone`-able).
    Io(String),
}

impl std::fmt::Display for CmpcError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CmpcError::InvalidParams(m) => write!(f, "invalid parameters: {m}"),
            CmpcError::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            CmpcError::NotDecodable(m) => write!(f, "not decodable: {m}"),
            CmpcError::InsufficientWorkers {
                needed,
                provisioned,
            } => write!(
                f,
                "insufficient workers: reconstruction needs {needed} shares \
                 but only {provisioned} workers are provisioned"
            ),
            CmpcError::BackendUnavailable(m) => write!(f, "backend unavailable: {m}"),
            CmpcError::Fabric(m) => write!(f, "fabric failure: {m}"),
            CmpcError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for CmpcError {}

impl From<std::io::Error> for CmpcError {
    fn from(e: std::io::Error) -> CmpcError {
        CmpcError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = CmpcError::InsufficientWorkers {
            needed: 6,
            provisioned: 4,
        };
        let msg = e.to_string();
        assert!(msg.contains('6') && msg.contains('4'));
        assert!(CmpcError::ShapeMismatch("8x8 vs 4x4".into())
            .to_string()
            .contains("8x8 vs 4x4"));
    }

    #[test]
    fn io_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: CmpcError = io.into();
        assert!(matches!(e, CmpcError::Io(_)));
    }
}
