//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `rust/benches/*.rs` declare `harness = false` and drive this module:
//! warmup, timed iterations, and a mean/median/p95 report printed in a
//! stable, grep-friendly format that `cargo bench` emits and
//! EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark label, e.g. `perf_core/e2e/m64/t1`.
    pub name: String,
    /// Number of timed iterations behind the statistics.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub median: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Fastest iteration.
    pub min: Duration,
}

impl Measurement {
    /// Print the stable one-line `bench …` report `cargo bench` emits.
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>12?} median={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95, self.min
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        median: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    };
    m.report();
    m
}

/// Time a single run of `f`, returning its result and duration.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Throughput helper: items per second given a duration.
pub fn per_second(items: u64, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or 0 where procfs is unavailable. Coarse but
/// dependency-free — enough for the `BENCH_*.json` storage trajectory.
pub fn peak_rss_bytes() -> u64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Minimal JSON value for the machine-readable `BENCH_*.json` reports
/// (the offline build vendors no serde; the schema is flat enough that a
/// six-variant enum covers it).
#[derive(Clone, Debug)]
pub enum Json {
    /// A string value.
    Str(String),
    /// A boolean value.
    Bool(bool),
    /// An unsigned integer value.
    Int(u64),
    /// A floating-point value.
    Float(f64),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object: key/value pairs in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from key/value pairs (insertion order preserved).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render to a compact JSON string (RFC 8259 string escaping).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32));
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Bool(v) => out.push_str(if *v { "true" } else { "false" }),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Float(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v:.3}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let m = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(m.iters, 10);
        assert!(m.min <= m.median && m.median <= m.p95);
    }

    #[test]
    fn per_second_math() {
        let r = per_second(100, Duration::from_millis(200));
        assert!((r - 500.0).abs() < 1.0);
    }

    #[test]
    fn json_renders_escaped_and_nested() {
        let j = Json::obj(vec![
            ("name", Json::Str("say \"hi\"\n".to_string())),
            ("n", Json::Int(42)),
            ("ratio", Json::Float(2.5)),
            ("xs", Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"name":"say \"hi\"\n","n":42,"ratio":2.500,"xs":[1,2]}"#
        );
    }

    #[test]
    fn peak_rss_probe_is_sane() {
        // On Linux this must be nonzero and at least a few pages; elsewhere
        // the probe degrades to 0.
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            assert!(rss > 4096, "VmHWM = {rss}");
        }
    }
}
