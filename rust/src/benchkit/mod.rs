//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `rust/benches/*.rs` declare `harness = false` and drive this module:
//! warmup, timed iterations, and a mean/median/p95 report printed in a
//! stable, grep-friendly format that `cargo bench` emits and
//! EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>12?} median={:>12?} p95={:>12?} min={:>12?}",
            self.name, self.iters, self.mean, self.median, self.p95, self.min
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        median: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        min: samples[0],
    };
    m.report();
    m
}

/// Time a single run of `f`, returning its result and duration.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Throughput helper: items per second given a duration.
pub fn per_second(items: u64, d: Duration) -> f64 {
    items as f64 / d.as_secs_f64().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let m = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(m.iters, 10);
        assert!(m.min <= m.median && m.median <= m.p95);
    }

    #[test]
    fn per_second_math() {
        let r = per_second(100, Duration::from_millis(200));
        assert!((r - 500.0).abs() < 1.0);
    }
}
