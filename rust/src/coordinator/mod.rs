//! Serving coordinator — the L3 layer a deployment actually talks to.
//!
//! Responsibilities (mirroring a vLLM-router-style front end, specialized to
//! CMPC):
//!
//! * **Job intake & validation** — [`Coordinator::submit`] accepts
//!   `Y = AᵀB` jobs with per-job privacy/partition parameters, validates
//!   parameters and matrix shapes at the door (typed
//!   [`crate::error::CmpcError`]s, no
//!   downstream panics), and returns a [`JobHandle`].
//! * **Scheme selection** — [`SchemePolicy::Adaptive`] runs Phase 0 of
//!   Algorithm 3 through the [`SchemeSpec`] registry: the constructible
//!   scheme (AGE at its λ*, PolyDot, Entangled) with the fewest workers for
//!   the job's `(s,t,z)`.
//! * **Deployment caching & batching** — [`Coordinator::drain`] groups
//!   queued jobs by `(scheme, s, t, z)` signature onto shared
//!   [`Deployment`]s, so the O(N³) generalized-Vandermonde solve, the
//!   backend service, **and the persistent worker runtime** (N long-lived
//!   Phase-2 threads + the job-multiplexed fabric) are provisioned once per
//!   signature and reused across jobs and across drains. Draining
//!   *pipelines* concurrent jobs into each live runtime — no per-job thread
//!   spawns, job-tagged envelopes interleaving on shared links, per-job
//!   traffic meters.
//! * **Failure isolation** — a job that fails at execution is reported in
//!   its [`JobReport::outcome`]; the rest of the batch keeps draining.
//! * **Backend management** — native or the artifact executor service per
//!   [`BackendChoice`], shared across every deployment.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::codes::{CmpcScheme, SchemeParams, SchemeSpec};
use crate::error::Result;
use crate::matrix::FpMat;
use crate::mpc::deployment::Deployment;
use crate::mpc::pipeline::{Pipeline, PipelineOutput};
use crate::mpc::protocol::{self, ProtocolConfig, ProtocolOutput};
use crate::runtime::pool::WorkerPool;
use crate::runtime::{BackendChoice, BackendFactory};

/// How the coordinator picks a construction for each job.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SchemePolicy {
    /// Always resolve the given spec from the registry.
    Fixed(SchemeSpec),
    /// Minimize provisioned workers across the registry
    /// (AGE λ*, PolyDot, Entangled).
    Adaptive,
}

/// Coordinator-wide configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Scheme-selection policy applied to every submitted job.
    pub policy: SchemePolicy,
    /// Compute backend shared by every deployment this coordinator builds.
    pub backend: BackendChoice,
    /// Verify every product natively (disable for throughput benchmarks).
    pub verify: bool,
    /// Optional link latency passed through to the protocol.
    pub link_delay: Option<Duration>,
    /// Worker-pool size shared by every deployment this coordinator
    /// provisions, and used by [`Coordinator::drain`] to run jobs on
    /// distinct deployments concurrently. `0` (the default) shares the
    /// process-wide pool; `1` makes draining strictly sequential.
    pub threads: usize,
    /// Fuse same-deployment, same-shape queued jobs into wide batches at
    /// drain time ([`crate::mpc::fused`]): per-job fixed costs amortize
    /// across the batch, outputs stay byte-identical job by job. Off by
    /// default — the fabric path exercises the full runtime (and tests
    /// that meter it expect envelope-level accounting).
    pub fused: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            policy: SchemePolicy::Adaptive,
            backend: BackendChoice::Native,
            verify: true,
            link_delay: None,
            threads: 0,
            fused: false,
        }
    }
}

impl CoordinatorConfig {
    /// Start a builder over the defaults.
    pub fn builder() -> CoordinatorConfigBuilder {
        CoordinatorConfigBuilder {
            config: CoordinatorConfig::default(),
        }
    }
}

/// Builder for [`CoordinatorConfig`].
#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfigBuilder {
    config: CoordinatorConfig,
}

impl CoordinatorConfigBuilder {
    /// Scheme-selection policy ([`SchemePolicy::Adaptive`] by default).
    pub fn policy(mut self, policy: SchemePolicy) -> Self {
        self.config.policy = policy;
        self
    }

    /// Compute backend for every deployment (native by default).
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.config.backend = backend;
        self
    }

    /// Verify every product natively (on by default; disable for
    /// throughput benchmarks).
    pub fn verify(mut self, verify: bool) -> Self {
        self.config.verify = verify;
        self
    }

    /// Simulated per-envelope link latency forwarded to the protocol.
    pub fn link_delay(mut self, delay: Option<Duration>) -> Self {
        self.config.link_delay = delay;
        self
    }

    /// Worker-pool size for deployments and parallel draining
    /// (0 = all cores, shared).
    pub fn threads(mut self, threads: usize) -> Self {
        self.config.threads = threads;
        self
    }

    /// Fuse same-deployment, same-shape jobs into wide batches at drain
    /// time (identical outputs, amortized fixed costs).
    pub fn fused(mut self, on: bool) -> Self {
        self.config.fused = on;
        self
    }

    /// Finish the builder.
    pub fn build(self) -> CoordinatorConfig {
        self.config
    }
}

/// Ticket for a submitted job; correlate with [`JobReport::id`] after a
/// drain.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct JobHandle {
    id: u64,
}

impl JobHandle {
    /// The job id, assigned in submission order.
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// One queued multiplication job.
pub struct Job {
    /// Id assigned at [`Coordinator::submit`] (ascending).
    pub id: u64,
    /// Left operand; the protocol computes `Y = AᵀB`.
    pub a: FpMat,
    /// Right operand.
    pub b: FpMat,
    /// Validated `(s, t, z)` privacy/partition parameters.
    pub params: SchemeParams,
    /// Per-job seed fixed at submission, so results are byte-identical
    /// regardless of drain order or pool size.
    pub seed: u64,
}

/// Outcome of one job: identification plus either the protocol output or
/// the typed error that stopped it. Per-job failures never abort the batch.
pub struct JobReport {
    /// The [`JobHandle::id`] this report answers.
    pub id: u64,
    /// Name of the scheme that served the job (empty on deployment failure).
    pub scheme: String,
    /// Workers provisioned by that scheme.
    pub n_workers: usize,
    /// True when the deployment was served from the coordinator cache
    /// (Setup + backend reused; solved once per signature).
    pub setup_cache_hit: bool,
    /// The decoded product, or the typed error that stopped this job.
    pub outcome: Result<ProtocolOutput>,
}

/// Signature under which deployments (α assignment + reconstruction
/// coefficients + backend) are shared between jobs. The scheme policy is
/// fixed for a coordinator's lifetime, so `(s, t, z)` fully determines the
/// resolved scheme — keying on the triple lets cache hits skip Phase-0
/// scheme resolution entirely.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct DeploymentKey {
    s: usize,
    t: usize,
    z: usize,
}

/// The serving coordinator.
pub struct Coordinator {
    config: CoordinatorConfig,
    queue: Vec<Job>,
    next_id: u64,
    deployments: BTreeMap<DeploymentKey, Arc<Deployment>>,
    /// Backend factory shared across all deployments: the executor service
    /// (and its artifact cache) lives for the coordinator's lifetime
    /// instead of being re-created per job (§Perf P1).
    backend: Option<Arc<BackendFactory>>,
    /// Worker pool shared across all deployments and by the parallel
    /// drain loop (§Perf P5).
    pool: Arc<WorkerPool>,
}

impl Coordinator {
    /// Build a coordinator over `config` with an empty queue and cache.
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        let pool = WorkerPool::sized_or_global(config.threads);
        Coordinator {
            config,
            queue: Vec::new(),
            next_id: 0,
            deployments: BTreeMap::new(),
            backend: None,
            pool,
        }
    }

    /// Validate and queue a job. Malformed parameters or shapes are rejected
    /// here — [`crate::error::CmpcError::InvalidParams`] /
    /// [`crate::error::CmpcError::ShapeMismatch`] —
    /// so nothing unconstructible ever reaches a deployment.
    pub fn submit(
        &mut self,
        a: FpMat,
        b: FpMat,
        s: usize,
        t: usize,
        z: usize,
    ) -> Result<JobHandle> {
        let params = SchemeParams::try_new(s, t, z)?;
        protocol::validate_job_shapes(&a, &b, params)?;
        let id = self.next_id;
        self.next_id += 1;
        let seed = 0x5EED ^ id.wrapping_mul(0x9E3779B97F4A7C15);
        self.queue.push(Job {
            id,
            a,
            b,
            params,
            seed,
        });
        Ok(JobHandle { id })
    }

    /// Validate and run a [`Pipeline`] — a chained sequence of secure
    /// matrix stages ([`crate::mpc::pipeline`]) — on the deployment that
    /// serves `(s, t, z)` under the current policy.
    ///
    /// Pipelines are interactive (the master re-shares each stage's masked
    /// intermediate), so they execute immediately instead of queueing for
    /// [`Coordinator::drain`]; they still share the deployment cache with
    /// ordinary jobs, so a pipeline after a drain of same-signature jobs
    /// reuses the provisioned runtime. The run consumes one id from the
    /// same submission-order seed schedule as [`Coordinator::submit`],
    /// keeping outputs byte-identical across processes for a given
    /// submission history.
    pub fn run_pipeline(
        &mut self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        s: usize,
        t: usize,
        z: usize,
    ) -> Result<PipelineOutput> {
        let params = SchemeParams::try_new(s, t, z)?;
        let (dep, _) = self.deployment_for(params)?;
        let id = self.next_id;
        self.next_id += 1;
        let seed = 0x5EED ^ id.wrapping_mul(0x9E3779B97F4A7C15);
        dep.execute_pipeline_seeded(pipe, x, weights, seed)
    }

    /// Jobs currently queued.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Deployments currently provisioned (one per distinct signature seen).
    pub fn provisioned_deployments(&self) -> usize {
        self.deployments.len()
    }

    /// Resolve the scheme for a parameter triple under the current policy.
    pub fn select_scheme(&self, s: usize, t: usize, z: usize) -> Result<Arc<dyn CmpcScheme>> {
        self.resolve_policy(SchemeParams::try_new(s, t, z)?)
    }

    fn resolve_policy(&self, params: SchemeParams) -> Result<Arc<dyn CmpcScheme>> {
        match self.config.policy {
            SchemePolicy::Fixed(spec) => spec.resolve(params),
            SchemePolicy::Adaptive => SchemeSpec::resolve_adaptive(params),
        }
    }

    fn factory(&mut self) -> Result<Arc<BackendFactory>> {
        if let Some(f) = &self.backend {
            return Ok(f.clone());
        }
        let f = Arc::new(BackendFactory::new(&self.config.backend)?);
        self.backend = Some(f.clone());
        Ok(f)
    }

    /// Fetch or provision the deployment serving `params` under the current
    /// policy. Returns the deployment and whether it was a cache hit.
    fn deployment_for(&mut self, params: SchemeParams) -> Result<(Arc<Deployment>, bool)> {
        let key = DeploymentKey {
            s: params.s,
            t: params.t,
            z: params.z,
        };
        if let Some(dep) = self.deployments.get(&key) {
            return Ok((dep.clone(), true));
        }
        let scheme = self.resolve_policy(params)?;
        let factory = self.factory()?;
        let proto_config = ProtocolConfig::builder()
            .backend(self.config.backend.clone())
            .verify(self.config.verify)
            .link_delay(self.config.link_delay)
            .threads(self.config.threads)
            .build();
        let dep = Arc::new(Deployment::for_scheme_shared(
            scheme,
            proto_config,
            factory,
            self.pool.clone(),
        )?);
        self.deployments.insert(key, dep.clone());
        Ok((dep, false))
    }

    /// Drain the queue, batching jobs that share a deployment signature.
    ///
    /// Deployment resolution runs first (sequentially — it touches the
    /// cache), then every job executes across the shared worker pool; jobs
    /// on the same *or* different deployments run concurrently. Jobs that
    /// share a deployment are **pipelined into its one persistent runtime**:
    /// their envelopes interleave, job-tagged, on the same fabric links and
    /// no threads are spawned per job (same-deployment jobs may contend on
    /// the shared scratch slots — see ROADMAP).
    ///
    /// **Ordering contract**: reports come back in **submission order**
    /// (ascending [`JobHandle`] id) regardless of pool size or completion
    /// order — `par_map` is order-preserving by construction, and callers
    /// (the CLI, tests that zip handles with reports, anything correlating
    /// responses by position) rely on `reports[i]` answering the i-th
    /// `submit`. A failing job yields an `Err` outcome *in its slot* and
    /// the batch keeps going. Per-job seeds are fixed at `submit`, so
    /// results are byte-identical at any pool size and under any job
    /// interleaving.
    pub fn drain(&mut self) -> Vec<JobReport> {
        let jobs = std::mem::take(&mut self.queue);
        let prepared: Vec<(Job, Result<(Arc<Deployment>, bool)>)> = jobs
            .into_iter()
            .map(|job| {
                let dep = self.deployment_for(job.params);
                (job, dep)
            })
            .collect();
        if self.config.fused {
            return self.drain_fused(prepared);
        }
        let pool = self.pool.clone();
        let reports = pool.par_map(&prepared, |_wid, _idx, (job, dep)| match dep {
            Err(e) => JobReport {
                id: job.id,
                scheme: String::new(),
                n_workers: 0,
                setup_cache_hit: false,
                outcome: Err(e.clone()),
            },
            Ok((dep, cache_hit)) => JobReport {
                id: job.id,
                scheme: dep.scheme().name(),
                n_workers: dep.n_workers(),
                setup_cache_hit: *cache_hit,
                outcome: dep.execute_seeded(&job.a, &job.b, job.seed),
            },
        });
        debug_assert!(
            reports.windows(2).all(|w| w[0].id < w[1].id),
            "drain must preserve submission order"
        );
        reports
    }

    /// The `config.fused` drain path: group job indices by (deployment
    /// identity, shape), run each ≥2-job group through
    /// [`Deployment::execute_fused_seeded`] (per-job seeds were fixed at
    /// `submit`, so results are byte-identical to the sequential drain),
    /// then run the leftovers — singletons, batch-level refusals, failed
    /// deployment lookups — through the ordinary per-job path. Reports
    /// still come back in submission order.
    fn drain_fused(&self, prepared: Vec<(Job, Result<(Arc<Deployment>, bool)>)>) -> Vec<JobReport> {
        let mut groups: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for (i, (job, dep)) in prepared.iter().enumerate() {
            if let Ok((dep, _)) = dep {
                groups
                    .entry((Arc::as_ptr(dep) as usize, job.a.rows))
                    .or_default()
                    .push(i);
            }
        }
        let mut outcomes: Vec<Option<Result<ProtocolOutput>>> =
            prepared.iter().map(|_| None).collect();
        for idxs in groups.values() {
            if idxs.len() < 2 {
                continue;
            }
            let (_, dep_res) = &prepared[idxs[0]];
            let (dep, _) = dep_res.as_ref().expect("grouped deployments are Ok");
            let refs: Vec<(&FpMat, &FpMat)> = idxs
                .iter()
                .map(|&i| (&prepared[i].0.a, &prepared[i].0.b))
                .collect();
            let seeds: Vec<u64> = idxs.iter().map(|&i| prepared[i].0.seed).collect();
            // A batch-level refusal leaves the group's slots unresolved;
            // they fall through to the per-job path below.
            if let Ok(outs) = dep.execute_fused_seeded(&refs, &seeds) {
                for (&i, out) in idxs.iter().zip(outs) {
                    outcomes[i] = Some(Ok(out));
                }
            }
        }
        let remaining: Vec<usize> = outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| o.is_none())
            .map(|(i, _)| i)
            .collect();
        let single_outs = self.pool.par_map(&remaining, |_wid, _k, &i| {
            let (job, dep) = &prepared[i];
            match dep {
                Err(e) => Err(e.clone()),
                Ok((dep, _)) => dep.execute_seeded(&job.a, &job.b, job.seed),
            }
        });
        for (&i, out) in remaining.iter().zip(single_outs) {
            outcomes[i] = Some(out);
        }
        prepared
            .into_iter()
            .zip(outcomes)
            .map(|((job, dep), outcome)| match dep {
                Err(e) => JobReport {
                    id: job.id,
                    scheme: String::new(),
                    n_workers: 0,
                    setup_cache_hit: false,
                    outcome: Err(e),
                },
                Ok((dep, cache_hit)) => JobReport {
                    id: job.id,
                    scheme: dep.scheme().name(),
                    n_workers: dep.n_workers(),
                    setup_cache_hit: cache_hit,
                    outcome: outcome.expect("every job resolved"),
                },
            })
            .collect()
    }
}

/// Instantiate a constructible scheme by analysis-level kind through the
/// registry. Formula-only baselines (SSMM, GCSA-NA) yield
/// [`crate::error::CmpcError::InvalidParams`] — they can be analyzed, not
/// run.
pub fn build_scheme(
    kind: crate::analysis::SchemeKind,
    s: usize,
    t: usize,
    z: usize,
) -> Result<Arc<dyn CmpcScheme>> {
    SchemeSpec::from_kind(kind)?.resolve(SchemeParams::try_new(s, t, z)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SchemeKind;
    use crate::error::CmpcError;
    use crate::util::rng::ChaChaRng;

    fn unwrap_output(r: &JobReport) -> &ProtocolOutput {
        r.outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("job {} failed: {e}", r.id))
    }

    #[test]
    fn adaptive_policy_picks_minimum_workers() {
        let coord = Coordinator::new(CoordinatorConfig::default());
        // Example 1 territory: AGE(17) < PolyDot(18) < Entangled(19).
        let sch = coord.select_scheme(2, 2, 2).unwrap();
        assert_eq!(sch.n_workers(), 17);
        assert!(sch.name().starts_with("AGE"));
    }

    #[test]
    fn jobs_batch_and_verify() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(6);
        let mats: Vec<(FpMat, FpMat)> = (0..3)
            .map(|_| {
                (
                    FpMat::random(&mut rng, 8, 8),
                    FpMat::random(&mut rng, 8, 8),
                )
            })
            .collect();
        let mut handles = Vec::new();
        for (a, b) in &mats {
            handles.push(coord.submit(a.clone(), b.clone(), 2, 2, 2).unwrap());
        }
        assert_eq!(coord.pending(), 3);
        let reports = coord.drain();
        assert_eq!(coord.pending(), 0);
        assert_eq!(reports.len(), 3);
        // handles correlate with reports in submission order
        for (h, r) in handles.iter().zip(&reports) {
            assert_eq!(h.id(), r.id);
        }
        // identical (scheme, s, t, z) ⇒ deployment provisioned once, reused
        assert!(!reports[0].setup_cache_hit);
        assert!(reports[1].setup_cache_hit && reports[2].setup_cache_hit);
        assert_eq!(coord.provisioned_deployments(), 1);
        for (r, (a, b)) in reports.iter().zip(&mats) {
            let out = unwrap_output(r);
            assert!(out.verified);
            assert_eq!(out.y, a.transpose().matmul(b));
        }
    }

    #[test]
    fn drain_reports_stay_in_submission_order_under_parallelism() {
        // S2 pin: the ordering contract holds at a pool size that forces
        // genuine interleaving, with jobs of different cost (two distinct
        // signatures) so completion order differs from submission order.
        let mut coord = Coordinator::new(
            CoordinatorConfig::builder().threads(4).build(),
        );
        let mut rng = ChaChaRng::seed_from_u64(42);
        let mut handles = Vec::new();
        for k in 0..8 {
            let m = if k % 2 == 0 { 8 } else { 4 };
            let a = FpMat::random(&mut rng, m, m);
            let b = FpMat::random(&mut rng, m, m);
            handles.push(coord.submit(a, b, 2, 2, if k % 2 == 0 { 2 } else { 1 }).unwrap());
        }
        let reports = coord.drain();
        assert_eq!(reports.len(), handles.len());
        for (h, r) in handles.iter().zip(&reports) {
            assert_eq!(h.id(), r.id, "reports[i] must answer the i-th submit");
        }
        assert!(
            reports.windows(2).all(|w| w[0].id < w[1].id),
            "ids must ascend"
        );
        for r in &reports {
            assert!(unwrap_output(r).verified);
        }
    }

    /// The fused drain must be observably identical to the default drain:
    /// same Y, same per-worker ξ/σ counters, same traffic, same order —
    /// seeds are fixed at `submit`, so two coordinators give the comparison.
    #[test]
    fn fused_drain_matches_sequential_drain() {
        let mut rng = ChaChaRng::seed_from_u64(31);
        // Two signatures and two shapes: (2,2,2)@m=8 fuses as a pair,
        // (2,2,1)@m=4 fuses as a pair, the odd m=8 job with z=1 runs alone.
        let jobs: Vec<(FpMat, FpMat, usize)> = vec![
            (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8), 2),
            (FpMat::random(&mut rng, 4, 4), FpMat::random(&mut rng, 4, 4), 1),
            (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8), 2),
            (FpMat::random(&mut rng, 4, 4), FpMat::random(&mut rng, 4, 4), 1),
            (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8), 1),
        ];
        let run = |fused: bool| -> Vec<JobReport> {
            let mut coord = Coordinator::new(
                CoordinatorConfig::builder().fused(fused).build(),
            );
            for (a, b, z) in &jobs {
                coord.submit(a.clone(), b.clone(), 2, 2, *z).unwrap();
            }
            coord.drain()
        };
        let sequential = run(false);
        let fused = run(true);
        assert_eq!(sequential.len(), fused.len());
        for (s, f) in sequential.iter().zip(&fused) {
            assert_eq!(s.id, f.id, "submission order");
            assert_eq!(s.scheme, f.scheme);
            let (so, fo) = (unwrap_output(s), unwrap_output(f));
            assert_eq!(so.y, fo.y, "job {}: Y", s.id);
            assert!(fo.verified);
            assert_eq!(so.traffic, fo.traffic, "job {}: traffic", s.id);
            for (wn, (sc, fc)) in so
                .worker_counters
                .iter()
                .zip(&fo.worker_counters)
                .enumerate()
            {
                assert_eq!(sc.mults(), fc.mults(), "job {} worker {wn}: ξ", s.id);
                assert_eq!(sc.stored(), fc.stored(), "job {} worker {wn}: σ", s.id);
            }
        }
    }

    #[test]
    fn pipelines_share_the_deployment_cache_with_jobs() {
        use crate::mpc::pipeline::{pipeline_input, pipeline_weight, Pipeline};
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(12);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        coord.submit(a, b, 2, 2, 2).unwrap();
        let reports = coord.drain();
        assert!(unwrap_output(&reports[0]).verified);
        let pipe = Pipeline::parse_spec("matmul,truncate:4,matmul").unwrap();
        let x = pipeline_input(5, 8);
        let w0 = pipeline_weight(5, 8, 0);
        let w1 = pipeline_weight(5, 8, 1);
        let out = coord.run_pipeline(&pipe, &x, &[&w0, &w1], 2, 2, 2).unwrap();
        assert!(out.verified);
        assert_eq!(out.rounds, 2);
        // same (s, t, z) signature ⇒ the drain's deployment was reused
        assert_eq!(coord.provisioned_deployments(), 1);
    }

    #[test]
    fn cache_persists_across_drains() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(7);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        coord.submit(a.clone(), b.clone(), 2, 2, 2).unwrap();
        let r1 = coord.drain();
        coord.submit(a, b, 2, 2, 2).unwrap();
        let r2 = coord.drain();
        assert!(!r1[0].setup_cache_hit);
        assert!(r2[0].setup_cache_hit);
    }

    #[test]
    fn submit_rejects_malformed_jobs_at_intake() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(8);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        // z = 0
        assert!(matches!(
            coord.submit(a.clone(), b.clone(), 2, 2, 0),
            Err(CmpcError::InvalidParams(_))
        ));
        // s = 0
        assert!(matches!(
            coord.submit(a.clone(), b.clone(), 0, 2, 1),
            Err(CmpcError::InvalidParams(_))
        ));
        // partition does not divide m
        assert!(matches!(
            coord.submit(a.clone(), b.clone(), 3, 2, 1),
            Err(CmpcError::ShapeMismatch(_))
        ));
        // mismatched operand sizes
        let small = FpMat::random(&mut rng, 4, 4);
        assert!(matches!(
            coord.submit(a.clone(), small, 2, 2, 1),
            Err(CmpcError::ShapeMismatch(_))
        ));
        // non-square operand
        let rect = FpMat::random(&mut rng, 8, 4);
        assert!(matches!(
            coord.submit(rect, b.clone(), 2, 2, 1),
            Err(CmpcError::ShapeMismatch(_))
        ));
        // nothing malformed was queued; a good job still flows
        assert_eq!(coord.pending(), 0);
        coord.submit(a, b, 2, 2, 1).unwrap();
        let reports = coord.drain();
        assert!(unwrap_output(&reports[0]).verified);
    }

    #[test]
    fn per_job_failure_does_not_abort_batch() {
        // A backend that cannot start fails each job's deployment lookup;
        // reports carry the error and the drain completes.
        let mut coord = Coordinator::new(
            CoordinatorConfig::builder()
                .backend(BackendChoice::Pjrt {
                    // a *file* path component makes manifest reading fail
                    artifacts_dir: std::path::PathBuf::from("/dev/null"),
                })
                .build(),
        );
        let mut rng = ChaChaRng::seed_from_u64(9);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        coord.submit(a.clone(), b.clone(), 2, 2, 1).unwrap();
        coord.submit(a, b, 2, 2, 1).unwrap();
        let reports = coord.drain();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(r.outcome.is_err(), "job {} should fail", r.id);
        }
    }

    #[test]
    fn fixed_policy_respected() {
        let coord = Coordinator::new(
            CoordinatorConfig::builder()
                .policy(SchemePolicy::Fixed(SchemeSpec::PolyDot))
                .build(),
        );
        assert_eq!(coord.select_scheme(2, 2, 2).unwrap().name(), "PolyDot-CMPC");
    }

    #[test]
    fn ssmm_not_constructible() {
        let err = build_scheme(SchemeKind::Ssmm, 2, 2, 2).unwrap_err();
        assert!(err.to_string().contains("formula-level baseline"));
    }
}
