//! Serving coordinator — the L3 layer a deployment actually talks to.
//!
//! Responsibilities (mirroring a vLLM-router-style front end, specialized to
//! CMPC):
//!
//! * **Job intake & queueing** — [`Coordinator::submit`] accepts
//!   `Y = AᵀB` jobs with per-job privacy/partition parameters.
//! * **Scheme selection** — [`SchemePolicy::Adaptive`] runs Phase 0 of
//!   Algorithm 3 generalized across constructions: it picks the
//!   constructible scheme (AGE at its λ*, PolyDot, Entangled) with the
//!   fewest workers for the job's `(s,t,z)`.
//! * **Setup caching & batching** — the O(N³) generalized-Vandermonde solve
//!   and α assignment are cached per `(scheme, s, t, z)` signature;
//!   [`Coordinator::run_all`] groups queued jobs by signature so a worker
//!   deployment is provisioned once per group.
//! * **Backend management** — native or PJRT (AOT artifacts) per
//!   [`BackendChoice`].
//! * **Metrics** — per-job [`JobReport`]s with worker counts, phase
//!   timings, traffic, and verification status.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use crate::analysis::SchemeKind;
use crate::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
use crate::matrix::FpMat;
use crate::metrics::{PhaseTimings, TrafficReport};
use crate::mpc::protocol::{self, ProtocolConfig, Setup};
use crate::runtime::BackendChoice;

/// How the coordinator picks a construction for each job.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum SchemePolicy {
    /// Always use the given constructible scheme.
    Fixed(SchemeKind),
    /// Minimize provisioned workers across constructible schemes
    /// (AGE λ*, PolyDot, Entangled).
    Adaptive,
}

/// Coordinator-wide configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub policy: SchemePolicy,
    pub backend: BackendChoice,
    /// Verify every product natively (disable for throughput benchmarks).
    pub verify: bool,
    /// Optional straggler injection passed through to the protocol.
    pub link_delay: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> CoordinatorConfig {
        CoordinatorConfig {
            policy: SchemePolicy::Adaptive,
            backend: BackendChoice::Native,
            verify: true,
            link_delay: None,
        }
    }
}

/// One queued multiplication job.
pub struct Job {
    pub id: u64,
    pub a: FpMat,
    pub b: FpMat,
    pub s: usize,
    pub t: usize,
    pub z: usize,
    pub seed: u64,
}

/// Outcome of one job.
pub struct JobReport {
    pub id: u64,
    pub scheme: String,
    pub n_workers: usize,
    pub stragglers_tolerated: usize,
    pub timings: PhaseTimings,
    pub traffic: TrafficReport,
    pub verified: bool,
    pub y: FpMat,
    /// True when the deployment setup was served from the coordinator cache.
    pub setup_cache_hit: bool,
}

/// Signature under which deployments (α assignment + reconstruction
/// coefficients) are shared between jobs.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct DeploymentKey {
    scheme: String,
    s: usize,
    t: usize,
    z: usize,
}

/// The serving coordinator.
pub struct Coordinator {
    config: CoordinatorConfig,
    queue: Vec<Job>,
    next_id: u64,
    setups: BTreeMap<DeploymentKey, Arc<Setup>>,
    /// Backend factory shared across all jobs: the PJRT client (and its
    /// compiled-executable cache) lives for the coordinator's lifetime
    /// instead of being re-created per job (§Perf P1).
    backend: Option<crate::runtime::BackendFactory>,
}

impl Coordinator {
    pub fn new(config: CoordinatorConfig) -> Coordinator {
        Coordinator {
            config,
            queue: Vec::new(),
            next_id: 0,
            setups: BTreeMap::new(),
            backend: None,
        }
    }

    /// Queue a job; returns its id.
    pub fn submit(&mut self, a: FpMat, b: FpMat, s: usize, t: usize, z: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let seed = 0x5EED ^ (id.wrapping_mul(0x9E3779B97F4A7C15));
        self.queue.push(Job {
            id,
            a,
            b,
            s,
            t,
            z,
            seed,
        });
        id
    }

    /// Resolve the scheme for a parameter triple under the current policy.
    pub fn select_scheme(&self, s: usize, t: usize, z: usize) -> Box<dyn CmpcScheme> {
        match self.config.policy {
            SchemePolicy::Fixed(kind) => build_scheme(kind, s, t, z),
            SchemePolicy::Adaptive => {
                let candidates: [Box<dyn CmpcScheme>; 3] = [
                    Box::new(AgeCmpc::with_optimal_lambda(s, t, z)),
                    Box::new(PolyDotCmpc::new(s, t, z)),
                    Box::new(EntangledCmpc::new(s, t, z)),
                ];
                candidates
                    .into_iter()
                    .min_by_key(|c| c.n_workers())
                    .unwrap()
            }
        }
    }

    /// Drain the queue, batching jobs that share a deployment. Jobs are
    /// returned in submission order.
    pub fn run_all(&mut self) -> anyhow::Result<Vec<JobReport>> {
        if self.backend.is_none() {
            self.backend = Some(crate::runtime::BackendFactory::new(&self.config.backend)?);
        }
        let jobs = std::mem::take(&mut self.queue);
        let mut reports: Vec<JobReport> = Vec::with_capacity(jobs.len());
        for job in jobs {
            let scheme = self.select_scheme(job.s, job.t, job.z);
            let key = DeploymentKey {
                scheme: scheme.name(),
                s: job.s,
                t: job.t,
                z: job.z,
            };
            let (setup, cache_hit) = match self.setups.get(&key) {
                Some(s) => (s.clone(), true),
                None => {
                    let s = Arc::new(protocol::prepare_setup(scheme.as_ref()));
                    self.setups.insert(key.clone(), s.clone());
                    (s, false)
                }
            };
            let cfg = ProtocolConfig {
                backend: self.config.backend.clone(),
                seed: job.seed,
                verify: self.config.verify,
                worker_delays: Vec::new(),
                link_delay: self.config.link_delay,
            };
            let out = protocol::run_protocol_with_factory(
                scheme.as_ref(),
                &setup,
                &job.a,
                &job.b,
                &cfg,
                self.backend.as_ref().unwrap(),
            )?;
            reports.push(JobReport {
                id: job.id,
                scheme: out.scheme_name,
                n_workers: out.n_workers,
                stragglers_tolerated: out.stragglers_tolerated,
                timings: out.timings,
                traffic: out.traffic,
                verified: out.verified,
                y: out.y,
                setup_cache_hit: cache_hit,
            });
        }
        Ok(reports)
    }
}

/// Instantiate a constructible scheme by kind.
///
/// # Panics
/// Panics for formula-only baselines (SSMM, GCSA-NA) — they cannot be run,
/// only analyzed (see `codes::baselines`).
pub fn build_scheme(kind: SchemeKind, s: usize, t: usize, z: usize) -> Box<dyn CmpcScheme> {
    match kind {
        SchemeKind::Age => Box::new(AgeCmpc::with_optimal_lambda(s, t, z)),
        SchemeKind::PolyDot => Box::new(PolyDotCmpc::new(s, t, z)),
        SchemeKind::Entangled => Box::new(EntangledCmpc::new(s, t, z)),
        SchemeKind::Ssmm | SchemeKind::GcsaNa => {
            panic!("{} is a formula-level baseline, not constructible", kind.label())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::ChaChaRng;

    #[test]
    fn adaptive_policy_picks_minimum_workers() {
        let coord = Coordinator::new(CoordinatorConfig::default());
        // Example 1 territory: AGE(17) < Entangled(19); PolyDot(2,2,2) = 18.
        let sch = coord.select_scheme(2, 2, 2);
        assert_eq!(sch.n_workers(), 17);
        assert!(sch.name().starts_with("AGE"));
    }

    #[test]
    fn jobs_batch_and_verify() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(6);
        let mats: Vec<(FpMat, FpMat)> = (0..3)
            .map(|_| {
                (
                    FpMat::random(&mut rng, 8, 8),
                    FpMat::random(&mut rng, 8, 8),
                )
            })
            .collect();
        for (a, b) in &mats {
            coord.submit(a.clone(), b.clone(), 2, 2, 2);
        }
        let reports = coord.run_all().unwrap();
        assert_eq!(reports.len(), 3);
        // identical (scheme, s, t, z) ⇒ setup computed once, reused twice
        assert!(!reports[0].setup_cache_hit);
        assert!(reports[1].setup_cache_hit && reports[2].setup_cache_hit);
        for (r, (a, b)) in reports.iter().zip(&mats) {
            assert!(r.verified);
            assert_eq!(r.y, a.transpose().matmul(b));
        }
    }

    #[test]
    fn cache_persists_across_run_all_calls() {
        let mut coord = Coordinator::new(CoordinatorConfig::default());
        let mut rng = ChaChaRng::seed_from_u64(7);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        coord.submit(a.clone(), b.clone(), 2, 2, 2);
        let r1 = coord.run_all().unwrap();
        coord.submit(a, b, 2, 2, 2);
        let r2 = coord.run_all().unwrap();
        assert!(!r1[0].setup_cache_hit);
        assert!(r2[0].setup_cache_hit);
    }

    #[test]
    fn fixed_policy_respected() {
        let coord = Coordinator::new(CoordinatorConfig {
            policy: SchemePolicy::Fixed(SchemeKind::PolyDot),
            ..CoordinatorConfig::default()
        });
        assert_eq!(coord.select_scheme(2, 2, 2).name(), "PolyDot-CMPC");
    }

    #[test]
    #[should_panic(expected = "formula-level baseline")]
    fn ssmm_not_constructible() {
        build_scheme(SchemeKind::Ssmm, 2, 2, 2);
    }
}
