//! Overhead accounting — the *measured* counterparts of Corollaries 10–12.
//!
//! The protocol engine increments these counters at the exact points the
//! paper's proofs enumerate (scalar multiplications performed, scalars
//! stored, scalars exchanged), so integration tests can assert
//! `measured == closed form` — validating both the implementation and the
//! paper's accounting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Per-worker overhead counters (shared across the worker's phases).
#[derive(Default, Debug)]
pub struct WorkerCounters {
    /// ξ contributions: scalar multiplications performed.
    pub scalar_mults: AtomicU64,
    /// σ contributions: scalars written to worker-resident storage
    /// (never decremented — the paper's σ ignores deletion, see fn. 5).
    pub stored_scalars: AtomicU64,
}

impl WorkerCounters {
    pub fn add_mults(&self, n: u64) {
        self.scalar_mults.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_stored(&self, n: u64) {
        self.stored_scalars.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite both counters with the totals a worker reported in its
    /// `JobDone`/`AbortAck` control message. On the in-process fabric the
    /// worker incremented this very instance, so the store is idempotent;
    /// over a remote transport (where the `Arc` cannot be shared) this is
    /// how the driver-side counters become exact.
    pub fn record_final(&self, mults: u64, stored: u64) {
        self.scalar_mults.store(mults, Ordering::Relaxed);
        self.stored_scalars.store(stored, Ordering::Relaxed);
    }

    pub fn mults(&self) -> u64 {
        self.scalar_mults.load(Ordering::Relaxed)
    }

    pub fn stored(&self) -> u64 {
        self.stored_scalars.load(Ordering::Relaxed)
    }
}

/// Traffic totals collected by the network fabric, split by edge class.
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficReport {
    /// Phase 1: source → worker scalars.
    pub source_to_worker: u64,
    /// Phase 2: worker ↔ worker scalars (the ζ of eq. 34).
    pub worker_to_worker: u64,
    /// Phase 3: worker → master scalars.
    pub worker_to_master: u64,
    /// Message count across all links.
    pub messages: u64,
}

/// Shared atomic accumulator behind [`TrafficReport`].
#[derive(Default, Debug)]
pub struct TrafficCounters {
    pub source_to_worker: AtomicU64,
    pub worker_to_worker: AtomicU64,
    pub worker_to_master: AtomicU64,
    pub messages: AtomicU64,
}

impl TrafficCounters {
    pub fn shared() -> Arc<TrafficCounters> {
        Arc::new(TrafficCounters::default())
    }

    pub fn snapshot(&self) -> TrafficReport {
        TrafficReport {
            source_to_worker: self.source_to_worker.load(Ordering::Relaxed),
            worker_to_worker: self.worker_to_worker.load(Ordering::Relaxed),
            worker_to_master: self.worker_to_master.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
        }
    }
}

/// On-wire byte totals of a serialized transport (the framed codec of
/// `transport::wire`), split by edge class like [`TrafficReport`] — but in
/// **bytes actually written to the wire**, framing included, so the
/// measured communication can be compared against the analytical ζ (eq. 34,
/// in scalars × 4 bytes) with the framing overhead made visible.
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireStats {
    /// Phase 1: source → worker frame bytes.
    pub bytes_source_to_worker: u64,
    /// Phase 2: worker ↔ worker frame bytes (the on-wire form of ζ).
    pub bytes_worker_to_worker: u64,
    /// Phase 3: worker → master frame bytes.
    pub bytes_worker_to_master: u64,
    /// Control-plane frame bytes (job lifecycle; unmetered in ζ).
    pub bytes_control: u64,
    /// Frames written.
    pub frames: u64,
    /// Inbound frames that failed to decode (corrupt/truncated/stale peer).
    pub decode_errors: u64,
}

impl WireStats {
    /// All payload-class bytes plus control bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_source_to_worker
            + self.bytes_worker_to_worker
            + self.bytes_worker_to_master
            + self.bytes_control
    }

    /// Fold another snapshot into this one (summing a cluster's transports).
    pub fn merge(&mut self, other: &WireStats) {
        self.bytes_source_to_worker += other.bytes_source_to_worker;
        self.bytes_worker_to_worker += other.bytes_worker_to_worker;
        self.bytes_worker_to_master += other.bytes_worker_to_master;
        self.bytes_control += other.bytes_control;
        self.frames += other.frames;
        self.decode_errors += other.decode_errors;
    }
}

/// Shared atomic accumulator behind [`WireStats`].
#[derive(Default, Debug)]
pub struct WireCounters {
    pub bytes_source_to_worker: AtomicU64,
    pub bytes_worker_to_worker: AtomicU64,
    pub bytes_worker_to_master: AtomicU64,
    pub bytes_control: AtomicU64,
    pub frames: AtomicU64,
    pub decode_errors: AtomicU64,
}

impl WireCounters {
    pub fn snapshot(&self) -> WireStats {
        WireStats {
            bytes_source_to_worker: self.bytes_source_to_worker.load(Ordering::Relaxed),
            bytes_worker_to_worker: self.bytes_worker_to_worker.load(Ordering::Relaxed),
            bytes_worker_to_master: self.bytes_worker_to_master.load(Ordering::Relaxed),
            bytes_control: self.bytes_control.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
        }
    }
}

/// Health counters of one elastic worker runtime (shared by its workers,
/// its reaper, and the job drivers; snapshot with
/// [`RuntimeCounters::snapshot`]).
///
/// These are the measured counterparts of the fault-tolerance story: how
/// often the runtime actually exercised eviction/respawn, the early-decode
/// fast path, and the per-job deadline machinery.
#[derive(Default, Debug)]
pub struct RuntimeCounters {
    /// Worker threads found dead (panic, chaos kill, or self-eviction
    /// after consecutive deadline misses) and removed.
    pub evictions: AtomicU64,
    /// Replacement worker threads provisioned (one per eviction, unless a
    /// respawn itself failed and was retried later).
    pub respawns: AtomicU64,
    /// Jobs whose master decoded at the `t²+z` quota and cancelled the
    /// straggler tail instead of draining it.
    pub early_decodes: AtomicU64,
    /// Per-job deadline expiries reported by workers (each failed exactly
    /// one job at one worker).
    pub deadline_misses: AtomicU64,
    /// `JobAbort` broadcasts issued by job drivers on the failure path.
    pub jobs_aborted: AtomicU64,
}

impl RuntimeCounters {
    pub fn snapshot(&self) -> RuntimeHealthReport {
        RuntimeHealthReport {
            evictions: self.evictions.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            early_decodes: self.early_decodes.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            jobs_aborted: self.jobs_aborted.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time snapshot of [`RuntimeCounters`].
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct RuntimeHealthReport {
    pub evictions: u64,
    pub respawns: u64,
    pub early_decodes: u64,
    pub deadline_misses: u64,
    pub jobs_aborted: u64,
}

/// Wall-clock phase breakdown of one protocol run.
///
/// The windows are measured separately and do **not** overlap, so
/// [`PhaseTimings::total`] is the job's end-to-end latency excluding
/// verification. (Before the persistent-runtime refactor,
/// `phase2_compute` reported total elapsed *including* reconstruction and
/// `phase3_reconstruct` was the worker-tail remainder — the fields now
/// mean what their names say.)
#[derive(Default, Debug, Clone, Copy)]
pub struct PhaseTimings {
    /// Per-job intake: secret-stream derivation, counter registration, and
    /// the `JobStart` hand-off to the persistent workers. (Deployment
    /// provisioning — the O(N³) solve, thread spawns — is *not* part of
    /// any job's timings.)
    pub setup: std::time::Duration,
    /// Phase 1: building both share polynomials and encoding + sending
    /// every worker's share pair.
    pub phase1_share: std::time::Duration,
    /// Phase 2 as observed by the master: from the end of Phase 1 until
    /// the `t²+z`-th I-share arrived, **plus** the post-reconstruction wait
    /// for the remaining workers to finish (the straggler tail). Worker
    /// compute, the G-exchange, and transfer overlap inside this window.
    pub phase2_compute: std::time::Duration,
    /// Phase 3: the master's reconstruction math only — the dense
    /// Vandermonde solve and the t² block combinations.
    pub phase3_reconstruct: std::time::Duration,
    /// Early-decode fast path only: after reconstruction, waiting for the
    /// aborted stragglers' `AbortAck`s so the per-worker overhead counters
    /// are final at job return. Zero on the full-drain path (its
    /// tail wait is inside `phase2_compute`). Kept out of `phase2_compute`
    /// because the decoded `Y` was already in hand when this window opened.
    pub ack_wait: std::time::Duration,
}

impl PhaseTimings {
    pub fn total(&self) -> std::time::Duration {
        self.setup + self.phase1_share + self.phase2_compute + self.phase3_reconstruct
            + self.ack_wait
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = WorkerCounters::default();
        c.add_mults(10);
        c.add_mults(5);
        c.add_stored(7);
        assert_eq!(c.mults(), 15);
        assert_eq!(c.stored(), 7);
    }

    #[test]
    fn runtime_health_snapshot() {
        let c = RuntimeCounters::default();
        c.evictions.fetch_add(2, Ordering::Relaxed);
        c.respawns.fetch_add(2, Ordering::Relaxed);
        c.early_decodes.fetch_add(1, Ordering::Relaxed);
        let snap = c.snapshot();
        assert_eq!(snap.evictions, 2);
        assert_eq!(snap.respawns, 2);
        assert_eq!(snap.early_decodes, 1);
        assert_eq!(snap.deadline_misses, 0);
        assert_eq!(snap.jobs_aborted, 0);
    }

    #[test]
    fn traffic_snapshot() {
        let t = TrafficCounters::shared();
        t.worker_to_worker.fetch_add(42, Ordering::Relaxed);
        t.messages.fetch_add(2, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.worker_to_worker, 42);
        assert_eq!(snap.messages, 2);
        assert_eq!(snap.source_to_worker, 0);
    }
}
