//! Overhead accounting — the *measured* counterparts of Corollaries 10–12.
//!
//! The protocol engine increments these counters at the exact points the
//! paper's proofs enumerate (scalar multiplications performed, scalars
//! stored, scalars exchanged), so integration tests can assert
//! `measured == closed form` — validating both the implementation and the
//! paper's accounting.
//!
//! # The runtime counter contract
//!
//! A deployment's lifetime counters advance the same way no matter which
//! execution path a workload takes, so operators can reconcile them:
//!
//! * **`jobs_started`** (`WorkerRuntime::jobs_started`) — one per fabric
//!   job id claimed: `execute` claims **1**, `execute_fused` claims **k**
//!   for a k-job batch (the genuinely fused path claims the whole block up
//!   front even though it streams no per-job envelopes — fixed in v0.10;
//!   before that, fused jobs did not advance the counter), and a pipeline
//!   claims **one per round** (each stage is a real fabric job so the
//!   reaper can respawn chaos-killed workers between rounds).
//! * **[`RuntimeHealthReport::phase3_decodes`]** — one per Phase-3
//!   interpolation of an output `Y`: **1** per executed job, **1** per
//!   fused batch (the fused decode amortizes the batch), and **1** per
//!   pipeline — intermediate pipeline stages are *masked opens*, not
//!   Phase-3 decodes, which is exactly the property the pipeline tests
//!   pin (`phase3_decodes == 1` for a 3-stage pipeline).
//! * **[`RuntimeHealthReport::pipeline_stages`]** — one per pipeline round
//!   driven (masked or final), so `pipeline_stages == Σ rounds` across all
//!   pipelines a deployment served.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Per-worker overhead counters (shared across the worker's phases).
#[derive(Default, Debug)]
pub struct WorkerCounters {
    /// ξ contributions: scalar multiplications performed.
    pub scalar_mults: AtomicU64,
    /// σ contributions: scalars written to worker-resident storage
    /// (never decremented — the paper's σ ignores deletion, see fn. 5).
    pub stored_scalars: AtomicU64,
}

impl WorkerCounters {
    /// Add `n` scalar multiplications to the ξ total.
    pub fn add_mults(&self, n: u64) {
        self.scalar_mults.fetch_add(n, Ordering::Relaxed);
    }

    /// Add `n` stored scalars to the σ total.
    pub fn add_stored(&self, n: u64) {
        self.stored_scalars.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite both counters with the totals a worker reported in its
    /// `JobDone`/`AbortAck` control message. On the in-process fabric the
    /// worker incremented this very instance, so the store is idempotent;
    /// over a remote transport (where the `Arc` cannot be shared) this is
    /// how the driver-side counters become exact.
    pub fn record_final(&self, mults: u64, stored: u64) {
        self.scalar_mults.store(mults, Ordering::Relaxed);
        self.stored_scalars.store(stored, Ordering::Relaxed);
    }

    /// Current ξ total (scalar multiplications performed).
    pub fn mults(&self) -> u64 {
        self.scalar_mults.load(Ordering::Relaxed)
    }

    /// Current σ total (scalars stored).
    pub fn stored(&self) -> u64 {
        self.stored_scalars.load(Ordering::Relaxed)
    }
}

/// Traffic totals collected by the network fabric, split by edge class.
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrafficReport {
    /// Phase 1: source → worker scalars.
    pub source_to_worker: u64,
    /// Phase 2: worker ↔ worker scalars (the ζ of eq. 34).
    pub worker_to_worker: u64,
    /// Phase 3: worker → master scalars.
    pub worker_to_master: u64,
    /// Message count across all links.
    pub messages: u64,
}

/// Shared atomic accumulator behind [`TrafficReport`].
#[derive(Default, Debug)]
pub struct TrafficCounters {
    /// Phase 1: source → worker scalars.
    pub source_to_worker: AtomicU64,
    /// Phase 2: worker ↔ worker scalars.
    pub worker_to_worker: AtomicU64,
    /// Phase 3: worker → master scalars.
    pub worker_to_master: AtomicU64,
    /// Message count across all links.
    pub messages: AtomicU64,
}

impl TrafficCounters {
    /// A fresh zeroed accumulator behind an `Arc`.
    pub fn shared() -> Arc<TrafficCounters> {
        Arc::new(TrafficCounters::default())
    }

    /// Snapshot the totals into a [`TrafficReport`].
    pub fn snapshot(&self) -> TrafficReport {
        TrafficReport {
            source_to_worker: self.source_to_worker.load(Ordering::Relaxed),
            worker_to_worker: self.worker_to_worker.load(Ordering::Relaxed),
            worker_to_master: self.worker_to_master.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
        }
    }
}

/// On-wire byte totals of a serialized transport (the framed codec of
/// `transport::wire`), split by edge class like [`TrafficReport`] — but in
/// **bytes actually written to the wire**, framing included, so the
/// measured communication can be compared against the analytical ζ (eq. 34,
/// in scalars × 4 bytes) with the framing overhead made visible.
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireStats {
    /// Phase 1: source → worker frame bytes.
    pub bytes_source_to_worker: u64,
    /// Phase 2: worker ↔ worker frame bytes (the on-wire form of ζ).
    pub bytes_worker_to_worker: u64,
    /// Phase 3: worker → master frame bytes.
    pub bytes_worker_to_master: u64,
    /// Control-plane frame bytes (job lifecycle; unmetered in ζ).
    pub bytes_control: u64,
    /// Frames written.
    pub frames: u64,
    /// Inbound frames that failed to decode (corrupt/truncated/stale peer).
    pub decode_errors: u64,
}

impl WireStats {
    /// All payload-class bytes plus control bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_source_to_worker
            + self.bytes_worker_to_worker
            + self.bytes_worker_to_master
            + self.bytes_control
    }

    /// Fold another snapshot into this one (summing a cluster's transports).
    pub fn merge(&mut self, other: &WireStats) {
        self.bytes_source_to_worker += other.bytes_source_to_worker;
        self.bytes_worker_to_worker += other.bytes_worker_to_worker;
        self.bytes_worker_to_master += other.bytes_worker_to_master;
        self.bytes_control += other.bytes_control;
        self.frames += other.frames;
        self.decode_errors += other.decode_errors;
    }
}

/// Shared atomic accumulator behind [`WireStats`].
#[derive(Default, Debug)]
pub struct WireCounters {
    /// Phase 1: source → worker frame bytes.
    pub bytes_source_to_worker: AtomicU64,
    /// Phase 2: worker ↔ worker frame bytes.
    pub bytes_worker_to_worker: AtomicU64,
    /// Phase 3: worker → master frame bytes.
    pub bytes_worker_to_master: AtomicU64,
    /// Control-plane frame bytes.
    pub bytes_control: AtomicU64,
    /// Frames written.
    pub frames: AtomicU64,
    /// Inbound frames that failed to decode.
    pub decode_errors: AtomicU64,
}

impl WireCounters {
    /// Snapshot the totals into a [`WireStats`].
    pub fn snapshot(&self) -> WireStats {
        WireStats {
            bytes_source_to_worker: self.bytes_source_to_worker.load(Ordering::Relaxed),
            bytes_worker_to_worker: self.bytes_worker_to_worker.load(Ordering::Relaxed),
            bytes_worker_to_master: self.bytes_worker_to_master.load(Ordering::Relaxed),
            bytes_control: self.bytes_control.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            decode_errors: self.decode_errors.load(Ordering::Relaxed),
        }
    }
}

/// Health counters of one elastic worker runtime (shared by its workers,
/// its reaper, and the job drivers; snapshot with
/// [`RuntimeCounters::snapshot`]).
///
/// These are the measured counterparts of the fault-tolerance story: how
/// often the runtime actually exercised eviction/respawn, the early-decode
/// fast path, and the per-job deadline machinery.
#[derive(Default, Debug)]
pub struct RuntimeCounters {
    /// Worker threads found dead (panic, chaos kill, or self-eviction
    /// after consecutive deadline misses) and removed.
    pub evictions: AtomicU64,
    /// Replacement worker threads provisioned (one per eviction, unless a
    /// respawn itself failed and was retried later).
    pub respawns: AtomicU64,
    /// Jobs whose master decoded at the `t²+z` quota and cancelled the
    /// straggler tail instead of draining it.
    pub early_decodes: AtomicU64,
    /// Per-job deadline expiries reported by workers (each failed exactly
    /// one job at one worker).
    pub deadline_misses: AtomicU64,
    /// `JobAbort` broadcasts issued by job drivers on the failure path.
    pub jobs_aborted: AtomicU64,
    /// Garbled I-shares located (and excluded) by the Byzantine decoder —
    /// one tick per blamed worker, across all jobs.
    pub byzantine_detected: AtomicU64,
    /// Phase-3 interpolations of an output `Y` — one per executed job, one
    /// per fused batch, one per *pipeline* (see the counter contract in the
    /// module docs).
    pub phase3_decodes: AtomicU64,
    /// Pipeline rounds driven (masked opens and final decodes alike).
    pub pipeline_stages: AtomicU64,
}

impl RuntimeCounters {
    /// Snapshot every counter into a [`RuntimeHealthReport`] (the blame
    /// log lives on the runtime, so `blamed_workers` stays empty here).
    pub fn snapshot(&self) -> RuntimeHealthReport {
        RuntimeHealthReport {
            evictions: self.evictions.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
            early_decodes: self.early_decodes.load(Ordering::Relaxed),
            deadline_misses: self.deadline_misses.load(Ordering::Relaxed),
            jobs_aborted: self.jobs_aborted.load(Ordering::Relaxed),
            byzantine_detected: self.byzantine_detected.load(Ordering::Relaxed),
            phase3_decodes: self.phase3_decodes.load(Ordering::Relaxed),
            pipeline_stages: self.pipeline_stages.load(Ordering::Relaxed),
            blamed_workers: Vec::new(),
            worker_strikes: Vec::new(),
        }
    }
}

/// Point-in-time snapshot of [`RuntimeCounters`], plus the runtime's blame
/// log ([`blamed_workers`] is filled in by `WorkerRuntime::health` — a bare
/// counter snapshot leaves it empty).
///
/// [`blamed_workers`]: RuntimeHealthReport::blamed_workers
#[derive(Default, Debug, Clone, PartialEq, Eq)]
pub struct RuntimeHealthReport {
    /// Worker threads found dead and removed.
    pub evictions: u64,
    /// Replacement worker threads provisioned.
    pub respawns: u64,
    /// Jobs decoded at the quota with the straggler tail cancelled.
    pub early_decodes: u64,
    /// Per-job deadline expiries reported by workers.
    pub deadline_misses: u64,
    /// `JobAbort` broadcasts issued by job drivers on the failure path.
    pub jobs_aborted: u64,
    /// Total garbled I-shares located and excluded (one per blamed worker
    /// per affected job).
    pub byzantine_detected: u64,
    /// Phase-3 decodes: one per executed job, one per fused batch, one per
    /// pipeline (the counter contract in the module docs).
    pub phase3_decodes: u64,
    /// Pipeline rounds driven (masked opens and final decodes alike).
    pub pipeline_stages: u64,
    /// Worker ids ever blamed by the Byzantine decoder, in blame order
    /// (duplicates possible if a respawned slot misbehaves again).
    pub blamed_workers: Vec<usize>,
    /// The strike ledger: `(worker_id, cumulative_strikes)` for every
    /// worker slot blamed at least once over the runtime's lifetime,
    /// ascending by id. Strikes **survive respawn** — the ledger is keyed
    /// by slot, so a flaky link that re-garbles the same index after every
    /// respawn accumulates strikes instead of resetting, which is how the
    /// autoscaler distinguishes persistent malice (or a bad NIC) from a
    /// one-off fault. Empty when no worker was ever blamed, so a healthy
    /// report still equals `RuntimeHealthReport::default()`.
    pub worker_strikes: Vec<(usize, u64)>,
}

/// Wall-clock phase breakdown of one protocol run.
///
/// The windows are measured separately and do **not** overlap, so
/// [`PhaseTimings::total`] is the job's end-to-end latency excluding
/// verification. (Before the persistent-runtime refactor,
/// `phase2_compute` reported total elapsed *including* reconstruction and
/// `phase3_reconstruct` was the worker-tail remainder — the fields now
/// mean what their names say.)
#[derive(Default, Debug, Clone, Copy)]
pub struct PhaseTimings {
    /// Per-job intake: secret-stream derivation, counter registration, and
    /// the `JobStart` hand-off to the persistent workers. (Deployment
    /// provisioning — the O(N³) solve, thread spawns — is *not* part of
    /// any job's timings.)
    pub setup: std::time::Duration,
    /// Phase 1: building both share polynomials and encoding + sending
    /// every worker's share pair.
    pub phase1_share: std::time::Duration,
    /// Phase 2 as observed by the master: from the end of Phase 1 until
    /// the `t²+z`-th I-share arrived, **plus** the post-reconstruction wait
    /// for the remaining workers to finish (the straggler tail). Worker
    /// compute, the G-exchange, and transfer overlap inside this window.
    pub phase2_compute: std::time::Duration,
    /// Phase 3: the master's reconstruction math only — the dense
    /// Vandermonde solve and the t² block combinations.
    pub phase3_reconstruct: std::time::Duration,
    /// Early-decode fast path only: after reconstruction, waiting for the
    /// aborted stragglers' `AbortAck`s so the per-worker overhead counters
    /// are final at job return. Zero on the full-drain path (its
    /// tail wait is inside `phase2_compute`). Kept out of `phase2_compute`
    /// because the decoded `Y` was already in hand when this window opened.
    pub ack_wait: std::time::Duration,
}

impl PhaseTimings {
    /// End-to-end job latency: the sum of the non-overlapping windows.
    pub fn total(&self) -> std::time::Duration {
        self.setup + self.phase1_share + self.phase2_compute + self.phase3_reconstruct
            + self.ack_wait
    }
}

/// Distinct typed rejection reasons the gateway can issue (the width of
/// the per-reason counter array — indexed by the reason's wire code, see
/// `transport::wire::RejectReason`).
pub const REJECT_REASONS: usize = 8;

/// Log₂ latency-histogram buckets: bucket `i` counts jobs whose serving
/// latency was in `[2^i, 2^{i+1})` µs — 32 buckets span sub-µs to ~35min.
pub const LATENCY_BUCKETS: usize = 32;

/// Batch-size histogram buckets: bucket `i` counts dispatched batches of
/// `i + 1` jobs; the last bucket absorbs everything at or above it.
pub const BATCH_BUCKETS: usize = 32;

/// Shared atomic accumulator behind [`GatewayStats`] — incremented by the
/// gateway's poller (admission), batcher (dispatch), and engine
/// (completion) threads.
#[derive(Default, Debug)]
pub struct GatewayCounters {
    /// Client connections accepted by the listener.
    pub connections: AtomicU64,
    /// Submissions admitted past the door (quota + validation passed).
    pub accepted: AtomicU64,
    /// Admitted jobs that returned a `Result` to their client.
    pub completed: AtomicU64,
    /// Admitted jobs that failed post-admission (`Internal` rejects).
    pub failed: AtomicU64,
    /// Typed rejections at the door, indexed by the reason's wire code.
    pub rejected: [AtomicU64; REJECT_REASONS],
    /// Batches dispatched onto a shared deployment.
    pub batches: AtomicU64,
    /// Jobs carried inside those batches (`batched_jobs / batches` =
    /// the mean batch size; ≥ 2-job batches prove observable batching).
    pub batched_jobs: AtomicU64,
    /// Gauge: admitted jobs currently waiting in the batcher.
    pub queue_depth: AtomicU64,
    /// High-water mark of `queue_depth`.
    pub peak_queue_depth: AtomicU64,
    /// Log₂ histogram of serving latency (admission → result encoded).
    pub latency_us: [AtomicU64; LATENCY_BUCKETS],
    /// Histogram of dispatched batch sizes.
    pub batch_size: [AtomicU64; BATCH_BUCKETS],
}

impl GatewayCounters {
    /// A fresh zeroed accumulator behind an `Arc`.
    pub fn shared() -> Arc<GatewayCounters> {
        Arc::new(GatewayCounters::default())
    }

    /// Record an accepted client connection.
    pub fn note_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a submission admitted past the door.
    pub fn note_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a typed rejection by its wire code (out-of-range codes fold
    /// into the last bucket rather than panic — the counter is telemetry,
    /// not a validator).
    pub fn note_rejected(&self, code: u8) {
        let idx = (code as usize).min(REJECT_REASONS - 1);
        self.rejected[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a post-admission failure (`Internal` reject to the client).
    pub fn note_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completed job and its serving latency.
    pub fn note_completed(&self, latency: std::time::Duration) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let us = latency.as_micros() as u64;
        // floor(log2(us)), with 0 µs in bucket 0.
        let idx = (63 - (us | 1).leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.latency_us[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched batch of `size` jobs.
    pub fn note_batch(&self, size: usize) {
        if size == 0 {
            return;
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_jobs.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_size[size.min(BATCH_BUCKETS) - 1].fetch_add(1, Ordering::Relaxed);
    }

    /// A job entered the batcher queue (bumps the gauge and its peak).
    pub fn queue_enter(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// A job left the batcher queue (dispatched or dropped).
    pub fn queue_exit(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// Snapshot every counter and histogram into a [`GatewayStats`].
    pub fn snapshot(&self) -> GatewayStats {
        use Ordering::Relaxed;
        let mut rejected = [0u64; REJECT_REASONS];
        for (slot, c) in rejected.iter_mut().zip(self.rejected.iter()) {
            *slot = c.load(Relaxed);
        }
        let mut latency_us = [0u64; LATENCY_BUCKETS];
        for (slot, c) in latency_us.iter_mut().zip(self.latency_us.iter()) {
            *slot = c.load(Relaxed);
        }
        let mut batch_size = [0u64; BATCH_BUCKETS];
        for (slot, c) in batch_size.iter_mut().zip(self.batch_size.iter()) {
            *slot = c.load(Relaxed);
        }
        GatewayStats {
            connections: self.connections.load(Relaxed),
            accepted: self.accepted.load(Relaxed),
            completed: self.completed.load(Relaxed),
            failed: self.failed.load(Relaxed),
            rejected,
            batches: self.batches.load(Relaxed),
            batched_jobs: self.batched_jobs.load(Relaxed),
            queue_depth: self.queue_depth.load(Relaxed),
            peak_queue_depth: self.peak_queue_depth.load(Relaxed),
            latency_us,
            batch_size,
        }
    }
}

/// Point-in-time snapshot of [`GatewayCounters`] — the serving-path
/// analogue of [`WireStats`], surfaced the same way (`cmpc gateway`
/// prints it at shutdown; `tests/gateway.rs` asserts on it).
#[derive(Default, Debug, Clone, Copy, PartialEq, Eq)]
pub struct GatewayStats {
    /// Client connections accepted by the listener.
    pub connections: u64,
    /// Submissions admitted past the door.
    pub accepted: u64,
    /// Admitted jobs that returned a `Result` to their client.
    pub completed: u64,
    /// Admitted jobs that failed post-admission.
    pub failed: u64,
    /// Typed rejections at the door, indexed by the reason's wire code.
    pub rejected: [u64; REJECT_REASONS],
    /// Batches dispatched onto a shared deployment.
    pub batches: u64,
    /// Jobs carried inside those batches.
    pub batched_jobs: u64,
    /// Gauge: admitted jobs waiting in the batcher at snapshot time.
    pub queue_depth: u64,
    /// High-water mark of `queue_depth`.
    pub peak_queue_depth: u64,
    /// Log₂ histogram of serving latency (admission → result encoded).
    pub latency_us: [u64; LATENCY_BUCKETS],
    /// Histogram of dispatched batch sizes.
    pub batch_size: [u64; BATCH_BUCKETS],
}

impl GatewayStats {
    /// Rejections summed across every reason.
    pub fn rejected_total(&self) -> u64 {
        self.rejected.iter().sum()
    }

    /// Approximate latency percentile (`p` in `0.0..=1.0`) from the log₂
    /// histogram: the upper bound of the bucket where the cumulative
    /// count crosses `p`, in µs. Zero when nothing completed.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_us.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &count) in self.latency_us.iter().enumerate() {
            seen += count;
            if seen >= target {
                return (1u64 << (i + 1)).saturating_sub(1);
            }
        }
        u64::MAX
    }

    /// Median serving latency (log₂-bucket upper bound, µs).
    pub fn p50_latency_us(&self) -> u64 {
        self.latency_percentile_us(0.50)
    }

    /// 99th-percentile serving latency (log₂-bucket upper bound, µs).
    pub fn p99_latency_us(&self) -> u64 {
        self.latency_percentile_us(0.99)
    }

    /// Largest batch size observed (bucket upper edge; 0 when none).
    pub fn max_batch(&self) -> usize {
        self.batch_size
            .iter()
            .enumerate()
            .rev()
            .find(|(_, &c)| c > 0)
            .map(|(i, _)| i + 1)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = WorkerCounters::default();
        c.add_mults(10);
        c.add_mults(5);
        c.add_stored(7);
        assert_eq!(c.mults(), 15);
        assert_eq!(c.stored(), 7);
    }

    #[test]
    fn runtime_health_snapshot() {
        let c = RuntimeCounters::default();
        c.evictions.fetch_add(2, Ordering::Relaxed);
        c.respawns.fetch_add(2, Ordering::Relaxed);
        c.early_decodes.fetch_add(1, Ordering::Relaxed);
        let snap = c.snapshot();
        assert_eq!(snap.evictions, 2);
        assert_eq!(snap.respawns, 2);
        assert_eq!(snap.early_decodes, 1);
        assert_eq!(snap.deadline_misses, 0);
        assert_eq!(snap.jobs_aborted, 0);
        c.byzantine_detected.fetch_add(3, Ordering::Relaxed);
        let snap = c.snapshot();
        assert_eq!(snap.byzantine_detected, 3);
        assert!(snap.blamed_workers.is_empty(), "bare snapshot has no blame log");
        assert!(snap.worker_strikes.is_empty(), "bare snapshot has no strike ledger");
    }

    #[test]
    fn traffic_snapshot() {
        let t = TrafficCounters::shared();
        t.worker_to_worker.fetch_add(42, Ordering::Relaxed);
        t.messages.fetch_add(2, Ordering::Relaxed);
        let snap = t.snapshot();
        assert_eq!(snap.worker_to_worker, 42);
        assert_eq!(snap.messages, 2);
        assert_eq!(snap.source_to_worker, 0);
    }

    #[test]
    fn gateway_snapshot_and_histograms() {
        let g = GatewayCounters::shared();
        g.note_connection();
        g.note_accepted();
        g.note_accepted();
        g.note_rejected(0); // quota-exceeded
        g.note_rejected(3); // malformed
        g.note_rejected(0xFF); // out-of-range folds into the last bucket
        g.queue_enter();
        g.queue_enter();
        g.queue_exit();
        g.note_batch(2);
        g.note_batch(1);
        g.note_batch(0); // ignored
        g.note_completed(std::time::Duration::from_micros(100));
        g.note_completed(std::time::Duration::from_micros(100));
        g.note_completed(std::time::Duration::from_millis(10));

        let s = g.snapshot();
        assert_eq!(s.connections, 1);
        assert_eq!(s.accepted, 2);
        assert_eq!(s.completed, 3);
        assert_eq!(s.rejected[0], 1);
        assert_eq!(s.rejected[3], 1);
        assert_eq!(s.rejected[REJECT_REASONS - 1], 1);
        assert_eq!(s.rejected_total(), 3);
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.peak_queue_depth, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.batched_jobs, 3);
        assert_eq!(s.max_batch(), 2);
        // 100 µs lands in bucket 6 ([64,128)); 10 ms in bucket 13.
        assert_eq!(s.latency_us[6], 2);
        assert_eq!(s.latency_us[13], 1);
        // p50 crosses in the 100 µs bucket, p99 in the 10 ms bucket.
        assert_eq!(s.p50_latency_us(), (1u64 << 7) - 1);
        assert_eq!(s.p99_latency_us(), (1u64 << 14) - 1);
        // Empty histogram → 0.
        assert_eq!(GatewayStats::default().latency_percentile_us(0.99), 0);
    }
}
