//! The persistent worker runtime: long-lived Phase-2 workers over a
//! job-multiplexed, buffer-pooled fabric.
//!
//! The paper's cost model (eqs. 32–34) assumes edge workers that hold their
//! shares and serve computation continuously; [`WorkerRuntime`] realizes
//! that. At provisioning it spawns `N` persistent worker threads and one
//! long-lived [`Fabric`], then any number of jobs are *streamed* to them:
//! [`WorkerRuntime::begin_job`] claims a [`JobId`] (registering per-job
//! traffic meters and a receive queue on the master's [`JobRouter`]), the
//! driving thread plays the source and master roles for that job, and
//! [`WorkerRuntime::finish_job`] returns the job's traffic snapshot and
//! unregisters it. Concurrent jobs interleave safely on the shared links —
//! every envelope is job-tagged — and payload buffers cycle through the
//! shared [`BufferPool`], so a warm runtime executes jobs with **zero
//! thread spawns and zero fabric-payload allocations**.
//!
//! Dropping the runtime shuts it down cleanly: a [`ControlMsg::Shutdown`]
//! to every worker, then joins. A worker that *panicked* (as opposed to
//! reporting job-level errors, which never kill the thread) has its panic
//! propagated to the dropping thread, so failures cannot vanish silently.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::codes::SchemeParams;
use crate::error::Result;
use crate::metrics::TrafficReport;
use crate::mpc::network::{BufferPool, ControlMsg, Fabric, JobId, JobRouter, Payload, CONTROL_JOB};
use crate::mpc::protocol::{ProtocolConfig, Setup};
use crate::mpc::worker::{self, WorkerCtx};
use crate::runtime::BackendFactory;

/// A provisioned set of persistent worker threads plus the multiplexed
/// fabric they serve on. Owned by a [`Deployment`] (one runtime per
/// session); `run_protocol_with_env` provisions a throwaway one for
/// one-shot compatibility callers.
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct WorkerRuntime {
    fabric: Arc<Fabric>,
    router: JobRouter,
    bufs: Arc<BufferPool>,
    handles: Vec<JoinHandle<Result<()>>>,
    next_job: AtomicU64,
    n_workers: usize,
    recv_timeout: Duration,
}

impl WorkerRuntime {
    /// Spawn the `N` persistent worker threads and the shared fabric.
    ///
    /// `config.worker_delays` is applied per worker when its length matches
    /// `N` (the per-job validation in the protocol layer rejects jobs
    /// otherwise, so a mismatched vector never silently half-applies).
    pub fn provision(
        setup: &Setup,
        params: SchemeParams,
        config: &ProtocolConfig,
        factory: &BackendFactory,
    ) -> Result<WorkerRuntime> {
        let n = setup.n_workers;
        let (fabric, mut endpoints) = Fabric::new(n, config.link_delay);
        let bufs = BufferPool::new();
        let worker_endpoints: Vec<_> = endpoints.drain(0..n).collect();
        let master_endpoint = endpoints.remove(0);
        // Sources only ever send; their endpoints are dropped.
        let delays_apply = config.worker_delays.len() == n;
        let mut handles: Vec<JoinHandle<Result<()>>> = Vec::with_capacity(n);
        for (wid, endpoint) in worker_endpoints.into_iter().enumerate() {
            let ctx = WorkerCtx {
                id: wid,
                n_workers: n,
                t: params.t,
                z: params.z,
                alphas: setup.alphas.clone(),
                r_coeffs: setup.r_coeffs.clone(),
                delay: if delays_apply {
                    config.worker_delays[wid]
                } else {
                    Duration::ZERO
                },
                recv_timeout: config.recv_timeout,
            };
            let fabric = fabric.clone();
            let backend = factory.make();
            let bufs = bufs.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("cmpc-worker-{wid}"))
                .spawn(move || worker::serve_worker(ctx, endpoint, fabric, backend, bufs));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partially provisioned runtime before
                    // surfacing the error, or the spawned threads leak.
                    shutdown(&fabric, &mut handles);
                    return Err(crate::error::CmpcError::Io(format!(
                        "spawning worker {wid}: {e}"
                    )));
                }
            }
        }
        Ok(WorkerRuntime {
            fabric,
            router: JobRouter::new(master_endpoint),
            bufs,
            handles,
            next_job: AtomicU64::new(0),
            n_workers: n,
            recv_timeout: config.recv_timeout,
        })
    }

    /// Claim a fresh [`JobId`]: registers the job's traffic meters on the
    /// fabric and its receive queue on the master router. Every envelope of
    /// the job must carry the returned id.
    pub fn begin_job(&self) -> JobId {
        let job = self.next_job.fetch_add(1, Ordering::Relaxed);
        self.router.open(job);
        self.fabric.begin_job(job);
        job
    }

    /// Unregister a finished (or failed) job and return its traffic
    /// snapshot. Late envelopes for the job are dropped by the router,
    /// returning their payload buffers to the pool.
    pub fn finish_job(&self, job: JobId) -> TrafficReport {
        self.router.close(job);
        self.fabric.end_job(job)
    }

    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    pub fn router(&self) -> &JobRouter {
        &self.router
    }

    pub fn buffers(&self) -> &Arc<BufferPool> {
        &self.bufs
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Persistent worker threads alive in this runtime (always `N`; the
    /// reuse tests assert no per-job growth).
    pub fn worker_threads(&self) -> usize {
        self.handles.len()
    }

    /// The per-receive timeout jobs run under.
    pub fn recv_timeout(&self) -> Duration {
        self.recv_timeout
    }

    /// Jobs started over the runtime's lifetime.
    pub fn jobs_started(&self) -> u64 {
        self.next_job.load(Ordering::Relaxed)
    }
}

/// Send every worker a shutdown and join, propagating worker panics to the
/// caller (unless the caller is itself already panicking).
fn shutdown(fabric: &Arc<Fabric>, handles: &mut Vec<JoinHandle<Result<()>>>) {
    for wid in 0..handles.len() {
        let _ = fabric.send(
            CONTROL_JOB,
            fabric.master_id(),
            wid,
            Payload::Control(ControlMsg::Shutdown),
        );
    }
    for h in handles.drain(..) {
        match h.join() {
            // Job-level Results were already reported to their jobs as
            // JobError control messages; nothing to do on Ok.
            Ok(_) => {}
            Err(panic) => {
                if !std::thread::panicking() {
                    std::panic::resume_unwind(panic);
                }
            }
        }
    }
}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        shutdown(&self.fabric, &mut self.handles);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme};
    use crate::mpc::protocol::prepare_setup;
    use crate::runtime::BackendChoice;

    #[test]
    fn provision_and_clean_shutdown() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let setup = prepare_setup(&scheme).unwrap();
        let factory = BackendFactory::new(&BackendChoice::Native).unwrap();
        let rt = WorkerRuntime::provision(
            &setup,
            scheme.params(),
            &ProtocolConfig::default(),
            &factory,
        )
        .unwrap();
        assert_eq!(rt.worker_threads(), 17);
        assert_eq!(rt.n_workers(), 17);
        let j0 = rt.begin_job();
        let j1 = rt.begin_job();
        assert_ne!(j0, j1);
        assert_eq!(rt.jobs_started(), 2);
        rt.finish_job(j0);
        rt.finish_job(j1);
        drop(rt); // joins all 17 threads without hanging
    }
}
