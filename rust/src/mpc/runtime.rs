//! The persistent worker runtime: long-lived Phase-2 workers over a
//! job-multiplexed, buffer-pooled fabric — with **eviction and respawn**.
//!
//! The paper's cost model (eqs. 32–34) assumes edge workers that hold their
//! shares and serve computation continuously; [`WorkerRuntime`] realizes
//! that. At provisioning it spawns `N` persistent worker threads and one
//! long-lived [`Fabric`], then any number of jobs are *streamed* to them:
//! [`WorkerRuntime::begin_job`] claims a [`JobId`] (registering per-job
//! traffic meters and a receive queue on the master's [`JobRouter`]), the
//! driving thread plays the source and master roles for that job, and
//! [`WorkerRuntime::finish_job`] returns the job's traffic snapshot and
//! unregisters it. Concurrent jobs interleave safely on the shared links —
//! every envelope is job-tagged — and payload buffers cycle through the
//! shared [`BufferPool`], so a warm runtime executes jobs with **zero
//! thread spawns and zero fabric-payload allocations**.
//!
//! **Elasticity.** A worker thread that dies — a panic, a chaos-plan kill
//! (see [`crate::mpc::chaos`]), or self-eviction after consecutive per-job
//! deadline misses — does not wedge the deployment: the next
//! [`WorkerRuntime::begin_job`] (or an explicit [`WorkerRuntime::reap`])
//! joins the dead thread, records an [`Eviction`], swaps the node's fabric
//! endpoint for a fresh channel, and spawns a replacement with the **same
//! worker index** — same α, same reconstruction coefficients, same per-job
//! rng derivation — so post-respawn outputs are byte-identical to an
//! uninterrupted worker's. The dead thread's pooled buffers were already
//! reclaimed when its job states dropped. [`RuntimeCounters`] meters
//! evictions, respawns, early decodes, deadline misses, and driver aborts.
//!
//! Dropping the runtime shuts it down cleanly: a [`ControlMsg::Shutdown`]
//! to every worker, then joins. A worker that *panicked* and was never
//! reaped has its panic propagated to the dropping thread, so failures
//! cannot vanish silently; reaped panics live on in the eviction log
//! instead.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::codes::SchemeParams;
use crate::error::Result;
use crate::metrics::{RuntimeCounters, RuntimeHealthReport, TrafficReport};
use crate::mpc::network::{
    BufferPool, ControlMsg, Endpoint, Fabric, FabricTuning, JobId, JobRouter, Payload,
    CONTROL_JOB,
};
use crate::mpc::protocol::{ProtocolConfig, Setup};
use crate::mpc::worker::{self, WorkerCtx};
use crate::runtime::BackendFactory;

/// One recorded worker eviction: which worker slot died and why (the
/// panic message, the worker's own typed error, or a clean exit — chaos
/// kill or fabric teardown).
#[derive(Clone, Debug)]
pub struct Eviction {
    /// Worker slot (stable index) whose thread died and was replaced.
    pub worker: usize,
    /// Why it died: the panic message, the worker's own typed error, a
    /// Byzantine blame, or a clean exit (chaos kill / fabric teardown).
    pub reason: String,
}

/// A provisioned set of persistent worker threads plus the multiplexed
/// fabric they serve on. Owned by a [`Deployment`] (one runtime per
/// session); `run_protocol_with_env` provisions a throwaway one for
/// one-shot compatibility callers.
///
/// [`Deployment`]: crate::mpc::deployment::Deployment
pub struct WorkerRuntime {
    fabric: Arc<Fabric>,
    router: JobRouter,
    bufs: Arc<BufferPool>,
    /// One slot per worker index; the reaper replaces slots in place, so
    /// the vector length is always `N`.
    handles: Mutex<Vec<JoinHandle<Result<()>>>>,
    next_job: AtomicU64,
    n_workers: usize,
    recv_timeout: Duration,
    health: Arc<RuntimeCounters>,
    /// Most recent evictions, oldest first, capped at `EVICTION_LOG_CAP`
    /// (the health counters stay exact; only the per-event detail rotates)
    /// so a chronically failing slot cannot grow memory without bound.
    eviction_log: Mutex<VecDeque<Eviction>>,
    /// Every worker id ever blamed by the Byzantine decoder, in blame
    /// order — surfaced verbatim by [`WorkerRuntime::health`].
    blame_log: Mutex<Vec<usize>>,
    /// Blamed workers shut down but not yet reaped: consulted (and
    /// drained) by [`WorkerRuntime::reap`] so their eviction records say
    /// *blamed* rather than "clean exit".
    pending_blame: Mutex<Vec<usize>>,
    /// The strike ledger: cumulative blame count per worker *slot*
    /// (length `N`, indexed by worker id). Deliberately **not** reset by
    /// the reaper — a respawned replacement inherits its slot's strikes,
    /// so a persistently garbled index (malicious peer, flaky NIC) keeps
    /// accumulating evidence across respawns. Surfaced sparsely through
    /// [`WorkerRuntime::health`] and consumed by the autoscaler policy.
    strikes: Mutex<Vec<u64>>,
    respawn: RespawnCtx,
}

/// Retained [`Eviction`] records (FIFO; see `WorkerRuntime::evictions`).
const EVICTION_LOG_CAP: usize = 256;

/// Everything needed to provision a replacement worker thread for any slot:
/// the job-independent deployment state a [`WorkerCtx`] is built from, plus
/// a handle on the backend factory.
struct RespawnCtx {
    alphas: Arc<Vec<u64>>,
    r_coeffs: Arc<Vec<Vec<u64>>>,
    t: usize,
    z: usize,
    /// Per-worker injected delays (empty = none; validated per job).
    delays: Vec<Duration>,
    recv_timeout: Duration,
    max_deadline_misses: usize,
    factory: Arc<BackendFactory>,
}

impl RespawnCtx {
    fn worker_ctx(&self, wid: usize, n: usize, health: &Arc<RuntimeCounters>) -> WorkerCtx {
        WorkerCtx {
            id: wid,
            n_workers: n,
            t: self.t,
            z: self.z,
            alphas: self.alphas.clone(),
            r_coeffs: self.r_coeffs.clone(),
            delay: self.delays.get(wid).copied().unwrap_or(Duration::ZERO),
            recv_timeout: self.recv_timeout,
            max_deadline_misses: self.max_deadline_misses,
            // The runtime owns its worker threads' lifecycle (Shutdown on
            // drop), so idle workers block indefinitely.
            idle_timeout: None,
            health: health.clone(),
        }
    }
}

fn spawn_worker(
    ctx: WorkerCtx,
    endpoint: Endpoint,
    fabric: Arc<Fabric>,
    factory: &BackendFactory,
    bufs: Arc<BufferPool>,
) -> std::io::Result<JoinHandle<Result<()>>> {
    let backend = factory.make();
    std::thread::Builder::new()
        .name(format!("cmpc-worker-{}", ctx.id))
        .spawn(move || worker::serve_worker(ctx, endpoint, fabric, backend, bufs))
}

impl WorkerRuntime {
    /// Spawn the `N` persistent worker threads and the shared fabric.
    ///
    /// `config.worker_delays` is applied per worker when its length matches
    /// `N` (the per-job validation in the protocol layer rejects jobs
    /// otherwise, so a mismatched vector never silently half-applies). The
    /// factory is retained (shared) so evicted workers can be respawned
    /// with fresh backend handles.
    pub fn provision(
        setup: &Setup,
        params: SchemeParams,
        config: &ProtocolConfig,
        factory: &Arc<BackendFactory>,
    ) -> Result<WorkerRuntime> {
        let n = setup.n_workers;
        let (fabric, mut endpoints) = Fabric::with_tuning(
            n,
            FabricTuning {
                link_delay: config.link_delay,
                chaos: config.chaos.clone(),
                shaper: config.shaper.clone(),
            },
        );
        let bufs = BufferPool::new();
        let worker_endpoints: Vec<_> = endpoints.drain(0..n).collect();
        let master_endpoint = endpoints.remove(0);
        // Sources only ever send; their endpoints are dropped.
        let health = Arc::new(RuntimeCounters::default());
        let respawn = RespawnCtx {
            alphas: setup.alphas.clone(),
            r_coeffs: setup.r_coeffs.clone(),
            t: params.t,
            z: params.z,
            delays: if config.worker_delays.len() == n {
                config.worker_delays.clone()
            } else {
                Vec::new()
            },
            recv_timeout: config.recv_timeout,
            max_deadline_misses: config.max_deadline_misses.max(1),
            factory: factory.clone(),
        };
        let mut handles: Vec<JoinHandle<Result<()>>> = Vec::with_capacity(n);
        for (wid, endpoint) in worker_endpoints.into_iter().enumerate() {
            let spawned = spawn_worker(
                respawn.worker_ctx(wid, n, &health),
                endpoint,
                fabric.clone(),
                factory,
                bufs.clone(),
            );
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Unwind the partially provisioned runtime before
                    // surfacing the error, or the spawned threads leak.
                    shutdown(&fabric, &mut handles);
                    return Err(crate::error::CmpcError::Io(format!(
                        "spawning worker {wid}: {e}"
                    )));
                }
            }
        }
        Ok(WorkerRuntime {
            fabric,
            router: JobRouter::new(master_endpoint),
            bufs,
            handles: Mutex::new(handles),
            next_job: AtomicU64::new(0),
            n_workers: n,
            recv_timeout: config.recv_timeout,
            health,
            eviction_log: Mutex::new(VecDeque::new()),
            blame_log: Mutex::new(Vec::new()),
            pending_blame: Mutex::new(Vec::new()),
            strikes: Mutex::new(vec![0; n]),
            respawn,
        })
    }

    /// Claim a fresh [`JobId`]: reaps any dead workers (so the job starts
    /// against a full complement), then registers the job's traffic meters
    /// on the fabric and its receive queue on the master router. Every
    /// envelope of the job must carry the returned id.
    pub fn begin_job(&self) -> JobId {
        self.reap();
        let job = self.next_job.fetch_add(1, Ordering::Relaxed);
        self.router.open(job);
        self.fabric.begin_job(job);
        job
    }

    /// Unregister a finished (or failed) job and return its traffic
    /// snapshot. Late envelopes for the job are dropped by the router,
    /// returning their payload buffers to the pool; the pool then gets a
    /// high-water [`BufferPool::trim`] so retained capacity tracks demand.
    pub fn finish_job(&self, job: JobId) -> TrafficReport {
        self.router.close(job);
        let traffic = self.fabric.end_job(job);
        self.bufs.trim();
        traffic
    }

    /// Evict dead worker threads and provision replacements in their slots.
    ///
    /// A worker thread can die three ways: a panic, a chaos-plan kill
    /// (simulated crash), or self-eviction after consecutive per-job
    /// deadline misses. All three end as a finished join handle; this sweep
    /// joins it (capturing the panic message or typed error into the
    /// [`Eviction`] record — its pooled buffers were already returned when
    /// its job states dropped), swaps the node's fabric endpoint for a
    /// fresh channel, and spawns a replacement thread with the same worker
    /// index and re-derived rng streams, so outputs stay byte-identical.
    ///
    /// Runs automatically at every [`WorkerRuntime::begin_job`]; callers
    /// may also invoke it directly after a suspected fault. Returns the
    /// number of workers respawned (0 on the healthy fast path, which costs
    /// one `is_finished` probe per worker).
    pub fn reap(&self) -> usize {
        let mut handles = self.handles.lock().unwrap();
        let mut respawned = 0;
        for (wid, slot) in handles.iter_mut().enumerate() {
            if !slot.is_finished() {
                continue;
            }
            // Fresh endpoint first (also clears any chaos-kill mark), so
            // the replacement starts with an empty, live channel. The
            // channel transport always hosts every node, so this cannot
            // fail; a remote transport would (respawn is in-process-only).
            let endpoint = match self.fabric.replace_endpoint(wid) {
                Ok(e) => e,
                Err(_) => continue,
            };
            let spawned = spawn_worker(
                self.respawn.worker_ctx(wid, self.n_workers, &self.health),
                endpoint,
                self.fabric.clone(),
                &self.respawn.factory,
                self.bufs.clone(),
            );
            let replacement = match spawned {
                Ok(h) => h,
                // Spawn failed (resource exhaustion): leave the finished
                // handle in place; the next reap retries.
                Err(_) => continue,
            };
            let dead = std::mem::replace(slot, replacement);
            let blamed = {
                let mut pending = self.pending_blame.lock().unwrap();
                let was = pending.contains(&wid);
                pending.retain(|&w| w != wid);
                was
            };
            let reason = match dead.join() {
                Ok(Ok(())) if blamed => {
                    "blamed: garbled I-share located by the Byzantine decoder".to_string()
                }
                Ok(Ok(())) => "exited (chaos kill or fabric teardown)".to_string(),
                Ok(Err(e)) => e.to_string(),
                Err(panic) => format!("panic: {}", panic_message(panic.as_ref())),
            };
            let mut log = self.eviction_log.lock().unwrap();
            if log.len() == EVICTION_LOG_CAP {
                log.pop_front();
            }
            log.push_back(Eviction {
                worker: wid,
                reason,
            });
            drop(log);
            self.health.evictions.fetch_add(1, Ordering::Relaxed);
            self.health.respawns.fetch_add(1, Ordering::Relaxed);
            respawned += 1;
        }
        respawned
    }

    /// Snapshot of the runtime's health counters (evictions, respawns,
    /// early decodes, deadline misses, driver aborts, Byzantine blames)
    /// plus the blame log — every worker id the Byzantine decoder has
    /// located serving a garbled I-share, in blame order.
    pub fn health(&self) -> RuntimeHealthReport {
        let mut snap = self.health.snapshot();
        snap.blamed_workers = self.blame_log.lock().unwrap().clone();
        snap.worker_strikes = self.worker_strikes();
        snap
    }

    /// The strike ledger, sparsely: `(worker_id, cumulative_strikes)` for
    /// every slot blamed at least once, ascending by id. Strikes survive
    /// respawn (the ledger is keyed by slot, not by thread), so repeated
    /// blame of the same index reads as a repeat offender rather than a
    /// string of first offenses.
    pub fn worker_strikes(&self) -> Vec<(usize, u64)> {
        self.strikes
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter(|&(_, &s)| s > 0)
            .map(|(wid, &s)| (wid, s))
            .collect()
    }

    /// Recent evictions (worker slot + reason), oldest first — the last
    /// `EVICTION_LOG_CAP` (256) events; [`WorkerRuntime::health`] keeps
    /// the exact lifetime counts.
    pub fn evictions(&self) -> Vec<Eviction> {
        self.eviction_log.lock().unwrap().iter().cloned().collect()
    }

    /// Record an early-decoded job (called by the job driver).
    pub(crate) fn note_early_decode(&self) {
        self.health.early_decodes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a driver-side abort broadcast (called on the job error path).
    pub(crate) fn note_job_aborted(&self) {
        self.health.jobs_aborted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completed Phase-3 decode (called by the job driver: once
    /// per executed job, once per fused batch, once per pipeline — the
    /// counter contract pinned in [`crate::metrics`]).
    pub(crate) fn note_decode(&self) {
        self.health.phase3_decodes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed pipeline stage (called by the pipeline driver
    /// once per round, masked or final).
    pub(crate) fn note_pipeline_stage(&self) {
        self.health.pipeline_stages.fetch_add(1, Ordering::Relaxed);
    }

    /// Claim a contiguous block of `k` job ids without opening router
    /// queues for them — the fused path's accounting hook, so
    /// [`WorkerRuntime::jobs_started`] advances by the batch size even
    /// though the batch streams no per-job envelopes.
    pub(crate) fn claim_job_ids(&self, k: u64) {
        self.next_job.fetch_add(k, Ordering::Relaxed);
    }

    /// Record workers the Byzantine decoder blamed for garbled I-shares
    /// and evict them: each gets a targeted [`ControlMsg::Shutdown`] (the
    /// worker exits cleanly, exactly like a chaos kill), is marked
    /// pending-blame so its eviction record carries the real reason, and
    /// the next [`WorkerRuntime::reap`] — automatic at `begin_job` —
    /// respawns a clean replacement with the same index and re-derived
    /// rng streams.
    pub(crate) fn note_byzantine(&self, blamed: &[usize]) {
        if blamed.is_empty() {
            return;
        }
        self.health
            .byzantine_detected
            .fetch_add(blamed.len() as u64, Ordering::Relaxed);
        self.blame_log.lock().unwrap().extend_from_slice(blamed);
        {
            let mut strikes = self.strikes.lock().unwrap();
            for &wid in blamed {
                if let Some(slot) = strikes.get_mut(wid) {
                    *slot += 1;
                }
            }
        }
        {
            let mut pending = self.pending_blame.lock().unwrap();
            for &wid in blamed {
                if !pending.contains(&wid) {
                    pending.push(wid);
                }
            }
        }
        for &wid in blamed {
            // Best-effort: a blamed worker that already died (or was
            // chaos-killed) simply has nothing to shut down.
            let _ = self.fabric.send(
                CONTROL_JOB,
                self.fabric.master_id(),
                wid,
                Payload::Control(ControlMsg::Shutdown),
            );
        }
    }

    /// The shared job-multiplexed fabric every node sends on.
    pub fn fabric(&self) -> &Arc<Fabric> {
        &self.fabric
    }

    /// The master-side receive router (per-job queues over one endpoint).
    pub fn router(&self) -> &JobRouter {
        &self.router
    }

    /// The shared payload buffer pool.
    pub fn buffers(&self) -> &Arc<BufferPool> {
        &self.bufs
    }

    /// Number of provisioned worker slots `N`.
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Persistent worker threads alive in this runtime (always `N`: the
    /// reaper replaces dead slots in place; the reuse tests assert no
    /// per-job growth).
    pub fn worker_threads(&self) -> usize {
        self.handles.lock().unwrap().len()
    }

    /// The per-receive timeout jobs run under.
    pub fn recv_timeout(&self) -> Duration {
        self.recv_timeout
    }

    /// Jobs started over the runtime's lifetime.
    pub fn jobs_started(&self) -> u64 {
        self.next_job.load(Ordering::Relaxed)
    }
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = panic.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Send every worker a shutdown and join, propagating worker panics to the
/// caller (unless the caller is itself already panicking).
fn shutdown(fabric: &Arc<Fabric>, handles: &mut Vec<JoinHandle<Result<()>>>) {
    for wid in 0..handles.len() {
        let _ = fabric.send(
            CONTROL_JOB,
            fabric.master_id(),
            wid,
            Payload::Control(ControlMsg::Shutdown),
        );
    }
    for h in handles.drain(..) {
        match h.join() {
            // Job-level Results were already reported to their jobs as
            // JobError control messages; self-eviction errors were either
            // reaped (and logged) or belong to a runtime being torn down.
            Ok(_) => {}
            Err(panic) => {
                if !std::thread::panicking() {
                    std::panic::resume_unwind(panic);
                }
            }
        }
    }
}

impl Drop for WorkerRuntime {
    fn drop(&mut self) {
        let mut handles = self.handles.lock().unwrap();
        shutdown(&self.fabric, &mut handles);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme};
    use crate::mpc::protocol::prepare_setup;
    use crate::runtime::BackendChoice;

    fn provision_example() -> WorkerRuntime {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let setup = prepare_setup(&scheme).unwrap();
        let factory = Arc::new(BackendFactory::new(&BackendChoice::Native).unwrap());
        WorkerRuntime::provision(
            &setup,
            scheme.params(),
            &ProtocolConfig::default(),
            &factory,
        )
        .unwrap()
    }

    #[test]
    fn provision_and_clean_shutdown() {
        let rt = provision_example();
        assert_eq!(rt.worker_threads(), 17);
        assert_eq!(rt.n_workers(), 17);
        let j0 = rt.begin_job();
        let j1 = rt.begin_job();
        assert_ne!(j0, j1);
        assert_eq!(rt.jobs_started(), 2);
        rt.finish_job(j0);
        rt.finish_job(j1);
        assert_eq!(rt.health(), RuntimeHealthReport::default());
        drop(rt); // joins all 17 threads without hanging
    }

    #[test]
    fn reap_is_a_noop_on_healthy_workers() {
        let rt = provision_example();
        assert_eq!(rt.reap(), 0);
        assert_eq!(rt.worker_threads(), 17);
        assert!(rt.evictions().is_empty());
    }
}
