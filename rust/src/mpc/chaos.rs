//! Deterministic, envelope-granular fault injection for the worker runtime.
//!
//! The whole point of a coded computation is that the master decodes from
//! *any* `t²+z` of the `N` workers — a claim that can only be trusted if the
//! failure modes are actually exercised. [`ChaosPlan`] makes them
//! reproducible: a plan is an ordered list of [`FaultRule`]s consulted by
//! [`Fabric::send`] for every envelope, and each rule can **delay**, **drop**,
//! or **garble** a matching envelope, or **kill** its sending node
//! outright (the crash model the runtime's eviction/respawn machinery
//! recovers from — see [`WorkerRuntime::reap`]).
//!
//! Plans are deterministic by construction: rules match on structural
//! criteria (sender, receiver, job, payload class, match ordinal), and the
//! seed-driven helpers ([`ChaosPlan::kill_k_workers`]) draw their victims
//! from a [`ChaChaRng`] so a failing run can be replayed exactly from its
//! seed. A plan is attached to a deployment through
//! [`ProtocolConfig::builder`]`().chaos(plan)` and lives for the fabric's
//! lifetime.
//!
//! Two invariants keep chaos from breaking the runtime itself:
//! [`ControlMsg::Shutdown`] envelopes are never faultable (a dropped
//! shutdown would hang the runtime's `Drop` join forever), and a kill marks
//! the sender dead inside the fabric so *all* of its later sends fail — a
//! crashed node cannot keep talking.
//!
//! [`Fabric::send`]: crate::mpc::network::Fabric::send
//! [`WorkerRuntime::reap`]: crate::mpc::runtime::WorkerRuntime::reap
//! [`ProtocolConfig::builder`]: crate::mpc::protocol::ProtocolConfig::builder
//! [`ControlMsg::Shutdown`]: crate::mpc::network::ControlMsg::Shutdown
//! [`ChaChaRng`]: crate::util::rng::ChaChaRng

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::mpc::network::{JobId, NodeId, Payload};
use crate::util::rng::ChaChaRng;

/// What a matching [`FaultRule`] does to an envelope.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Hold the envelope this long before delivering it (a *busy or slow
    /// peer*: the sleep happens on the sender's thread, like the fabric's
    /// own `link_delay`, so the sender can do nothing else meanwhile). To
    /// model a slow **link** that delays delivery without blocking the
    /// sender, use the transport shaper
    /// ([`crate::transport::shaper::LinkShaper`]) instead — the two
    /// compose.
    Delay(Duration),
    /// Silently discard the envelope (lossy link, or a peer that is mute
    /// for one job). Dropped envelopes are unmetered — they never
    /// traversed the fabric.
    Drop,
    /// Perturb the payload's first scalar before delivery (corruption in
    /// flight; verify-mode jobs surface it as a decode failure).
    Garble,
    /// Kill the *sending* node: the envelope is discarded, the node is
    /// marked dead inside the fabric (every later send from it fails), and
    /// a worker thread observing the kill exits as a crashed thread would.
    Kill,
}

/// Payload classification for fault matching (one variant per
/// [`Payload`] arm).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadClass {
    /// Phase-1 share pairs (source → worker).
    Shares,
    /// Phase-2 `G` evaluations (worker ↔ worker).
    GShare,
    /// Phase-3 `I` evaluations (worker → master).
    IShare,
    /// Runtime control plane (job lifecycle).
    Control,
}

impl PayloadClass {
    /// Classify a payload. The split Phase-1 forms (`ShareA`/`ShareB`,
    /// sent by physically separate source processes) classify as
    /// [`PayloadClass::Shares`], so one rule covers both delivery shapes.
    /// Pipeline payloads classify by their link role: a stage mask is a
    /// source→worker share, a masked I-share is a worker→master I-share —
    /// so existing chaos rules hit pipeline rounds without rewriting.
    pub fn of(payload: &Payload) -> PayloadClass {
        match payload {
            Payload::Shares { .. } => PayloadClass::Shares,
            Payload::ShareA(_) | Payload::ShareB(_) => PayloadClass::Shares,
            Payload::StageMask { .. } => PayloadClass::Shares,
            Payload::GShare(_) => PayloadClass::GShare,
            Payload::IShare(_) => PayloadClass::IShare,
            Payload::StageMasked { .. } => PayloadClass::IShare,
            Payload::Control(_) => PayloadClass::Control,
        }
    }
}

/// One envelope-granular fault rule.
///
/// `None` criteria are wildcards; an envelope matches when every set
/// criterion agrees. Matches are counted per rule (atomically, so
/// concurrent senders agree on ordinals): the first `skip` matching
/// envelopes pass unharmed, the next `limit` (or every later one, when
/// unset) receive the action, and matches beyond the limit fall through to
/// later rules.
#[derive(Debug)]
pub struct FaultRule {
    from: Option<NodeId>,
    to: Option<NodeId>,
    job: Option<JobId>,
    class: Option<PayloadClass>,
    skip: u64,
    limit: Option<u64>,
    action: FaultAction,
    /// Matching envelopes seen so far (including skipped ones).
    hits: AtomicU64,
}

impl FaultRule {
    /// A wildcard rule applying `action` to every envelope; narrow it with
    /// the builder methods.
    pub fn new(action: FaultAction) -> FaultRule {
        FaultRule {
            from: None,
            to: None,
            job: None,
            class: None,
            skip: 0,
            limit: None,
            action,
            hits: AtomicU64::new(0),
        }
    }

    /// Match only envelopes sent by `node`.
    pub fn from_node(mut self, node: NodeId) -> Self {
        self.from = Some(node);
        self
    }

    /// Match only envelopes addressed to `node`.
    pub fn to_node(mut self, node: NodeId) -> Self {
        self.to = Some(node);
        self
    }

    /// Match only envelopes of `job`.
    pub fn job(mut self, job: JobId) -> Self {
        self.job = Some(job);
        self
    }

    /// Match only payloads of `class`.
    pub fn class(mut self, class: PayloadClass) -> Self {
        self.class = Some(class);
        self
    }

    /// Let the first `n` matching envelopes through unharmed.
    pub fn skip(mut self, n: u64) -> Self {
        self.skip = n;
        self
    }

    /// Apply the action to at most `n` envelopes (after `skip`).
    pub fn limit(mut self, n: u64) -> Self {
        self.limit = Some(n);
        self
    }

    /// Matching envelopes observed so far (skipped and faulted alike).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    fn matches(&self, job: JobId, from: NodeId, to: NodeId, class: PayloadClass) -> bool {
        // `None` criteria are wildcards (written out so the comparison
        // stays MSRV-1.73 friendly).
        let from_ok = match self.from {
            Some(n) => n == from,
            None => true,
        };
        let to_ok = match self.to {
            Some(n) => n == to,
            None => true,
        };
        let job_ok = match self.job {
            Some(j) => j == job,
            None => true,
        };
        let class_ok = match self.class {
            Some(c) => c == class,
            None => true,
        };
        from_ok && to_ok && job_ok && class_ok
    }
}

/// An ordered set of [`FaultRule`]s consulted on every fabric send.
#[derive(Debug, Default)]
pub struct ChaosPlan {
    rules: Vec<FaultRule>,
}

impl ChaosPlan {
    /// An empty plan (injects nothing).
    pub fn new() -> ChaosPlan {
        ChaosPlan::default()
    }

    /// Append a rule (builder style; earlier rules win).
    pub fn rule(mut self, rule: FaultRule) -> ChaosPlan {
        self.rules.push(rule);
        self
    }

    /// Wrap the plan for attachment to a `ProtocolConfig`.
    pub fn into_shared(self) -> Arc<ChaosPlan> {
        Arc::new(self)
    }

    /// The plan's rules, in consult order (rule hit counters live here).
    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    /// Decide the fate of one envelope: the first rule that matches within
    /// its `[skip, skip+limit)` window acts; a match inside the skip window
    /// delivers normally without consulting later rules; an exhausted rule
    /// falls through.
    pub fn decide(
        &self,
        job: JobId,
        from: NodeId,
        to: NodeId,
        payload: &Payload,
    ) -> Option<FaultAction> {
        let class = PayloadClass::of(payload);
        for rule in &self.rules {
            if !rule.matches(job, from, to, class) {
                continue;
            }
            let ordinal = rule.hits.fetch_add(1, Ordering::Relaxed);
            if ordinal < rule.skip {
                return None; // inside the skip window: deliver unharmed
            }
            if let Some(limit) = rule.limit {
                if ordinal >= rule.skip + limit {
                    continue; // rule exhausted: later rules may still act
                }
            }
            return Some(rule.action);
        }
        None
    }

    /// The `k` distinct victim workers every seed-driven plan under `seed`
    /// picks: `0..n_workers` shuffled with a [`ChaChaRng`], first `k`
    /// taken. Public so tests can predict (and assert blame against) the
    /// exact victims of [`ChaosPlan::kill_k_workers`] /
    /// [`ChaosPlan::garble_k_workers`] without duplicating the shuffle.
    ///
    /// [`ChaChaRng`]: crate::util::rng::ChaChaRng
    pub fn chosen_victims(seed: u64, n_workers: usize, k: usize) -> Vec<usize> {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let mut ids: Vec<usize> = (0..n_workers).collect();
        rng.shuffle(&mut ids);
        ids.truncate(k);
        ids
    }

    /// Seed-driven Byzantine plan: each of the `k` victims (chosen as in
    /// [`ChaosPlan::chosen_victims`]) has the **first `I`-share it sends**
    /// garbled in flight — the adversary model of the Byzantine decoder:
    /// the worker computed honestly (its G-exchange is untouched, so peers
    /// are unaffected) but the evaluation the master receives is corrupt.
    /// `limit(1)` scopes the corruption to one share per victim; a master
    /// running with `adversary_tolerance ≥ k` must locate exactly these
    /// victims and reconstruct byte-identically without them.
    pub fn garble_k_workers(seed: u64, n_workers: usize, k: usize) -> ChaosPlan {
        let mut plan = ChaosPlan::new();
        for victim in ChaosPlan::chosen_victims(seed, n_workers, k) {
            plan = plan.rule(
                FaultRule::new(FaultAction::Garble)
                    .from_node(victim)
                    .class(PayloadClass::IShare)
                    .limit(1),
            );
        }
        plan
    }

    /// Seed-driven crash plan: choose `k` distinct victim workers by
    /// shuffling `0..n_workers` with a [`ChaChaRng`] under `seed`, and kill
    /// each on its first envelope of `class`.
    ///
    /// `class` selects the crash *moment*: [`PayloadClass::IShare`] kills a
    /// worker after its full `G`-exchange (the paper's dropout model — its
    /// peers can still finish, only its own evaluation is lost), while
    /// [`PayloadClass::GShare`] kills it mid-exchange.
    ///
    /// [`ChaChaRng`]: crate::util::rng::ChaChaRng
    pub fn kill_k_workers(
        seed: u64,
        n_workers: usize,
        k: usize,
        class: PayloadClass,
    ) -> ChaosPlan {
        let mut plan = ChaosPlan::new();
        for victim in ChaosPlan::chosen_victims(seed, n_workers, k) {
            plan = plan.rule(
                FaultRule::new(FaultAction::Kill)
                    .from_node(victim)
                    .class(class)
                    .limit(1),
            );
        }
        plan
    }

    /// Seed-driven crash plan with a **deterministic trigger**: each of the
    /// `k` victims (chosen as in [`ChaosPlan::kill_k_workers`]) is killed
    /// on its `(N−1)`-th G-share send of its first job — i.e. mid-send of
    /// its final exchange evaluation, unconditionally during its compute
    /// phase, so the crash can never race a `JobAbort`.
    ///
    /// The victim's first `N−2` G-shares were already delivered, so all but
    /// (at most) one peer per victim still complete their `I(αₙ)` — the
    /// paper's dropout-after-exchange regime, where the master decodes from
    /// the surviving `≥ N−2k` evaluations.
    pub fn kill_k_workers_after_exchange(seed: u64, n_workers: usize, k: usize) -> ChaosPlan {
        let mut plan = ChaosPlan::new();
        for victim in ChaosPlan::chosen_victims(seed, n_workers, k) {
            plan = plan.rule(
                FaultRule::new(FaultAction::Kill)
                    .from_node(victim)
                    .class(PayloadClass::GShare)
                    .skip(n_workers.saturating_sub(2) as u64)
                    .limit(1),
            );
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::FpMat;
    use crate::mpc::network::PooledMat;

    fn ishare() -> Payload {
        Payload::IShare(PooledMat::detached(FpMat::zeros(1, 1)))
    }

    #[test]
    fn wildcards_and_criteria_match() {
        let plan = ChaosPlan::new().rule(
            FaultRule::new(FaultAction::Drop)
                .from_node(3)
                .class(PayloadClass::IShare),
        );
        assert_eq!(plan.decide(0, 3, 9, &ishare()), Some(FaultAction::Drop));
        // wrong sender, wrong class: untouched
        assert_eq!(plan.decide(0, 4, 9, &ishare()), None);
        let g = Payload::GShare(PooledMat::detached(FpMat::zeros(1, 1)));
        assert_eq!(plan.decide(0, 3, 9, &g), None);
    }

    #[test]
    fn skip_and_limit_windows() {
        let plan = ChaosPlan::new().rule(
            FaultRule::new(FaultAction::Drop).skip(1).limit(2),
        );
        assert_eq!(plan.decide(0, 0, 1, &ishare()), None); // skipped
        assert_eq!(plan.decide(0, 0, 1, &ishare()), Some(FaultAction::Drop));
        assert_eq!(plan.decide(0, 0, 1, &ishare()), Some(FaultAction::Drop));
        assert_eq!(plan.decide(0, 0, 1, &ishare()), None); // exhausted
        assert_eq!(plan.rules()[0].hits(), 4);
    }

    #[test]
    fn exhausted_rule_falls_through_to_later_rules() {
        let plan = ChaosPlan::new()
            .rule(FaultRule::new(FaultAction::Drop).limit(1))
            .rule(FaultRule::new(FaultAction::Garble));
        assert_eq!(plan.decide(0, 0, 1, &ishare()), Some(FaultAction::Drop));
        assert_eq!(plan.decide(0, 0, 1, &ishare()), Some(FaultAction::Garble));
    }

    #[test]
    fn garble_plan_matches_chosen_victims() {
        let victims = ChaosPlan::chosen_victims(7, 17, 2);
        assert_eq!(victims.len(), 2);
        assert_ne!(victims[0], victims[1]);
        let plan = ChaosPlan::garble_k_workers(7, 17, 2);
        let rule_victims: Vec<usize> =
            plan.rules().iter().filter_map(|r| r.from).collect();
        assert_eq!(rule_victims, victims);
        for rule in plan.rules() {
            assert_eq!(rule.action, FaultAction::Garble);
            assert_eq!(rule.class, Some(PayloadClass::IShare));
        }
    }

    #[test]
    fn kill_plan_is_seed_deterministic() {
        let a = ChaosPlan::kill_k_workers(42, 17, 2, PayloadClass::IShare);
        let b = ChaosPlan::kill_k_workers(42, 17, 2, PayloadClass::IShare);
        assert_eq!(a.rules().len(), 2);
        let victims = |p: &ChaosPlan| -> Vec<Option<NodeId>> {
            p.rules().iter().map(|r| r.from).collect()
        };
        assert_eq!(victims(&a), victims(&b));
        assert_ne!(
            victims(&a),
            victims(&ChaosPlan::kill_k_workers(43, 17, 2, PayloadClass::IShare))
        );
        // distinct victims
        let v = victims(&a);
        assert_ne!(v[0], v[1]);
    }
}
