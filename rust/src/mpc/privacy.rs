//! Privacy analysis harness (§VI-D / Theorem 13, Lemma 14).
//!
//! The information-theoretic argument is algebraic: the view of a colluding
//! set `C` (|C| ≤ z) of each share polynomial `F = C + S` is
//! `F(α_n) = C(α_n) + Σ_w S̄_w α_n^{e_w}` for `n ∈ C`. The mask term is a
//! linear image of the `z` uniform secrets under the |C|×z matrix
//! `M[n][w] = α_n^{e_w}`. If `M` has full row rank, the masks are jointly
//! uniform over the colluders' view and the shares carry zero information —
//! Lemma 14's `I(𝒜; T̃) = 0`.
//!
//! This module makes that argument *executable*:
//!
//! * [`mask_rank`] — rank of the collusion mask matrix over `GF(p)`;
//! * [`audit_collusion`] — sample z-subsets and verify full rank (a real
//!   deployment runs this at α-assignment time, because a *generalized*
//!   Vandermonde over a finite field can be singular for unlucky αs);
//! * [`secret_free_combination`] — for |C| > z, produce the explicit linear
//!   combination of shares that eliminates every secret term (the attack:
//!   `Σ v_n F(α_n)` is then a deterministic function of the private data),
//!   demonstrating the `z+1` breakdown the threshold model predicts;
//! * [`shares_leak_deterministically`] — end-to-end leak check: rerun
//!   share generation under different secret seeds and test whether the
//!   combined view changes (masked ⇒ changes; unmasked ⇒ identical leak).

use crate::codes::CmpcScheme;
use crate::ff;
use crate::matrix::FpMat;
use crate::mpc::source;
use crate::util::rng::ChaChaRng;

/// Rank over `GF(p)` of the |subset| × |secret_powers| matrix
/// `M[n][w] = α_{subset[n]}^{secret_powers[w]}`.
pub fn mask_rank(alphas: &[u64], secret_powers: &[u64], subset: &[usize]) -> usize {
    let rows: Vec<Vec<u64>> = subset
        .iter()
        .map(|&n| {
            secret_powers
                .iter()
                .map(|&e| ff::pow(alphas[n], e))
                .collect()
        })
        .collect();
    rank(rows)
}

fn rank(mut m: Vec<Vec<u64>>) -> usize {
    if m.is_empty() {
        return 0;
    }
    let cols = m[0].len();
    let mut r = 0usize;
    for c in 0..cols {
        let Some(pivot) = (r..m.len()).find(|&i| m[i][c] != 0) else {
            continue;
        };
        m.swap(r, pivot);
        let inv = ff::inv(m[r][c]);
        for v in m[r].iter_mut() {
            *v = ff::mul(*v, inv);
        }
        let pivot_row = m[r].clone();
        for (i, row) in m.iter_mut().enumerate() {
            if i != r && row[c] != 0 {
                let f = row[c];
                for (v, &pv) in row.iter_mut().zip(pivot_row.iter()) {
                    *v = ff::sub(*v, ff::mul(f, pv));
                }
            }
        }
        r += 1;
        if r == m.len() {
            break;
        }
    }
    r
}

/// Left null-space vector of `M` (a `v ≠ 0` with `vᵀM = 0`), if one exists.
/// For |subset| > z such a vector always exists and defines the share
/// combination free of all secret terms.
pub fn secret_free_combination(
    alphas: &[u64],
    secret_powers: &[u64],
    subset: &[usize],
) -> Option<Vec<u64>> {
    // vᵀM = 0 ⟺ Mᵀ v = 0; solve for the null space of the transpose.
    let rows = secret_powers.len();
    let cols = subset.len();
    let mut m: Vec<Vec<u64>> = (0..rows)
        .map(|w| {
            (0..cols)
                .map(|n| ff::pow(alphas[subset[n]], secret_powers[w]))
                .collect()
        })
        .collect();
    // Gauss-Jordan; track pivot column per row.
    let mut pivots: Vec<usize> = Vec::new();
    let mut r = 0usize;
    for c in 0..cols {
        let Some(p_row) = (r..rows).find(|&i| m[i][c] != 0) else {
            continue;
        };
        m.swap(r, p_row);
        let inv = ff::inv(m[r][c]);
        for v in m[r].iter_mut() {
            *v = ff::mul(*v, inv);
        }
        let pr = m[r].clone();
        for (i, row) in m.iter_mut().enumerate() {
            if i != r && row[c] != 0 {
                let f = row[c];
                for (v, &pv) in row.iter_mut().zip(pr.iter()) {
                    *v = ff::sub(*v, ff::mul(f, pv));
                }
            }
        }
        pivots.push(c);
        r += 1;
        if r == rows {
            break;
        }
    }
    // free column = non-pivot column; build the null vector.
    let free = (0..cols).find(|c| !pivots.contains(c))?;
    let mut v = vec![0u64; cols];
    v[free] = 1;
    for (row_idx, &pc) in pivots.iter().enumerate() {
        v[pc] = ff::neg(m[row_idx][free]);
    }
    Some(v)
}

/// Audit `trials` random collusion sets of size `z`: every mask matrix must
/// have full rank `z` for the deployment's α assignment to be
/// privacy-sound. Returns the number of deficient subsets found (0 = pass).
pub fn audit_collusion(
    alphas: &[u64],
    secret_powers: &[u64],
    z: usize,
    trials: usize,
    rng: &mut ChaChaRng,
) -> usize {
    let n = alphas.len();
    let mut bad = 0usize;
    let mut ids: Vec<usize> = (0..n).collect();
    for _ in 0..trials {
        rng.shuffle(&mut ids);
        let subset = &ids[..z.min(n)];
        if mask_rank(alphas, secret_powers, subset) < subset.len() {
            bad += 1;
        }
    }
    bad
}

/// Empirical leak test on the *A-side* share view of `subset`:
/// regenerate shares under two different secret streams and report whether
/// the view combination `Σ v_n F_A(α_n)` (with `v` from
/// [`secret_free_combination`], or plain concatenation when `v` is None)
/// is identical across runs — identical means the view deterministically
/// exposes a function of `A`.
pub fn shares_leak_deterministically(
    scheme: &dyn CmpcScheme,
    a: &FpMat,
    alphas: &[u64],
    subset: &[usize],
) -> bool {
    let secret_powers = scheme.secret_powers_a();
    match secret_free_combination(alphas, &secret_powers, subset) {
        None => false, // no secret-free combination ⇒ masked view
        Some(v) => {
            let view = |seed: u64| -> FpMat {
                let mut rng = ChaChaRng::seed_from_u64(seed);
                let poly = source::build_f_a(scheme, a, &mut rng);
                let mut acc = FpMat::zeros(poly.rows, poly.cols);
                for (&coef, &n) in v.iter().zip(subset.iter()) {
                    acc.axpy_inplace(coef, &poly.eval(alphas[n]));
                }
                acc
            };
            view(11) == view(12345)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme, PolyDotCmpc};
    use crate::poly::interp::evaluation_points;
    use crate::util::testing::property;

    #[test]
    fn z_colluders_have_full_rank_masks() {
        property("z-collusion masks full rank", 60, |rng| {
            let s = rng.gen_index(3) + 1;
            let t = rng.gen_index(3) + 1;
            let z = rng.gen_index(4) + 1;
            let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
            let n = scheme.n_workers();
            let alphas = evaluation_points(n, 0);
            let bad = audit_collusion(&alphas, &scheme.secret_powers_a(), z, 20, rng)
                + audit_collusion(&alphas, &scheme.secret_powers_b(), z, 20, rng);
            if bad != 0 {
                return Err(format!("s={s} t={t} z={z}: {bad} deficient subsets"));
            }
            Ok(())
        });
    }

    #[test]
    fn z_plus_one_colluders_break_masking() {
        // The threshold is tight: z+1 colluders admit a secret-free
        // combination, and the combined view becomes deterministic in A.
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let n = scheme.n_workers();
        let alphas = evaluation_points(n, 0);
        let mut rng = ChaChaRng::seed_from_u64(3);
        let a = FpMat::random(&mut rng, 8, 8);
        let subset: Vec<usize> = (0..3).collect(); // z+1 = 3
        let v = secret_free_combination(&alphas, &scheme.secret_powers_a(), &subset);
        assert!(v.is_some(), "z+1 subset must admit elimination");
        assert!(shares_leak_deterministically(&scheme, &a, &alphas, &subset));
    }

    #[test]
    fn z_colluders_see_randomized_shares() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let n = scheme.n_workers();
        let alphas = evaluation_points(n, 0);
        let mut rng = ChaChaRng::seed_from_u64(4);
        let a = FpMat::random(&mut rng, 8, 8);
        let subset: Vec<usize> = vec![0, 9]; // |subset| = z = 2
        assert!(!shares_leak_deterministically(&scheme, &a, &alphas, &subset));
    }

    #[test]
    fn polydot_masks_audit_clean() {
        let scheme = PolyDotCmpc::new(3, 2, 3);
        let n = scheme.n_workers();
        let alphas = evaluation_points(n, 0);
        let mut rng = ChaChaRng::seed_from_u64(8);
        assert_eq!(
            audit_collusion(&alphas, &scheme.secret_powers_a(), 3, 50, &mut rng),
            0
        );
        assert_eq!(
            audit_collusion(&alphas, &scheme.secret_powers_b(), 3, 50, &mut rng),
            0
        );
    }

    #[test]
    fn rank_of_identity_like() {
        // sanity for the rank kernel
        let m = vec![vec![1, 0, 0], vec![0, 1, 0], vec![0, 0, 1]];
        assert_eq!(super::rank(m), 3);
        let m2 = vec![vec![1, 2, 3], vec![2, 4, 6]];
        assert_eq!(super::rank(m2), 1);
    }
}
