//! Phase 3 — master reconstruction (eq. 21) over the multiplexed fabric.
//!
//! `I(x)` is a *dense* polynomial of degree `t²+z−1` whose first `t²`
//! coefficients are the output blocks `Y_{i,l}` (at power `i + t·l`) and
//! whose top `z` coefficients are the summed masks. Any `t²+z` evaluations
//! determine it, so the master reconstructs from the **first** `t²+z`
//! `I(αₙ)` arrivals — the protocol tolerates `N − (t²+z)` stragglers.
//!
//! With Byzantine adversary tolerance `a > 0` the recovery quota rises to
//! `t²+z+2a` arrivals: the extra `2a` evaluations are the Reed–Solomon
//! margin that lets the master *locate* up to `a` garbled shares (see
//! [`locate_corrupt_evaluations`]) instead of failing on them. Location
//! runs over per-share scalar fingerprints whose random weights are drawn
//! from a **master-local secret RNG** — never derived from anything a
//! worker sees, so a Byzantine worker cannot craft a corruption that is
//! invisible to the fingerprint (see `locate_corrupt_shares` below). Blamed
//! shares are excluded (and reported in [`MasterOutput::blamed_workers`]
//! for the runtime to evict), the surviving candidate set is verified
//! **against the full share data** before it is trusted, and
//! reconstruction proceeds on `t²+z` consistent shares — byte-identical
//! to a fault-free run, since interpolation over `GF(p)` is exact and
//! unique.
//!
//! The correction guarantee is the Reed–Solomon unique-decoding bound:
//! it holds for **up to `a` corruptions**. Beyond the budget the master
//! refuses with a typed [`CmpcError::NotDecodable`] unless the `> a`
//! corrupted shares are mutually consistent, in full matrix data, with a
//! wrong degree-`< t²+z` polynomial through the honest shares — which
//! requires knowing honest share values the corrupt workers never see,
//! but is not information-theoretically excluded. Deployments that must
//! rule out even that alignment should keep `verify = true` as the
//! backstop: the end-to-end `Y = AᵀB` product check catches any wrong
//! reconstruction regardless of how it was produced.
//!
//! The master endpoint is shared by every in-flight job of a deployment:
//! [`run_master`] receives through a [`JobRouter`], which filters envelopes
//! by [`JobId`] (buffering concurrent jobs' traffic for their own driving
//! threads) and converts a dead worker thread into a typed
//! [`CmpcError::Fabric`] timeout instead of a deadlock.
//!
//! After reconstructing, the tail is handled one of two ways. On the
//! default path the master drains it — every worker sends `I(αₙ)` then a
//! [`JobDone`] control message carrying its final overhead totals — so
//! per-worker counters are final when the job returns and no stale
//! envelopes linger on the shared link. On the **early-decode fast path**
//! (`early_decode = true`) the master instead cancels the job as soon as
//! the quota reconstruction is done, with a [`JobAbort`] broadcast to
//! **every** worker — finished peers need it too, to tombstone the id
//! against a mid-compute straggler's late G-shares: the job's latency
//! stops depending on its slowest `N − (t²+z)` workers — the measured form
//! of the code's straggler tolerance.
//!
//! The fast path then drains one [`AbortAck`] per outstanding worker
//! (bounded by the receive timeout): a worker acks only after dropping and
//! tombstoning the job's state, so its reported totals can never tick
//! again — the driver's ξ/σ counters are **exact on both paths**, not
//! lower bounds. Workers already known dead are excluded from the wait —
//! on the in-process transport that detection is reliable (the abort send
//! fails on a dropped endpoint, and chaos kills mark the shared fabric);
//! on a remote transport a write to a just-crashed peer can still succeed
//! into the OS buffer, so the drain additionally polls the transport's
//! link-liveness ([`Fabric::peer_dead`]) in bounded slices: when the
//! reader side observes the peer's connections die (EOF/reset), the wait
//! on that worker is abandoned immediately instead of running out the
//! full `recv_timeout`. A worker that is genuinely *busy* (not merely
//! behind a slow link) also delays only the ack window — the decoded `Y`
//! was in hand before it opened, which is why the wait is metered
//! separately as [`MasterTimings::ack_wait`].
//!
//! [`JobAbort`]: crate::mpc::network::ControlMsg::JobAbort
//! [`AbortAck`]: crate::mpc::network::ControlMsg::AbortAck
//!
//! The `t²` block reconstructions (`Y_{i,l} = Σₙ rows[i+t·l][n]·I(αₙ)`) are
//! independent linear combinations, so they fan out across the worker pool;
//! each block is folded with delayed reduction through a per-worker
//! [`Scratch`] accumulator (one reduction per output element, no
//! allocation in the combination loop).
//!
//! [`JobDone`]: crate::mpc::network::ControlMsg::JobDone
//! [`Scratch`]: crate::runtime::pool::Scratch

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::ff::{self, P};
use crate::matrix::FpMat;
use crate::metrics::WorkerCounters;
use crate::mpc::network::{ControlMsg, Fabric, JobId, JobRouter, Payload, PooledMat};
use crate::poly::interp::{locate_corrupt_evaluations, try_vandermonde_inverse_rows};
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::util::rng::ChaChaRng;

/// Result of the master phase.
pub struct MasterOutput {
    /// The reconstructed product `Y = Aᵀ·B` (m×m).
    pub y: FpMat,
    /// Worker ids whose `I(αₙ)` arrived in time to be used.
    pub used_workers: Vec<usize>,
    /// Worker ids whose shares arrived late or never (tolerated stragglers).
    pub stragglers_tolerated: usize,
    /// Whether the early-decode fast path actually cancelled a straggler
    /// tail (`early_decode` was set *and* at least one worker had not
    /// acknowledged when reconstruction finished).
    pub early_decoded: bool,
    /// Worker ids whose `I(αₙ)` was located as *corrupted* by the
    /// Byzantine error-locator pass and excluded from reconstruction
    /// (sorted; empty when every arrived share was consistent or
    /// `adversary_tolerance = 0`). The runtime evicts these like dead
    /// workers.
    pub blamed_workers: Vec<usize>,
}

/// Independent secret fingerprint components per location attempt. A
/// fixed corruption vector survives one uniformly random weighted sum
/// with probability exactly `1/P`; surviving both components of an
/// attempt is `1/P²` ≈ 2.3·10⁻¹⁰.
const FP_COMPONENTS: usize = 2;
/// Location attempts with fresh secret weights before giving up. Each
/// retry fires only when a corruption slipped every fingerprint of the
/// previous attempt *and* was then caught by the full-data verification,
/// so reaching the bound is astronomically unlikely under `≤ a` faults.
const FP_ATTEMPTS: usize = 4;

/// OS-entropy seed for the master-local fingerprint RNG. `RandomState`
/// keys come from the platform's secure entropy source; the seed never
/// leaves this process, is never derived from the job id or any other
/// value a worker can observe, and is drawn *after* the shares arrived —
/// a Byzantine worker cannot target its corruption at the weights.
fn entropy_seed() -> u64 {
    use std::collections::hash_map::RandomState;
    use std::hash::{BuildHasher, Hasher};
    RandomState::new().build_hasher().finish()
}

/// Compress one I-share into a single scalar: `Σ_p w[p]·data[p]`.
/// Position `p` across the I-shares is an evaluation of one dense
/// polynomial of degree `< t²+z` at the worker's α, so for any fixed
/// weight vector the fingerprints are evaluations of the *weighted-sum*
/// polynomial — the error locator runs on scalars instead of whole
/// matrices. With uniformly random secret weights, a fixed nonzero
/// corruption vector hashes to zero with probability exactly `1/P`.
fn fingerprint(data: &[u32], weights: &[u64]) -> u64 {
    let mut acc = 0u64;
    for (&w, &x) in weights.iter().zip(data.iter()) {
        acc = ff::add(acc, ff::mul(w, x as u64));
    }
    acc
}

/// Check that every share in `kept` lies on one polynomial of degree
/// `< k_dim` **position-by-position in full data** — the deterministic
/// acceptance test behind the probabilistic fingerprint location. The
/// interpolant through the first `k_dim` shares is evaluated at each
/// surplus share's α via scalar Lagrange weights and compared entry-wise;
/// with distinct αs, any single inconsistent share in the set forces at
/// least one surplus mismatch (two distinct degree-`< k_dim` polynomials
/// cannot agree at `k_dim` points), so a corruption that survived the
/// fingerprints cannot survive this.
fn shares_fully_consistent(kept: &[(u64, &[u32])], k_dim: usize) -> bool {
    if kept.len() < k_dim {
        return false;
    }
    let base: Vec<u64> = kept[..k_dim].iter().map(|&(x, _)| x).collect();
    let len = kept[0].1.len();
    if kept.iter().any(|&(_, d)| d.len() != len) {
        return false;
    }
    let mut weights = vec![0u64; k_dim];
    for &(xm, data_m) in &kept[k_dim..] {
        for (j, w) in weights.iter_mut().enumerate() {
            let mut num = 1u64;
            let mut den = 1u64;
            for (i, &bi) in base.iter().enumerate() {
                if i != j {
                    num = ff::mul(num, ff::sub(xm, bi));
                    den = ff::mul(den, ff::sub(base[j], bi));
                }
            }
            *w = ff::mul(num, ff::inv(den));
        }
        for p in 0..len {
            let mut acc = 0u64;
            for (j, &w) in weights.iter().enumerate() {
                acc = ff::add(acc, ff::mul(w, kept[j].1[p] as u64));
            }
            if acc != data_m[p] as u64 {
                return false;
            }
        }
    }
    true
}

/// Locate up to `a` corrupted shares among `shares` (`(α, full data)`
/// pairs, at least `k_dim + 2a` of them for the full correction radius).
///
/// Three layers compose into the soundness story:
/// 1. **Secret fingerprints** — each attempt compresses every share with
///    [`FP_COMPONENTS`] independent uniformly random weight vectors drawn
///    from `rng` (master-local, seeded from OS entropy after the shares
///    are already in hand). Unlike a public or job-derived fingerprint
///    point, the weights are unpredictable to the workers, so a crafted
///    corruption with a vanishing weighted sum is a `1/P` lottery per
///    component, not a computable construction.
/// 2. **Error location** — [`locate_corrupt_evaluations`]
///    (Berlekamp–Welch, polynomial-time) runs per component; the blamed
///    union across components is the candidate corrupt set.
/// 3. **Full-data verification** — the surviving candidate set must be
///    consistent position-by-position in the actual share matrices
///    ([`shares_fully_consistent`]); a fingerprint-evading corruption is
///    caught here and the attempt retries with fresh secret weights.
///
/// Shares whose length differs from the (honest-majority) modal length
/// can never be consistent and are pre-blamed before fingerprinting.
/// Returns blamed indices into `shares` (sorted), or `None` when the
/// faults exceed the correction radius — the caller's typed
/// [`CmpcError::NotDecodable`].
fn locate_corrupt_shares(
    shares: &[(u64, &[u32])],
    k_dim: usize,
    a: usize,
    rng: &mut ChaChaRng,
) -> Option<Vec<usize>> {
    let n = shares.len();
    if n < k_dim {
        return None;
    }
    // A repeated α can only come from a forged duplicate sender id (each
    // worker evaluates at one α and sends once per job): refuse it typed,
    // exactly like the `a = 0` path's singular Vandermonde — and never
    // feed it to the Lagrange denominators below, which would divide by
    // zero.
    let mut seen_alphas: Vec<u64> = shares.iter().map(|&(x, _)| x).collect();
    seen_alphas.sort_unstable();
    if seen_alphas.windows(2).any(|w| w[0] == w[1]) {
        return None;
    }
    // Honest shares (a strict majority: ≥ k_dim + a of n ≤ k_dim + 2a)
    // agree on the block length; any minority-length share is corrupt by
    // construction and would otherwise defeat entry-wise comparison.
    let mut lens: Vec<usize> = shares.iter().map(|s| s.1.len()).collect();
    lens.sort_unstable();
    let modal_len = lens[lens.len() / 2];
    let pre_blamed: Vec<usize> = (0..n).filter(|&i| shares[i].1.len() != modal_len).collect();
    if pre_blamed.len() > a {
        return None;
    }
    let sized: Vec<usize> = (0..n).filter(|i| !pre_blamed.contains(i)).collect();
    let budget = a - pre_blamed.len();

    for _attempt in 0..FP_ATTEMPTS {
        let mut blamed: Vec<usize> = pre_blamed.clone();
        for _component in 0..FP_COMPONENTS {
            let weights: Vec<u64> = (0..modal_len).map(|_| rng.field_element()).collect();
            let pts: Vec<(u64, u64)> = sized
                .iter()
                .map(|&i| (shares[i].0, fingerprint(shares[i].1, &weights)))
                .collect();
            let located = locate_corrupt_evaluations(&pts, k_dim, budget)?;
            for idx in located {
                let share_idx = sized[idx];
                if !blamed.contains(&share_idx) {
                    blamed.push(share_idx);
                }
            }
        }
        if blamed.len() > a {
            return None;
        }
        blamed.sort_unstable();
        let kept: Vec<(u64, &[u32])> = (0..n)
            .filter(|i| !blamed.contains(i))
            .map(|i| shares[i])
            .collect();
        if shares_fully_consistent(&kept, k_dim) {
            return Some(blamed);
        }
        // A corruption hashed to zero under every weight vector of this
        // attempt (probability ≤ a/P² ≈ 10⁻⁹): redraw and relocate.
    }
    None
}

/// Wall-clock windows of the master phase, measured separately so
/// [`PhaseTimings`] can attribute compute and reconstruction honestly.
///
/// [`PhaseTimings`]: crate::metrics::PhaseTimings
#[derive(Default, Debug, Clone, Copy)]
pub struct MasterTimings {
    /// From entry until the `t²+z`-th I-share arrived (worker compute +
    /// exchange + transfer, overlapped across workers).
    pub quota_wait: Duration,
    /// The reconstruction math only: the dense Vandermonde solve plus the
    /// `t²` block combinations.
    pub reconstruct: Duration,
    /// After reconstruction, waiting for the remaining workers' I-shares
    /// and `JobDone` acks (the straggler tail). Near-zero on the
    /// early-decode fast path, which cancels the tail instead of waiting
    /// for it.
    pub tail_wait: Duration,
    /// Early-decode fast path only: draining `AbortAck`s from the aborted
    /// stragglers so the overhead counters are final at return. `Y` was
    /// already decoded when this window opened.
    pub ack_wait: Duration,
}

/// Collect `t²+z+2a` I-shares for `job` (`a = adversary_tolerance`),
/// locate and exclude up to `a` corrupted shares, reconstruct `Y`, then
/// finish the tail: drain `n_workers` `JobDone` acks, or — with
/// `early_decode` — abort the stragglers and drain their `AbortAck`s (so
/// counters are final) without waiting for their remaining work.
///
/// `alphas[n]` is worker `n`'s evaluation point; `t`/`z` are scheme
/// parameters; `adversary_tolerance` is the Byzantine error budget `a`
/// (0 keeps the erasure-only decode, byte-identical to previous
/// releases); `n_workers` is the provisioned worker count. `timeout`
/// bounds every receive (a dead worker surfaces as
/// [`CmpcError::Fabric`]); a worker-reported [`ControlMsg::JobError`]
/// fails the job immediately. `fabric` carries the targeted
/// [`ControlMsg::JobAbort`]s of the early-decode path. `counters` are the
/// driver-side per-worker overhead counters, finalized from the totals in
/// `JobDone`/`AbortAck` (pass `&[]` to skip — unit harnesses). `pool` and
/// `scratch` drive the parallel block reconstruction.
#[allow(clippy::too_many_arguments)]
pub fn run_master(
    router: &JobRouter,
    fabric: &Fabric,
    job: JobId,
    alphas: &Arc<Vec<u64>>,
    n_workers: usize,
    t: usize,
    z: usize,
    adversary_tolerance: usize,
    timeout: Duration,
    early_decode: bool,
    counters: &[Arc<WorkerCounters>],
    pool: &WorkerPool,
    scratch: &ScratchPool,
) -> Result<(MasterOutput, MasterTimings)> {
    // k_dim evaluations determine I(x); 2a extra buy location + exclusion
    // of up to a corrupted shares (Reed–Solomon unique decoding).
    let k_dim = t * t + z;
    let needed = k_dim + 2 * adversary_tolerance;
    if needed > n_workers {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n_workers,
        });
    }
    let t_quota = Instant::now();
    let mut arrived: Vec<(usize, PooledMat)> = Vec::with_capacity(needed);
    // Per-worker JobDone dedup, shared by the quota and drain loops (a
    // worker acks exactly once; out-of-range senders are ignored).
    let mut done = vec![false; n_workers];
    let mut done_count = 0usize;
    fn note_done(done: &mut [bool], done_count: &mut usize, from: usize) {
        if from < done.len() && !done[from] {
            done[from] = true;
            *done_count += 1;
        }
    }
    let finalize = |counters: &[Arc<WorkerCounters>], from: usize, mults: u64, stored: u64| {
        if let Some(c) = counters.get(from) {
            c.record_final(mults, stored);
        }
    };
    while arrived.len() < needed {
        let env = router.recv_for(job, timeout)?;
        match env.payload {
            // The sender id is attacker-controlled on a remote transport
            // (frames need no handshake): an out-of-range worker id must
            // be dropped, never index `alphas`. A *forged duplicate* id
            // surfaces downstream as a typed NotDecodable (repeated αs
            // make the dense Vandermonde singular).
            Payload::IShare(m) => {
                if env.from < n_workers {
                    arrived.push((env.from, m));
                }
            }
            // A worker can finish (I-share consumed above) before slower
            // peers reach the quota.
            Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                finalize(counters, env.from, mults, stored);
                note_done(&mut done, &mut done_count, env.from);
            }
            Payload::Control(ControlMsg::JobError(msg)) => {
                return Err(CmpcError::Fabric(format!("job {job}: {msg}")));
            }
            other => {
                return Err(CmpcError::Fabric(format!("master: unexpected {other:?}")));
            }
        }
    }
    let quota_wait = t_quota.elapsed();
    let t_rec = Instant::now();

    // --- Byzantine error location (a > 0) ---
    // Run the secret-fingerprint error locator over the arrived shares:
    // with k_dim+2a shares and ≤ a corruptions, the blamed set is exactly
    // the corrupted shares, and the kept set is verified against the full
    // share data before it is trusted (see `locate_corrupt_shares`).
    // Locatees are excluded (and reported for eviction); faults beyond the
    // correction radius are a typed refusal.
    let mut blamed_workers: Vec<usize> = Vec::new();
    if adversary_tolerance > 0 {
        let share_views: Vec<(u64, &[u32])> = arrived
            .iter()
            .map(|(id, share)| (alphas[*id], share.data.as_slice()))
            .collect();
        let mut fp_rng = ChaChaRng::seed_from_u64(entropy_seed());
        let blamed_idx =
            locate_corrupt_shares(&share_views, k_dim, adversary_tolerance, &mut fp_rng)
                .ok_or_else(|| {
                    CmpcError::NotDecodable(format!(
                        "job {job}: more than {adversary_tolerance} corrupted I-shares \
                         among {needed} — error location failed (raise adversary_tolerance?)"
                    ))
                })?;
        if !blamed_idx.is_empty() {
            blamed_workers = blamed_idx.iter().map(|&i| arrived[i].0).collect();
            blamed_workers.sort_unstable();
            let mut pos = 0usize;
            arrived.retain(|_| {
                let keep = !blamed_idx.contains(&pos);
                pos += 1;
                keep
            });
        }
        // Any k_dim consistent shares reconstruct the exact same Y (unique
        // interpolation over GF(p)); surplus honest shares just return
        // their buffers to the pool.
        arrived.truncate(k_dim);
    }
    let used_workers: Vec<usize> = arrived.iter().map(|&(id, _)| id).collect();

    // Dense Vandermonde over the arrived points: coefficient c_e of I(x)
    // satisfies c_e = Σₙ rows[e][n]·I(αₙ). Distinct αs make the dense solve
    // invertible; a `None` here means corrupted shares.
    let pts: Vec<u64> = used_workers.iter().map(|&id| alphas[id]).collect();
    let support: Vec<u64> = (0..k_dim as u64).collect();
    let rows = try_vandermonde_inverse_rows(&pts, &support).ok_or_else(|| {
        CmpcError::NotDecodable(
            "singular dense Vandermonde during reconstruction (repeated αs?)".to_string(),
        )
    })?;

    // Y blocks are coefficients 0..t² (power i + t·l): t² independent
    // linear combinations of the arrived shares, one flat slot per block
    // so the pool can hand them out as disjoint &mut chunks.
    let block = arrived[0].1.rows;
    let len = block * block;
    let mut flat: Vec<FpMat> = (0..t * t).map(|_| FpMat::zeros(block, block)).collect();
    pool.par_chunks_mut(&mut flat, 1, |wid, idx, blk| {
        // idx = i + t·l is exactly the coefficient power of block (i,l).
        let e = idx;
        scratch.with(wid, |s| {
            s.acc.clear();
            s.acc.resize(len, 0);
            for (n_idx, (_, share)) in arrived.iter().enumerate() {
                debug_assert_eq!(share.data.len(), len, "I-share {n_idx} shape");
                let c = rows[e][n_idx] % P;
                if c == 0 {
                    continue;
                }
                for (a, &x) in s.acc.iter_mut().zip(share.data.iter()) {
                    *a += c * x as u64;
                }
            }
            // Montgomery fold: the combination summed at most k_dim
            // (≤ t²+z+2a ≪ 65536) products of reduced elements, so the
            // REDC fast path always applies here.
            ff::mont::fold(&mut blk[0].data, &s.acc, arrived.len());
        });
    });
    // Reassemble the t×t grid: flat[i + t·l] is block (i, l), i.e. grid
    // row-part i, column-part l.
    let mut y_blocks: Vec<Vec<FpMat>> = (0..t).map(|_| Vec::with_capacity(t)).collect();
    for (idx, blk) in flat.into_iter().enumerate() {
        let i = idx % t;
        y_blocks[i].push(blk);
    }
    let y = FpMat::from_blocks(&y_blocks);
    // Straggler I-shares return their buffers to the pool here; the top z
    // coefficients of I(x) are mask sums and never need reconstructing —
    // decodability is asserted end-to-end by the caller (Y == AᵀB).
    drop(arrived);
    let reconstruct = t_rec.elapsed();

    // --- finish the tail ---
    let t_tail = Instant::now();
    let early_decoded = early_decode && done_count < n_workers;
    let (tail_wait, ack_wait) = if early_decoded {
        // Fast path: the quota decoded Y, so the stragglers' remaining work
        // is pure waste — cancel the job with a JobAbort to every worker.
        // Completed workers tombstone the id, which is load-bearing: a
        // straggler caught mid-compute still emits its G-shares after
        // waking, and without the tombstone those late shares would seed
        // phantom `JobState`s at its finished peers (pinning pooled buffers
        // until a deadline sweep). A worker that died never receives the
        // abort (`send` to a dropped endpoint is a tolerated error here —
        // and excludes it from the ack wait, as does a chaos-kill mark);
        // anything still in flight after the drain is dropped when the
        // driver closes the job on the router.
        let mut awaiting = vec![false; n_workers];
        let mut awaiting_count = 0usize;
        for (wid, wait) in awaiting.iter_mut().enumerate() {
            let sent = fabric.send(
                job,
                fabric.master_id(),
                wid,
                Payload::Control(ControlMsg::JobAbort),
            );
            if !done[wid] && sent.is_ok() && !fabric.peer_dead(wid) {
                *wait = true;
                awaiting_count += 1;
            }
        }
        let tail_wait = t_tail.elapsed();
        // Drain one AbortAck (or late JobDone) per live outstanding
        // worker, so every counter is final at return. Bounded by the
        // receive timeout: a worker that dies between the send and its
        // ack cannot stall the job — its counters are final anyway
        // (dead workers don't count), and the decoded Y is already in
        // hand, so running out the clock degrades nothing but this
        // window. The wait polls in bounded slices, re-probing
        // link-liveness between them: a remote worker that crashed after
        // the abort write landed in its OS buffer will never ack, and the
        // reader-side EOF is the only signal — without the probe this
        // window would silently run out the whole timeout.
        let t_ack = Instant::now();
        let deadline = t_ack + timeout;
        const ACK_POLL: Duration = Duration::from_millis(50);
        while awaiting_count > 0 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // Abandon workers whose links died since the abort went out.
            for (wid, wait) in awaiting.iter_mut().enumerate() {
                if *wait && fabric.peer_dead(wid) {
                    *wait = false;
                    awaiting_count -= 1;
                }
            }
            if awaiting_count == 0 {
                break;
            }
            let env = match router.recv_for(job, (deadline - now).min(ACK_POLL)) {
                Ok(env) => env,
                Err(_) => continue, // slice expired: re-probe, re-check deadline
            };
            let from = env.from;
            let mut acked = false;
            match env.payload {
                // A straggler that was already mid-send delivers its
                // I-share before seeing the abort; ignore it.
                Payload::IShare(_) => {}
                Payload::Control(ControlMsg::AbortAck { mults, stored })
                | Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                    // First report wins: a straggler that completed right
                    // as the abort went out sends JobDone (real totals)
                    // and then acks the abort for a job it has already
                    // forgotten (zeros) — the zeros must not clobber.
                    if from < done.len() && !done[from] {
                        finalize(counters, from, mults, stored);
                    }
                    note_done(&mut done, &mut done_count, from);
                    acked = true;
                }
                // The job already decoded; a worker failing its (now
                // cancelled) remainder is not a job failure.
                Payload::Control(ControlMsg::JobError(_)) => acked = true,
                _ => {}
            }
            if acked && from < awaiting.len() && awaiting[from] {
                awaiting[from] = false;
                awaiting_count -= 1;
            }
        }
        (tail_wait, t_ack.elapsed())
    } else {
        // Full drain: every worker sends I-share then JobDone (with its
        // final totals), so overhead counters are final when the job
        // returns.
        while done_count < n_workers {
            let env = router.recv_for(job, timeout)?;
            match env.payload {
                Payload::IShare(_) => {} // straggler share beyond the quota
                Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                    finalize(counters, env.from, mults, stored);
                    note_done(&mut done, &mut done_count, env.from);
                }
                Payload::Control(ControlMsg::JobError(msg)) => {
                    return Err(CmpcError::Fabric(format!("job {job}: {msg}")));
                }
                other => {
                    return Err(CmpcError::Fabric(format!("master: unexpected {other:?}")));
                }
            }
        }
        (t_tail.elapsed(), Duration::ZERO)
    };
    Ok((
        MasterOutput {
            y,
            stragglers_tolerated: n_workers - needed,
            used_workers,
            early_decoded,
            blamed_workers,
        },
        MasterTimings {
            quota_wait,
            reconstruct,
            tail_wait,
            ack_wait,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build `n` I-share-shaped vectors: position `p` across the shares is
    /// the evaluation of one dense degree-`< k_dim` polynomial at αₙ = n+1.
    fn make_shares(k_dim: usize, len: usize, n: usize, seed: u64) -> (Vec<u64>, Vec<Vec<u32>>) {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let coeffs: Vec<Vec<u64>> = (0..k_dim)
            .map(|_| (0..len).map(|_| rng.field_element()).collect())
            .collect();
        let alphas: Vec<u64> = (1..=n as u64).collect();
        let shares = alphas
            .iter()
            .map(|&alpha| {
                (0..len)
                    .map(|p| {
                        let mut acc = 0u64;
                        let mut xp = 1u64;
                        for c in &coeffs {
                            acc = ff::add(acc, ff::mul(c[p], xp));
                            xp = ff::mul(xp, alpha);
                        }
                        acc as u32
                    })
                    .collect()
            })
            .collect();
        (alphas, shares)
    }

    fn views<'a>(alphas: &[u64], shares: &'a [Vec<u32>]) -> Vec<(u64, &'a [u32])> {
        alphas
            .iter()
            .zip(shares)
            .map(|(&x, s)| (x, s.as_slice()))
            .collect()
    }

    #[test]
    fn random_corruption_is_located() {
        let (k_dim, len, a) = (5usize, 64usize, 2usize);
        let (alphas, mut shares) = make_shares(k_dim, len, k_dim + 2 * a, 1);
        shares[3][17] = ff::add(shares[3][17] as u64, 1234) as u32;
        shares[6][0] = ff::add(shares[6][0] as u64, 7) as u32;
        let mut rng = ChaChaRng::seed_from_u64(99);
        let blamed =
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng).expect("located");
        assert_eq!(blamed, vec![3, 6]);
    }

    /// Regression for the public-fingerprint hole: a Byzantine worker that
    /// knows a *predictable* fingerprint point `r` (the old scheme derived
    /// it from the job id) can corrupt two positions with
    /// `d₀·r⁰ = −d₁·r¹`, making the corruption a root of the weight
    /// polynomial — invisible to any fingerprint at `r`. The locator's
    /// weights are now secret, uniform, and drawn after the shares are in
    /// hand, so the same crafted share must be blamed.
    #[test]
    fn crafted_fingerprint_evasion_is_still_located() {
        let (k_dim, len, a) = (4usize, 48usize, 1usize);
        let (alphas, mut shares) = make_shares(k_dim, len, k_dim + 2 * a, 2);
        // The point the attacker predicts (any fixed/public derivation).
        let r = 2 + 0xDEAD_BEEFu64 % (P - 2);
        let d0 = 4242u64;
        let d1 = ff::neg(ff::mul(d0, ff::inv(r))); // d0 + d1·r ≡ 0 (mod p)
        shares[2][0] = ff::add(shares[2][0] as u64, d0) as u32;
        shares[2][1] = ff::add(shares[2][1] as u64, d1) as u32;
        // Sanity: the corruption really is invisible to a fingerprint at r.
        let evade: u64 = ff::add(d0, ff::mul(d1, r));
        assert_eq!(evade, 0, "attack vector must vanish at the public point");
        let mut rng = ChaChaRng::seed_from_u64(5);
        let blamed =
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng).expect("located");
        assert_eq!(blamed, vec![2], "crafted corruption evaded the locator");
    }

    #[test]
    fn beyond_budget_is_refused_not_misdecoded() {
        let (k_dim, len, a) = (4usize, 32usize, 1usize);
        let (alphas, mut shares) = make_shares(k_dim, len, k_dim + 2 * a, 3);
        shares[0][3] = ff::add(shares[0][3] as u64, 5) as u32;
        shares[4][9] = ff::add(shares[4][9] as u64, 11) as u32;
        let mut rng = ChaChaRng::seed_from_u64(8);
        assert_eq!(
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng),
            None,
            "a+1 corruptions must refuse"
        );
    }

    /// A share with the wrong length can never be consistent entry-wise;
    /// the honest-majority modal length pre-blames it.
    #[test]
    fn wrong_length_share_is_blamed() {
        let (k_dim, len, a) = (3usize, 40usize, 1usize);
        let (alphas, mut shares) = make_shares(k_dim, len, k_dim + 2 * a, 4);
        shares[1].truncate(len - 5);
        let mut rng = ChaChaRng::seed_from_u64(6);
        let blamed =
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng).expect("located");
        assert_eq!(blamed, vec![1]);
    }

    #[test]
    fn clean_shares_blame_nobody() {
        let (k_dim, len, a) = (6usize, 50usize, 2usize);
        let (alphas, shares) = make_shares(k_dim, len, k_dim + 2 * a, 10);
        let mut rng = ChaChaRng::seed_from_u64(11);
        let blamed =
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng).expect("located");
        assert!(blamed.is_empty());
    }

    /// A forged duplicate sender id (two shares at one α) is a typed
    /// refusal — matching the `a = 0` path's singular Vandermonde — and
    /// never a divide-by-zero panic in the consistency check.
    #[test]
    fn duplicate_alpha_is_refused_not_a_panic() {
        let (k_dim, len, a) = (3usize, 24usize, 1usize);
        let (mut alphas, shares) = make_shares(k_dim, len, k_dim + 2 * a, 12);
        alphas[4] = alphas[0]; // replayed worker id
        let mut rng = ChaChaRng::seed_from_u64(13);
        assert_eq!(
            locate_corrupt_shares(&views(&alphas, &shares), k_dim, a, &mut rng),
            None
        );
    }

    #[test]
    fn entropy_seeds_differ_across_draws() {
        // Not a randomness-quality test — just that the secret seed is not
        // a constant (which would make the weights predictable again).
        let seeds: Vec<u64> = (0..4).map(|_| entropy_seed()).collect();
        assert!(seeds.windows(2).any(|w| w[0] != w[1]));
    }
}
