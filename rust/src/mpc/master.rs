//! Phase 3 — master reconstruction (eq. 21).
//!
//! `I(x)` is a *dense* polynomial of degree `t²+z−1` whose first `t²`
//! coefficients are the output blocks `Y_{i,l}` (at power `i + t·l`) and
//! whose top `z` coefficients are the summed masks. Any `t²+z` evaluations
//! determine it, so the master reconstructs from the **first** `t²+z`
//! `I(αₙ)` arrivals — the protocol tolerates `N − (t²+z)` stragglers.

use std::sync::Arc;

use crate::error::{CmpcError, Result};
use crate::matrix::FpMat;
use crate::mpc::network::{Endpoint, Payload};
use crate::poly::interp::try_vandermonde_inverse_rows;

/// Result of the master phase.
pub struct MasterOutput {
    /// The reconstructed product `Y = Aᵀ·B` (m×m).
    pub y: FpMat,
    /// Worker ids whose `I(αₙ)` arrived in time to be used.
    pub used_workers: Vec<usize>,
    /// Worker ids whose shares arrived late or never (tolerated stragglers).
    pub stragglers_tolerated: usize,
}

/// Collect `t²+z` I-shares and reconstruct `Y`.
///
/// `alphas[n]` is worker `n`'s evaluation point; `t`/`z` are scheme
/// parameters; `n_workers` is the provisioned worker count.
pub fn run_master(
    endpoint: &Endpoint,
    alphas: &Arc<Vec<u64>>,
    n_workers: usize,
    t: usize,
    z: usize,
) -> Result<MasterOutput> {
    let needed = t * t + z;
    if needed > n_workers {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n_workers,
        });
    }
    let mut arrived: Vec<(usize, FpMat)> = Vec::with_capacity(needed);
    while arrived.len() < needed {
        let env = endpoint
            .recv()
            .map_err(|_| CmpcError::Fabric("fabric closed before reconstruction".to_string()))?;
        match env.payload {
            Payload::IShare(m) => arrived.push((env.from, m)),
            other => {
                return Err(CmpcError::Fabric(format!("master: unexpected {other:?}")));
            }
        }
    }
    let used_workers: Vec<usize> = arrived.iter().map(|&(id, _)| id).collect();

    // Dense Vandermonde over the arrived points: coefficient c_e of I(x)
    // satisfies c_e = Σₙ rows[e][n]·I(αₙ). Distinct αs make the dense solve
    // invertible; a `None` here means corrupted shares.
    let pts: Vec<u64> = used_workers.iter().map(|&id| alphas[id]).collect();
    let support: Vec<u64> = (0..needed as u64).collect();
    let rows = try_vandermonde_inverse_rows(&pts, &support).ok_or_else(|| {
        CmpcError::NotDecodable(
            "singular dense Vandermonde during reconstruction (repeated αs?)".to_string(),
        )
    })?;

    // Y blocks are coefficients 0..t² (power i + t·l).
    let block = arrived[0].1.rows;
    let mut y_blocks: Vec<Vec<FpMat>> = (0..t)
        .map(|_| (0..t).map(|_| FpMat::zeros(block, block)).collect())
        .collect();
    for i in 0..t {
        for l in 0..t {
            let e = i + t * l;
            let blk = &mut y_blocks[i][l];
            for (n_idx, (_, share)) in arrived.iter().enumerate() {
                let c = rows[e][n_idx];
                if c != 0 {
                    blk.axpy_inplace(c, share);
                }
            }
        }
    }
    // The top z coefficients of I(x) are mask sums; reconstructing them is
    // unnecessary — decodability is asserted end-to-end by the caller
    // (Y == AᵀB in verify mode).
    Ok(MasterOutput {
        y: FpMat::from_blocks(&y_blocks),
        stragglers_tolerated: n_workers - needed,
        used_workers,
    })
}
