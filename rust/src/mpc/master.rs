//! Phase 3 — master reconstruction (eq. 21) over the multiplexed fabric.
//!
//! `I(x)` is a *dense* polynomial of degree `t²+z−1` whose first `t²`
//! coefficients are the output blocks `Y_{i,l}` (at power `i + t·l`) and
//! whose top `z` coefficients are the summed masks. Any `t²+z` evaluations
//! determine it, so the master reconstructs from the **first** `t²+z`
//! `I(αₙ)` arrivals — the protocol tolerates `N − (t²+z)` stragglers.
//!
//! With Byzantine adversary tolerance `a > 0` the recovery quota rises to
//! `t²+z+2a` arrivals: the extra `2a` evaluations are the Reed–Solomon
//! margin that lets the master *locate* up to `a` garbled shares (see
//! [`locate_corrupt_evaluations`]) instead of failing on them. Location
//! runs over per-share scalar fingerprints, blamed shares are excluded
//! (and reported in [`MasterOutput::blamed_workers`] for the runtime to
//! evict), and reconstruction proceeds on `t²+z` consistent shares —
//! byte-identical to a fault-free run, since interpolation over `GF(p)`
//! is exact and unique. More than `a` corruptions is a typed
//! [`CmpcError::NotDecodable`], never a wrong product.
//!
//! The master endpoint is shared by every in-flight job of a deployment:
//! [`run_master`] receives through a [`JobRouter`], which filters envelopes
//! by [`JobId`] (buffering concurrent jobs' traffic for their own driving
//! threads) and converts a dead worker thread into a typed
//! [`CmpcError::Fabric`] timeout instead of a deadlock.
//!
//! After reconstructing, the tail is handled one of two ways. On the
//! default path the master drains it — every worker sends `I(αₙ)` then a
//! [`JobDone`] control message carrying its final overhead totals — so
//! per-worker counters are final when the job returns and no stale
//! envelopes linger on the shared link. On the **early-decode fast path**
//! (`early_decode = true`) the master instead cancels the job as soon as
//! the quota reconstruction is done, with a [`JobAbort`] broadcast to
//! **every** worker — finished peers need it too, to tombstone the id
//! against a mid-compute straggler's late G-shares: the job's latency
//! stops depending on its slowest `N − (t²+z)` workers — the measured form
//! of the code's straggler tolerance.
//!
//! The fast path then drains one [`AbortAck`] per outstanding worker
//! (bounded by the receive timeout): a worker acks only after dropping and
//! tombstoning the job's state, so its reported totals can never tick
//! again — the driver's ξ/σ counters are **exact on both paths**, not
//! lower bounds. Workers already known dead are excluded from the wait —
//! on the in-process transport that detection is reliable (the abort send
//! fails on a dropped endpoint, and chaos kills mark the shared fabric);
//! on a remote transport a write to a just-crashed peer can still succeed
//! into the OS buffer, so the drain additionally polls the transport's
//! link-liveness ([`Fabric::peer_dead`]) in bounded slices: when the
//! reader side observes the peer's connections die (EOF/reset), the wait
//! on that worker is abandoned immediately instead of running out the
//! full `recv_timeout`. A worker that is genuinely *busy* (not merely
//! behind a slow link) also delays only the ack window — the decoded `Y`
//! was in hand before it opened, which is why the wait is metered
//! separately as [`MasterTimings::ack_wait`].
//!
//! [`JobAbort`]: crate::mpc::network::ControlMsg::JobAbort
//! [`AbortAck`]: crate::mpc::network::ControlMsg::AbortAck
//!
//! The `t²` block reconstructions (`Y_{i,l} = Σₙ rows[i+t·l][n]·I(αₙ)`) are
//! independent linear combinations, so they fan out across the worker pool;
//! each block is folded with delayed reduction through a per-worker
//! [`Scratch`] accumulator (one reduction per output element, no
//! allocation in the combination loop).
//!
//! [`JobDone`]: crate::mpc::network::ControlMsg::JobDone
//! [`Scratch`]: crate::runtime::pool::Scratch

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::ff::{self, P};
use crate::matrix::FpMat;
use crate::metrics::WorkerCounters;
use crate::mpc::network::{ControlMsg, Fabric, JobId, JobRouter, Payload, PooledMat};
use crate::poly::interp::{locate_corrupt_evaluations, try_vandermonde_inverse_rows};
use crate::runtime::pool::{ScratchPool, WorkerPool};

/// Result of the master phase.
pub struct MasterOutput {
    /// The reconstructed product `Y = Aᵀ·B` (m×m).
    pub y: FpMat,
    /// Worker ids whose `I(αₙ)` arrived in time to be used.
    pub used_workers: Vec<usize>,
    /// Worker ids whose shares arrived late or never (tolerated stragglers).
    pub stragglers_tolerated: usize,
    /// Whether the early-decode fast path actually cancelled a straggler
    /// tail (`early_decode` was set *and* at least one worker had not
    /// acknowledged when reconstruction finished).
    pub early_decoded: bool,
    /// Worker ids whose `I(αₙ)` was located as *corrupted* by the
    /// Byzantine error-locator pass and excluded from reconstruction
    /// (sorted; empty when every arrived share was consistent or
    /// `adversary_tolerance = 0`). The runtime evicts these like dead
    /// workers.
    pub blamed_workers: Vec<usize>,
}

/// Per-job fingerprint weight: any fixed nonzero field point defines a
/// valid fingerprint family (the weighted share combination is itself an
/// evaluation of one dense degree-`< t²+z` polynomial); deriving it from
/// the job id makes a crafted fingerprint-invisible corruption
/// unrepeatable across jobs while keeping every path (in-process,
/// multi-process, gateway) byte-deterministic.
fn fingerprint_point(job: JobId) -> u64 {
    2 + job.wrapping_mul(0x9E37_79B9_7F4A_7C15) % (P - 2)
}

/// Compress one I-share into a single scalar: `Σ_p data[p]·r^p` (Horner
/// over the reversed scalars). Position `p` of the I-shares is an
/// evaluation of a dense polynomial of degree `< t²+z` at the worker's α,
/// so the fingerprints are evaluations of the *weighted-sum* polynomial —
/// the error locator runs on scalars instead of whole matrices. A
/// corrupted share evades the fingerprint only if its corruption vector is
/// a root of the weight polynomial (probability ~`len/P`); the verify-mode
/// product check backstops that sliver.
fn fingerprint(data: &[u32], r: u64) -> u64 {
    let mut acc = 0u64;
    for &x in data.iter().rev() {
        acc = ff::add(ff::mul(acc, r), x as u64);
    }
    acc
}

/// Wall-clock windows of the master phase, measured separately so
/// [`PhaseTimings`] can attribute compute and reconstruction honestly.
///
/// [`PhaseTimings`]: crate::metrics::PhaseTimings
#[derive(Default, Debug, Clone, Copy)]
pub struct MasterTimings {
    /// From entry until the `t²+z`-th I-share arrived (worker compute +
    /// exchange + transfer, overlapped across workers).
    pub quota_wait: Duration,
    /// The reconstruction math only: the dense Vandermonde solve plus the
    /// `t²` block combinations.
    pub reconstruct: Duration,
    /// After reconstruction, waiting for the remaining workers' I-shares
    /// and `JobDone` acks (the straggler tail). Near-zero on the
    /// early-decode fast path, which cancels the tail instead of waiting
    /// for it.
    pub tail_wait: Duration,
    /// Early-decode fast path only: draining `AbortAck`s from the aborted
    /// stragglers so the overhead counters are final at return. `Y` was
    /// already decoded when this window opened.
    pub ack_wait: Duration,
}

/// Collect `t²+z+2a` I-shares for `job` (`a = adversary_tolerance`),
/// locate and exclude up to `a` corrupted shares, reconstruct `Y`, then
/// finish the tail: drain `n_workers` `JobDone` acks, or — with
/// `early_decode` — abort the stragglers and drain their `AbortAck`s (so
/// counters are final) without waiting for their remaining work.
///
/// `alphas[n]` is worker `n`'s evaluation point; `t`/`z` are scheme
/// parameters; `adversary_tolerance` is the Byzantine error budget `a`
/// (0 keeps the erasure-only decode, byte-identical to previous
/// releases); `n_workers` is the provisioned worker count. `timeout`
/// bounds every receive (a dead worker surfaces as
/// [`CmpcError::Fabric`]); a worker-reported [`ControlMsg::JobError`]
/// fails the job immediately. `fabric` carries the targeted
/// [`ControlMsg::JobAbort`]s of the early-decode path. `counters` are the
/// driver-side per-worker overhead counters, finalized from the totals in
/// `JobDone`/`AbortAck` (pass `&[]` to skip — unit harnesses). `pool` and
/// `scratch` drive the parallel block reconstruction.
#[allow(clippy::too_many_arguments)]
pub fn run_master(
    router: &JobRouter,
    fabric: &Fabric,
    job: JobId,
    alphas: &Arc<Vec<u64>>,
    n_workers: usize,
    t: usize,
    z: usize,
    adversary_tolerance: usize,
    timeout: Duration,
    early_decode: bool,
    counters: &[Arc<WorkerCounters>],
    pool: &WorkerPool,
    scratch: &ScratchPool,
) -> Result<(MasterOutput, MasterTimings)> {
    // k_dim evaluations determine I(x); 2a extra buy location + exclusion
    // of up to a corrupted shares (Reed–Solomon unique decoding).
    let k_dim = t * t + z;
    let needed = k_dim + 2 * adversary_tolerance;
    if needed > n_workers {
        return Err(CmpcError::InsufficientWorkers {
            needed,
            provisioned: n_workers,
        });
    }
    let t_quota = Instant::now();
    let mut arrived: Vec<(usize, PooledMat)> = Vec::with_capacity(needed);
    // Per-worker JobDone dedup, shared by the quota and drain loops (a
    // worker acks exactly once; out-of-range senders are ignored).
    let mut done = vec![false; n_workers];
    let mut done_count = 0usize;
    fn note_done(done: &mut [bool], done_count: &mut usize, from: usize) {
        if from < done.len() && !done[from] {
            done[from] = true;
            *done_count += 1;
        }
    }
    let finalize = |counters: &[Arc<WorkerCounters>], from: usize, mults: u64, stored: u64| {
        if let Some(c) = counters.get(from) {
            c.record_final(mults, stored);
        }
    };
    while arrived.len() < needed {
        let env = router.recv_for(job, timeout)?;
        match env.payload {
            // The sender id is attacker-controlled on a remote transport
            // (frames need no handshake): an out-of-range worker id must
            // be dropped, never index `alphas`. A *forged duplicate* id
            // surfaces downstream as a typed NotDecodable (repeated αs
            // make the dense Vandermonde singular).
            Payload::IShare(m) => {
                if env.from < n_workers {
                    arrived.push((env.from, m));
                }
            }
            // A worker can finish (I-share consumed above) before slower
            // peers reach the quota.
            Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                finalize(counters, env.from, mults, stored);
                note_done(&mut done, &mut done_count, env.from);
            }
            Payload::Control(ControlMsg::JobError(msg)) => {
                return Err(CmpcError::Fabric(format!("job {job}: {msg}")));
            }
            other => {
                return Err(CmpcError::Fabric(format!("master: unexpected {other:?}")));
            }
        }
    }
    let quota_wait = t_quota.elapsed();
    let t_rec = Instant::now();

    // --- Byzantine error location (a > 0) ---
    // Fingerprint every arrived share into one scalar and run the
    // decode-and-verify error locator over the (α, fingerprint) pairs: with
    // k_dim+2a points and ≤ a corruptions, the minimal consistent exclusion
    // set is exactly the corrupted shares. Locatees are excluded (and
    // reported for eviction); more than `a` corruptions is a typed refusal
    // — never a silently wrong product.
    let mut blamed_workers: Vec<usize> = Vec::new();
    if adversary_tolerance > 0 {
        let r = fingerprint_point(job);
        let fp_pts: Vec<(u64, u64)> = arrived
            .iter()
            .map(|(id, share)| (alphas[*id], fingerprint(&share.data, r)))
            .collect();
        let blamed_idx = locate_corrupt_evaluations(&fp_pts, k_dim, adversary_tolerance)
            .ok_or_else(|| {
                CmpcError::NotDecodable(format!(
                    "job {job}: more than {adversary_tolerance} corrupted I-shares \
                     among {needed} — error location failed (raise adversary_tolerance?)"
                ))
            })?;
        if !blamed_idx.is_empty() {
            blamed_workers = blamed_idx.iter().map(|&i| arrived[i].0).collect();
            blamed_workers.sort_unstable();
            let mut pos = 0usize;
            arrived.retain(|_| {
                let keep = !blamed_idx.contains(&pos);
                pos += 1;
                keep
            });
        }
        // Any k_dim consistent shares reconstruct the exact same Y (unique
        // interpolation over GF(p)); surplus honest shares just return
        // their buffers to the pool.
        arrived.truncate(k_dim);
    }
    let used_workers: Vec<usize> = arrived.iter().map(|&(id, _)| id).collect();

    // Dense Vandermonde over the arrived points: coefficient c_e of I(x)
    // satisfies c_e = Σₙ rows[e][n]·I(αₙ). Distinct αs make the dense solve
    // invertible; a `None` here means corrupted shares.
    let pts: Vec<u64> = used_workers.iter().map(|&id| alphas[id]).collect();
    let support: Vec<u64> = (0..k_dim as u64).collect();
    let rows = try_vandermonde_inverse_rows(&pts, &support).ok_or_else(|| {
        CmpcError::NotDecodable(
            "singular dense Vandermonde during reconstruction (repeated αs?)".to_string(),
        )
    })?;

    // Y blocks are coefficients 0..t² (power i + t·l): t² independent
    // linear combinations of the arrived shares, one flat slot per block
    // so the pool can hand them out as disjoint &mut chunks.
    let block = arrived[0].1.rows;
    let len = block * block;
    let mut flat: Vec<FpMat> = (0..t * t).map(|_| FpMat::zeros(block, block)).collect();
    pool.par_chunks_mut(&mut flat, 1, |wid, idx, blk| {
        // idx = i + t·l is exactly the coefficient power of block (i,l).
        let e = idx;
        scratch.with(wid, |s| {
            s.acc.clear();
            s.acc.resize(len, 0);
            for (n_idx, (_, share)) in arrived.iter().enumerate() {
                debug_assert_eq!(share.data.len(), len, "I-share {n_idx} shape");
                let c = rows[e][n_idx] % P;
                if c == 0 {
                    continue;
                }
                for (a, &x) in s.acc.iter_mut().zip(share.data.iter()) {
                    *a += c * x as u64;
                }
            }
            for (o, &a) in blk[0].data.iter_mut().zip(s.acc.iter()) {
                *o = ff::reduce(a) as u32;
            }
        });
    });
    // Reassemble the t×t grid: flat[i + t·l] is block (i, l), i.e. grid
    // row-part i, column-part l.
    let mut y_blocks: Vec<Vec<FpMat>> = (0..t).map(|_| Vec::with_capacity(t)).collect();
    for (idx, blk) in flat.into_iter().enumerate() {
        let i = idx % t;
        y_blocks[i].push(blk);
    }
    let y = FpMat::from_blocks(&y_blocks);
    // Straggler I-shares return their buffers to the pool here; the top z
    // coefficients of I(x) are mask sums and never need reconstructing —
    // decodability is asserted end-to-end by the caller (Y == AᵀB).
    drop(arrived);
    let reconstruct = t_rec.elapsed();

    // --- finish the tail ---
    let t_tail = Instant::now();
    let early_decoded = early_decode && done_count < n_workers;
    let (tail_wait, ack_wait) = if early_decoded {
        // Fast path: the quota decoded Y, so the stragglers' remaining work
        // is pure waste — cancel the job with a JobAbort to every worker.
        // Completed workers tombstone the id, which is load-bearing: a
        // straggler caught mid-compute still emits its G-shares after
        // waking, and without the tombstone those late shares would seed
        // phantom `JobState`s at its finished peers (pinning pooled buffers
        // until a deadline sweep). A worker that died never receives the
        // abort (`send` to a dropped endpoint is a tolerated error here —
        // and excludes it from the ack wait, as does a chaos-kill mark);
        // anything still in flight after the drain is dropped when the
        // driver closes the job on the router.
        let mut awaiting = vec![false; n_workers];
        let mut awaiting_count = 0usize;
        for (wid, wait) in awaiting.iter_mut().enumerate() {
            let sent = fabric.send(
                job,
                fabric.master_id(),
                wid,
                Payload::Control(ControlMsg::JobAbort),
            );
            if !done[wid] && sent.is_ok() && !fabric.peer_dead(wid) {
                *wait = true;
                awaiting_count += 1;
            }
        }
        let tail_wait = t_tail.elapsed();
        // Drain one AbortAck (or late JobDone) per live outstanding
        // worker, so every counter is final at return. Bounded by the
        // receive timeout: a worker that dies between the send and its
        // ack cannot stall the job — its counters are final anyway
        // (dead workers don't count), and the decoded Y is already in
        // hand, so running out the clock degrades nothing but this
        // window. The wait polls in bounded slices, re-probing
        // link-liveness between them: a remote worker that crashed after
        // the abort write landed in its OS buffer will never ack, and the
        // reader-side EOF is the only signal — without the probe this
        // window would silently run out the whole timeout.
        let t_ack = Instant::now();
        let deadline = t_ack + timeout;
        const ACK_POLL: Duration = Duration::from_millis(50);
        while awaiting_count > 0 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // Abandon workers whose links died since the abort went out.
            for (wid, wait) in awaiting.iter_mut().enumerate() {
                if *wait && fabric.peer_dead(wid) {
                    *wait = false;
                    awaiting_count -= 1;
                }
            }
            if awaiting_count == 0 {
                break;
            }
            let env = match router.recv_for(job, (deadline - now).min(ACK_POLL)) {
                Ok(env) => env,
                Err(_) => continue, // slice expired: re-probe, re-check deadline
            };
            let from = env.from;
            let mut acked = false;
            match env.payload {
                // A straggler that was already mid-send delivers its
                // I-share before seeing the abort; ignore it.
                Payload::IShare(_) => {}
                Payload::Control(ControlMsg::AbortAck { mults, stored })
                | Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                    // First report wins: a straggler that completed right
                    // as the abort went out sends JobDone (real totals)
                    // and then acks the abort for a job it has already
                    // forgotten (zeros) — the zeros must not clobber.
                    if from < done.len() && !done[from] {
                        finalize(counters, from, mults, stored);
                    }
                    note_done(&mut done, &mut done_count, from);
                    acked = true;
                }
                // The job already decoded; a worker failing its (now
                // cancelled) remainder is not a job failure.
                Payload::Control(ControlMsg::JobError(_)) => acked = true,
                _ => {}
            }
            if acked && from < awaiting.len() && awaiting[from] {
                awaiting[from] = false;
                awaiting_count -= 1;
            }
        }
        (tail_wait, t_ack.elapsed())
    } else {
        // Full drain: every worker sends I-share then JobDone (with its
        // final totals), so overhead counters are final when the job
        // returns.
        while done_count < n_workers {
            let env = router.recv_for(job, timeout)?;
            match env.payload {
                Payload::IShare(_) => {} // straggler share beyond the quota
                Payload::Control(ControlMsg::JobDone { mults, stored }) => {
                    finalize(counters, env.from, mults, stored);
                    note_done(&mut done, &mut done_count, env.from);
                }
                Payload::Control(ControlMsg::JobError(msg)) => {
                    return Err(CmpcError::Fabric(format!("job {job}: {msg}")));
                }
                other => {
                    return Err(CmpcError::Fabric(format!("master: unexpected {other:?}")));
                }
            }
        }
        (t_tail.elapsed(), Duration::ZERO)
    };
    Ok((
        MasterOutput {
            y,
            stragglers_tolerated: n_workers - needed,
            used_workers,
            early_decoded,
            blamed_workers,
        },
        MasterTimings {
            quota_wait,
            reconstruct,
            tail_wait,
            ack_wait,
        },
    ))
}
