//! The three-phase CMPC protocol engine (§IV-A / Algorithm 3), run over a
//! simulated edge-network fabric.
//!
//! * **Phase 1** ([`source`]) — each source partitions its matrix, builds the
//!   share polynomial `F = C + S` prescribed by the scheme, and sends
//!   `F(αₙ)` to every worker over D2D links.
//! * **Phase 2** ([`worker`]) — worker `n` computes
//!   `H(αₙ) = F_A(αₙ)·F_B(αₙ)` (via the configured [`crate::runtime`]
//!   backend), forms `Gₙ(x)` (eq. 19) with `z` fresh random masks, exchanges
//!   `Gₙ(αₙ')` with every peer, and sends `I(αₙ) = Σₙ' Gₙ'(αₙ)` to the
//!   master.
//! * **Phase 3** ([`master`]) — the master interpolates the dense degree
//!   `t²+z−1` polynomial `I(x)` from the *first* `t²+z` arrivals (straggler
//!   tolerance) and reads `Y_{i,l}` off the first `t²` coefficients
//!   (eq. 21).
//!
//! Workers are **persistent**: [`runtime::WorkerRuntime`] spawns the `N`
//! worker threads once per deployment and streams jobs to them over a
//! long-lived, job-multiplexed [`network::Fabric`], which meters scalars
//! per edge class — globally and per job — so measured communication can be
//! asserted against ζ (eq. 34). Payload buffers cycle through a
//! [`network::BufferPool`], making warm jobs free of fabric allocations.
//!
//! The runtime is **straggler-resilient**: every in-flight job carries its
//! own deadline at each worker (a dead peer fails only the job it starved,
//! never its healthy siblings), and the master can decode as soon as any
//! `t²+z` evaluations arrive and cancel the straggler tail
//! (`ProtocolConfig::early_decode`) — tolerating up to `N−(t²+z)` workers
//! that straggle, or that crash *after* delivering their G-exchange
//! contribution (a pre-exchange crash fails the in-flight job, since every
//! `I(αₙ)` sums all `N` G-shares). Worker threads that crash — or are
//! killed by a [`chaos`] fault plan — are evicted and respawned with the
//! same worker index and re-derived rng streams, so subsequent jobs run on
//! a full complement with byte-identical outputs.

pub mod chaos;
pub mod deployment;
pub mod fused;
pub mod master;
pub mod network;
pub mod pipeline;
pub mod privacy;
pub mod protocol;
pub mod runtime;
pub mod source;
pub mod worker;
