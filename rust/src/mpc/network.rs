//! Edge-network fabric, multiplexed across concurrent jobs and **pluggable
//! over real transports**.
//!
//! Models the paper's topology: every source connects to every worker, every
//! worker to every other worker and to the master (D2D links). Links are
//! routed through a central [`Fabric`] that meters traffic per edge class —
//! globally and **per job** — and can inject link latency, chaos faults
//! ([`crate::mpc::chaos`]), and shaped latency/bandwidth
//! ([`crate::transport::shaper`]).
//!
//! The [`Fabric`] is policy (topology checks, metering, chaos, shaping);
//! the link layer underneath it is a [`Transport`]:
//!
//! * [`ChannelTransport`] — the in-process default: nodes are threads and
//!   links are mpsc channels. Zero-copy (envelopes move with their
//!   [`PooledMat`] payloads intact) and zero-cost relative to the
//!   pre-transport fabric.
//! * [`crate::transport::tcp::TcpTransport`] — each party is a separate
//!   process (or thread) reachable at a `host:port` from a
//!   [`crate::runtime::manifest::TopologyManifest`]; envelopes cross the
//!   wire in the framed codec of [`crate::transport::wire`].
//!
//! Since the persistent-runtime refactor the fabric is *long-lived*: one
//! [`Fabric`] (and one set of worker threads) serves every job of a
//! deployment, so every [`Envelope`] carries a [`JobId`] tag and Phase-1/2/3
//! messages from concurrent jobs interleave safely on the same links. Data
//! payloads ride in [`PooledMat`] buffers loaned from a [`BufferPool`] and
//! returned on drop, so a steady-state job performs zero fabric-payload heap
//! allocations (pinned by `tests/alloc_discipline.rs`). A separate
//! *control plane* ([`Payload::Control`]) starts jobs, acknowledges their
//! completion, reports worker failures, and shuts the runtime down; control
//! messages are unmetered and exempt from the data-topology rules.
//!
//! Node-id layout for an `N`-worker deployment:
//! `0..N` → workers, `N` → master, `N+1` → source A, `N+2` → source B.

use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{CmpcError, Result};
use crate::ff;
use crate::matrix::FpMat;
use crate::metrics::{TrafficCounters, TrafficReport, WireStats, WorkerCounters};
use crate::mpc::chaos::{ChaosPlan, FaultAction, PayloadClass};
use crate::transport::shaper::LinkShaper;
use crate::transport::wire;

/// Flat node index on a fabric: `0..N` are workers, then master,
/// source A, source B (see [`Fabric::role`]).
pub type NodeId = usize;

/// Identifies one job multiplexed over a shared fabric. Assigned by the
/// worker runtime at submission; unique for the lifetime of the fabric.
pub type JobId = u64;

/// `JobId` used for job-independent control traffic (shutdown).
pub const CONTROL_JOB: JobId = u64::MAX;

/// Role classification derived from a node id.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Role {
    /// Phase-2 worker with the given worker index.
    Worker(usize),
    /// The Phase-3 master.
    Master,
    /// The source holding matrix `A`.
    SourceA,
    /// The source holding matrix `B`.
    SourceB,
}

/// A payload matrix loaned from a [`BufferPool`].
///
/// Dereferences to [`FpMat`]; the underlying buffer is returned to its pool
/// when the `PooledMat` drops (receive side), so steady-state jobs recycle
/// a fixed working set of payload buffers instead of allocating per message.
/// [`PooledMat::detached`] wraps a plain matrix with no pool (tests, ad-hoc
/// sends); its buffer is simply freed on drop.
#[derive(Debug)]
pub struct PooledMat {
    mat: FpMat,
    pool: Option<Weak<BufferPool>>,
}

impl PooledMat {
    /// Wrap a matrix that does not belong to any pool.
    pub fn detached(mat: FpMat) -> PooledMat {
        PooledMat { mat, pool: None }
    }
}

impl Deref for PooledMat {
    type Target = FpMat;

    fn deref(&self) -> &FpMat {
        &self.mat
    }
}

impl DerefMut for PooledMat {
    fn deref_mut(&mut self) -> &mut FpMat {
        &mut self.mat
    }
}

impl Drop for PooledMat {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take().and_then(|w| w.upgrade()) {
            // `FpMat::zeros(0, 0)` holds an empty Vec — no allocation.
            pool.give_back(std::mem::replace(&mut self.mat, FpMat::zeros(0, 0)));
        }
    }
}

/// Loan/return pool of payload buffers shared by every node of a fabric.
///
/// `loan` pops a free buffer (or creates one on a cold pool) and reshapes it
/// to the requested size; dropping the returned [`PooledMat`] gives the
/// buffer back. After one warmup job at the largest shape in flight, loans
/// and returns perform zero heap allocations.
///
/// The pool also tracks *demand*: the high-water mark of concurrently
/// loaned scalars since the last [`BufferPool::trim`]. The runtime trims at
/// every job finish, so a deployment that once served a huge-`m` job and
/// then settles into small-`m` traffic releases its peak-sized buffers
/// instead of pinning them forever (the RSS-creep item in ROADMAP), while
/// steady same-size traffic — where retained capacity tracks demand —
/// never trims and stays allocation-free.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: Mutex<Vec<FpMat>>,
    /// Scalars currently loaned out.
    loaned: AtomicUsize,
    /// High-water mark of `loaned` since the last trim (demand proxy).
    peak: AtomicUsize,
}

/// Free capacity above `TRIM_SLACK ×` recent demand triggers a trim…
const TRIM_SLACK: usize = 4;
/// …which releases the largest buffers until free capacity is back under
/// `TRIM_KEEP ×` recent demand.
const TRIM_KEEP: usize = 2;
/// Never trim a pool retaining fewer scalars than this (64 KiB of `u32`s) —
/// below that, churn costs more than the memory.
const TRIM_MIN_RETAINED: usize = 16 * 1024;

impl BufferPool {
    /// Fresh, empty pool behind an `Arc` (loans hold a `Weak` to it).
    pub fn new() -> Arc<BufferPool> {
        Arc::new(BufferPool::default())
    }

    /// Borrow a `rows × cols` buffer from `pool`. Contents are unspecified
    /// (callers fully overwrite before sending). Associated function
    /// because the loan must capture a `Weak` back-reference for the
    /// return-on-drop.
    pub fn loan(pool: &Arc<BufferPool>, rows: usize, cols: usize) -> PooledMat {
        let mut mat = pool
            .free
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| FpMat::zeros(0, 0));
        mat.reshape(rows, cols);
        let scalars = rows * cols;
        let now = pool.loaned.fetch_add(scalars, Ordering::Relaxed) + scalars;
        pool.peak.fetch_max(now, Ordering::Relaxed);
        PooledMat {
            mat,
            pool: Some(Arc::downgrade(pool)),
        }
    }

    fn give_back(&self, mat: FpMat) {
        self.loaned.fetch_sub(mat.len(), Ordering::Relaxed);
        self.free.lock().unwrap().push(mat);
    }

    /// Buffers currently sitting in the free list (tests assert recycling).
    pub fn free_buffers(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Total capacity (in scalars) retained by the free list.
    pub fn free_capacity_scalars(&self) -> usize {
        let free = self.free.lock().unwrap();
        free.iter().map(|m| m.data.capacity()).sum()
    }

    /// High-water mark of concurrently loaned scalars since the last trim
    /// (what the next [`BufferPool::trim`] will treat as demand).
    pub fn peak_loaned_scalars(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// High-water trim: when the free list retains far more capacity than
    /// recent demand (the loaned high-water mark since the previous trim),
    /// release the largest buffers until it no longer does. Returns how
    /// many buffers were freed. Called by the worker runtime at every job
    /// finish; steady same-size traffic never trims.
    pub fn trim(&self) -> usize {
        let outstanding = self.loaned.load(Ordering::Relaxed);
        let demand = self.peak.swap(outstanding, Ordering::Relaxed);
        let trigger = demand.saturating_mul(TRIM_SLACK).max(TRIM_MIN_RETAINED);
        let keep = demand.saturating_mul(TRIM_KEEP).max(TRIM_MIN_RETAINED);
        let mut free = self.free.lock().unwrap();
        let mut free_cap: usize = free.iter().map(|m| m.data.capacity()).sum();
        if free_cap <= trigger {
            return 0;
        }
        // Largest buffers last, so `pop` releases peak-sized ones first.
        free.sort_by_key(|m| m.data.capacity());
        let mut released = 0;
        while free_cap > keep {
            match free.pop() {
                Some(mat) => {
                    free_cap -= mat.data.capacity();
                    released += 1;
                }
                None => break,
            }
        }
        released
    }
}

/// Runtime control-plane messages (unmetered; exempt from data topology).
#[derive(Debug)]
pub enum ControlMsg {
    /// Start serving a job: the worker derives its per-job secret stream
    /// from `seed` (+ its own id) and reports overheads into `counters`.
    ///
    /// The counters `Arc` is shared memory and cannot cross a remote
    /// transport: the wire codec serializes only `seed`, and a remote
    /// worker installs a fresh local instance whose totals travel back in
    /// [`ControlMsg::JobDone`] / [`ControlMsg::AbortAck`].
    JobStart {
        /// Per-job secret seed (worker id is mixed in locally).
        seed: u64,
        /// Shared overhead counters the worker reports into.
        counters: Arc<WorkerCounters>,
    },
    /// A worker finished every Phase-2/3 obligation of the job; carries its
    /// final overhead totals so the driver-side counters are exact even
    /// when the worker lives in another process.
    JobDone {
        /// Final scalar-multiplication count for the job.
        mults: u64,
        /// Final stored-scalar count for the job.
        stored: u64,
    },
    /// A worker had to abandon the job (backend failure, dead peer, …).
    JobError(String),
    /// The job's driver gave up (worker failure or receive timeout) or the
    /// master early-decoded and cancelled the straggler tail: workers drop
    /// any state for the job and tombstone it, so one aborted job cannot
    /// leave stuck `JobState`s leaking on its surviving peers.
    JobAbort,
    /// A worker's acknowledgement of a [`ControlMsg::JobAbort`]: the job's
    /// state is dropped and tombstoned, so the overhead totals carried here
    /// are **final** — the early-decode driver drains these to report exact
    /// ξ/σ counters instead of lower bounds.
    AbortAck {
        /// Final scalar-multiplication count at abort time.
        mults: u64,
        /// Final stored-scalar count at abort time.
        stored: u64,
    },
    /// Terminate the worker's serve loop (runtime teardown).
    Shutdown,
    /// Push one job's *input matrix* to a source node, with the per-job
    /// secret seed: the gateway's remote engine drives arbitrary
    /// client-submitted data through a distributed cluster by sending
    /// source A its `A` and source B its `B`, instead of the sources
    /// deriving manifest-seeded inputs locally. Control-plane by design:
    /// master→source is not a data-topology edge, and these bytes are the
    /// job input, not protocol overhead, so they stay unmetered.
    JobInput {
        /// The job's per-job secret seed.
        seed: u64,
        /// The input matrix (`A` for source A, `B` for source B).
        mat: FpMat,
    },
    /// Pipeline form of [`ControlMsg::JobStart`]: start serving round
    /// `stage` of a pipeline under the round seed. When `masked` is set
    /// the worker must **withhold** its plain I-share, wait for the
    /// round's [`Payload::StageMask`], and answer with a
    /// [`Payload::StageMasked`] instead — the flag travels in the start
    /// message precisely so no worker can race ahead of its mask and leak
    /// an unmasked intermediate to the master. Like `JobStart`, the
    /// counters `Arc` never crosses a remote transport.
    StageStart {
        /// Pipeline round index (0-based).
        stage: u32,
        /// The round's secret seed.
        seed: u64,
        /// Whether this round's I-share must travel masked.
        masked: bool,
        /// Shared overhead counters the worker reports into.
        counters: Arc<WorkerCounters>,
    },
    /// The master's re-share of an intermediate masked open: worker
    /// `to`'s evaluation of `build_f_a(Z', rng)` for pipeline round
    /// `stage`. Control-plane like [`ControlMsg::JobInput`] (its
    /// precedent): master→worker is not a data-topology edge, and the
    /// masked re-share is round input, not protocol overhead.
    StageShareZ {
        /// Pipeline round index this share feeds.
        stage: u32,
        /// The worker's evaluation of the masked-open re-share polynomial.
        mat: FpMat,
    },
    /// Source A's residual share for pipeline round `stage`: the
    /// evaluation of the secret-term-free polynomial of the replayed mask
    /// `R'`. The worker's round input is `StageShareZ − StageShareR`,
    /// which by GF(p) linearity equals a fresh A-share of the true
    /// (never-materialized) next state.
    StageShareR {
        /// Pipeline round index this share feeds.
        stage: u32,
        /// The worker's evaluation of the replayed-mask residual polynomial.
        mat: FpMat,
    },
}

/// A protocol message payload.
#[derive(Debug)]
pub enum Payload {
    /// Phase 1: a worker's evaluations of the two share polynomials in one
    /// combined envelope (the in-process driver plays both sources on one
    /// thread, so one message per worker keeps the fabric simple).
    Shares {
        /// `F_A(α_to)` — the worker's A-share.
        fa: PooledMat,
        /// `F_B(α_to)` — the worker's B-share.
        fb: PooledMat,
    },
    /// Phase 1, split form: `F_A(α_to)` alone — what a *physically
    /// separate* source-A process sends (it does not hold `B`). Workers
    /// accept the combined and split forms interchangeably.
    ShareA(PooledMat),
    /// Phase 1, split form: `F_B(α_to)` from source B.
    ShareB(PooledMat),
    /// Phase 2: `G_{from}(α_to)`.
    GShare(PooledMat),
    /// Phase 3: `I(α_from)`.
    IShare(PooledMat),
    /// Pipeline round `stage`: source B's evaluation `D(α_to)` of the
    /// round's mask polynomial (source→worker, metered like a share).
    StageMask {
        /// Pipeline round index the mask belongs to.
        stage: u32,
        /// `D(α_to)` — the mask polynomial evaluated at the receiver.
        mat: PooledMat,
    },
    /// Pipeline round `stage`: a worker's **masked** I-share
    /// `I(α_from) + D(α_from)` (worker→master, metered like an I-share) —
    /// what intermediate rounds send in place of [`Payload::IShare`], so
    /// the master only ever interpolates `Z = Y + R`.
    StageMasked {
        /// Pipeline round index the share belongs to.
        stage: u32,
        /// `I(α_from) + D(α_from)` — the masked I-share.
        mat: PooledMat,
    },
    /// Runtime control plane (job lifecycle, shutdown).
    Control(ControlMsg),
}

impl Payload {
    /// Number of field scalars carried (the unit of eq. 32–34).
    pub fn scalars(&self) -> u64 {
        match self {
            Payload::Shares { fa, fb } => (fa.len() + fb.len()) as u64,
            Payload::ShareA(m) | Payload::ShareB(m) => m.len() as u64,
            Payload::GShare(m) | Payload::IShare(m) => m.len() as u64,
            Payload::StageMask { mat, .. } | Payload::StageMasked { mat, .. } => {
                mat.len() as u64
            }
            Payload::Control(_) => 0,
        }
    }
}

/// [`FaultAction::Garble`]: perturb the payload's first scalar (mod p) so a
/// verify-mode job detects the corruption as a decode failure. Control
/// payloads carry no scalars and pass through.
fn garble(payload: &mut Payload) {
    let mat = match payload {
        Payload::Shares { fa, .. } => fa,
        Payload::ShareA(m) | Payload::ShareB(m) => m,
        Payload::GShare(m) | Payload::IShare(m) => m,
        Payload::StageMask { mat, .. } | Payload::StageMasked { mat, .. } => mat,
        Payload::Control(_) => return,
    };
    if !mat.is_empty() {
        let v = mat.at(0, 0);
        mat.set(0, 0, ff::add(v, 1));
    }
}

/// A routed message, tagged with the job it belongs to.
#[derive(Debug)]
pub struct Envelope {
    /// The job this message belongs to ([`CONTROL_JOB`] for job-free control).
    pub job: JobId,
    /// Sending node.
    pub from: NodeId,
    /// The message body.
    pub payload: Payload,
}

/// The pluggable link layer beneath a [`Fabric`]: raw, policy-free
/// delivery of [`Envelope`]s to node ids.
///
/// Everything above the trait — topology legality, traffic metering, chaos
/// fault injection, link shaping — lives in [`Fabric::send`], so the two
/// implementations stay small: [`ChannelTransport`] moves envelopes through
/// in-process mpsc channels (payload buffers intact, zero copies), and
/// [`crate::transport::tcp::TcpTransport`] serializes them through the
/// framed wire codec onto `std::net` sockets.
pub trait Transport: Send + Sync {
    /// Nodes this transport can address (`n_workers + 3`).
    fn n_nodes(&self) -> usize;

    /// Deliver `env` to node `to`. Blocking; a dead or unreachable
    /// destination surfaces as a typed [`CmpcError::Fabric`].
    fn deliver(&self, to: NodeId, env: Envelope) -> Result<()>;

    /// Deliver several envelopes to one peer, preserving order. The
    /// default is a plain loop — semantically identical to repeated
    /// [`Transport::deliver`] calls. A wire transport overrides this to
    /// coalesce the batch into a single write (one syscall for k frames);
    /// metering must stay **per envelope** so frame/byte counters are
    /// byte-identical to the sequential path.
    fn deliver_batch(&self, to: NodeId, envs: Vec<Envelope>) -> Result<()> {
        for env in envs {
            self.deliver(to, env)?;
        }
        Ok(())
    }

    /// Swap `node`'s local receive queue for a fresh one (the
    /// eviction/respawn path). Errors when `node` is not hosted by this
    /// transport (e.g. a remote peer of a TCP transport).
    fn replace_endpoint(&self, node: NodeId) -> Result<Endpoint>;

    /// On-wire byte totals, when this transport serializes at all (the
    /// in-process channel transport reports zeros: nothing crosses a wire).
    fn wire_stats(&self) -> WireStats {
        WireStats::default()
    }

    /// Link-liveness: `false` once the transport has *observed* `node`
    /// die — every inbound connection that ever carried its envelopes hit
    /// EOF or a read error. Default `true`: an in-process transport has no
    /// link failures, and a peer we have not heard from yet is presumed
    /// alive (absence of evidence is not death). The master's abort-ack
    /// drain polls this to stop waiting on a crashed remote worker instead
    /// of running out its full `recv_timeout`.
    fn peer_alive(&self, _node: NodeId) -> bool {
        true
    }
}

/// The in-process [`Transport`]: one mpsc channel per node.
pub struct ChannelTransport {
    /// One sender per node. RwLock (not plain Vec) so the eviction/respawn
    /// path can swap a dead node's channel in place while traffic flows to
    /// the other nodes; sends clone the `Sender` under the read lock.
    txs: RwLock<Vec<Sender<Envelope>>>,
    n_nodes: usize,
}

impl ChannelTransport {
    /// Build channels for `n_nodes` nodes; returns one endpoint per node,
    /// indexed by node id.
    pub fn new(n_nodes: usize) -> (Arc<ChannelTransport>, Vec<Endpoint>) {
        let mut txs = Vec::with_capacity(n_nodes);
        let mut endpoints = Vec::with_capacity(n_nodes);
        for id in 0..n_nodes {
            let (tx, rx) = channel();
            txs.push(tx);
            endpoints.push(Endpoint { id, rx });
        }
        (
            Arc::new(ChannelTransport {
                txs: RwLock::new(txs),
                n_nodes,
            }),
            endpoints,
        )
    }
}

impl Transport for ChannelTransport {
    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn deliver(&self, to: NodeId, env: Envelope) -> Result<()> {
        // Clone the sender out of the lock so a concurrent endpoint
        // replacement never waits on an in-flight send.
        let tx = self.txs.read().unwrap()[to].clone();
        tx.send(env).map_err(|_| {
            CmpcError::Fabric(format!("node {to} endpoint dropped (dead node thread?)"))
        })
    }

    fn replace_endpoint(&self, node: NodeId) -> Result<Endpoint> {
        let (tx, rx) = channel();
        self.txs.write().unwrap()[node] = tx;
        Ok(Endpoint { id: node, rx })
    }
}

/// Fabric policy knobs independent of the transport underneath.
#[derive(Clone, Default)]
pub struct FabricTuning {
    /// Fixed per-hop latency injected on every data send (sleeps the
    /// sender; prefer the shaper for non-blocking in-flight latency).
    pub link_delay: Option<Duration>,
    /// Fault-injection plan consulted on every send.
    pub chaos: Option<Arc<ChaosPlan>>,
    /// Per-link latency/bandwidth emulation; shaped envelopes are released
    /// by a pump thread at their modeled arrival time.
    pub shaper: Option<Arc<LinkShaper>>,
}

/// A shaped envelope waiting for its modeled arrival time.
struct Delayed {
    at: Instant,
    seq: u64,
    to: NodeId,
    env: Envelope,
}

impl PartialEq for Delayed {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}

impl Eq for Delayed {}

impl PartialOrd for Delayed {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Delayed {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed so BinaryHeap (a max-heap) pops the earliest release
        // first; seq breaks ties FIFO.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deliver shaped envelopes at their release instants. Exits when the
/// fabric drops its sender; anything still queued is flushed immediately
/// (the runtime is tearing down — late envelopes are dropped by routers
/// and tombstones downstream).
fn shaper_pump(rx: Receiver<Delayed>, transport: Arc<dyn Transport>) {
    let mut heap: BinaryHeap<Delayed> = BinaryHeap::new();
    let mut open = true;
    loop {
        let now = Instant::now();
        loop {
            let due = match heap.peek() {
                Some(head) => head.at <= now || !open,
                None => false,
            };
            if !due {
                break;
            }
            let d = heap.pop().expect("peeked non-empty");
            let _ = transport.deliver(d.to, d.env);
        }
        if !open && heap.is_empty() {
            return;
        }
        let wait = heap
            .peek()
            .map(|head| head.at.saturating_duration_since(Instant::now()));
        match wait {
            None => match rx.recv() {
                Ok(d) => heap.push(d),
                Err(_) => open = false,
            },
            Some(wait) => match rx.recv_timeout(wait) {
                Ok(d) => heap.push(d),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            },
        }
    }
}

/// Central switch: transport + policy (topology, per-edge-class meters —
/// global and per registered job — chaos, shaping).
pub struct Fabric {
    transport: Arc<dyn Transport>,
    traffic: Arc<TrafficCounters>,
    /// Live per-job meters, registered by `begin_job` / drained by `end_job`.
    /// RwLock so the n(n−1) concurrent data sends of a job share the read
    /// path; only job registration takes the write lock.
    job_traffic: RwLock<HashMap<JobId, Arc<TrafficCounters>>>,
    n_workers: usize,
    n_nodes: usize,
    /// Optional per-hop latency injected on every data send.
    link_delay: Option<Duration>,
    /// Optional fault-injection plan consulted on every send.
    chaos: Option<Arc<ChaosPlan>>,
    /// Per-node kill marks set by [`FaultAction::Kill`]; a killed node's
    /// sends fail until [`Fabric::replace_endpoint`] revives it.
    killed: Vec<AtomicBool>,
    /// Link shaper + the pump feeding shaped envelopes (None when unshaped).
    shaper: Option<Arc<LinkShaper>>,
    shaper_tx: Option<Sender<Delayed>>,
    shaper_seq: AtomicU64,
    pump: Mutex<Option<JoinHandle<()>>>,
}

/// Receive side handed to a node thread.
pub struct Endpoint {
    /// The node this endpoint receives for.
    pub id: NodeId,
    rx: Receiver<Envelope>,
}

impl Fabric {
    /// Build an in-process fabric for `n_workers` workers (+ master + two
    /// sources). Returns the fabric and one endpoint per node, indexed by
    /// node id.
    pub fn new(n_workers: usize, link_delay: Option<Duration>) -> (Arc<Fabric>, Vec<Endpoint>) {
        Fabric::with_chaos(n_workers, link_delay, None)
    }

    /// [`Fabric::new`] with a fault-injection plan attached for the
    /// fabric's lifetime (see [`crate::mpc::chaos`]).
    pub fn with_chaos(
        n_workers: usize,
        link_delay: Option<Duration>,
        chaos: Option<Arc<ChaosPlan>>,
    ) -> (Arc<Fabric>, Vec<Endpoint>) {
        Fabric::with_tuning(
            n_workers,
            FabricTuning {
                link_delay,
                chaos,
                shaper: None,
            },
        )
    }

    /// In-process fabric with the full set of policy knobs.
    pub fn with_tuning(n_workers: usize, tuning: FabricTuning) -> (Arc<Fabric>, Vec<Endpoint>) {
        let (transport, endpoints) = ChannelTransport::new(n_workers + 3);
        let fabric = Fabric::over_transport(transport, tuning);
        (fabric, endpoints)
    }

    /// Wrap an existing [`Transport`] (e.g. a bound TCP transport) in
    /// fabric policy. The node count comes from the transport
    /// (`n_workers = n_nodes − 3`); endpoints are obtained from the
    /// transport separately.
    pub fn over_transport(transport: Arc<dyn Transport>, tuning: FabricTuning) -> Arc<Fabric> {
        let n_nodes = transport.n_nodes();
        let n_workers = n_nodes.saturating_sub(3);
        let (shaper_tx, pump) = match &tuning.shaper {
            Some(_) => {
                let (tx, rx) = channel::<Delayed>();
                let t = transport.clone();
                let handle = std::thread::Builder::new()
                    .name("cmpc-shaper".to_string())
                    .spawn(move || shaper_pump(rx, t))
                    .expect("spawning shaper pump");
                (Some(tx), Some(handle))
            }
            None => (None, None),
        };
        Arc::new(Fabric {
            transport,
            traffic: TrafficCounters::shared(),
            job_traffic: RwLock::new(HashMap::new()),
            n_workers,
            n_nodes,
            link_delay: tuning.link_delay,
            chaos: tuning.chaos,
            killed: (0..n_nodes).map(|_| AtomicBool::new(false)).collect(),
            shaper: tuning.shaper,
            shaper_tx,
            shaper_seq: AtomicU64::new(0),
            pump: Mutex::new(pump),
        })
    }

    /// Replace a (dead) node's receive endpoint with a fresh channel and
    /// clear its chaos-kill mark — the eviction/respawn path. Envelopes
    /// that raced into the old channel drop with it (pooled payloads
    /// return to their pool); envelopes sent after the old receiver
    /// dropped were already reported to their senders as typed
    /// [`CmpcError::Fabric`] errors. Errors when the underlying transport
    /// does not host `node` locally.
    pub fn replace_endpoint(&self, node: NodeId) -> Result<Endpoint> {
        let endpoint = self.transport.replace_endpoint(node)?;
        self.killed[node].store(false, Ordering::Relaxed);
        Ok(endpoint)
    }

    /// Whether the chaos plan killed `node` (a worker observing a send
    /// failure checks this to die like a crashed thread instead of
    /// reporting a job error — see `serve_worker`).
    pub fn chaos_killed(&self, node: NodeId) -> bool {
        self.killed[node].load(Ordering::Relaxed)
    }

    /// Whether `node` is known dead — chaos-killed, or reported gone by
    /// the transport's link-liveness ([`Transport::peer_alive`]): on TCP
    /// every connection that carried its envelopes hit EOF/error. Used by
    /// the master's abort-ack drain to give up on a crashed peer early
    /// instead of running out the full receive timeout.
    pub fn peer_dead(&self, node: NodeId) -> bool {
        self.chaos_killed(node) || !self.transport.peer_alive(node)
    }

    /// Number of worker nodes (ids `0..n_workers`).
    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Node id of the master (`N`).
    pub fn master_id(&self) -> NodeId {
        self.n_workers
    }

    /// Node id of source A (`N + 1`).
    pub fn source_a_id(&self) -> NodeId {
        self.n_workers + 1
    }

    /// Node id of source B (`N + 2`).
    pub fn source_b_id(&self) -> NodeId {
        self.n_workers + 2
    }

    /// Classify a node id into its [`Role`].
    pub fn role(&self, id: NodeId) -> Role {
        if id < self.n_workers {
            Role::Worker(id)
        } else if id == self.master_id() {
            Role::Master
        } else if id == self.source_a_id() {
            Role::SourceA
        } else {
            Role::SourceB
        }
    }

    /// Register per-job traffic meters for `job` (runtime job intake).
    pub fn begin_job(&self, job: JobId) {
        self.job_traffic
            .write()
            .unwrap()
            .insert(job, TrafficCounters::shared());
    }

    /// Drain and return the meters of a finished job. Returns an empty
    /// report when the job was never registered.
    pub fn end_job(&self, job: JobId) -> TrafficReport {
        self.job_traffic
            .write()
            .unwrap()
            .remove(&job)
            .map(|c| c.snapshot())
            .unwrap_or_default()
    }

    /// Send `payload` from `from` to `to` on behalf of `job`, metering data
    /// payloads by edge class (globally and on the job's meters).
    ///
    /// Errors are typed [`CmpcError::Fabric`]: a link outside the CMPC data
    /// topology, a destination endpoint that has been dropped (a dead node
    /// thread), or a sender the chaos plan killed. Control payloads skip
    /// metering, injected link latency, shaping, and the topology check —
    /// they model the runtime, not the network. When a [`ChaosPlan`] is
    /// attached, it is consulted here for every envelope except
    /// [`ControlMsg::Shutdown`] (dropping a shutdown would hang runtime
    /// teardown); dropped envelopes vanish unmetered.
    ///
    /// When a [`LinkShaper`] rule matches a data envelope, the send
    /// returns immediately and the envelope is delivered by the pump
    /// thread at its modeled arrival time (token-bucket serialization +
    /// propagation latency) — the sender is **not** blocked, unlike
    /// `link_delay` and chaos [`FaultAction::Delay`], which model a busy
    /// sender rather than a slow link. A delivery failure after shaping
    /// (dead endpoint) cannot be reported to the sender; it surfaces as
    /// the receiver's per-job deadline instead.
    pub fn send(&self, job: JobId, from: NodeId, to: NodeId, payload: Payload) -> Result<()> {
        match self.apply_policy(job, from, to, payload)? {
            Some(env) => self.transport.deliver(to, env),
            None => Ok(()), // chaos-dropped or diverted to the shaper pump
        }
    }

    /// [`Fabric::send`] for several payloads to **one** peer, preserving
    /// order. Every per-envelope policy step — chaos decisions, link
    /// delay, per-class metering, shaper diversion — runs exactly as it
    /// would for sequential sends (counters are byte-identical); only the
    /// final delivery is coalesced through [`Transport::deliver_batch`],
    /// which a wire transport turns into a single write. When policy
    /// fails mid-batch (e.g. a chaos kill), the payloads accepted before
    /// the failure are still delivered — matching what sequential sends
    /// would already have put on the wire — and the error is returned.
    pub fn send_batch(
        &self,
        job: JobId,
        from: NodeId,
        to: NodeId,
        payloads: Vec<Payload>,
    ) -> Result<()> {
        let mut batch: Vec<Envelope> = Vec::with_capacity(payloads.len());
        let mut policy: Result<()> = Ok(());
        for payload in payloads {
            match self.apply_policy(job, from, to, payload) {
                Ok(Some(env)) => batch.push(env),
                Ok(None) => {}
                Err(e) => {
                    policy = Err(e);
                    break;
                }
            }
        }
        let delivered = if batch.len() == 1 {
            let env = batch.pop().expect("len checked");
            self.transport.deliver(to, env)
        } else if !batch.is_empty() {
            self.transport.deliver_batch(to, batch)
        } else {
            Ok(())
        };
        policy.and(delivered)
    }

    /// Everything [`Fabric::send`] does *except* the final delivery:
    /// topology check, chaos, link delay, metering, shaper diversion.
    /// `Ok(None)` means the envelope was consumed (chaos-dropped, or
    /// handed to the shaper pump which delivers it at its modeled arrival
    /// time); `Ok(Some(env))` means the caller still owes a delivery.
    fn apply_policy(
        &self,
        job: JobId,
        from: NodeId,
        to: NodeId,
        mut payload: Payload,
    ) -> Result<Option<Envelope>> {
        use std::sync::atomic::Ordering::Relaxed;
        if to >= self.n_nodes {
            return Err(CmpcError::Fabric(format!(
                "send to nonexistent node {to} (fabric has {} nodes)",
                self.n_nodes
            )));
        }
        if let Some(plan) = &self.chaos {
            // Shutdown bypasses chaos entirely — including the killed-sender
            // check — so runtime teardown always works even if a plan
            // managed to kill the master node itself.
            if !matches!(payload, Payload::Control(ControlMsg::Shutdown)) {
                if self.killed[from].load(Relaxed) {
                    return Err(CmpcError::Fabric(format!(
                        "node {from} was killed by the chaos plan (dead node cannot send)"
                    )));
                }
                match plan.decide(job, from, to, &payload) {
                    None => {}
                    Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                    Some(FaultAction::Drop) => return Ok(None),
                    Some(FaultAction::Garble) => garble(&mut payload),
                    Some(FaultAction::Kill) => {
                        self.killed[from].store(true, Relaxed);
                        return Err(CmpcError::Fabric(format!(
                            "node {from} killed by the chaos plan mid-send"
                        )));
                    }
                }
            }
        }
        if !matches!(payload, Payload::Control(_)) {
            if let Some(d) = self.link_delay {
                std::thread::sleep(d);
            }
            let scalars = payload.scalars();
            let job_counters = self.job_traffic.read().unwrap().get(&job).cloned();
            let meters: [Option<&TrafficCounters>; 2] =
                [Some(self.traffic.as_ref()), job_counters.as_deref()];
            match (self.role(from), self.role(to)) {
                (Role::SourceA | Role::SourceB, Role::Worker(_)) => {
                    for m in meters.into_iter().flatten() {
                        m.source_to_worker.fetch_add(scalars, Relaxed);
                        m.messages.fetch_add(1, Relaxed);
                    }
                }
                (Role::Worker(_), Role::Worker(_)) => {
                    for m in meters.into_iter().flatten() {
                        m.worker_to_worker.fetch_add(scalars, Relaxed);
                        m.messages.fetch_add(1, Relaxed);
                    }
                }
                (Role::Worker(_), Role::Master) => {
                    for m in meters.into_iter().flatten() {
                        m.worker_to_master.fetch_add(scalars, Relaxed);
                        m.messages.fetch_add(1, Relaxed);
                    }
                }
                (f, t) => {
                    return Err(CmpcError::Fabric(format!(
                        "illegal link {f:?} -> {t:?} in CMPC topology"
                    )));
                }
            }
        }
        let env = Envelope { job, from, payload };
        if let (Some(shaper), Some(tx)) = (&self.shaper, &self.shaper_tx) {
            if !matches!(env.payload, Payload::Control(_)) {
                let class = PayloadClass::of(&env.payload);
                let bytes = wire::frame_len(&env) as u64;
                if let Some(at) = shaper.release_at(from, to, class, bytes, Instant::now()) {
                    let seq = self.shaper_seq.fetch_add(1, Relaxed);
                    return tx
                        .send(Delayed { at, seq, to, env })
                        .map(|_| None)
                        .map_err(|_| {
                            CmpcError::Fabric("link shaper pump is gone".to_string())
                        });
                }
            }
        }
        Ok(Some(env))
    }

    /// Cumulative traffic snapshot across all jobs (scalars per edge class).
    pub fn traffic(&self) -> TrafficReport {
        self.traffic.snapshot()
    }

    /// On-wire byte totals of the underlying transport (zeros for the
    /// in-process channel transport).
    pub fn wire_stats(&self) -> WireStats {
        self.transport.wire_stats()
    }
}

impl Drop for Fabric {
    fn drop(&mut self) {
        // Disconnect the pump (it flushes whatever is still queued) and
        // join it so no delivery races the transport teardown.
        self.shaper_tx = None;
        if let Some(handle) = self.pump.lock().unwrap().take() {
            let _ = handle.join();
        }
    }
}

impl Endpoint {
    /// Wrap a receive queue as a node endpoint (transport construction).
    pub(crate) fn new(id: NodeId, rx: Receiver<Envelope>) -> Endpoint {
        Endpoint { id, rx }
    }

    /// Block for the next message. Errors ([`CmpcError::Fabric`]) only when
    /// every sender — i.e. the fabric itself — is gone.
    pub fn recv(&self) -> Result<Envelope> {
        self.rx
            .recv()
            .map_err(|_| CmpcError::Fabric(format!("node {}: fabric closed", self.id)))
    }

    /// Block for the next message, at most `timeout`. A timeout surfaces as
    /// a typed [`CmpcError::Fabric`] instead of deadlocking the caller when
    /// a peer thread died mid-job.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<Envelope> {
        self.rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => CmpcError::Fabric(format!(
                "node {}: no message within {timeout:?} (peer thread dead or stalled?)",
                self.id
            )),
            RecvTimeoutError::Disconnected => {
                CmpcError::Fabric(format!("node {}: fabric closed", self.id))
            }
        })
    }

    /// `recv_timeout` that preserves the timeout/disconnect distinction
    /// (the worker serve loop reacts differently to the two).
    pub(crate) fn recv_timeout_raw(
        &self,
        timeout: Duration,
    ) -> std::result::Result<Envelope, RecvTimeoutError> {
        self.rx.recv_timeout(timeout)
    }
}

/// Demultiplexes one [`Endpoint`] across concurrent jobs.
///
/// The master endpoint is shared by every in-flight job of a deployment;
/// each job's driving thread calls [`JobRouter::recv_for`] to receive *its*
/// envelopes. Whichever thread currently holds the receiver routes foreign
/// envelopes into per-job queues and wakes the waiters; envelopes for jobs
/// that are not open (already finished or failed) are dropped, returning
/// their payload buffers to the pool.
pub struct JobRouter {
    inner: Mutex<RouterInner>,
    cv: Condvar,
}

struct RouterInner {
    /// Present while no thread is actively receiving.
    rx: Option<Endpoint>,
    /// Buffered envelopes per open job.
    queues: HashMap<JobId, VecDeque<Envelope>>,
}

impl JobRouter {
    /// Wrap the master's endpoint for job-filtered receiving.
    pub fn new(endpoint: Endpoint) -> JobRouter {
        JobRouter {
            inner: Mutex::new(RouterInner {
                rx: Some(endpoint),
                queues: HashMap::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Register `job` so its envelopes are buffered while other jobs hold
    /// the receiver. Must precede any traffic for the job.
    pub fn open(&self, job: JobId) {
        self.inner
            .lock()
            .unwrap()
            .queues
            .insert(job, VecDeque::new());
    }

    /// Unregister `job`, dropping anything still buffered for it. Late
    /// arrivals for a closed job are dropped on receipt.
    pub fn close(&self, job: JobId) {
        self.inner.lock().unwrap().queues.remove(&job);
    }

    /// Receive the next envelope tagged `job`, waiting at most `timeout`.
    ///
    /// Envelopes for other open jobs are routed to their queues as a side
    /// effect; a timeout surfaces as [`CmpcError::Fabric`] (the deadlock fix
    /// for a worker thread dying mid-job).
    pub fn recv_for(&self, job: JobId, timeout: Duration) -> Result<Envelope> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(env) = inner.queues.get_mut(&job).and_then(|q| q.pop_front()) {
                return Ok(env);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(CmpcError::Fabric(format!(
                    "job {job}: deadline expired — no message within {timeout:?} \
                     (worker thread dead or stalled?)"
                )));
            }
            let remaining = deadline - now;
            if let Some(rx) = inner.rx.take() {
                drop(inner);
                let got = rx.recv_timeout_raw(remaining);
                inner = self.inner.lock().unwrap();
                inner.rx = Some(rx);
                self.cv.notify_all();
                match got {
                    Ok(env) if env.job == job => return Ok(env),
                    Ok(env) => {
                        // Buffer for an open sibling job; drop otherwise
                        // (the PooledMat payload returns to its pool).
                        if let Some(q) = inner.queues.get_mut(&env.job) {
                            q.push_back(env);
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {} // deadline re-checked above
                    Err(RecvTimeoutError::Disconnected) => {
                        return Err(CmpcError::Fabric("fabric closed".to_string()));
                    }
                }
            } else {
                let (guard, _) = self.cv.wait_timeout(inner, remaining).unwrap();
                inner = guard;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pooled(m: &FpMat) -> PooledMat {
        PooledMat::detached(m.clone())
    }

    #[test]
    fn node_id_layout() {
        let (fabric, endpoints) = Fabric::new(4, None);
        assert_eq!(endpoints.len(), 7);
        assert_eq!(fabric.role(0), Role::Worker(0));
        assert_eq!(fabric.role(3), Role::Worker(3));
        assert_eq!(fabric.role(4), Role::Master);
        assert_eq!(fabric.role(5), Role::SourceA);
        assert_eq!(fabric.role(6), Role::SourceB);
    }

    #[test]
    fn traffic_metered_by_class_and_job() {
        let (fabric, endpoints) = Fabric::new(2, None);
        fabric.begin_job(7);
        let m = FpMat::zeros(2, 3); // 6 scalars
        fabric
            .send(
                7,
                fabric.source_a_id(),
                0,
                Payload::Shares {
                    fa: pooled(&m),
                    fb: pooled(&m),
                },
            )
            .unwrap();
        fabric.send(7, 0, 1, Payload::GShare(pooled(&m))).unwrap();
        fabric
            .send(7, 1, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap();
        // traffic on a different (unregistered) job meters globally only
        fabric.send(8, 0, 1, Payload::GShare(pooled(&m))).unwrap();
        let global = fabric.traffic();
        assert_eq!(global.source_to_worker, 12);
        assert_eq!(global.worker_to_worker, 12);
        assert_eq!(global.worker_to_master, 6);
        assert_eq!(global.messages, 4);
        let job = fabric.end_job(7);
        assert_eq!(job.source_to_worker, 12);
        assert_eq!(job.worker_to_worker, 6);
        assert_eq!(job.worker_to_master, 6);
        assert_eq!(job.messages, 3);
        // an ended job leaves an empty report behind
        assert_eq!(fabric.end_job(7), TrafficReport::default());
        // endpoints received
        assert!(endpoints[0].recv().is_ok());
        assert!(endpoints[1].recv().is_ok());
        assert!(endpoints[1].recv().is_ok());
        assert!(endpoints[2].recv().is_ok());
    }

    #[test]
    fn illegal_link_is_a_typed_error() {
        // One misrouted data message must not take down a serving process.
        let (fabric, _eps) = Fabric::new(2, None);
        let err = fabric
            .send(
                0,
                fabric.master_id(),
                0,
                Payload::GShare(PooledMat::detached(FpMat::zeros(1, 1))),
            )
            .unwrap_err();
        assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
        assert!(err.to_string().contains("illegal link"), "{err}");
        // control messages are exempt (the runtime starts jobs this way)
        fabric
            .send(
                0,
                fabric.master_id(),
                0,
                Payload::Control(ControlMsg::Shutdown),
            )
            .unwrap();
    }

    #[test]
    fn send_to_dropped_endpoint_errors() {
        let (fabric, mut endpoints) = Fabric::new(1, None);
        endpoints.remove(0); // drop worker 0's receiver
        let r = fabric.send(
            0,
            fabric.source_a_id(),
            0,
            Payload::Shares {
                fa: PooledMat::detached(FpMat::zeros(1, 1)),
                fb: PooledMat::detached(FpMat::zeros(1, 1)),
            },
        );
        assert!(matches!(r, Err(CmpcError::Fabric(_))));
    }

    #[test]
    fn recv_timeout_surfaces_typed_error() {
        let (_fabric, endpoints) = Fabric::new(1, None);
        let err = endpoints[0]
            .recv_timeout(Duration::from_millis(5))
            .unwrap_err();
        assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    }

    #[test]
    fn buffer_pool_trim_releases_peak_buffers() {
        let pool = BufferPool::new();
        // A "huge-m" working set: 16 buffers of 4096 scalars each.
        {
            let _big: Vec<PooledMat> =
                (0..16).map(|_| BufferPool::loan(&pool, 64, 64)).collect();
        }
        let peak_free = pool.free_capacity_scalars();
        assert_eq!(peak_free, 16 * 64 * 64);
        // Steady demand at the same size keeps everything: the first trim
        // still sees the huge peak as demand.
        assert_eq!(pool.trim(), 0);
        assert_eq!(pool.free_capacity_scalars(), peak_free);
        // Small-m traffic afterwards: demand collapses and the trims (as
        // the runtime issues at each job finish) release the peak buffers.
        for _ in 0..2 {
            drop(BufferPool::loan(&pool, 8, 8));
            pool.trim();
        }
        let after = pool.free_capacity_scalars();
        assert!(
            after < peak_free / 2,
            "trim retained {after} of {peak_free} scalars"
        );
        // …but never below the churn floor, so tiny pools are left alone.
        assert!(after <= 16 * 1024, "retained {after} scalars");
        let tiny = BufferPool::new();
        drop(BufferPool::loan(&tiny, 4, 4));
        assert_eq!(tiny.trim(), 0);
        assert_eq!(tiny.free_buffers(), 1);
    }

    #[test]
    fn chaos_drop_and_garble_and_kill() {
        use crate::mpc::chaos::{ChaosPlan, FaultAction, FaultRule, PayloadClass};
        let plan = ChaosPlan::new()
            .rule(
                FaultRule::new(FaultAction::Drop)
                    .class(PayloadClass::GShare)
                    .limit(1),
            )
            .rule(
                FaultRule::new(FaultAction::Garble)
                    .class(PayloadClass::IShare)
                    .limit(1),
            )
            .rule(FaultRule::new(FaultAction::Kill).from_node(1))
            .into_shared();
        let (fabric, endpoints) = Fabric::with_chaos(2, None, Some(plan));
        let m = FpMat::zeros(2, 2);
        // dropped: delivered nowhere, unmetered
        fabric.send(0, 0, 1, Payload::GShare(pooled(&m))).unwrap();
        assert_eq!(fabric.traffic().worker_to_worker, 0);
        // garbled: delivered with the first scalar perturbed
        fabric
            .send(0, 0, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap();
        let env = endpoints[fabric.master_id()].recv().unwrap();
        match env.payload {
            Payload::IShare(g) => assert_eq!(g.at(0, 0), 1),
            other => panic!("unexpected {other:?}"),
        }
        // kill: the send fails, the node is marked dead, later sends fail
        let err = fabric
            .send(0, 1, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap_err();
        assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
        assert!(fabric.chaos_killed(1));
        assert!(fabric
            .send(0, 1, 0, Payload::GShare(pooled(&m)))
            .is_err());
        // shutdown is never faultable, even from a killed... (revive first)
        let _fresh = fabric.replace_endpoint(1).unwrap();
        assert!(!fabric.chaos_killed(1));
        fabric
            .send(
                CONTROL_JOB,
                fabric.master_id(),
                1,
                Payload::Control(ControlMsg::Shutdown),
            )
            .unwrap();
    }

    #[test]
    fn replace_endpoint_revives_a_dead_node() {
        let (fabric, mut endpoints) = Fabric::new(1, None);
        drop(endpoints.remove(0)); // worker 0's receiver gone
        let m = FpMat::zeros(1, 1);
        assert!(fabric
            .send(0, fabric.source_a_id(), 0, Payload::GShare(pooled(&m)))
            .is_err());
        let fresh = fabric.replace_endpoint(0).unwrap();
        fabric
            .send(
                0,
                fabric.source_a_id(),
                0,
                Payload::Shares {
                    fa: pooled(&m),
                    fb: pooled(&m),
                },
            )
            .unwrap();
        assert!(fresh.recv().is_ok());
    }

    #[test]
    fn buffer_pool_recycles() {
        let pool = BufferPool::new();
        {
            let mut a = BufferPool::loan(&pool, 4, 4);
            a.set(0, 0, 9);
            assert_eq!((a.rows, a.cols), (4, 4));
        }
        assert_eq!(pool.free_buffers(), 1);
        // the recycled buffer is reshaped for the next loan
        let b = BufferPool::loan(&pool, 2, 8);
        assert_eq!((b.rows, b.cols, b.len()), (2, 8, 16));
        assert_eq!(pool.free_buffers(), 0);
        drop(b);
        assert_eq!(pool.free_buffers(), 1);
        // detached mats never enter the pool
        drop(PooledMat::detached(FpMat::zeros(3, 3)));
        assert_eq!(pool.free_buffers(), 1);
    }

    #[test]
    fn split_shares_meter_as_source_traffic() {
        // The split Phase-1 form (separate source processes) meters on the
        // same source→worker class as the combined envelope.
        let (fabric, endpoints) = Fabric::new(1, None);
        fabric.begin_job(3);
        let m = FpMat::zeros(2, 2); // 4 scalars
        fabric
            .send(3, fabric.source_a_id(), 0, Payload::ShareA(pooled(&m)))
            .unwrap();
        fabric
            .send(3, fabric.source_b_id(), 0, Payload::ShareB(pooled(&m)))
            .unwrap();
        let job = fabric.end_job(3);
        assert_eq!(job.source_to_worker, 8);
        assert_eq!(job.messages, 2);
        assert!(endpoints[0].recv().is_ok());
        assert!(endpoints[0].recv().is_ok());
    }

    #[test]
    fn shaper_delays_delivery_without_blocking_sender() {
        use crate::transport::shaper::{LinkShaper, LinkSpec, ShapeRule};
        let latency = Duration::from_millis(80);
        let shaper = LinkShaper::new()
            .rule(ShapeRule::new(LinkSpec::latency(latency)).to_node(0))
            .into_shared();
        let (fabric, endpoints) = Fabric::with_tuning(
            1,
            FabricTuning {
                shaper: Some(shaper),
                ..FabricTuning::default()
            },
        );
        let m = FpMat::zeros(2, 2);
        let t0 = Instant::now();
        fabric
            .send(0, fabric.source_a_id(), 0, Payload::ShareA(pooled(&m)))
            .unwrap();
        let sent_in = t0.elapsed();
        assert!(
            sent_in < latency / 2,
            "shaped send blocked the sender for {sent_in:?}"
        );
        // Control messages bypass the shaper entirely: this one overtakes
        // the shaped data envelope still sitting in the pump.
        fabric
            .send(0, fabric.master_id(), 0, Payload::Control(ControlMsg::JobAbort))
            .unwrap();
        let first = endpoints[0].recv().unwrap();
        assert!(
            matches!(first.payload, Payload::Control(ControlMsg::JobAbort)),
            "control did not overtake shaped data"
        );
        let second = endpoints[0].recv().unwrap();
        assert!(matches!(second.payload, Payload::ShareA(_)));
        assert!(
            t0.elapsed() >= latency - Duration::from_millis(10),
            "shaped envelope released after only {:?}",
            t0.elapsed()
        );
        // Metering happened at send time regardless of shaping.
        assert_eq!(fabric.traffic().source_to_worker, 4);
        drop(endpoints);
        drop(fabric); // joins the pump thread without hanging
    }

    /// Batched sends must meter exactly like the equivalent sequential
    /// sends and deliver in order — only the transport call count differs.
    #[test]
    fn send_batch_meters_and_orders_like_sequential_sends() {
        let (fabric, endpoints) = Fabric::new(2, None);
        fabric.begin_job(5);
        let m = FpMat::zeros(2, 3); // 6 scalars
        fabric
            .send_batch(
                5,
                1,
                fabric.master_id(),
                vec![
                    Payload::IShare(pooled(&m)),
                    Payload::Control(ControlMsg::JobDone { mults: 7, stored: 9 }),
                ],
            )
            .unwrap();
        let job = fabric.end_job(5);
        assert_eq!(job.worker_to_master, 6);
        assert_eq!(job.messages, 1, "control stays unmetered in a batch");
        let master_ep = &endpoints[fabric.master_id()];
        let first = master_ep.recv().unwrap();
        assert!(matches!(first.payload, Payload::IShare(_)), "order kept");
        let second = master_ep.recv().unwrap();
        assert!(matches!(
            second.payload,
            Payload::Control(ControlMsg::JobDone { mults: 7, stored: 9 })
        ));
    }

    #[test]
    fn router_filters_by_job() {
        let (fabric, mut endpoints) = Fabric::new(1, None);
        let master = endpoints.remove(1);
        let router = JobRouter::new(master);
        router.open(1);
        router.open(2);
        let m = FpMat::zeros(1, 2);
        fabric
            .send(2, 0, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap();
        fabric
            .send(1, 0, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap();
        // job 1's receive skips past job 2's envelope, which stays queued
        let e1 = router.recv_for(1, Duration::from_secs(1)).unwrap();
        assert_eq!(e1.job, 1);
        let e2 = router.recv_for(2, Duration::from_secs(1)).unwrap();
        assert_eq!(e2.job, 2);
        // closed jobs drop late arrivals; an open one still times out typed
        router.close(2);
        fabric
            .send(2, 0, fabric.master_id(), Payload::IShare(pooled(&m)))
            .unwrap();
        let err = router.recv_for(1, Duration::from_millis(10)).unwrap_err();
        assert!(matches!(err, CmpcError::Fabric(_)), "{err}");
    }
}
