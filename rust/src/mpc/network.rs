//! Simulated edge-network fabric.
//!
//! Models the paper's topology: every source connects to every worker, every
//! worker to every other worker and to the master (D2D links). Nodes are
//! threads; links are mpsc channels routed through a central [`Fabric`] that
//! meters traffic per edge class and can inject link latency.
//!
//! Node-id layout for an `N`-worker deployment:
//! `0..N` → workers, `N` → master, `N+1` → source A, `N+2` → source B.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::matrix::FpMat;
use crate::metrics::TrafficCounters;

pub type NodeId = usize;

/// Role classification derived from a node id.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Role {
    Worker(usize),
    Master,
    SourceA,
    SourceB,
}

/// A protocol message payload.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Phase 1: a worker's evaluations of the two share polynomials.
    Shares { fa: FpMat, fb: FpMat },
    /// Phase 2: `G_{from}(α_to)`.
    GShare(FpMat),
    /// Phase 3: `I(α_from)`.
    IShare(FpMat),
}

impl Payload {
    /// Number of field scalars carried (the unit of eq. 32–34).
    pub fn scalars(&self) -> u64 {
        match self {
            Payload::Shares { fa, fb } => (fa.len() + fb.len()) as u64,
            Payload::GShare(m) | Payload::IShare(m) => m.len() as u64,
        }
    }
}

/// A routed message.
#[derive(Debug)]
pub struct Envelope {
    pub from: NodeId,
    pub payload: Payload,
}

/// Central switch: owns one sender per node plus the traffic meters.
pub struct Fabric {
    txs: Vec<Sender<Envelope>>,
    traffic: Arc<TrafficCounters>,
    n_workers: usize,
    /// Optional per-hop latency injected on every send.
    link_delay: Option<Duration>,
}

/// Receive side handed to a node thread.
pub struct Endpoint {
    pub id: NodeId,
    rx: Receiver<Envelope>,
}

impl Fabric {
    /// Build a fabric for `n_workers` workers (+ master + two sources).
    /// Returns the fabric and one endpoint per node, indexed by node id.
    pub fn new(n_workers: usize, link_delay: Option<Duration>) -> (Arc<Fabric>, Vec<Endpoint>) {
        let n_nodes = n_workers + 3;
        let mut txs = Vec::with_capacity(n_nodes);
        let mut endpoints = Vec::with_capacity(n_nodes);
        for id in 0..n_nodes {
            let (tx, rx) = channel();
            txs.push(tx);
            endpoints.push(Endpoint { id, rx });
        }
        let fabric = Arc::new(Fabric {
            txs,
            traffic: TrafficCounters::shared(),
            n_workers,
            link_delay,
        });
        (fabric, endpoints)
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn master_id(&self) -> NodeId {
        self.n_workers
    }

    pub fn source_a_id(&self) -> NodeId {
        self.n_workers + 1
    }

    pub fn source_b_id(&self) -> NodeId {
        self.n_workers + 2
    }

    pub fn role(&self, id: NodeId) -> Role {
        if id < self.n_workers {
            Role::Worker(id)
        } else if id == self.master_id() {
            Role::Master
        } else if id == self.source_a_id() {
            Role::SourceA
        } else {
            Role::SourceB
        }
    }

    /// Send `payload` from `from` to `to`, metering by edge class.
    ///
    /// Returns an error when the destination endpoint has been dropped
    /// (e.g. a straggler master that already finished Phase 3 — senders may
    /// legitimately race with teardown, so callers usually ignore it).
    pub fn send(&self, from: NodeId, to: NodeId, payload: Payload) -> Result<(), ()> {
        use std::sync::atomic::Ordering::Relaxed;
        if let Some(d) = self.link_delay {
            std::thread::sleep(d);
        }
        let scalars = payload.scalars();
        match (self.role(from), self.role(to)) {
            (Role::SourceA | Role::SourceB, Role::Worker(_)) => {
                self.traffic.source_to_worker.fetch_add(scalars, Relaxed);
            }
            (Role::Worker(_), Role::Worker(_)) => {
                self.traffic.worker_to_worker.fetch_add(scalars, Relaxed);
            }
            (Role::Worker(_), Role::Master) => {
                self.traffic.worker_to_master.fetch_add(scalars, Relaxed);
            }
            (f, t) => panic!("illegal link {f:?} -> {t:?} in CMPC topology"),
        }
        self.traffic.messages.fetch_add(1, Relaxed);
        self.txs[to].send(Envelope { from, payload }).map_err(|_| ())
    }

    /// Traffic snapshot (scalars per edge class).
    pub fn traffic(&self) -> crate::metrics::TrafficReport {
        self.traffic.snapshot()
    }
}

impl Endpoint {
    /// Block for the next message.
    pub fn recv(&self) -> Result<Envelope, ()> {
        self.rx.recv().map_err(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_id_layout() {
        let (fabric, endpoints) = Fabric::new(4, None);
        assert_eq!(endpoints.len(), 7);
        assert_eq!(fabric.role(0), Role::Worker(0));
        assert_eq!(fabric.role(3), Role::Worker(3));
        assert_eq!(fabric.role(4), Role::Master);
        assert_eq!(fabric.role(5), Role::SourceA);
        assert_eq!(fabric.role(6), Role::SourceB);
    }

    #[test]
    fn traffic_metered_by_class() {
        let (fabric, endpoints) = Fabric::new(2, None);
        let m = FpMat::zeros(2, 3); // 6 scalars
        fabric
            .send(
                fabric.source_a_id(),
                0,
                Payload::Shares {
                    fa: m.clone(),
                    fb: m.clone(),
                },
            )
            .unwrap();
        fabric.send(0, 1, Payload::GShare(m.clone())).unwrap();
        fabric
            .send(1, fabric.master_id(), Payload::IShare(m.clone()))
            .unwrap();
        let t = fabric.traffic();
        assert_eq!(t.source_to_worker, 12);
        assert_eq!(t.worker_to_worker, 6);
        assert_eq!(t.worker_to_master, 6);
        assert_eq!(t.messages, 3);
        // endpoints received
        assert!(endpoints[0].recv().is_ok());
        assert!(endpoints[1].recv().is_ok());
        assert!(endpoints[2].recv().is_ok());
    }

    #[test]
    #[should_panic(expected = "illegal link")]
    fn master_cannot_message_workers() {
        let (fabric, _eps) = Fabric::new(2, None);
        let _ = fabric.send(fabric.master_id(), 0, Payload::GShare(FpMat::zeros(1, 1)));
    }

    #[test]
    fn send_to_dropped_endpoint_errors() {
        let (fabric, mut endpoints) = Fabric::new(1, None);
        endpoints.remove(0); // drop worker 0's receiver
        let r = fabric.send(
            fabric.source_a_id(),
            0,
            Payload::Shares {
                fa: FpMat::zeros(1, 1),
                fb: FpMat::zeros(1, 1),
            },
        );
        assert!(r.is_err());
    }
}
