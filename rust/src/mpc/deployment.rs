//! Session-based serving: provision a worker deployment once, stream many
//! jobs through it.
//!
//! The paper's Algorithm 3 splits naturally into a *provisioning* phase
//! (Phase 0 scheme selection, α assignment, the O(N³) generalized-Vandermonde
//! solve — all independent of the job matrices) and a *per-job* phase
//! (share generation, worker compute, reconstruction). [`Deployment`] owns
//! the provisioning products — the resolved scheme, the cached [`Setup`],
//! the backend factory (executor service + artifact cache), **and the
//! persistent [`WorkerRuntime`]**: `N` long-lived Phase-2 worker threads
//! plus the job-multiplexed, buffer-pooled fabric they serve on. A warm
//! [`Deployment::execute`] therefore spawns zero threads and performs zero
//! fabric-payload allocations — it only streams the job:
//!
//! ```no_run
//! use cmpc::codes::SchemeParams;
//! use cmpc::matrix::FpMat;
//! use cmpc::mpc::protocol::ProtocolConfig;
//! use cmpc::util::rng::ChaChaRng;
//! use cmpc::{Deployment, SchemeSpec};
//!
//! # fn main() -> cmpc::Result<()> {
//! let params = SchemeParams::try_new(2, 2, 2)?;
//! let dep = Deployment::provision(
//!     SchemeSpec::Age { lambda: None },
//!     params,
//!     ProtocolConfig::default(),
//! )?; // 17 persistent worker threads start here
//! let mut rng = ChaChaRng::seed_from_u64(1);
//! for _ in 0..3 {
//!     let a = FpMat::random(&mut rng, 64, 64);
//!     let b = FpMat::random(&mut rng, 64, 64);
//!     let out = dep.execute(&a, &b)?; // job streamed to the live workers
//!     assert_eq!(out.y, a.transpose().matmul(&b));
//! }
//! assert_eq!(dep.jobs_executed(), 3);
//! # Ok(())
//! # }
//! ```
//!
//! Jobs may run **concurrently** on one deployment (the coordinator's
//! `drain` does exactly that): envelopes are job-tagged, traffic meters are
//! per job, and outputs are byte-identical for a given seed regardless of
//! interleaving. A failed `execute` (e.g. a [`CmpcError::ShapeMismatch`]
//! job, or a [`CmpcError::Fabric`] per-job deadline expiry) leaves the
//! deployment intact — subsequent jobs keep flowing, and a worker thread
//! that *died* (panic, chaos kill, deadline self-eviction) is evicted and
//! respawned before the next job starts (see
//! [`WorkerRuntime::reap`]; [`Deployment::health`] meters it). Dropping
//! the deployment shuts the runtime down cleanly and propagates any
//! unreaped worker panic.
//!
//! [`WorkerRuntime::reap`]: crate::mpc::runtime::WorkerRuntime::reap
//!
//! [`CmpcError::ShapeMismatch`]: crate::error::CmpcError::ShapeMismatch
//! [`CmpcError::Fabric`]: crate::error::CmpcError::Fabric
//! [`WorkerRuntime`]: crate::mpc::runtime::WorkerRuntime

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::codes::{CmpcScheme, SchemeParams, SchemeSpec};
use crate::error::Result;
use crate::matrix::FpMat;
use crate::mpc::fused;
use crate::mpc::pipeline::{self, Pipeline, PipelineOutput};
use crate::mpc::protocol::{self, ExecEnv, ProtocolConfig, ProtocolOutput, Setup};
use crate::mpc::runtime::WorkerRuntime;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::BackendFactory;

/// Per-job secret-seed derivation: `base + k·golden` (wrapping). The
/// **single source of truth** shared by [`Deployment::execute`] (where `k`
/// is the atomically claimed job counter) and the distributed runner
/// (where `k` is the manifest job id) — byte-identical
/// distributed-vs-in-process outputs depend on these never diverging.
pub fn derive_job_seed(base: u64, k: u64) -> u64 {
    base.wrapping_add(k.wrapping_mul(0x9E3779B97F4A7C15))
}

/// A provisioned worker deployment: resolved scheme + cached [`Setup`] +
/// shared backend + worker pool + per-pool-worker scratch **+ the live
/// worker runtime**, reusable across any number of (possibly concurrent)
/// jobs with the same `(scheme, s, t, z)` signature.
pub struct Deployment {
    /// Declared first so Drop joins the worker threads before the backend
    /// factory (whose handles the workers hold) is torn down.
    runtime: WorkerRuntime,
    scheme: Arc<dyn CmpcScheme>,
    setup: Arc<Setup>,
    factory: Arc<BackendFactory>,
    /// Pool driving the parallel sections of every job (Phase-1 encoding,
    /// Phase-3 reconstruction, verify) — shared process-wide when
    /// `config.threads == 0`, or sized per [`ProtocolConfig::threads`].
    pool: Arc<WorkerPool>,
    /// One scratch slot per pool worker; grown at the first job, reused by
    /// every subsequent one (the zero-steady-state-allocation contract of
    /// the compute kernels).
    scratch: Arc<ScratchPool>,
    config: ProtocolConfig,
    /// Jobs attempted through this deployment (successful or not); also
    /// perturbs the per-job secret seed so repeated jobs draw fresh masks.
    jobs_executed: AtomicU64,
}

impl Deployment {
    /// Resolve `spec` for `params` and provision the deployment: α
    /// assignment, the O(N³) reconstruction solve, the backend factory,
    /// and the `N` persistent worker threads all start here, once.
    pub fn provision(
        spec: SchemeSpec,
        mut params: SchemeParams,
        config: ProtocolConfig,
    ) -> Result<Deployment> {
        // Either knob may carry the Byzantine tolerance; fold the config's
        // into the scheme params so the provisioning quota check
        // (`recovery_quota` = t²+z+2a) sees it.
        params.adversary_tolerance = params.adversary_tolerance.max(config.adversary_tolerance);
        Deployment::for_scheme(spec.resolve(params)?, config)
    }

    /// Provision with registry-wide adaptive scheme selection (Phase 0 of
    /// Algorithm 3): the constructible scheme with the fewest workers.
    pub fn provision_adaptive(
        mut params: SchemeParams,
        config: ProtocolConfig,
    ) -> Result<Deployment> {
        params.adversary_tolerance = params.adversary_tolerance.max(config.adversary_tolerance);
        Deployment::for_scheme(SchemeSpec::resolve_adaptive(params)?, config)
    }

    /// Provision around an already-constructed scheme instance (custom or
    /// experimental constructions outside the registry).
    pub fn for_scheme(scheme: Arc<dyn CmpcScheme>, config: ProtocolConfig) -> Result<Deployment> {
        let factory = Arc::new(BackendFactory::new(&config.backend)?);
        Deployment::for_scheme_with_factory(scheme, config, factory)
    }

    /// Provision sharing an existing backend factory — the coordinator path,
    /// where one executor service backs every deployment. The worker pool is
    /// resolved from [`ProtocolConfig::threads`].
    pub fn for_scheme_with_factory(
        scheme: Arc<dyn CmpcScheme>,
        config: ProtocolConfig,
        factory: Arc<BackendFactory>,
    ) -> Result<Deployment> {
        let pool = WorkerPool::sized_or_global(config.threads);
        Deployment::for_scheme_shared(scheme, config, factory, pool)
    }

    /// Provision sharing both an existing backend factory *and* an existing
    /// worker pool — the coordinator path, where one executor service and
    /// one pool back every deployment.
    pub fn for_scheme_shared(
        scheme: Arc<dyn CmpcScheme>,
        config: ProtocolConfig,
        factory: Arc<BackendFactory>,
        pool: Arc<WorkerPool>,
    ) -> Result<Deployment> {
        let setup = Arc::new(protocol::prepare_setup(scheme.as_ref())?);
        let scratch = Arc::new(ScratchPool::for_pool(&pool));
        let runtime = WorkerRuntime::provision(&setup, scheme.params(), &config, &factory)?;
        Ok(Deployment {
            runtime,
            scheme,
            setup,
            factory,
            pool,
            scratch,
            config,
            jobs_executed: AtomicU64::new(0),
        })
    }

    /// Run one `Y = AᵀB` job through the provisioned runtime. Per-job secret
    /// randomness is derived from the config seed and an atomically claimed
    /// job counter ([`derive_job_seed`]), so concurrent jobs on a shared
    /// deployment never reuse masks.
    pub fn execute(&self, a: &FpMat, b: &FpMat) -> Result<ProtocolOutput> {
        // One fetch_add both claims a unique seed slot and counts the job —
        // a separate load would let two racing executes draw the same masks.
        let k = self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run(a, b, derive_job_seed(self.config.seed, k))
    }

    /// [`Deployment::execute`] with an explicit secret seed (reproducible
    /// serving tests; the coordinator assigns per-job seeds at intake).
    /// Callers own mask-reuse avoidance across their seeds.
    pub fn execute_seeded(&self, a: &FpMat, b: &FpMat, seed: u64) -> Result<ProtocolOutput> {
        self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run(a, b, seed)
    }

    /// Run `jobs` (same shape) as **one fused batch** — the small-job fast
    /// path ([`crate::mpc::fused`]): per worker, the k per-job `H` blocks
    /// are stacked into wide buffers so every downstream kernel (scaled
    /// copies, masks, G evaluations, I accumulation, reconstruction) runs
    /// once over `k·len` scalars instead of k times over `len`. Outputs are
    /// byte-identical (Y, ξ/σ counters, traffic) to k sequential
    /// [`Deployment::execute`] calls with the same derived seeds, and come
    /// back in job order.
    ///
    /// Falls back to sequential execution — same results, fabric path —
    /// when the batch or config is not fusible: fewer than 2 jobs, mixed
    /// shapes, or fabric knobs the fused path cannot honor (chaos plans,
    /// link shapers, injected delays). Although the genuinely fused path
    /// streams no per-job envelopes, it claims the batch's job ids up
    /// front, so `runtime().jobs_started()` advances by the batch size on
    /// either path (the counter contract in [`crate::metrics`]).
    pub fn execute_fused(&self, jobs: &[(&FpMat, &FpMat)]) -> Result<Vec<ProtocolOutput>> {
        // One fetch_add claims the whole seed range — concurrent batches
        // and singleton executes can never draw overlapping mask streams.
        let base = self.jobs_executed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let seeds: Vec<u64> = (0..jobs.len() as u64)
            .map(|i| derive_job_seed(self.config.seed, base + i))
            .collect();
        self.fused_run(jobs, &seeds)
    }

    /// [`Deployment::execute_fused`] with explicit per-job seeds (the
    /// coordinator path, where seeds are assigned at intake). Callers own
    /// mask-reuse avoidance across their seeds.
    pub fn execute_fused_seeded(
        &self,
        jobs: &[(&FpMat, &FpMat)],
        seeds: &[u64],
    ) -> Result<Vec<ProtocolOutput>> {
        self.jobs_executed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        self.fused_run(jobs, seeds)
    }

    /// Dispatch a seeded batch: fused when legal, else job-by-job through
    /// the fabric path (which honors chaos/shaping/delays exactly).
    fn fused_run(&self, jobs: &[(&FpMat, &FpMat)], seeds: &[u64]) -> Result<Vec<ProtocolOutput>> {
        if seeds.len() != jobs.len() {
            return Err(crate::error::CmpcError::InvalidParams(format!(
                "fused batch has {} jobs but {} seeds",
                jobs.len(),
                seeds.len()
            )));
        }
        let same_shape = jobs
            .windows(2)
            .all(|w| w[0].0.rows == w[1].0.rows && w[0].0.cols == w[1].0.cols);
        if jobs.len() < 2 || !same_shape || !fused::config_fusible(&self.config) {
            return jobs
                .iter()
                .zip(seeds)
                .map(|(&(a, b), &seed)| self.run(a, b, seed))
                .collect();
        }
        // The genuinely fused path bypasses the fabric, so claim its job
        // ids explicitly: `jobs_started` advances by the batch size on
        // both paths, and the batch's single amortized reconstruction is
        // recorded as one Phase-3 decode (the counter contract in
        // `metrics`).
        self.runtime.claim_job_ids(jobs.len() as u64);
        let outs = fused::run_fused_batch(
            self.scheme.as_ref(),
            &self.setup,
            jobs,
            seeds,
            &self.config,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
        )?;
        self.runtime.note_decode();
        Ok(outs)
    }

    /// Run a [`Pipeline`] — a validated chain of secure matrix ops — end
    /// to end on the provisioned runtime: one fabric job per matmul round,
    /// masked re-shares between rounds, and a single Phase-3 decode of the
    /// final output (see [`crate::mpc::pipeline`]). The pipeline claims
    /// one seed slot like a job; per-round secrets derive from
    /// [`crate::mpc::pipeline::stage_seed`] of it.
    pub fn execute_pipeline(
        &self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
    ) -> Result<PipelineOutput> {
        let k = self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run_pipeline(pipe, x, weights, derive_job_seed(self.config.seed, k))
    }

    /// [`Deployment::execute_pipeline`] with an explicit pipeline seed —
    /// the reproducibility hook the CI digest lanes and the multi-process
    /// reference role drive. Callers own mask-reuse avoidance.
    pub fn execute_pipeline_seeded(
        &self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        seed: u64,
    ) -> Result<PipelineOutput> {
        self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run_pipeline(pipe, x, weights, seed)
    }

    fn run_pipeline(
        &self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        seed: u64,
    ) -> Result<PipelineOutput> {
        let cfg = ProtocolConfig {
            seed,
            ..self.config.clone()
        };
        pipeline::run_pipeline(
            self.scheme.as_ref(),
            &self.setup,
            pipe,
            x,
            weights,
            &cfg,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
            &self.runtime,
        )
    }

    fn run(&self, a: &FpMat, b: &FpMat, seed: u64) -> Result<ProtocolOutput> {
        let cfg = ProtocolConfig {
            seed,
            ..self.config.clone()
        };
        protocol::run_job(
            self.scheme.as_ref(),
            &self.setup,
            a,
            b,
            &cfg,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
            &self.runtime,
        )
    }

    /// The resolved scheme this deployment runs.
    pub fn scheme(&self) -> &dyn CmpcScheme {
        self.scheme.as_ref()
    }

    /// The worker pool driving this deployment's parallel sections.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// The live worker runtime (persistent threads + multiplexed fabric,
    /// including the eviction/respawn reaper and the chaos hooks).
    pub fn runtime(&self) -> &WorkerRuntime {
        &self.runtime
    }

    /// Snapshot of the runtime's fault-tolerance counters — evictions,
    /// respawns, early decodes, per-job deadline misses, driver aborts,
    /// Byzantine detections — plus `blamed_workers`: every worker id the
    /// Byzantine decoder located serving a garbled I-share.
    pub fn health(&self) -> crate::metrics::RuntimeHealthReport {
        self.runtime.health()
    }

    /// The scheme parameters of this deployment.
    pub fn params(&self) -> SchemeParams {
        self.scheme.params()
    }

    /// Provisioned worker count.
    pub fn n_workers(&self) -> usize {
        self.setup.n_workers
    }

    /// Persistent worker threads serving this deployment (constant for its
    /// lifetime — jobs spawn nothing).
    pub fn worker_threads(&self) -> usize {
        self.runtime.worker_threads()
    }

    /// Jobs attempted through the cached setup (the Setup itself was solved
    /// exactly once, at provisioning).
    pub fn jobs_executed(&self) -> u64 {
        self.jobs_executed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::CmpcError;
    use crate::util::rng::ChaChaRng;

    #[test]
    fn deployment_reuses_setup_across_jobs() {
        let params = SchemeParams::new(2, 2, 2);
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::default(),
        )
        .unwrap();
        assert_eq!(dep.n_workers(), 17);
        assert_eq!(dep.worker_threads(), 17);
        let mut rng = ChaChaRng::seed_from_u64(10);
        for _ in 0..3 {
            let a = FpMat::random(&mut rng, 8, 8);
            let b = FpMat::random(&mut rng, 8, 8);
            let out = dep.execute(&a, &b).unwrap();
            assert!(out.verified);
            assert_eq!(out.y, a.transpose().matmul(&b));
        }
        assert_eq!(dep.jobs_executed(), 3);
        // the persistent runtime served every job; thread count is flat
        assert_eq!(dep.worker_threads(), 17);
        assert_eq!(dep.runtime().jobs_started(), 3);
    }

    #[test]
    fn failed_job_leaves_deployment_usable() {
        let params = SchemeParams::new(2, 2, 1);
        let dep =
            Deployment::provision_adaptive(params, ProtocolConfig::default()).unwrap();
        let mut rng = ChaChaRng::seed_from_u64(11);
        let bad_a = FpMat::random(&mut rng, 6, 6);
        let bad_b = FpMat::random(&mut rng, 7, 7); // size disagrees with A
        let err = dep.execute(&bad_a, &bad_b).unwrap_err();
        assert!(matches!(err, CmpcError::ShapeMismatch(_)));
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        assert!(dep.execute(&a, &b).unwrap().verified);
        assert_eq!(dep.jobs_executed(), 2);
    }

    /// `execute_fused` must be byte-identical to the same jobs streamed
    /// sequentially through `execute` — both claim seed slots from the same
    /// atomic counter, so two fresh deployments give the comparison.
    #[test]
    fn fused_execute_matches_sequential_execute() {
        let params = SchemeParams::new(2, 2, 2);
        let provision = || {
            Deployment::provision(
                SchemeSpec::Age { lambda: None },
                params,
                ProtocolConfig::default(),
            )
            .unwrap()
        };
        let mut rng = ChaChaRng::seed_from_u64(77);
        let jobs: Vec<(FpMat, FpMat)> = (0..3)
            .map(|_| (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8)))
            .collect();

        let seq_dep = provision();
        let sequential: Vec<_> = jobs
            .iter()
            .map(|(a, b)| seq_dep.execute(a, b).unwrap())
            .collect();

        let fused_dep = provision();
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let fused = fused_dep.execute_fused(&refs).unwrap();
        assert_eq!(fused_dep.jobs_executed(), 3);
        // The fused path claims the batch's job ids even though it streams
        // no envelopes — jobs_started advances like the sequential path,
        // and the batch's amortized reconstruction is one Phase-3 decode
        // (vs three for the sequential jobs).
        assert_eq!(fused_dep.runtime().jobs_started(), 3);
        assert_eq!(fused_dep.health().phase3_decodes, 1);
        assert_eq!(seq_dep.health().phase3_decodes, 3);

        for (j, (f, s)) in fused.iter().zip(&sequential).enumerate() {
            assert_eq!(f.y, s.y, "job {j}: Y");
            assert!(f.verified, "job {j}: verified");
            assert_eq!(f.traffic, s.traffic, "job {j}: traffic");
            for (wn, (fc, sc)) in
                f.worker_counters.iter().zip(&s.worker_counters).enumerate()
            {
                assert_eq!(fc.mults(), sc.mults(), "job {j} worker {wn}: ξ");
                assert_eq!(fc.stored(), sc.stored(), "job {j} worker {wn}: σ");
            }
        }
    }

    /// Unfusible batches (here: a config with an injected link delay) fall
    /// back to the sequential fabric path with the same per-job seeds.
    #[test]
    fn unfusible_batch_falls_back_to_sequential() {
        let params = SchemeParams::new(2, 2, 1);
        let config = ProtocolConfig::builder()
            .link_delay(Some(std::time::Duration::from_micros(1)))
            .build();
        let dep = Deployment::provision(SchemeSpec::Age { lambda: None }, params, config)
            .unwrap();
        let mut rng = ChaChaRng::seed_from_u64(78);
        let jobs: Vec<(FpMat, FpMat)> = (0..2)
            .map(|_| (FpMat::random(&mut rng, 4, 4), FpMat::random(&mut rng, 4, 4)))
            .collect();
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let outs = dep.execute_fused(&refs).unwrap();
        assert_eq!(outs.len(), 2);
        for ((a, b), out) in jobs.iter().zip(&outs) {
            assert_eq!(out.y, a.transpose().matmul(b));
            assert!(out.verified);
        }
        // the fabric path streamed both jobs through the live runtime
        assert_eq!(dep.runtime().jobs_started(), 2);
    }

    #[test]
    fn provision_rejects_bad_spec() {
        let params = SchemeParams::new(2, 2, 2);
        let err = Deployment::provision(
            SchemeSpec::Age { lambda: Some(9) },
            params,
            ProtocolConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }
}
