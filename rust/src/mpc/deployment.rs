//! Session-based serving: provision a worker deployment once, stream many
//! jobs through it — and **reconfigure it live** without dropping a job.
//!
//! The paper's Algorithm 3 splits naturally into a *provisioning* phase
//! (Phase 0 scheme selection, α assignment, the O(N³) generalized-Vandermonde
//! solve — all independent of the job matrices) and a *per-job* phase
//! (share generation, worker compute, reconstruction). [`Deployment`] owns
//! the provisioning products — the resolved scheme, the cached [`Setup`],
//! the backend factory (executor service + artifact cache), **and the
//! persistent [`WorkerRuntime`]**: `N` long-lived Phase-2 worker threads
//! plus the job-multiplexed, buffer-pooled fabric they serve on. A warm
//! [`Deployment::execute`] therefore spawns zero threads and performs zero
//! fabric-payload allocations — it only streams the job:
//!
//! ```no_run
//! use cmpc::codes::SchemeParams;
//! use cmpc::matrix::FpMat;
//! use cmpc::mpc::protocol::ProtocolConfig;
//! use cmpc::util::rng::ChaChaRng;
//! use cmpc::{Deployment, SchemeSpec};
//!
//! # fn main() -> cmpc::Result<()> {
//! let params = SchemeParams::try_new(2, 2, 2)?;
//! let dep = Deployment::provision(
//!     SchemeSpec::Age { lambda: None },
//!     params,
//!     ProtocolConfig::default(),
//! )?; // 17 persistent worker threads start here
//! let mut rng = ChaChaRng::seed_from_u64(1);
//! for _ in 0..3 {
//!     let a = FpMat::random(&mut rng, 64, 64);
//!     let b = FpMat::random(&mut rng, 64, 64);
//!     let out = dep.execute(&a, &b)?; // job streamed to the live workers
//!     assert_eq!(out.y, a.transpose().matmul(&b));
//! }
//! assert_eq!(dep.jobs_executed(), 3);
//! # Ok(())
//! # }
//! ```
//!
//! Jobs may run **concurrently** on one deployment (the coordinator's
//! `drain` does exactly that): envelopes are job-tagged, traffic meters are
//! per job, and outputs are byte-identical for a given seed regardless of
//! interleaving. A failed `execute` (e.g. a [`CmpcError::ShapeMismatch`]
//! job, or a [`CmpcError::Fabric`] per-job deadline expiry) leaves the
//! deployment intact — subsequent jobs keep flowing, and a worker thread
//! that *died* (panic, chaos kill, deadline self-eviction) is evicted and
//! respawned before the next job starts (see
//! [`WorkerRuntime::reap`]; [`Deployment::health`] meters it). Dropping
//! the deployment shuts the runtime down cleanly and propagates any
//! unreaped worker panic.
//!
//! # Blue/green reconfiguration
//!
//! A deployment's `(scheme, λ, adversary_tolerance)` is no longer frozen at
//! provision time. [`Deployment::reconfigure`] provisions a **green**
//! generation — new scheme resolution, new [`Setup`] solve, new
//! [`WorkerRuntime`] — *beside* the live **blue** one, then atomically cuts
//! new submissions over to green. In-flight jobs keep the blue generation
//! alive through the `Arc` they cloned at submission and finish on the
//! runtime they started on, so the swap drops **zero jobs**; blue is torn
//! down by [`Deployment::drain_retired`] once its last job returns. The
//! per-job seed schedule lives on the *deployment* (one atomic counter, see
//! [`derive_job_seed`]), not on a generation — so a job stream spanning a
//! swap draws exactly the seeds it would have drawn without one, and
//! outputs stay byte-identical. Every swap appends a [`SwapRecord`] to the
//! audit trail ([`Deployment::swap_history`]).
//!
//! The `(s, t, z)` triple — the data layout clients encoded against — is
//! fixed for the deployment's lifetime; reconfiguration retunes the gap λ,
//! the scheme family, and the Byzantine tolerance `a` around it. That is
//! exactly the paper's λ-tradeoff surface (eq. 30 + Corollaries 10–12),
//! and walking it from live telemetry is the job of
//! [`crate::autoscale::Autoscaler`].
//!
//! [`WorkerRuntime::reap`]: crate::mpc::runtime::WorkerRuntime::reap
//!
//! [`CmpcError::ShapeMismatch`]: crate::error::CmpcError::ShapeMismatch
//! [`CmpcError::Fabric`]: crate::error::CmpcError::Fabric
//! [`WorkerRuntime`]: crate::mpc::runtime::WorkerRuntime

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::codes::{CmpcScheme, SchemeParams, SchemeSpec};
use crate::error::Result;
use crate::matrix::FpMat;
use crate::metrics::TrafficReport;
use crate::mpc::fused;
use crate::mpc::pipeline::{self, Pipeline, PipelineOutput};
use crate::mpc::protocol::{self, ExecEnv, ProtocolConfig, ProtocolOutput, Setup};
use crate::mpc::runtime::WorkerRuntime;
use crate::runtime::pool::{ScratchPool, WorkerPool};
use crate::runtime::BackendFactory;

/// Per-job secret-seed derivation: `base + k·golden` (wrapping). The
/// **single source of truth** shared by [`Deployment::execute`] (where `k`
/// is the atomically claimed job counter) and the distributed runner
/// (where `k` is the manifest job id) — byte-identical
/// distributed-vs-in-process outputs depend on these never diverging.
pub fn derive_job_seed(base: u64, k: u64) -> u64 {
    base.wrapping_add(k.wrapping_mul(0x9E3779B97F4A7C15))
}

/// Retained [`SwapRecord`]s (the counters stay exact; only per-event
/// detail rotates).
const SWAP_LOG_CAP: usize = 256;

/// One provisioned serving generation: the scheme resolution, the cached
/// setup, the live worker runtime, and the config they were built under.
/// Jobs clone the generation `Arc` at submission and run entirely against
/// it, so a blue/green swap never moves a job between runtimes.
struct Generation {
    /// Declared first so Drop joins the worker threads before the rest of
    /// the generation (whose state the workers borrow) is torn down.
    runtime: WorkerRuntime,
    scheme: Arc<dyn CmpcScheme>,
    setup: Arc<Setup>,
    config: ProtocolConfig,
}

/// One blue → green reconfiguration, as recorded in the deployment's
/// audit trail ([`Deployment::swap_history`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SwapRecord {
    /// 1-based generation number the swap produced (generation 0 is the
    /// original provisioning).
    pub generation: u64,
    /// Scheme name of the retired blue generation.
    pub from: String,
    /// Scheme name of the new green generation.
    pub to: String,
    /// Worker count of the retired blue generation.
    pub from_workers: usize,
    /// Worker count of the new green generation.
    pub to_workers: usize,
    /// Byzantine adversary tolerance of the new green generation.
    pub adversary_tolerance: usize,
}

/// Borrow-like handle on the active generation's [`WorkerRuntime`]
/// (derefs to it). Holding the handle keeps that generation alive even
/// across a concurrent [`Deployment::reconfigure`], exactly like an
/// in-flight job does — so reads through a stale handle are consistent,
/// never dangling.
pub struct RuntimeHandle(Arc<Generation>);

impl Deref for RuntimeHandle {
    type Target = WorkerRuntime;

    fn deref(&self) -> &WorkerRuntime {
        &self.0.runtime
    }
}

/// Live traffic/latency totals a deployment accumulates across every job
/// it serves — the *measured* side of the autoscaler's cost tradeoff
/// (deployment-lifetime, so they survive blue/green swaps, unlike the
/// per-generation [`Deployment::health`] counters).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct DeploymentTelemetry {
    /// Jobs that returned successfully (fused batches count each job).
    pub jobs_completed: u64,
    /// Phase-2 worker↔worker scalars exchanged — the measured ζ of eq. 34,
    /// summed over all completed jobs.
    pub w2w_scalars: u64,
    /// Wall-clock nanoseconds spent inside successful `execute*` calls,
    /// summed (divide by `jobs_completed` for the mean job latency).
    pub latency_ns_total: u64,
}

/// A provisioned worker deployment: resolved scheme + cached [`Setup`] +
/// shared backend + worker pool + per-pool-worker scratch **+ the live
/// worker runtime**, reusable across any number of (possibly concurrent)
/// jobs with the same `(scheme, s, t, z)` signature — and live-swappable
/// to a different `(scheme, λ, a)` via [`Deployment::reconfigure`].
pub struct Deployment {
    /// Declared before `factory` so generations (and their worker threads)
    /// drop before the backend factory whose handles the workers hold.
    active: RwLock<Arc<Generation>>,
    /// Blue generations retired by a swap but possibly still serving
    /// in-flight jobs; swept by [`Deployment::drain_retired`].
    retired: Mutex<Vec<Arc<Generation>>>,
    /// Serializes reconfigurations (concurrent swaps would race the
    /// blue→retired hand-off); job submission never takes this lock.
    swap_lock: Mutex<()>,
    factory: Arc<BackendFactory>,
    /// Pool driving the parallel sections of every job (Phase-1 encoding,
    /// Phase-3 reconstruction, verify) — shared process-wide when
    /// `config.threads == 0`, or sized per [`ProtocolConfig::threads`].
    pool: Arc<WorkerPool>,
    /// One scratch slot per pool worker; grown at the first job, reused by
    /// every subsequent one (the zero-steady-state-allocation contract of
    /// the compute kernels).
    scratch: Arc<ScratchPool>,
    /// Jobs attempted through this deployment (successful or not); also
    /// perturbs the per-job secret seed so repeated jobs draw fresh masks.
    /// Deployment-level, **not** per generation: the seed schedule must
    /// not restart at a blue/green swap.
    jobs_executed: AtomicU64,
    /// Completed reconfigurations (the current generation number).
    swaps: AtomicU64,
    /// Audit trail of the last `SWAP_LOG_CAP` swaps, oldest first.
    swap_log: Mutex<Vec<SwapRecord>>,
    /// Measured telemetry totals (see [`DeploymentTelemetry`]).
    jobs_completed: AtomicU64,
    w2w_scalars: AtomicU64,
    latency_ns: AtomicU64,
}

impl Deployment {
    /// Resolve `spec` for `params` and provision the deployment: α
    /// assignment, the O(N³) reconstruction solve, the backend factory,
    /// and the `N` persistent worker threads all start here, once.
    pub fn provision(
        spec: SchemeSpec,
        mut params: SchemeParams,
        config: ProtocolConfig,
    ) -> Result<Deployment> {
        // Either knob may carry the Byzantine tolerance; fold the config's
        // into the scheme params so the provisioning quota check
        // (`recovery_quota` = t²+z+2a) sees it.
        params.adversary_tolerance = params.adversary_tolerance.max(config.adversary_tolerance);
        Deployment::for_scheme(spec.resolve(params)?, config)
    }

    /// Provision with registry-wide adaptive scheme selection (Phase 0 of
    /// Algorithm 3): the constructible scheme with the fewest workers.
    pub fn provision_adaptive(
        mut params: SchemeParams,
        config: ProtocolConfig,
    ) -> Result<Deployment> {
        params.adversary_tolerance = params.adversary_tolerance.max(config.adversary_tolerance);
        Deployment::for_scheme(SchemeSpec::resolve_adaptive(params)?, config)
    }

    /// Provision around an already-constructed scheme instance (custom or
    /// experimental constructions outside the registry).
    pub fn for_scheme(scheme: Arc<dyn CmpcScheme>, config: ProtocolConfig) -> Result<Deployment> {
        let factory = Arc::new(BackendFactory::new(&config.backend)?);
        Deployment::for_scheme_with_factory(scheme, config, factory)
    }

    /// Provision sharing an existing backend factory — the coordinator path,
    /// where one executor service backs every deployment. The worker pool is
    /// resolved from [`ProtocolConfig::threads`].
    pub fn for_scheme_with_factory(
        scheme: Arc<dyn CmpcScheme>,
        config: ProtocolConfig,
        factory: Arc<BackendFactory>,
    ) -> Result<Deployment> {
        let pool = WorkerPool::sized_or_global(config.threads);
        Deployment::for_scheme_shared(scheme, config, factory, pool)
    }

    /// Provision sharing both an existing backend factory *and* an existing
    /// worker pool — the coordinator path, where one executor service and
    /// one pool back every deployment.
    pub fn for_scheme_shared(
        scheme: Arc<dyn CmpcScheme>,
        config: ProtocolConfig,
        factory: Arc<BackendFactory>,
        pool: Arc<WorkerPool>,
    ) -> Result<Deployment> {
        let generation = Deployment::provision_generation(scheme, config, &factory)?;
        let scratch = Arc::new(ScratchPool::for_pool(&pool));
        Ok(Deployment {
            active: RwLock::new(Arc::new(generation)),
            retired: Mutex::new(Vec::new()),
            swap_lock: Mutex::new(()),
            factory,
            pool,
            scratch,
            jobs_executed: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            swap_log: Mutex::new(Vec::new()),
            jobs_completed: AtomicU64::new(0),
            w2w_scalars: AtomicU64::new(0),
            latency_ns: AtomicU64::new(0),
        })
    }

    /// Solve the setup and spawn a runtime for one generation.
    fn provision_generation(
        scheme: Arc<dyn CmpcScheme>,
        config: ProtocolConfig,
        factory: &Arc<BackendFactory>,
    ) -> Result<Generation> {
        let setup = Arc::new(protocol::prepare_setup(scheme.as_ref())?);
        let runtime = WorkerRuntime::provision(&setup, scheme.params(), &config, factory)?;
        Ok(Generation {
            runtime,
            scheme,
            setup,
            config,
        })
    }

    /// The active generation, cloned — the handle every job (and every
    /// read-side accessor) runs against. Cheap: one `RwLock` read + one
    /// `Arc` bump.
    fn active(&self) -> Arc<Generation> {
        self.active.read().unwrap().clone()
    }

    /// **Blue/green swap**: provision a green generation for `spec` at
    /// Byzantine tolerance `adversary_tolerance` — same `(s, t, z)` triple,
    /// new scheme resolution, new setup solve, new worker runtime — then
    /// atomically cut new submissions over to it. In-flight jobs finish on
    /// the blue generation they started on (their cloned `Arc` keeps it
    /// alive), so **no job is dropped or moved**; blue's threads are joined
    /// by [`Deployment::drain_retired`] once its last job returns. The
    /// per-job seed schedule is deployment-level, so outputs for any job
    /// index are byte-identical whether or not a swap happened before it —
    /// *provided the scheme is unchanged*; with a changed scheme the
    /// outputs are still correct (`Y = AᵀB` verifies), just served by a
    /// different construction.
    ///
    /// Provisioning failure (bad spec, quota exceeding every `N`) leaves
    /// the blue generation serving untouched — the swap is all-or-nothing.
    ///
    /// Returns the [`SwapRecord`] appended to [`Deployment::swap_history`].
    pub fn reconfigure(
        &self,
        spec: SchemeSpec,
        adversary_tolerance: usize,
    ) -> Result<SwapRecord> {
        let _guard = self.swap_lock.lock().unwrap();
        let blue = self.active();
        let mut params = blue.scheme.params();
        params.adversary_tolerance = adversary_tolerance;
        let scheme = spec.resolve(params)?;
        let config = ProtocolConfig {
            adversary_tolerance,
            ..blue.config.clone()
        };
        let green = Arc::new(Deployment::provision_generation(
            scheme,
            config,
            &self.factory,
        )?);
        let record = SwapRecord {
            generation: self.swaps.fetch_add(1, Ordering::Relaxed) + 1,
            from: blue.scheme.name(),
            to: green.scheme.name(),
            from_workers: blue.setup.n_workers,
            to_workers: green.setup.n_workers,
            adversary_tolerance,
        };
        *self.active.write().unwrap() = green;
        self.retired.lock().unwrap().push(blue);
        let mut log = self.swap_log.lock().unwrap();
        if log.len() == SWAP_LOG_CAP {
            log.remove(0);
        }
        log.push(record.clone());
        drop(log);
        // Opportunistic sweep: a blue with no in-flight jobs is torn down
        // right here instead of lingering until the next drain call.
        self.drain_retired();
        Ok(record)
    }

    /// Sweep retired blue generations: every one whose last in-flight job
    /// has returned is dropped (joining its worker threads); the rest keep
    /// draining. Returns how many are still draining. Called automatically
    /// at each [`Deployment::reconfigure`] and by the autoscaler tick;
    /// idle deployments converge to zero retired generations.
    pub fn drain_retired(&self) -> usize {
        let mut retired = self.retired.lock().unwrap();
        // The vector holds one strong ref per generation; any extra ref is
        // an in-flight job (or a RuntimeHandle) still using it.
        retired.retain(|g| Arc::strong_count(g) > 1);
        retired.len()
    }

    /// Retired blue generations still draining in-flight jobs.
    pub fn retired_generations(&self) -> usize {
        self.retired.lock().unwrap().len()
    }

    /// Current generation number: 0 until the first
    /// [`Deployment::reconfigure`], then the count of completed swaps.
    pub fn generation(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// The blue/green audit trail, oldest first (last `256` swaps;
    /// [`Deployment::generation`] keeps the exact lifetime count).
    pub fn swap_history(&self) -> Vec<SwapRecord> {
        self.swap_log.lock().unwrap().clone()
    }

    /// Run one `Y = AᵀB` job through the provisioned runtime. Per-job secret
    /// randomness is derived from the config seed and an atomically claimed
    /// job counter ([`derive_job_seed`]), so concurrent jobs on a shared
    /// deployment never reuse masks.
    pub fn execute(&self, a: &FpMat, b: &FpMat) -> Result<ProtocolOutput> {
        // One fetch_add both claims a unique seed slot and counts the job —
        // a separate load would let two racing executes draw the same masks.
        let k = self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        let gen = self.active();
        self.run(&gen, a, b, derive_job_seed(gen.config.seed, k))
    }

    /// [`Deployment::execute`] with an explicit secret seed (reproducible
    /// serving tests; the coordinator assigns per-job seeds at intake).
    /// Callers own mask-reuse avoidance across their seeds.
    pub fn execute_seeded(&self, a: &FpMat, b: &FpMat, seed: u64) -> Result<ProtocolOutput> {
        self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run(&self.active(), a, b, seed)
    }

    /// Run `jobs` (same shape) as **one fused batch** — the small-job fast
    /// path ([`crate::mpc::fused`]): per worker, the k per-job `H` blocks
    /// are stacked into wide buffers so every downstream kernel (scaled
    /// copies, masks, G evaluations, I accumulation, reconstruction) runs
    /// once over `k·len` scalars instead of k times over `len`. Outputs are
    /// byte-identical (Y, ξ/σ counters, traffic) to k sequential
    /// [`Deployment::execute`] calls with the same derived seeds, and come
    /// back in job order.
    ///
    /// Falls back to sequential execution — same results, fabric path —
    /// when the batch or config is not fusible: fewer than 2 jobs, mixed
    /// shapes, or fabric knobs the fused path cannot honor (chaos plans,
    /// link shapers, injected delays). Although the genuinely fused path
    /// streams no per-job envelopes, it claims the batch's job ids up
    /// front, so `runtime().jobs_started()` advances by the batch size on
    /// either path (the counter contract in [`crate::metrics`]).
    pub fn execute_fused(&self, jobs: &[(&FpMat, &FpMat)]) -> Result<Vec<ProtocolOutput>> {
        // One fetch_add claims the whole seed range — concurrent batches
        // and singleton executes can never draw overlapping mask streams.
        let base = self.jobs_executed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let gen = self.active();
        let seeds: Vec<u64> = (0..jobs.len() as u64)
            .map(|i| derive_job_seed(gen.config.seed, base + i))
            .collect();
        self.fused_run(&gen, jobs, &seeds)
    }

    /// [`Deployment::execute_fused`] with explicit per-job seeds (the
    /// coordinator path, where seeds are assigned at intake). Callers own
    /// mask-reuse avoidance across their seeds.
    pub fn execute_fused_seeded(
        &self,
        jobs: &[(&FpMat, &FpMat)],
        seeds: &[u64],
    ) -> Result<Vec<ProtocolOutput>> {
        self.jobs_executed.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        self.fused_run(&self.active(), jobs, seeds)
    }

    /// Dispatch a seeded batch: fused when legal, else job-by-job through
    /// the fabric path (which honors chaos/shaping/delays exactly).
    fn fused_run(
        &self,
        gen: &Arc<Generation>,
        jobs: &[(&FpMat, &FpMat)],
        seeds: &[u64],
    ) -> Result<Vec<ProtocolOutput>> {
        if seeds.len() != jobs.len() {
            return Err(crate::error::CmpcError::InvalidParams(format!(
                "fused batch has {} jobs but {} seeds",
                jobs.len(),
                seeds.len()
            )));
        }
        let same_shape = jobs
            .windows(2)
            .all(|w| w[0].0.rows == w[1].0.rows && w[0].0.cols == w[1].0.cols);
        if jobs.len() < 2 || !same_shape || !fused::config_fusible(&gen.config) {
            return jobs
                .iter()
                .zip(seeds)
                .map(|(&(a, b), &seed)| self.run(gen, a, b, seed))
                .collect();
        }
        // The genuinely fused path bypasses the fabric, so claim its job
        // ids explicitly: `jobs_started` advances by the batch size on
        // both paths, and the batch's single amortized reconstruction is
        // recorded as one Phase-3 decode (the counter contract in
        // `metrics`).
        gen.runtime.claim_job_ids(jobs.len() as u64);
        let started = Instant::now();
        let outs = fused::run_fused_batch(
            gen.scheme.as_ref(),
            &gen.setup,
            jobs,
            seeds,
            &gen.config,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
        )?;
        gen.runtime.note_decode();
        let elapsed = started.elapsed().as_nanos() as u64;
        for out in &outs {
            self.note_completed(elapsed / outs.len().max(1) as u64, &out.traffic);
        }
        Ok(outs)
    }

    /// Run a [`Pipeline`] — a validated chain of secure matrix ops — end
    /// to end on the provisioned runtime: one fabric job per matmul round,
    /// masked re-shares between rounds, and a single Phase-3 decode of the
    /// final output (see [`crate::mpc::pipeline`]). The pipeline claims
    /// one seed slot like a job; per-round secrets derive from
    /// [`crate::mpc::pipeline::stage_seed`] of it.
    pub fn execute_pipeline(
        &self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
    ) -> Result<PipelineOutput> {
        let k = self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        let gen = self.active();
        self.run_pipeline(&gen, pipe, x, weights, derive_job_seed(gen.config.seed, k))
    }

    /// [`Deployment::execute_pipeline`] with an explicit pipeline seed —
    /// the reproducibility hook the CI digest lanes and the multi-process
    /// reference role drive. Callers own mask-reuse avoidance.
    pub fn execute_pipeline_seeded(
        &self,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        seed: u64,
    ) -> Result<PipelineOutput> {
        self.jobs_executed.fetch_add(1, Ordering::Relaxed);
        self.run_pipeline(&self.active(), pipe, x, weights, seed)
    }

    fn run_pipeline(
        &self,
        gen: &Arc<Generation>,
        pipe: &Pipeline,
        x: &FpMat,
        weights: &[&FpMat],
        seed: u64,
    ) -> Result<PipelineOutput> {
        let cfg = ProtocolConfig {
            seed,
            ..gen.config.clone()
        };
        let started = Instant::now();
        let out = pipeline::run_pipeline(
            gen.scheme.as_ref(),
            &gen.setup,
            pipe,
            x,
            weights,
            &cfg,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
            &gen.runtime,
        )?;
        self.note_completed(started.elapsed().as_nanos() as u64, &out.traffic);
        Ok(out)
    }

    fn run(
        &self,
        gen: &Arc<Generation>,
        a: &FpMat,
        b: &FpMat,
        seed: u64,
    ) -> Result<ProtocolOutput> {
        let cfg = ProtocolConfig {
            seed,
            ..gen.config.clone()
        };
        let started = Instant::now();
        let out = protocol::run_job(
            gen.scheme.as_ref(),
            &gen.setup,
            a,
            b,
            &cfg,
            &ExecEnv {
                factory: &self.factory,
                pool: &self.pool,
                scratch: &self.scratch,
            },
            &gen.runtime,
        )?;
        self.note_completed(started.elapsed().as_nanos() as u64, &out.traffic);
        Ok(out)
    }

    /// Fold one successful job into the deployment-lifetime telemetry.
    fn note_completed(&self, elapsed_ns: u64, traffic: &TrafficReport) {
        self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        self.w2w_scalars
            .fetch_add(traffic.worker_to_worker, Ordering::Relaxed);
        self.latency_ns.fetch_add(elapsed_ns, Ordering::Relaxed);
    }

    /// Deployment-lifetime measured telemetry: completed jobs, Phase-2
    /// worker↔worker scalars (the measured ζ), and total in-call latency.
    /// Unlike [`Deployment::health`] these totals survive blue/green swaps
    /// — they belong to the deployment, not a generation.
    pub fn telemetry(&self) -> DeploymentTelemetry {
        DeploymentTelemetry {
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            w2w_scalars: self.w2w_scalars.load(Ordering::Relaxed),
            latency_ns_total: self.latency_ns.load(Ordering::Relaxed),
        }
    }

    /// The resolved scheme the **active generation** runs (shared handle —
    /// a concurrent swap retires the generation, not the returned `Arc`).
    pub fn scheme(&self) -> Arc<dyn CmpcScheme> {
        self.active().scheme.clone()
    }

    /// The active scheme's AGE gap λ, if it has one (`None` for PolyDot /
    /// Entangled) — the autoscaler's position on the λ curve.
    pub fn gap_lambda(&self) -> Option<u64> {
        self.active().scheme.gap_lambda()
    }

    /// The worker pool driving this deployment's parallel sections.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Handle on the **active generation's** worker runtime (persistent
    /// threads + multiplexed fabric, including the eviction/respawn reaper
    /// and the chaos hooks). Derefs to [`WorkerRuntime`]; holding it keeps
    /// that generation alive across a concurrent swap.
    pub fn runtime(&self) -> RuntimeHandle {
        RuntimeHandle(self.active())
    }

    /// Snapshot of the **active generation's** fault-tolerance counters —
    /// evictions, respawns, early decodes, per-job deadline misses, driver
    /// aborts, Byzantine detections — plus `blamed_workers` and the
    /// per-slot strike ledger. A blue/green swap starts a fresh generation
    /// (and thus fresh counters); the autoscaler re-baselines its decision
    /// window at every swap for exactly this reason.
    pub fn health(&self) -> crate::metrics::RuntimeHealthReport {
        self.active().runtime.health()
    }

    /// The scheme parameters of the active generation (the `(s, t, z)`
    /// triple is fixed for the deployment's lifetime; only
    /// `adversary_tolerance` can change across swaps).
    pub fn params(&self) -> SchemeParams {
        self.active().scheme.params()
    }

    /// Provisioned worker count of the active generation.
    pub fn n_workers(&self) -> usize {
        self.active().setup.n_workers
    }

    /// Persistent worker threads serving the active generation (constant
    /// between swaps — jobs spawn nothing).
    pub fn worker_threads(&self) -> usize {
        self.active().runtime.worker_threads()
    }

    /// Jobs attempted through this deployment (seed slots claimed), across
    /// every generation — the Setup of each generation was solved exactly
    /// once, at its provisioning.
    pub fn jobs_executed(&self) -> u64 {
        self.jobs_executed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::CmpcError;
    use crate::util::rng::ChaChaRng;

    #[test]
    fn deployment_reuses_setup_across_jobs() {
        let params = SchemeParams::new(2, 2, 2);
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::default(),
        )
        .unwrap();
        assert_eq!(dep.n_workers(), 17);
        assert_eq!(dep.worker_threads(), 17);
        let mut rng = ChaChaRng::seed_from_u64(10);
        for _ in 0..3 {
            let a = FpMat::random(&mut rng, 8, 8);
            let b = FpMat::random(&mut rng, 8, 8);
            let out = dep.execute(&a, &b).unwrap();
            assert!(out.verified);
            assert_eq!(out.y, a.transpose().matmul(&b));
        }
        assert_eq!(dep.jobs_executed(), 3);
        // the persistent runtime served every job; thread count is flat
        assert_eq!(dep.worker_threads(), 17);
        assert_eq!(dep.runtime().jobs_started(), 3);
        // measured telemetry accumulated per job
        let tel = dep.telemetry();
        assert_eq!(tel.jobs_completed, 3);
        assert!(tel.w2w_scalars > 0, "Phase-2 exchange was metered");
        assert!(tel.latency_ns_total > 0);
    }

    #[test]
    fn failed_job_leaves_deployment_usable() {
        let params = SchemeParams::new(2, 2, 1);
        let dep =
            Deployment::provision_adaptive(params, ProtocolConfig::default()).unwrap();
        let mut rng = ChaChaRng::seed_from_u64(11);
        let bad_a = FpMat::random(&mut rng, 6, 6);
        let bad_b = FpMat::random(&mut rng, 7, 7); // size disagrees with A
        let err = dep.execute(&bad_a, &bad_b).unwrap_err();
        assert!(matches!(err, CmpcError::ShapeMismatch(_)));
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        assert!(dep.execute(&a, &b).unwrap().verified);
        assert_eq!(dep.jobs_executed(), 2);
        // only the successful job entered the telemetry
        assert_eq!(dep.telemetry().jobs_completed, 1);
    }

    /// `execute_fused` must be byte-identical to the same jobs streamed
    /// sequentially through `execute` — both claim seed slots from the same
    /// atomic counter, so two fresh deployments give the comparison.
    #[test]
    fn fused_execute_matches_sequential_execute() {
        let params = SchemeParams::new(2, 2, 2);
        let provision = || {
            Deployment::provision(
                SchemeSpec::Age { lambda: None },
                params,
                ProtocolConfig::default(),
            )
            .unwrap()
        };
        let mut rng = ChaChaRng::seed_from_u64(77);
        let jobs: Vec<(FpMat, FpMat)> = (0..3)
            .map(|_| (FpMat::random(&mut rng, 8, 8), FpMat::random(&mut rng, 8, 8)))
            .collect();

        let seq_dep = provision();
        let sequential: Vec<_> = jobs
            .iter()
            .map(|(a, b)| seq_dep.execute(a, b).unwrap())
            .collect();

        let fused_dep = provision();
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let fused = fused_dep.execute_fused(&refs).unwrap();
        assert_eq!(fused_dep.jobs_executed(), 3);
        // The fused path claims the batch's job ids even though it streams
        // no envelopes — jobs_started advances like the sequential path,
        // and the batch's amortized reconstruction is one Phase-3 decode
        // (vs three for the sequential jobs).
        assert_eq!(fused_dep.runtime().jobs_started(), 3);
        assert_eq!(fused_dep.health().phase3_decodes, 1);
        assert_eq!(seq_dep.health().phase3_decodes, 3);
        // Both paths metered the same per-job w2w traffic.
        assert_eq!(
            fused_dep.telemetry().w2w_scalars,
            seq_dep.telemetry().w2w_scalars
        );

        for (j, (f, s)) in fused.iter().zip(&sequential).enumerate() {
            assert_eq!(f.y, s.y, "job {j}: Y");
            assert!(f.verified, "job {j}: verified");
            assert_eq!(f.traffic, s.traffic, "job {j}: traffic");
            for (wn, (fc, sc)) in
                f.worker_counters.iter().zip(&s.worker_counters).enumerate()
            {
                assert_eq!(fc.mults(), sc.mults(), "job {j} worker {wn}: ξ");
                assert_eq!(fc.stored(), sc.stored(), "job {j} worker {wn}: σ");
            }
        }
    }

    /// Unfusible batches (here: a config with an injected link delay) fall
    /// back to the sequential fabric path with the same per-job seeds.
    #[test]
    fn unfusible_batch_falls_back_to_sequential() {
        let params = SchemeParams::new(2, 2, 1);
        let config = ProtocolConfig::builder()
            .link_delay(Some(std::time::Duration::from_micros(1)))
            .build();
        let dep = Deployment::provision(SchemeSpec::Age { lambda: None }, params, config)
            .unwrap();
        let mut rng = ChaChaRng::seed_from_u64(78);
        let jobs: Vec<(FpMat, FpMat)> = (0..2)
            .map(|_| (FpMat::random(&mut rng, 4, 4), FpMat::random(&mut rng, 4, 4)))
            .collect();
        let refs: Vec<(&FpMat, &FpMat)> = jobs.iter().map(|(a, b)| (a, b)).collect();
        let outs = dep.execute_fused(&refs).unwrap();
        assert_eq!(outs.len(), 2);
        for ((a, b), out) in jobs.iter().zip(&outs) {
            assert_eq!(out.y, a.transpose().matmul(b));
            assert!(out.verified);
        }
        // the fabric path streamed both jobs through the live runtime
        assert_eq!(dep.runtime().jobs_started(), 2);
    }

    #[test]
    fn provision_rejects_bad_spec() {
        let params = SchemeParams::new(2, 2, 2);
        let err = Deployment::provision(
            SchemeSpec::Age { lambda: Some(9) },
            params,
            ProtocolConfig::default(),
        )
        .unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }

    #[test]
    fn reconfigure_swaps_scheme_and_records_audit_trail() {
        let params = SchemeParams::new(2, 2, 2);
        // Start deliberately suboptimal: AGE λ=0 provisions 18 workers.
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: Some(0) },
            params,
            ProtocolConfig::default(),
        )
        .unwrap();
        assert_eq!(dep.n_workers(), 18);
        assert_eq!(dep.gap_lambda(), Some(0));
        assert_eq!(dep.generation(), 0);

        let mut rng = ChaChaRng::seed_from_u64(21);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        assert!(dep.execute(&a, &b).unwrap().verified);

        // Swap to the λ* generation (17 workers).
        let rec = dep.reconfigure(SchemeSpec::Age { lambda: Some(2) }, 0).unwrap();
        assert_eq!(rec.generation, 1);
        assert_eq!(rec.from_workers, 18);
        assert_eq!(rec.to_workers, 17);
        assert_eq!(dep.n_workers(), 17);
        assert_eq!(dep.gap_lambda(), Some(2));
        assert_eq!(dep.swap_history(), vec![rec]);

        // The green generation serves immediately; the seed schedule did
        // not restart (jobs_executed kept counting).
        assert!(dep.execute(&a, &b).unwrap().verified);
        assert_eq!(dep.jobs_executed(), 2);
        // No jobs in flight → the swap's opportunistic sweep already
        // retired blue.
        assert_eq!(dep.drain_retired(), 0);
        assert_eq!(dep.retired_generations(), 0);
    }

    #[test]
    fn reconfigure_failure_leaves_blue_serving() {
        let params = SchemeParams::new(2, 2, 2);
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: None },
            params,
            ProtocolConfig::default(),
        )
        .unwrap();
        let err = dep.reconfigure(SchemeSpec::Age { lambda: Some(9) }, 0).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
        assert_eq!(dep.generation(), 0, "failed swap recorded no generation");
        assert!(dep.swap_history().is_empty());
        let mut rng = ChaChaRng::seed_from_u64(22);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        assert!(dep.execute(&a, &b).unwrap().verified, "blue still serves");
    }

    #[test]
    fn runtime_handle_pins_its_generation_across_a_swap() {
        let params = SchemeParams::new(2, 2, 2);
        let dep = Deployment::provision(
            SchemeSpec::Age { lambda: Some(0) },
            params,
            ProtocolConfig::default(),
        )
        .unwrap();
        let blue_handle = dep.runtime();
        assert_eq!(blue_handle.n_workers(), 18);
        dep.reconfigure(SchemeSpec::Age { lambda: Some(2) }, 0).unwrap();
        // The handle still reads the blue generation it captured…
        assert_eq!(blue_handle.n_workers(), 18);
        // …and keeps it alive: the sweep cannot drop blue yet.
        assert_eq!(dep.drain_retired(), 1);
        drop(blue_handle);
        assert_eq!(dep.drain_retired(), 0);
        // A fresh handle sees green.
        assert_eq!(dep.runtime().n_workers(), 17);
    }
}
