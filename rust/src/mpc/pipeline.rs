//! Pipeline jobs: chained secure matrix ops on one deployment — private
//! ML inference without per-stage decode-and-re-encode at the master.
//!
//! A [`Pipeline`] is a small validated chain of matrix ops — secure matmul,
//! transpose, element-wise scale / bias add, fixed-point truncation — run
//! against a single provisioned [`Deployment`]. Semantically, with state
//! `S_0 = X` and per-round weights `W_0..W_{R−1}`:
//!
//! ```text
//! S_{r+1} = boundary_ops_r( S_rᵀ · W_r )        (rounds r = 0..R−1)
//! ```
//!
//! and the pipeline output is the last round's product after its trailing
//! ops. Every round is one CMPC job (Algorithm 3) on the job-multiplexed
//! fabric, with its own [`JobId`], stage-tagged control traffic
//! ([`ControlMsg::StageStart`]) and stage-tagged payloads.
//!
//! # Why the master never sees an intermediate product
//!
//! The naive chain would decode `Y_r` at the master after every round and
//! re-encode it as the next round's input — leaking every intermediate
//! activation to the master. Instead, intermediate rounds perform a
//! **masked open**: source B draws a secret per-round mask `R_r` (from the
//! round seed) and ships each worker the evaluation of
//!
//! ```text
//! D_r(x) = Σ_{i,l} R_r[i][l] · x^{i+t·l}
//! ```
//!
//! as a [`Payload::StageMask`]. A worker adds `D_r(αₙ)` to its finished
//! I-share and answers with a [`Payload::StageMasked`] instead of a plain
//! I-share, so what the master interpolates at the `t²+z` stage quota is
//! `Z_r = Y_r + R_r` — uniformly masked on every full-field coordinate.
//! The master applies the round's boundary ops to `Z_r` and re-shares it;
//! source A independently replays the same ops on `R_r` and ships the
//! *residual* share ([`ControlMsg::StageShareR`], no secret terms), and
//! each worker subtracts the two evaluations. By linearity of the share
//! encoding over GF(p), `F_A(Z') − F_res(R') = F_A(Z' − R') = F_A(S_{r+1})`
//! — byte-identical to sharing the true next input, which is exactly what
//! the in-process driver (who plays all roles) does directly. Only the
//! **final** round runs the ordinary Phase-3 reconstruction
//! ([`crate::mpc::master::run_master`]): one decode per pipeline, counted
//! by [`RuntimeHealthReport::phase3_decodes`].
//!
//! # Fixed-point truncation
//!
//! [`PipelineOp::Truncate`]`(f)` models a fixed-point activation rescale
//! (`v >> f`). On a *masked* boundary it is probabilistic in the usual
//! MPC sense: the opened value is `(y + r) >> f` minus the replayed
//! `r >> f`, which equals `(y >> f) + ε` with `ε ∈ {0,1}` — and requires
//! `y + r < p` to avoid wraparound, which is why truncating boundaries
//! draw `R_r` entries below `2¹⁵` and why callers should keep truncated
//! activations small (see [`pipeline_input`]). The protocol/reference
//! byte-identity contract is unconditional regardless: the reference
//! replays the identical masked arithmetic with the identical `R_r`.
//!
//! # Determinism and fault tolerance
//!
//! All per-round randomness derives from [`stage_seed`] of the pipeline
//! seed, so in-process, multi-process TCP, and the
//! [`reference_eval`] replay agree byte-for-byte. Intermediate rounds
//! always decode at the stage quota and cancel their tail with a
//! `JobAbort` — a worker chaos-killed mid-stage costs nothing as long as
//! `t²+z` peers survive the round, and the runtime reaper respawns it
//! before the next round's [`WorkerRuntime::begin_job`].
//!
//! [`Deployment`]: crate::mpc::deployment::Deployment
//! [`ControlMsg::StageStart`]: crate::mpc::network::ControlMsg::StageStart
//! [`ControlMsg::StageShareR`]: crate::mpc::network::ControlMsg::StageShareR
//! [`Payload::StageMask`]: crate::mpc::network::Payload::StageMask
//! [`Payload::StageMasked`]: crate::mpc::network::Payload::StageMasked
//! [`RuntimeHealthReport::phase3_decodes`]:
//!     crate::metrics::RuntimeHealthReport::phase3_decodes

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::{CmpcScheme, SchemeParams};
use crate::error::{CmpcError, Result};
use crate::ff;
use crate::matrix::FpMat;
use crate::metrics::{TrafficReport, WorkerCounters};
use crate::mpc::deployment::derive_job_seed;
use crate::mpc::master;
use crate::mpc::network::{ControlMsg, Fabric, JobId, JobRouter, Payload, PooledMat};
use crate::mpc::protocol::{validate_job_shapes, ExecEnv, ProtocolConfig, Setup};
use crate::mpc::runtime::WorkerRuntime;
use crate::mpc::source;
use crate::poly::interp::try_vandermonde_inverse_rows;
use crate::poly::MatPoly;
use crate::util::rng::ChaChaRng;

/// Upper bound on secure-matmul rounds per pipeline (keeps stage indices
/// comfortably inside the wire's `u32` tag and bounds mask bookkeeping).
pub const MAX_PIPELINE_ROUNDS: usize = 32;

/// Domain separator folded into the pipeline seed before per-round
/// derivation, so pipeline stage seeds can never collide with the
/// singleton-job seed schedule of the same deployment.
const PIPE_DOMAIN: u64 = 0x5049_5045_4C4E_4553;

/// Domain separator for the per-round mask stream: the mask RNG must be
/// independent of the round's source/worker streams even though all three
/// derive from the same broadcast round seed.
const MASK_DOMAIN: u64 = 0xA5A5_5A5A_D00D_F00D;

/// One operation in a [`Pipeline`] chain.
#[derive(Clone, Debug, PartialEq)]
pub enum PipelineOp {
    /// A secure coded matrix multiplication: `S ← Sᵀ · W` where `W` is the
    /// next unconsumed weight matrix supplied to the run. Every pipeline
    /// starts with one, and each costs one full CMPC round.
    Matmul,
    /// Transpose the running state (free: applied to the masked open and
    /// its mask replay, never decoded in the clear mid-chain).
    Transpose,
    /// Multiply every element by a non-zero constant (mod p).
    Scale(u64),
    /// Add a public bias matrix element-wise. The bias is public protocol
    /// state (model biases, not activations), so it is applied to the
    /// masked value only — the mask replay is unchanged.
    AddBias(FpMat),
    /// Fixed-point truncation: shift every element right by `f` bits
    /// (`1..=15`). Only legal directly after a [`PipelineOp::Matmul`]; on
    /// masked boundaries the result carries the standard `ε ∈ {0,1}`
    /// probabilistic-truncation slack (see the module docs).
    Truncate(u32),
}

/// A validated linear chain of matrix ops, ready to run on a deployment.
///
/// Build one with [`Pipeline::new`] (typed [`CmpcError::InvalidParams`] on
/// an illegal chain) or parse the manifest spec form with
/// [`Pipeline::parse_spec`]. `R` = number of [`PipelineOp::Matmul`] ops =
/// number of secure rounds = number of weight matrices the run consumes.
#[derive(Clone, Debug)]
pub struct Pipeline {
    ops: Vec<PipelineOp>,
    /// `boundaries[r]` = index range of the ops between matmul `r` and the
    /// next matmul (for the last round: the trailing ops).
    boundaries: Vec<(usize, usize)>,
}

impl Pipeline {
    /// Validate `ops` into a runnable pipeline.
    ///
    /// Rules (each violation is a typed [`CmpcError::InvalidParams`]):
    /// the chain is non-empty and starts with a [`PipelineOp::Matmul`];
    /// at most [`MAX_PIPELINE_ROUNDS`] matmuls; [`PipelineOp::Truncate`]
    /// bits are in `1..=15` and a truncation directly follows a matmul
    /// (the only position where the bounded-mask open is sound); and
    /// [`PipelineOp::Scale`] constants are non-zero mod p.
    pub fn new(ops: Vec<PipelineOp>) -> Result<Pipeline> {
        if ops.first() != Some(&PipelineOp::Matmul) {
            return Err(CmpcError::InvalidParams(
                "a pipeline must start with a matmul op".to_string(),
            ));
        }
        let rounds = ops.iter().filter(|o| matches!(o, PipelineOp::Matmul)).count();
        if rounds > MAX_PIPELINE_ROUNDS {
            return Err(CmpcError::InvalidParams(format!(
                "pipeline has {rounds} matmul rounds; the limit is {MAX_PIPELINE_ROUNDS}"
            )));
        }
        for (k, op) in ops.iter().enumerate() {
            match op {
                PipelineOp::Truncate(f) => {
                    if !(1..=15).contains(f) {
                        return Err(CmpcError::InvalidParams(format!(
                            "truncation by {f} bits is outside 1..=15"
                        )));
                    }
                    if k == 0 || ops[k - 1] != PipelineOp::Matmul {
                        return Err(CmpcError::InvalidParams(
                            "truncation must directly follow a matmul (the only \
                             boundary position where the bounded-mask open is sound)"
                                .to_string(),
                        ));
                    }
                }
                PipelineOp::Scale(c) => {
                    if c % ff::P == 0 {
                        return Err(CmpcError::InvalidParams(
                            "scale constant is 0 mod p".to_string(),
                        ));
                    }
                }
                PipelineOp::Matmul | PipelineOp::Transpose | PipelineOp::AddBias(_) => {}
            }
        }
        // Precompute each round's boundary slice: the ops strictly between
        // matmul r and matmul r+1 (trailing ops for the last round).
        let mut boundaries = Vec::with_capacity(rounds);
        let mut start = None;
        for (k, op) in ops.iter().enumerate() {
            if matches!(op, PipelineOp::Matmul) {
                if let Some(s) = start {
                    boundaries.push((s, k));
                }
                start = Some(k + 1);
            }
        }
        if let Some(s) = start {
            boundaries.push((s, ops.len()));
        }
        Ok(Pipeline { ops, boundaries })
    }

    /// Number of secure matmul rounds (= weight matrices a run consumes).
    pub fn rounds(&self) -> usize {
        self.boundaries.len()
    }

    /// The validated op chain.
    pub fn ops(&self) -> &[PipelineOp] {
        &self.ops
    }

    /// The ops applied after round `r`'s matmul: a masked boundary for
    /// intermediate rounds, the in-the-clear trailing ops for the last.
    pub fn boundary(&self, r: usize) -> &[PipelineOp] {
        let (s, e) = self.boundaries[r];
        &self.ops[s..e]
    }

    /// Whether round `r`'s mask must be drawn bounded (`< 2¹⁵`): true iff
    /// its boundary starts with a truncation. The distributed source-B
    /// role derives the same answer from its manifest copy of the spec.
    pub(crate) fn bounded_mask(&self, r: usize) -> bool {
        matches!(self.boundary(r).first(), Some(PipelineOp::Truncate(_)))
    }

    /// Parse the manifest/CLI spec form: comma-separated ops from
    /// `matmul`, `transpose`, `scale:<c>`, `truncate:<f>` — e.g. the
    /// private-inference chain `matmul,truncate:8,matmul`.
    /// [`PipelineOp::AddBias`] carries matrix data and has no spec form.
    pub fn parse_spec(spec: &str) -> Result<Pipeline> {
        let mut ops = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            let op = match tok.split_once(':') {
                None => match tok {
                    "matmul" => PipelineOp::Matmul,
                    "transpose" => PipelineOp::Transpose,
                    _ => {
                        return Err(CmpcError::InvalidParams(format!(
                            "unknown pipeline op {tok:?} (expected matmul, transpose, \
                             scale:<c> or truncate:<f>)"
                        )))
                    }
                },
                Some(("scale", c)) => PipelineOp::Scale(c.parse::<u64>().map_err(|_| {
                    CmpcError::InvalidParams(format!("bad scale constant {c:?}"))
                })?),
                Some(("truncate", f)) => PipelineOp::Truncate(f.parse::<u32>().map_err(
                    |_| CmpcError::InvalidParams(format!("bad truncate bits {f:?}")),
                )?),
                Some((other, _)) => {
                    return Err(CmpcError::InvalidParams(format!(
                        "unknown pipeline op {other:?}"
                    )))
                }
            };
            ops.push(op);
        }
        Pipeline::new(ops)
    }

    /// Render back to the spec form, or `None` if the chain contains an
    /// op with no spec representation ([`PipelineOp::AddBias`]).
    pub fn spec_string(&self) -> Option<String> {
        let mut toks = Vec::with_capacity(self.ops.len());
        for op in &self.ops {
            toks.push(match op {
                PipelineOp::Matmul => "matmul".to_string(),
                PipelineOp::Transpose => "transpose".to_string(),
                PipelineOp::Scale(c) => format!("scale:{c}"),
                PipelineOp::Truncate(f) => format!("truncate:{f}"),
                PipelineOp::AddBias(_) => return None,
            });
        }
        Some(toks.join(","))
    }
}

/// Everything a pipeline run reports back.
pub struct PipelineOutput {
    /// The final product, after the last round's trailing ops — the only
    /// value the master ever decoded unmasked.
    pub y: FpMat,
    /// Secure matmul rounds executed.
    pub rounds: usize,
    /// Scheme that served every round.
    pub scheme_name: String,
    /// Provisioned worker count.
    pub n_workers: usize,
    /// Whether the output was checked against [`reference_eval`]
    /// (requested via [`ProtocolConfig::verify`]; a mismatch is a typed
    /// error, so a returned `false` only ever means "not checked").
    pub verified: bool,
    /// Whether the final round's Phase-3 decode took the early-decode
    /// fast path (intermediate rounds always decode at the stage quota).
    pub early_decoded: bool,
    /// Per-round fabric traffic, in round order.
    pub stage_traffic: Vec<TrafficReport>,
    /// Field-wise total of `stage_traffic`.
    pub traffic: TrafficReport,
    /// Per-round wall time, in round order (the bench's stages-vs-e2e
    /// section sums these against an end-to-end clock).
    pub stage_elapsed: Vec<Duration>,
}

/// Per-round seed schedule: every secret stream of round `r` (sources,
/// worker masks, stage mask) derives from `stage_seed(pipeline_seed, r)`.
/// The domain separator keeps the schedule disjoint from the singleton-job
/// seeds a shared deployment hands out.
pub fn stage_seed(pipeline_seed: u64, r: u32) -> u64 {
    derive_job_seed(pipeline_seed ^ PIPE_DOMAIN, r as u64)
}

/// Deterministic demo input for the private-inference example and the CI
/// digest lanes: entries in `[0, 8)` so a `truncate:8` chain stays inside
/// the bounded-mask exactness window (see the module docs).
pub fn pipeline_input(seed: u64, m: usize) -> FpMat {
    let mut rng = ChaChaRng::seed_from_u64(seed ^ 0x5049_5045_0000_0001);
    FpMat::from_fn(m, m, |_, _| rng.gen_range(8))
}

/// Deterministic demo weight matrix for round `r` (companion of
/// [`pipeline_input`]).
pub fn pipeline_weight(seed: u64, m: usize, r: u32) -> FpMat {
    let mut rng =
        ChaChaRng::seed_from_u64(derive_job_seed(seed ^ 0x5049_5045_0000_0002, r as u64));
    FpMat::from_fn(m, m, |_, _| rng.gen_range(8))
}

/// Round `r`'s mask blocks `R_r[i][l]` (t×t blocks of (m/t)×(m/t)):
/// entries below `2¹⁵` when `bounded` (truncating boundary), else
/// full-field uniform. Shared verbatim by the in-process driver, the TCP
/// source/master roles, and [`reference_eval`] — byte-identity across all
/// three hangs on this derivation.
pub(crate) fn stage_mask_blocks(
    t: usize,
    block: usize,
    bounded: bool,
    round_seed: u64,
) -> Vec<Vec<FpMat>> {
    let mut rng = ChaChaRng::seed_from_u64(round_seed ^ MASK_DOMAIN);
    (0..t)
        .map(|_i| {
            (0..t)
                .map(|_l| {
                    FpMat::from_fn(block, block, |_, _| {
                        if bounded {
                            rng.gen_range(1 << 15)
                        } else {
                            rng.field_element()
                        }
                    })
                })
                .collect()
        })
        .collect()
}

/// The mask polynomial `D_r(x) = Σ_{i,l} R_r[i][l]·x^{i+t·l}`: blocks sit
/// at the *dense-basis* important coefficients `i+t·l` of the exchanged
/// I-polynomial (its top `z` coefficients are already randomized by the
/// workers' own G-masks, so `Z = Y + R` leaks nothing to the master).
pub(crate) fn stage_mask_poly(blocks: &[Vec<FpMat>], t: usize) -> MatPoly {
    let (br, bc) = (blocks[0][0].rows, blocks[0][0].cols);
    let mut poly = MatPoly::new(br, bc);
    for (i, row) in blocks.iter().enumerate() {
        for (l, blk) in row.iter().enumerate() {
            poly.insert((i + t * l) as u64, blk.clone());
        }
    }
    poly
}

/// The secret-term-free A-side share polynomial of `mat`: the coded blocks
/// of [`source::build_f_a`] *without* the trailing random masks. Evaluated
/// per worker as [`ControlMsg::StageShareR`], it lets a worker cancel the
/// mask out of the master's re-shared `Z'` — by GF(p) linearity,
/// `build_f_a(Z', rng) − residual(R') = build_f_a(Z' − R', rng)` with the
/// identical secret draws.
///
/// [`ControlMsg::StageShareR`]: crate::mpc::network::ControlMsg::StageShareR
pub(crate) fn residual_poly_a(scheme: &dyn CmpcScheme, mat: &FpMat) -> MatPoly {
    let p = scheme.params();
    let at = mat.transpose();
    let blocks = at.blocks(p.t, p.s);
    let (br, bc) = (blocks[0][0].rows, blocks[0][0].cols);
    let mut poly = MatPoly::new(br, bc);
    for (i, row) in blocks.into_iter().enumerate() {
        for (j, blk) in row.into_iter().enumerate() {
            poly.insert(scheme.coded_power_a(i, j), blk);
        }
    }
    poly
}

/// Apply a boundary-op slice to a matrix. `with_bias` distinguishes the
/// two lockstep replays: the masked value `Z` takes bias adds, the mask
/// replay `R` skips them (a public bias shifts `Z − R` exactly once).
pub(crate) fn apply_ops(mut m: FpMat, ops: &[PipelineOp], with_bias: bool) -> FpMat {
    for op in ops {
        match op {
            PipelineOp::Matmul => {} // never inside a boundary slice
            PipelineOp::Transpose => m = m.transpose(),
            PipelineOp::Scale(c) => m = m.scale(*c),
            PipelineOp::AddBias(b) => {
                if with_bias {
                    m.add_assign(b);
                }
            }
            PipelineOp::Truncate(f) => {
                let f = *f;
                let shifted = FpMat::from_fn(m.rows, m.cols, |r, c| m.at(r, c) >> f);
                m = shifted;
            }
        }
    }
    m
}

/// Validate a pipeline run against a scheme and config: square equal-shape
/// inputs that the partition divides, one weight per round, no Byzantine
/// tolerance (the masked open is an erasure decode; location needs the
/// singleton path), and — per stage, since every round re-shares and
/// re-interpolates — the dense-basis degree/quota accounting.
pub fn validate_pipeline(
    pipe: &Pipeline,
    params: SchemeParams,
    n_workers: usize,
    x: &FpMat,
    weights: &[&FpMat],
    config: &ProtocolConfig,
) -> Result<()> {
    if weights.len() != pipe.rounds() {
        return Err(CmpcError::InvalidParams(format!(
            "pipeline has {} matmul rounds but {} weight matrices were supplied",
            pipe.rounds(),
            weights.len()
        )));
    }
    if params.adversary_tolerance != 0 || config.adversary_tolerance != 0 {
        return Err(CmpcError::InvalidParams(
            "pipelines require adversary_tolerance = 0: the intermediate masked \
             open is an erasure decode with no Byzantine location pass"
                .to_string(),
        ));
    }
    for (r, w) in weights.iter().enumerate() {
        validate_job_shapes(x, w, params)
            .map_err(|e| CmpcError::ShapeMismatch(format!("pipeline round {r}: {e}")))?;
        if w.rows != x.rows {
            return Err(CmpcError::ShapeMismatch(format!(
                "pipeline round {r}: weight is {}x{} but the chain state is {}x{}",
                w.rows, w.cols, x.rows, x.cols
            )));
        }
        // Per-stage accounting: round r interpolates the dense basis
        // 0..t²+z, so its quota and every important coefficient must fit
        // the provisioned worker set — checked here per round, not assumed
        // from round 0.
        let quota = params.stage_quota();
        if quota > n_workers {
            return Err(CmpcError::InsufficientWorkers {
                needed: quota,
                provisioned: n_workers,
            });
        }
        for i in 0..params.t {
            for l in 0..params.t {
                debug_assert!(i + params.t * l < quota);
            }
        }
    }
    Ok(())
}

/// Shape-only pipeline validation from `(m, s, t)` — everything a
/// topology manifest can decide before any matrices exist. The full
/// [`validate_pipeline`] re-checks shapes and quotas at run time.
pub fn validate_pipeline_shape(pipe: &Pipeline, m: usize, s: usize, t: usize) -> Result<()> {
    if m == 0 || s == 0 || t == 0 || m % s != 0 || m % t != 0 {
        return Err(CmpcError::ShapeMismatch(format!(
            "pipeline ({} rounds) runs {m}x{m} stages, but the partition (s={s}, t={t}) \
             must divide m",
            pipe.rounds()
        )));
    }
    Ok(())
}

/// Master-side collection of one intermediate round: gather `quota`
/// stage-tagged masked I-shares, interpolate the dense basis over
/// whichever subset arrived first (RS uniqueness makes the coefficients
/// independent of the arrival order), and cancel the straggler tail with
/// a `JobAbort` broadcast. Returns the masked open `Z = Y + R`.
///
/// Shared by the in-process driver and the TCP master role.
#[allow(clippy::too_many_arguments)]
pub(crate) fn collect_stage(
    router: &JobRouter,
    fabric: &Fabric,
    job: JobId,
    stage: u32,
    alphas: &[u64],
    n_workers: usize,
    t: usize,
    quota: usize,
    timeout: Duration,
    counters: &[Arc<WorkerCounters>],
) -> Result<FpMat> {
    let deadline = Instant::now() + timeout;
    let mut arrived: Vec<(usize, FpMat)> = Vec::with_capacity(quota);
    let mut seen = vec![false; n_workers];
    while arrived.len() < quota {
        let remaining = deadline.saturating_duration_since(Instant::now());
        let env = router.recv_for(job, remaining)?;
        match env.payload {
            Payload::StageMasked { stage: s, mat } if s == stage => {
                if env.from < n_workers && !seen[env.from] {
                    seen[env.from] = true;
                    arrived.push((env.from, (*mat).clone()));
                }
            }
            Payload::Control(ControlMsg::JobDone { mults, stored })
            | Payload::Control(ControlMsg::AbortAck { mults, stored }) => {
                if env.from < n_workers {
                    counters[env.from].record_final(mults, stored);
                }
            }
            // A worker that errored mid-round is a straggler for this
            // round: the quota tolerates it, the reaper replaces it.
            Payload::Control(ControlMsg::JobError(_)) => {}
            Payload::IShare(_) => {
                return Err(CmpcError::Fabric(format!(
                    "pipeline stage {stage}: worker {} answered with an unmasked \
                     I-share",
                    env.from
                )));
            }
            other => {
                return Err(CmpcError::Fabric(format!(
                    "pipeline stage {stage}: unexpected payload {other:?} from node {}",
                    env.from
                )));
            }
        }
    }
    // Interpolate coefficients 0..t² of the masked I-polynomial from the
    // first `quota` arrivals. Any quota-subset of evaluations of a
    // degree-< quota polynomial determines it uniquely, so the result is
    // byte-identical however the race resolved.
    let pts: Vec<u64> = arrived.iter().map(|&(wid, _)| alphas[wid]).collect();
    let support: Vec<u64> = (0..quota as u64).collect();
    let rows = try_vandermonde_inverse_rows(&pts, &support).ok_or_else(|| {
        CmpcError::NotDecodable(format!(
            "pipeline stage {stage}: arrival set is not interpolable"
        ))
    })?;
    let (br, bc) = (arrived[0].1.rows, arrived[0].1.cols);
    let mut z_blocks: Vec<Vec<FpMat>> = vec![Vec::with_capacity(t); t];
    for (j, row) in rows.iter().enumerate().take(t * t) {
        let mut blk = FpMat::zeros(br, bc);
        let terms: Vec<(u64, &[u32])> = row
            .iter()
            .zip(arrived.iter())
            .map(|(&c, (_, share))| (c, share.data.as_slice()))
            .collect();
        ff::weighted_sum_into(&mut blk.data, &terms);
        z_blocks[j % t].push(blk);
    }
    // Cancel the tail: stragglers drop the round immediately instead of
    // holding state until their per-job deadline. No ack drain — the
    // router queue closes with the round, so late acks are simply dropped
    // (per-stage ξ/σ finality is not promised for aborted stragglers).
    for wid in 0..n_workers {
        let _ = fabric.send(job, fabric.master_id(), wid, Payload::Control(ControlMsg::JobAbort));
    }
    Ok(FpMat::from_blocks(&z_blocks))
}

/// The cleartext replay of a pipeline: per round, the true product plus
/// the *identical* masked boundary arithmetic (`Z = Y + R_r`, boundary ops
/// on both, next state `Z' − R'`), trailing ops exact. This **is** the
/// naive master-side decode-and-re-encode chain, so a protocol run with
/// the same pipeline seed must match it byte-for-byte — which the
/// in-process driver asserts when [`ProtocolConfig::verify`] is set.
pub fn reference_eval(
    pipe: &Pipeline,
    params: SchemeParams,
    x: &FpMat,
    weights: &[&FpMat],
    pipeline_seed: u64,
) -> Result<FpMat> {
    if weights.len() != pipe.rounds() {
        return Err(CmpcError::InvalidParams(format!(
            "pipeline has {} matmul rounds but {} weight matrices were supplied",
            pipe.rounds(),
            weights.len()
        )));
    }
    let rounds = pipe.rounds();
    let mut state = x.clone();
    let mut out = FpMat::zeros(0, 0);
    for r in 0..rounds {
        let y = state.transpose().matmul(weights[r]);
        let ops = pipe.boundary(r);
        if r + 1 < rounds {
            let seed_r = stage_seed(pipeline_seed, r as u32);
            let blocks =
                stage_mask_blocks(params.t, y.rows / params.t, pipe.bounded_mask(r), seed_r);
            let r_mat = FpMat::from_blocks(&blocks);
            let mut z = y;
            z.add_assign(&r_mat);
            let z2 = apply_ops(z, ops, true);
            let r2 = apply_ops(r_mat, ops, false);
            let mut next = z2;
            next.axpy_inplace(ff::P - 1, &r2);
            state = next;
        } else {
            out = apply_ops(y, ops, true);
        }
    }
    Ok(out)
}

/// What one driven round hands back to the loop.
struct StageOutcome {
    /// Masked open `Z` (intermediate) or raw final product `Y` (last).
    mat: FpMat,
    early_decoded: bool,
}

/// Drive one round against the live runtime: announce with a stage-tagged
/// start, play both sources, then collect — masked open for intermediate
/// rounds, the full Phase-3 master for the final one.
#[allow(clippy::too_many_arguments)]
fn drive_stage(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    job: JobId,
    stage: u32,
    seed_r: u64,
    x: &FpMat,
    w: &FpMat,
    mask_blocks: Option<&Vec<Vec<FpMat>>>,
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
    runtime: &WorkerRuntime,
) -> Result<(StageOutcome, Vec<Arc<WorkerCounters>>)> {
    let p = scheme.params();
    let n = setup.n_workers;
    let fabric = runtime.fabric();
    let masked = mask_blocks.is_some();

    let counters: Vec<Arc<WorkerCounters>> =
        (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
    for (wid, c) in counters.iter().enumerate() {
        fabric.send(
            job,
            fabric.master_id(),
            wid,
            Payload::Control(ControlMsg::StageStart {
                stage,
                seed: seed_r,
                masked,
                counters: c.clone(),
            }),
        )?;
    }

    // Stage mask first (cheap: t² terms per evaluation) so no worker that
    // finishes Phase 2 quickly ever stalls waiting for it.
    if let Some(blocks) = mask_blocks {
        let d_poly = stage_mask_poly(blocks, p.t);
        for (wid, &alpha) in setup.alphas.iter().enumerate() {
            fabric.send(
                job,
                fabric.source_b_id(),
                wid,
                Payload::StageMask {
                    stage,
                    mat: PooledMat::detached(d_poly.eval(alpha)),
                },
            )?;
        }
    }

    // Phase 1 for this round — same fork order as a singleton job
    // (source A, then source B) under the round seed, so the persistent
    // workers' own re-derived streams line up.
    let mut job_rng = ChaChaRng::seed_from_u64(seed_r);
    let mut rng_src_a = job_rng.fork();
    let mut rng_src_b = job_rng.fork();
    let fa_poly = source::build_f_a(scheme, x, &mut rng_src_a);
    let fb_poly = source::build_f_b(scheme, w, &mut rng_src_b);
    let shares = source::encode_shares_pooled(
        &fa_poly,
        &fb_poly,
        &setup.alphas,
        env.pool,
        env.scratch,
        runtime.buffers(),
    );
    for (wid, (fa_n, fb_n)) in shares.into_iter().enumerate() {
        fabric.send(
            job,
            fabric.source_a_id(),
            wid,
            Payload::Shares { fa: fa_n, fb: fb_n },
        )?;
    }

    if masked {
        let z = collect_stage(
            runtime.router(),
            fabric,
            job,
            stage,
            &setup.alphas,
            n,
            p.t,
            p.stage_quota(),
            config.recv_timeout,
            &counters,
        )?;
        Ok((
            StageOutcome {
                mat: z,
                early_decoded: false,
            },
            counters,
        ))
    } else {
        let (m_out, _mt) = master::run_master(
            runtime.router(),
            fabric,
            job,
            &setup.alphas,
            n,
            p.t,
            p.z,
            0,
            config.recv_timeout,
            config.early_decode,
            &counters,
            env.pool,
            env.scratch,
        )?;
        runtime.note_decode();
        Ok((
            StageOutcome {
                mat: m_out.y,
                early_decoded: m_out.early_decoded,
            },
            counters,
        ))
    }
}

/// Run a pipeline against a live runtime — the in-process path behind
/// [`Deployment::execute_pipeline`]. The caller's thread plays the source
/// and master roles for every round; each round is one job on the
/// multiplexed fabric ([`WorkerRuntime::begin_job`] per round, so
/// `jobs_started` advances by [`Pipeline::rounds`]), and only the final
/// round performs a Phase-3 decode.
///
/// `config.seed` is the **pipeline seed**: round `r` derives everything
/// from [`stage_seed`]`(config.seed, r)`.
///
/// [`Deployment::execute_pipeline`]:
///     crate::mpc::deployment::Deployment::execute_pipeline
#[allow(clippy::too_many_arguments)]
pub fn run_pipeline(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    pipe: &Pipeline,
    x: &FpMat,
    weights: &[&FpMat],
    config: &ProtocolConfig,
    env: &ExecEnv<'_>,
    runtime: &WorkerRuntime,
) -> Result<PipelineOutput> {
    let p = scheme.params();
    validate_pipeline(pipe, p, setup.n_workers, x, weights, config)?;
    if runtime.n_workers() != setup.n_workers {
        return Err(CmpcError::InvalidParams(format!(
            "runtime provisions {} workers but the setup expects {}",
            runtime.n_workers(),
            setup.n_workers
        )));
    }
    let rounds = pipe.rounds();
    let mut state = x.clone();
    let mut y = FpMat::zeros(0, 0);
    let mut early_decoded = false;
    let mut stage_traffic = Vec::with_capacity(rounds);
    let mut stage_elapsed = Vec::with_capacity(rounds);
    for r in 0..rounds {
        let t_round = Instant::now();
        let seed_r = stage_seed(config.seed, r as u32);
        let masked = r + 1 < rounds;
        let mask_blocks = if masked {
            Some(stage_mask_blocks(
                p.t,
                state.rows / p.t,
                pipe.bounded_mask(r),
                seed_r,
            ))
        } else {
            None
        };
        // begin_job reaps first: a worker chaos-killed in round r−1 is
        // respawned before this round's shares go out.
        let job = runtime.begin_job();
        runtime.note_pipeline_stage();
        let result = drive_stage(
            scheme,
            setup,
            job,
            r as u32,
            seed_r,
            &state,
            weights[r],
            mask_blocks.as_ref(),
            config,
            env,
            runtime,
        );
        if result.is_err() {
            let fabric = runtime.fabric();
            for wid in 0..setup.n_workers {
                let _ = fabric.send(
                    job,
                    fabric.master_id(),
                    wid,
                    Payload::Control(ControlMsg::JobAbort),
                );
            }
            runtime.note_job_aborted();
        }
        stage_traffic.push(runtime.finish_job(job));
        let (outcome, _counters) = result?;
        stage_elapsed.push(t_round.elapsed());
        if masked {
            let blocks = mask_blocks.expect("masked round derived blocks");
            let ops = pipe.boundary(r);
            let z2 = apply_ops(outcome.mat, ops, true);
            let r2 = apply_ops(FpMat::from_blocks(&blocks), ops, false);
            let mut next = z2;
            next.axpy_inplace(ff::P - 1, &r2);
            state = next;
        } else {
            early_decoded = outcome.early_decoded;
            y = apply_ops(outcome.mat, pipe.boundary(r), true);
        }
    }

    let verified = if config.verify {
        let expect = reference_eval(pipe, p, x, weights, config.seed)?;
        if y != expect {
            return Err(CmpcError::NotDecodable(format!(
                "pipeline reconstruction mismatch vs the decode-re-encode \
                 reference under {}",
                scheme.name()
            )));
        }
        true
    } else {
        false
    };

    let mut traffic = TrafficReport::default();
    for t in &stage_traffic {
        traffic.source_to_worker += t.source_to_worker;
        traffic.worker_to_worker += t.worker_to_worker;
        traffic.worker_to_master += t.worker_to_master;
        traffic.messages += t.messages;
    }
    Ok(PipelineOutput {
        y,
        rounds,
        scheme_name: scheme.name(),
        n_workers: setup.n_workers,
        verified,
        early_decoded,
        stage_traffic,
        traffic,
        stage_elapsed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(spec: &str) -> Result<Pipeline> {
        Pipeline::parse_spec(spec)
    }

    #[test]
    fn validates_op_chains() {
        assert!(chain("matmul").is_ok());
        assert!(chain("matmul,truncate:8,matmul").is_ok());
        assert!(chain("matmul,transpose,scale:3,matmul,truncate:1").is_ok());
        // must start with a matmul
        assert!(matches!(chain("transpose,matmul"), Err(CmpcError::InvalidParams(_))));
        assert!(matches!(chain(""), Err(CmpcError::InvalidParams(_))));
        // truncation only directly after a matmul, bits in 1..=15
        assert!(matches!(
            chain("matmul,transpose,truncate:8,matmul"),
            Err(CmpcError::InvalidParams(_))
        ));
        assert!(matches!(chain("matmul,truncate:0"), Err(CmpcError::InvalidParams(_))));
        assert!(matches!(chain("matmul,truncate:16"), Err(CmpcError::InvalidParams(_))));
        // scale must be non-zero mod p
        assert!(matches!(chain("matmul,scale:0"), Err(CmpcError::InvalidParams(_))));
        assert!(matches!(chain("matmul,scale:65537"), Err(CmpcError::InvalidParams(_))));
        // unknown ops are typed rejects
        assert!(matches!(chain("matmul,relu"), Err(CmpcError::InvalidParams(_))));
        assert!(matches!(chain("matmul,scale:x"), Err(CmpcError::InvalidParams(_))));
    }

    #[test]
    fn round_and_boundary_accounting() {
        let p = chain("matmul,truncate:8,matmul,transpose,scale:2").unwrap();
        assert_eq!(p.rounds(), 2);
        assert_eq!(p.boundary(0), &[PipelineOp::Truncate(8)]);
        assert_eq!(
            p.boundary(1),
            &[PipelineOp::Transpose, PipelineOp::Scale(2)]
        );
        assert!(p.bounded_mask(0));
        let q = chain("matmul,matmul").unwrap();
        assert!(!q.bounded_mask(0));
    }

    #[test]
    fn rounds_cap_is_enforced() {
        let many = vec!["matmul"; MAX_PIPELINE_ROUNDS + 1].join(",");
        assert!(matches!(chain(&many), Err(CmpcError::InvalidParams(_))));
        let max = vec!["matmul"; MAX_PIPELINE_ROUNDS].join(",");
        assert!(chain(&max).is_ok());
    }

    #[test]
    fn spec_round_trips() {
        for spec in [
            "matmul",
            "matmul,truncate:8,matmul",
            "matmul,transpose,scale:7,matmul,truncate:2",
        ] {
            assert_eq!(chain(spec).unwrap().spec_string().as_deref(), Some(spec));
        }
        let with_bias = Pipeline::new(vec![
            PipelineOp::Matmul,
            PipelineOp::AddBias(FpMat::zeros(4, 4)),
        ])
        .unwrap();
        assert_eq!(with_bias.spec_string(), None);
    }

    #[test]
    fn stage_seeds_are_distinct_and_domain_separated() {
        let base = 0xC0DE;
        let s0 = stage_seed(base, 0);
        let s1 = stage_seed(base, 1);
        assert_ne!(s0, s1);
        // disjoint from the singleton-job schedule of the same base seed
        assert_ne!(s0, derive_job_seed(base, 0));
        assert_ne!(s1, derive_job_seed(base, 1));
    }

    #[test]
    fn masked_truncation_replay_is_within_epsilon() {
        // The reference's masked truncate equals exact truncate up to the
        // documented ε ∈ {0,1} when values stay inside the bounded window.
        let params = SchemeParams::new(2, 2, 2);
        let pipe = chain("matmul,truncate:8,matmul").unwrap();
        let x = pipeline_input(42, 8);
        let weights: Vec<FpMat> = (0..2).map(|r| pipeline_weight(42, 8, r)).collect();
        let wrefs: Vec<&FpMat> = weights.iter().collect();
        let got = reference_eval(&pipe, params, &x, &wrefs, 0xC0DE).unwrap();
        // exact replay: truncate without the mask
        let y0 = x.transpose().matmul(&weights[0]);
        let exact0 = FpMat::from_fn(8, 8, |r, c| y0.at(r, c) >> 8);
        let exact = exact0.transpose().matmul(&weights[1]);
        for r in 0..8 {
            for c in 0..8 {
                // each truncated activation slips by ≤1, amplified by one
                // matmul row: |got − exact| ≤ Σ_k w[k][c] < 8·8
                let d = (got.at(r, c) + ff::P - exact.at(r, c)) % ff::P;
                assert!(d < 64, "({r},{c}): got {} exact {}", got.at(r, c), exact.at(r, c));
            }
        }
    }

    #[test]
    fn reference_rejects_weight_count_mismatch() {
        let params = SchemeParams::new(2, 2, 2);
        let pipe = chain("matmul,matmul").unwrap();
        let x = pipeline_input(1, 8);
        let w = pipeline_weight(1, 8, 0);
        let err = reference_eval(&pipe, params, &x, &[&w], 7).unwrap_err();
        assert!(matches!(err, CmpcError::InvalidParams(_)));
    }
}
