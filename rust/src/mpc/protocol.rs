//! End-to-end orchestration of one CMPC job (Algorithm 3).
//!
//! [`run_protocol`] wires the whole thing together: setup (α assignment and
//! the generalized-Vandermonde solve for the `rₙ^{(i,l)}` coefficients),
//! Phase 1 source sharing, `N` Phase-2 worker threads over the network
//! fabric, and Phase-3 master reconstruction — then verifies `Y = AᵀB`
//! natively when asked.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::codes::CmpcScheme;
use crate::matrix::FpMat;
use crate::metrics::{PhaseTimings, TrafficReport, WorkerCounters};
use crate::mpc::network::{Fabric, Payload};
use crate::mpc::{master, source, worker};
use crate::poly::interp::choose_alphas;
use crate::runtime::{BackendChoice, BackendFactory};
use crate::util::rng::ChaChaRng;

/// Knobs for one protocol run.
#[derive(Clone, Debug)]
pub struct ProtocolConfig {
    pub backend: BackendChoice,
    /// Seed for all secret randomness (sources and worker masks derive
    /// independent ChaCha streams from it).
    pub seed: u64,
    /// Check `Y == AᵀB` natively before returning.
    pub verify: bool,
    /// Per-worker injected compute delay (straggler model); empty = none.
    pub worker_delays: Vec<Duration>,
    /// Per-hop link latency.
    pub link_delay: Option<Duration>,
}

impl Default for ProtocolConfig {
    fn default() -> ProtocolConfig {
        ProtocolConfig {
            backend: BackendChoice::Native,
            seed: 0xC0DE,
            verify: true,
            worker_delays: Vec::new(),
            link_delay: None,
        }
    }
}

/// Everything a run reports back.
pub struct ProtocolOutput {
    pub y: FpMat,
    pub scheme_name: String,
    pub n_workers: usize,
    pub stragglers_tolerated: usize,
    pub timings: PhaseTimings,
    pub traffic: TrafficReport,
    /// Per-worker overhead counters (index = worker id).
    pub worker_counters: Vec<Arc<WorkerCounters>>,
    pub verified: bool,
}

/// Precomputed per-deployment state reusable across jobs with the same
/// scheme and shape (the coordinator caches this — the O(N³) solve dominates
/// setup).
pub struct Setup {
    pub alphas: Arc<Vec<u64>>,
    /// `r_coeffs[n][i + t·l]` = worker n's combination coefficient for the
    /// important power (i,l) — eq. (18).
    pub r_coeffs: Arc<Vec<Vec<u64>>>,
    pub n_workers: usize,
}

/// Build the α assignment and reconstruction coefficients for a scheme.
pub fn prepare_setup(scheme: &dyn CmpcScheme) -> Setup {
    let p = scheme.params();
    let n = scheme.n_workers();
    let support = scheme.reconstruction_support();
    let (alphas, inv_rows) = choose_alphas(n, &support);
    // Worker n needs r_n^{(i,l)} = inv_rows[row_of(imp(i,l))][n].
    let mut r_coeffs = vec![vec![0u64; p.t * p.t]; n];
    for i in 0..p.t {
        for l in 0..p.t {
            let e = scheme.important_power(i, l);
            let row = support
                .binary_search(&e)
                .expect("important power missing from reconstruction support");
            for (wn, coeffs) in r_coeffs.iter_mut().enumerate() {
                coeffs[i + p.t * l] = inv_rows[row][wn];
            }
        }
    }
    Setup {
        alphas: Arc::new(alphas),
        r_coeffs: Arc::new(r_coeffs),
        n_workers: n,
    }
}

/// Run one full CMPC multiplication under `scheme`.
pub fn run_protocol(
    scheme: &dyn CmpcScheme,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
) -> anyhow::Result<ProtocolOutput> {
    let setup = prepare_setup(scheme);
    run_protocol_with_setup(scheme, &setup, a, b, config)
}

/// Run one job against a prepared (possibly cached) [`Setup`], constructing
/// a fresh backend factory. Callers issuing many jobs should build the
/// factory once (PJRT client creation + artifact compilation are expensive)
/// and use [`run_protocol_with_factory`].
pub fn run_protocol_with_setup(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
) -> anyhow::Result<ProtocolOutput> {
    let factory = BackendFactory::new(&config.backend)?;
    run_protocol_with_factory(scheme, setup, a, b, config, &factory)
}

/// Run one job with an existing backend factory (shared PJRT service and
/// executable cache across jobs — the steady-state serving path).
pub fn run_protocol_with_factory(
    scheme: &dyn CmpcScheme,
    setup: &Setup,
    a: &FpMat,
    b: &FpMat,
    config: &ProtocolConfig,
    backend_factory: &BackendFactory,
) -> anyhow::Result<ProtocolOutput> {
    let p = scheme.params();
    let m = a.rows;
    anyhow::ensure!(
        a.rows == a.cols && b.rows == b.cols && a.rows == b.rows,
        "inputs must be square matrices of equal size (got {}x{} and {}x{})",
        a.rows,
        a.cols,
        b.rows,
        b.cols
    );
    anyhow::ensure!(
        m % p.s == 0 && m % p.t == 0,
        "partition (s={}, t={}) must divide m={m}",
        p.s,
        p.t
    );
    let t_setup = Instant::now();
    let n = setup.n_workers;
    let mut job_rng = ChaChaRng::seed_from_u64(config.seed);
    let mut rng_src_a = job_rng.fork();
    let mut rng_src_b = job_rng.fork();
    let worker_rngs: Vec<ChaChaRng> = (0..n).map(|_| job_rng.fork()).collect();

    let (fabric, mut endpoints) = Fabric::new(n, config.link_delay);
    let counters: Vec<Arc<WorkerCounters>> =
        (0..n).map(|_| Arc::new(WorkerCounters::default())).collect();
    let setup_time = t_setup.elapsed();

    // --- spawn workers ---
    let mut worker_endpoints: Vec<_> = endpoints.drain(0..n).collect();
    let master_endpoint = endpoints.remove(0);
    let mut handles = Vec::with_capacity(n);
    for (wid, rng) in worker_rngs.into_iter().enumerate() {
        let ctx = worker::WorkerCtx {
            id: wid,
            n_workers: n,
            t: p.t,
            z: p.z,
            alphas: setup.alphas.clone(),
            r_coeffs: setup.r_coeffs.clone(),
            rng,
            counters: counters[wid].clone(),
            delay: config
                .worker_delays
                .get(wid)
                .copied()
                .unwrap_or(Duration::ZERO),
        };
        let endpoint = worker_endpoints.remove(0);
        let fabric = fabric.clone();
        let backend = backend_factory.make();
        handles.push(
            std::thread::Builder::new()
                .name(format!("cmpc-worker-{wid}"))
                .spawn(move || worker::run_worker(ctx, endpoint, fabric, backend))
                .expect("spawn worker"),
        );
    }

    // --- Phase 1: sources share ---
    let t1 = Instant::now();
    let fa_poly = source::build_f_a(scheme, a, &mut rng_src_a);
    let fb_poly = source::build_f_b(scheme, b, &mut rng_src_b);
    for wid in 0..n {
        let alpha = setup.alphas[wid];
        let payload = Payload::Shares {
            fa: fa_poly.eval(alpha),
            fb: fb_poly.eval(alpha),
        };
        // Source A evaluates F_A, source B evaluates F_B; one combined
        // envelope per worker keeps the fabric simple — traffic is metered
        // identically (both legs are source→worker).
        fabric
            .send(fabric.source_a_id(), wid, payload)
            .map_err(|_| anyhow::anyhow!("worker {wid} unreachable in phase 1"))?;
    }
    let phase1 = t1.elapsed();

    // --- Phase 2/3 run concurrently; wait for the master ---
    let t2 = Instant::now();
    let m_out = master::run_master(&master_endpoint, &setup.alphas, n, p.t, p.z)?;
    let reconstruct_done = t2.elapsed();
    // Workers finish their sends after reconstruction; join them for clean
    // counter totals. Their tail time counts toward phase 2.
    for h in handles {
        h.join()
            .map_err(|_| anyhow::anyhow!("worker thread panicked"))??;
    }
    let all_done = t2.elapsed();

    let verified = if config.verify {
        m_out.y == a.transpose().matmul(b)
    } else {
        false
    };
    if config.verify {
        anyhow::ensure!(
            verified,
            "reconstruction mismatch: Y != AᵀB under {}",
            scheme.name()
        );
    }

    Ok(ProtocolOutput {
        y: m_out.y,
        scheme_name: scheme.name(),
        n_workers: n,
        stragglers_tolerated: m_out.stragglers_tolerated,
        timings: PhaseTimings {
            setup: setup_time,
            phase1_share: phase1,
            phase2_compute: all_done,
            phase3_reconstruct: all_done.saturating_sub(reconstruct_done),
        },
        traffic: fabric.traffic(),
        worker_counters: counters,
        verified,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::{AgeCmpc, CmpcScheme, EntangledCmpc, PolyDotCmpc};
    use crate::util::testing::property;

    fn run_scheme(scheme: &dyn CmpcScheme, m: usize, seed: u64) {
        let mut rng = ChaChaRng::seed_from_u64(seed);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_protocol(scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        assert!(out.verified);
        assert_eq!(out.y, a.transpose().matmul(&b));
    }

    #[test]
    fn age_example1_end_to_end() {
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        assert_eq!(scheme.n_workers(), 17);
        run_scheme(&scheme, 8, 1);
    }

    #[test]
    fn polydot_end_to_end() {
        run_scheme(&PolyDotCmpc::new(2, 2, 2), 8, 2);
        run_scheme(&PolyDotCmpc::new(3, 2, 4), 12, 3);
    }

    #[test]
    fn entangled_end_to_end() {
        run_scheme(&EntangledCmpc::new(2, 2, 2), 8, 4);
    }

    #[test]
    fn random_schemes_and_shapes_decode() {
        property("protocol decodes across (s,t,z,m)", 12, |rng| {
            let s = rng.gen_index(3) + 1;
            let t = rng.gen_index(3) + 1;
            let z = rng.gen_index(3) + 1;
            let m = (s * t) * (rng.gen_index(2) + 1) * 2;
            let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
            let a = FpMat::random(rng, m, m);
            let b = FpMat::random(rng, m, m);
            let cfg = ProtocolConfig {
                seed: rng.next_u64(),
                ..ProtocolConfig::default()
            };
            let out = run_protocol(&scheme, &a, &b, &cfg)
                .map_err(|e| format!("s={s} t={t} z={z} m={m}: {e}"))?;
            if out.y != a.transpose().matmul(&b) {
                return Err(format!("wrong product at s={s} t={t} z={z} m={m}"));
            }
            Ok(())
        });
    }

    #[test]
    fn straggler_tolerance_still_decodes() {
        // Delay two workers far beyond the rest; the master reconstructs
        // from the first t²+z arrivals regardless.
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2); // N=17, needs 6
        let mut delays = vec![Duration::ZERO; 17];
        delays[0] = Duration::from_millis(150);
        delays[5] = Duration::from_millis(150);
        let cfg = ProtocolConfig {
            worker_delays: delays,
            ..ProtocolConfig::default()
        };
        let mut rng = ChaChaRng::seed_from_u64(77);
        let a = FpMat::random(&mut rng, 8, 8);
        let b = FpMat::random(&mut rng, 8, 8);
        let out = run_protocol(&scheme, &a, &b, &cfg).unwrap();
        assert!(out.verified);
        assert_eq!(out.stragglers_tolerated, 17 - 6);
    }

    #[test]
    fn traffic_matches_zeta_exactly() {
        // Measured worker↔worker scalars == ζ = N(N−1)·m²/t² (eq. 34).
        let scheme = AgeCmpc::with_optimal_lambda(2, 2, 2);
        let (m, t) = (8usize, 2usize);
        let mut rng = ChaChaRng::seed_from_u64(13);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_protocol(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let zeta = crate::analysis::communication_overhead(m, t, n) as u64;
        assert_eq!(out.traffic.worker_to_worker, zeta);
    }

    #[test]
    fn worker_counters_match_xi_and_sigma() {
        // Measured per-worker multiplications == ξ (eq. 32) and stored
        // scalars == σ (eq. 33) — E10 in DESIGN.md.
        let (s, t, z, m) = (2usize, 2usize, 2usize, 8usize);
        let scheme = AgeCmpc::with_optimal_lambda(s, t, z);
        let mut rng = ChaChaRng::seed_from_u64(21);
        let a = FpMat::random(&mut rng, m, m);
        let b = FpMat::random(&mut rng, m, m);
        let out = run_protocol(&scheme, &a, &b, &ProtocolConfig::default()).unwrap();
        let n = out.n_workers as u64;
        let xi = crate::analysis::computation_overhead(m, s, t, z, n) as u64;
        let sigma = crate::analysis::storage_overhead(m, s, t, z, n) as u64;
        for (wid, c) in out.worker_counters.iter().enumerate() {
            assert_eq!(c.mults(), xi, "ξ mismatch at worker {wid}");
            assert_eq!(c.stored(), sigma, "σ mismatch at worker {wid}");
        }
    }

    #[test]
    fn rejects_bad_partition() {
        let scheme = AgeCmpc::new(3, 2, 1, 0);
        let mut rng = ChaChaRng::seed_from_u64(2);
        let a = FpMat::random(&mut rng, 8, 8); // 3 ∤ 8
        let b = FpMat::random(&mut rng, 8, 8);
        assert!(run_protocol(&scheme, &a, &b, &ProtocolConfig::default()).is_err());
    }
}
